"""Layer 2: configurable transformer forward pass in JAX.

This is the *subject* model that AE-LLM's search tunes: a small decoder
transformer whose architecture/fine-tuning/inference knobs mirror the
paper's configuration space (Table 1):

* attention ∈ {mha, gqa, mqa, mla} — grouped KV heads, or multi-head
  latent attention (DeepSeek-style KV compression);
* FFN ∈ {dense, MoE with E experts / top-k routing};
* fine-tuning ∈ {none, LoRA adapters with rank r and scaling alpha};
* inference quantization ∈ {fp16, fp8, int8, int4} applied to all
  projection/FFN weights (embeddings, norms and routers stay f32,
  QLoRA-style the LoRA deltas stay f32 too).

The hot matmuls and the attention inner loop call the Layer-1 Pallas
kernels (``kernels.quant_matmul``, ``kernels.attention``); with
``use_pallas=False`` the same graph is built from the pure-jnp oracles in
``kernels.ref`` so the two paths can be differentially tested.

``aot.py`` lowers ``forward`` for a set of named variants to HLO text;
the rust runtime (Layer 3) executes them and never imports Python.

Numerics note: "fp16" and "fp8" share f32 arithmetic here — on the CPU
interpret path their *numeric* difference is irrelevant to the search
(their memory/latency effects are modeled at L3 from the manifest's
bytes-per-weight) — while int8/int4 apply real symmetric quantization so
the measured accuracy-fidelity signal is genuine.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import attention as att_k
from .kernels import quant_matmul as qm_k
from .kernels import ref

ATTENTION_KINDS = ("mha", "gqa", "mqa", "mla")
QUANT_KINDS = ("fp16", "fp8", "int8", "int4")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + efficiency-technique configuration of one variant."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 8
    attention: str = "gqa"       # mha | gqa | mqa | mla
    gqa_groups: int = 4          # q heads per kv head when attention == gqa
    mla_latent: int = 32         # latent dim when attention == mla
    ffn_mult: int = 4
    moe_experts: int = 0         # 0 = dense FFN
    moe_top_k: int = 2
    quant: str = "fp16"          # fp16 | fp8 | int8 | int4
    lora_rank: int = 0           # 0 = no adapters
    lora_alpha: float = 32.0
    use_pallas: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def kv_heads(self) -> int:
        if self.attention == "mha":
            return self.n_heads
        if self.attention == "gqa":
            assert self.n_heads % self.gqa_groups == 0
            return self.n_heads // self.gqa_groups
        if self.attention == "mqa":
            return 1
        if self.attention == "mla":
            # MLA keeps full heads after up-projection; the cache saving
            # comes from storing the latent instead of K/V.
            return self.n_heads
        raise ValueError(f"unknown attention kind {self.attention!r}")

    def validate(self) -> None:
        if self.attention not in ATTENTION_KINDS:
            raise ValueError(f"attention must be one of {ATTENTION_KINDS}")
        if self.quant not in QUANT_KINDS:
            raise ValueError(f"quant must be one of {QUANT_KINDS}")
        if self.moe_experts not in (0, 2, 4, 8):
            raise ValueError("moe_experts must be 0/2/4/8")
        if self.moe_experts and self.moe_top_k > self.moe_experts:
            raise ValueError("moe_top_k exceeds expert count")
        if self.d_model % self.n_heads:
            raise ValueError("d_model must divide by n_heads")
        if self.quant == "int4" and self.d_model % 2:
            raise ValueError("int4 packing requires even d_model")

    def to_dict(self) -> dict:
        return asdict(self)


# ---------------------------------------------------------------------------
# Parameter initialization (deterministic numpy; becomes HLO constants)
# ---------------------------------------------------------------------------

def _init(rng: np.random.Generator, shape, scale=None):
    scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def pack_weight(w: np.ndarray, quant: str):
    """Quantize a weight matrix per the inference config.

    Returns the tuple consumed by ``kernels.quant_matmul.linear``.
    """
    wj = jnp.asarray(w)
    if quant in ("fp16", "fp8"):
        return (wj,)
    if quant == "int8":
        return tuple(ref.quantize_int8(wj))
    if quant == "int4":
        return tuple(ref.quantize_int4(wj))
    raise ValueError(f"unknown quant mode {quant!r}")


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Build the parameter pytree for ``forward`` (weights pre-quantized)."""
    cfg.validate()
    rng = np.random.default_rng(seed)
    d, hd = cfg.d_model, cfg.head_dim
    q_dim = cfg.n_heads * hd
    kv_dim = cfg.kv_heads * hd
    f = cfg.ffn_mult * d

    params = {
        "embed": jnp.asarray(_init(rng, (cfg.vocab, d), scale=0.02)),
        "final_norm": jnp.asarray(np.ones(d, np.float32)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "attn_norm": jnp.asarray(np.ones(d, np.float32)),
            "ffn_norm": jnp.asarray(np.ones(d, np.float32)),
            "wq": pack_weight(_init(rng, (d, q_dim)), cfg.quant),
            "wo": pack_weight(_init(rng, (q_dim, d)), cfg.quant),
        }
        if cfg.attention == "mla":
            lat = cfg.mla_latent
            layer["w_dkv"] = pack_weight(_init(rng, (d, lat)), cfg.quant)
            layer["w_uk"] = pack_weight(_init(rng, (lat, kv_dim)), cfg.quant)
            layer["w_uv"] = pack_weight(_init(rng, (lat, kv_dim)), cfg.quant)
        else:
            layer["wk"] = pack_weight(_init(rng, (d, kv_dim)), cfg.quant)
            layer["wv"] = pack_weight(_init(rng, (d, kv_dim)), cfg.quant)
        if cfg.moe_experts:
            e = cfg.moe_experts
            layer["moe_router"] = jnp.asarray(_init(rng, (d, e)))
            layer["moe_gate"] = jnp.asarray(
                np.stack([_init(rng, (d, f)) for _ in range(e)]))
            layer["moe_up"] = jnp.asarray(
                np.stack([_init(rng, (d, f)) for _ in range(e)]))
            layer["moe_down"] = jnp.asarray(
                np.stack([_init(rng, (f, d)) for _ in range(e)]))
        else:
            layer["w_gate"] = pack_weight(_init(rng, (d, f)), cfg.quant)
            layer["w_up"] = pack_weight(_init(rng, (d, f)), cfg.quant)
            layer["w_down"] = pack_weight(_init(rng, (f, d)), cfg.quant)
        if cfg.lora_rank:
            r = cfg.lora_rank
            # QLoRA-style f32 adapters on the q and o projections.
            layer["lora_qa"] = jnp.asarray(_init(rng, (d, r)))
            layer["lora_qb"] = jnp.asarray(np.zeros((r, q_dim), np.float32))
            layer["lora_oa"] = jnp.asarray(_init(rng, (q_dim, r)))
            layer["lora_ob"] = jnp.asarray(np.zeros((r, d), np.float32))
            # Give the zero-init B matrices a tiny deterministic kick so
            # the adapter path is numerically *live* in fidelity tests.
            layer["lora_qb"] = layer["lora_qb"] + 0.01 * jnp.asarray(
                _init(rng, (r, q_dim)))
            layer["lora_ob"] = layer["lora_ob"] + 0.01 * jnp.asarray(
                _init(rng, (r, d)))
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _linear(x, pack, cfg: ModelConfig):
    if cfg.use_pallas:
        return qm_k.linear(x, pack, cfg.quant)
    # Reference path: dequantize then plain matmul.
    lead, k = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    if cfg.quant in ("fp16", "fp8"):
        y = ref.matmul_f32_ref(x2, pack[0])
    elif cfg.quant == "int8":
        y = ref.quant_matmul_int8_ref(x2, *pack)
    else:
        y = ref.quant_matmul_int4_ref(x2, *pack)
    return y.reshape(*lead, y.shape[-1])


def _lora(x, a, b, cfg: ModelConfig):
    scale = cfg.lora_alpha / cfg.lora_rank
    lead, k = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    y = (x2 @ a) @ b * scale
    return y.reshape(*lead, y.shape[-1])


def _attention_block(x, layer, cfg: ModelConfig):
    b, s, d = x.shape
    hd = cfg.head_dim

    q = _linear(x, layer["wq"], cfg)
    if cfg.lora_rank:
        q = q + _lora(x, layer["lora_qa"], layer["lora_qb"], cfg)
    if cfg.attention == "mla":
        latent = _linear(x, layer["w_dkv"], cfg)          # (B, S, lat)
        k = _linear(latent, layer["w_uk"], cfg)
        v = _linear(latent, layer["w_uv"], cfg)
    else:
        k = _linear(x, layer["wk"], cfg)
        v = _linear(x, layer["wv"], cfg)

    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.kv_heads, hd).transpose(0, 2, 1, 3)

    if cfg.use_pallas:
        o = att_k.attention(q, k, v, causal=True)
    else:
        o = ref.attention_ref(q, k, v, causal=True)

    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    y = _linear(o, layer["wo"], cfg)
    if cfg.lora_rank:
        y = y + _lora(o, layer["lora_oa"], layer["lora_ob"], cfg)
    return y


def _ffn_block(x, layer, cfg: ModelConfig):
    b, s, d = x.shape
    if cfg.moe_experts:
        x2 = x.reshape(b * s, d)
        y = ref.moe_ffn_ref(x2, layer["moe_gate"], layer["moe_up"],
                            layer["moe_down"], layer["moe_router"],
                            cfg.moe_top_k)
        return y.reshape(b, s, d)
    h_gate = _linear(x, layer["w_gate"], cfg)
    h_up = _linear(x, layer["w_up"], cfg)
    h = jnp.where(h_gate > 0, h_gate, h_gate * 0.01) * h_up
    return _linear(h, layer["w_down"], cfg)


def forward(params: dict, tokens: jnp.ndarray, cfg: ModelConfig):
    """Full decoder forward: int32 tokens (B, S) -> f32 logits (B, S, V)."""
    x = params["embed"][tokens]  # (B, S, D)
    for layer in params["layers"]:
        h = ref.rmsnorm_ref(x, layer["attn_norm"])
        x = x + _attention_block(h, layer, cfg)
        h = ref.rmsnorm_ref(x, layer["ffn_norm"])
        x = x + _ffn_block(h, layer, cfg)
    x = ref.rmsnorm_ref(x, params["final_norm"])
    # Tied unembedding.
    return jnp.einsum("bsd,vd->bsv", x, params["embed"])


def build_forward_fn(cfg: ModelConfig, seed: int = 0):
    """Close over deterministic parameters; returns tokens -> (logits,)."""
    params = init_params(cfg, seed)

    def fn(tokens):
        return (forward(params, tokens, cfg),)

    return fn


# ---------------------------------------------------------------------------
# Cost accounting used by the AOT manifest (consumed by the rust L3)
# ---------------------------------------------------------------------------

_BYTES_PER_WEIGHT = {"fp16": 2.0, "fp8": 1.0, "int8": 1.0, "int4": 0.5}


def param_count(cfg: ModelConfig) -> int:
    """Total parameter count of one variant (weights only, incl. MoE)."""
    d, hd = cfg.d_model, cfg.head_dim
    q_dim, kv_dim = cfg.n_heads * hd, cfg.kv_heads * hd
    f = cfg.ffn_mult * d
    per_layer = d * q_dim + q_dim * d  # wq, wo
    if cfg.attention == "mla":
        per_layer += d * cfg.mla_latent + 2 * cfg.mla_latent * kv_dim
    else:
        per_layer += 2 * d * kv_dim
    if cfg.moe_experts:
        per_layer += d * cfg.moe_experts + cfg.moe_experts * (2 * d * f + f * d)
    else:
        per_layer += 2 * d * f + f * d
    if cfg.lora_rank:
        per_layer += 2 * cfg.lora_rank * (d + q_dim)
    return cfg.n_layers * per_layer + cfg.vocab * d


def weight_bytes(cfg: ModelConfig) -> int:
    """Approximate resident weight bytes under the quantization config."""
    return int(param_count(cfg) * _BYTES_PER_WEIGHT[cfg.quant])


def flops_per_token(cfg: ModelConfig, seq: int) -> int:
    """Forward FLOPs per token (2*MACs), incl. attention quadratic term."""
    d, hd = cfg.d_model, cfg.head_dim
    q_dim, kv_dim = cfg.n_heads * hd, cfg.kv_heads * hd
    f = cfg.ffn_mult * d
    proj = d * q_dim + q_dim * d
    if cfg.attention == "mla":
        proj += d * cfg.mla_latent + 2 * cfg.mla_latent * kv_dim
    else:
        proj += 2 * d * kv_dim
    attn = 2 * cfg.n_heads * hd * seq  # scores + values, per token
    if cfg.moe_experts:
        ffn = cfg.moe_top_k * (2 * d * f + f * d) + d * cfg.moe_experts
    else:
        ffn = 2 * d * f + f * d
    unembed = d * cfg.vocab
    return 2 * cfg.n_layers * (proj + attn + ffn) + 2 * unembed
