"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops only.  The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` across shape/dtype sweeps
(hypothesis-driven), which is the core correctness signal for Layer 1:
the AOT-compiled HLO embeds the *kernel*, and the kernel is only trusted
because it matches these oracles.

The oracles are also used directly by ``model.py`` when a configuration
disables the Pallas path (``use_pallas=False``), so the L2 graph can be
differentially tested kernel-vs-reference end-to-end.
"""

from __future__ import annotations

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Quantization helpers (shared by kernels, model, and tests)
# ---------------------------------------------------------------------------

def quantize_int8(w):
    """Symmetric per-output-channel int8 quantization of ``w`` (K, N).

    Returns ``(w_q int8 (K, N), scales f32 (1, N))`` such that
    ``w ~= w_q * scales``.
    """
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)  # (1, N)
    scales = jnp.where(absmax > 0, absmax / 127.0, 1.0).astype(jnp.float32)
    w_q = jnp.clip(jnp.round(w / scales), -127, 127).astype(jnp.int8)
    return w_q, scales


def quantize_int4(w):
    """Symmetric per-output-channel int4 quantization with K-axis packing.

    Two signed 4-bit values are packed per uint8 along the K axis:
    element ``2k`` in the low nibble, ``2k+1`` in the high nibble, both
    stored biased by +8 (range 0..15 encodes -8..7).

    Returns ``(w_packed uint8 (K//2, N), scales f32 (1, N))``.  K must be
    even.
    """
    k, _ = w.shape
    assert k % 2 == 0, "int4 packing requires even K"
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scales = jnp.where(absmax > 0, absmax / 7.0, 1.0).astype(jnp.float32)
    w_q = jnp.clip(jnp.round(w / scales), -8, 7).astype(jnp.int32) + 8
    lo = w_q[0::2, :]
    hi = w_q[1::2, :]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, scales


def unpack_int4(w_packed):
    """Inverse of the packing in :func:`quantize_int4` (without scales).

    Returns centered int32 values in -8..7, shape (K, N).
    """
    lo = (w_packed & 0xF).astype(jnp.int32) - 8
    hi = ((w_packed >> 4) & 0xF).astype(jnp.int32) - 8
    k2, n = w_packed.shape
    out = jnp.zeros((k2 * 2, n), dtype=jnp.int32)
    out = out.at[0::2, :].set(lo)
    out = out.at[1::2, :].set(hi)
    return out


# ---------------------------------------------------------------------------
# Reference matmuls
# ---------------------------------------------------------------------------

def matmul_f32_ref(x, w):
    """Plain f32 matmul reference: (M, K) @ (K, N) -> (M, N)."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def quant_matmul_int8_ref(x, w_q, scales):
    """Reference for the fused int8 dequant-matmul.

    x: (M, K) f32, w_q: (K, N) int8, scales: (1, N) f32.
    """
    w = w_q.astype(jnp.float32) * scales.astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w)


def quant_matmul_int4_ref(x, w_packed, scales):
    """Reference for the fused int4(packed) dequant-matmul.

    x: (M, K) f32, w_packed: (K//2, N) uint8, scales: (1, N) f32.
    """
    w = unpack_int4(w_packed).astype(jnp.float32) * scales.astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w)


# ---------------------------------------------------------------------------
# Reference attention (grouped KV heads covers MHA / GQA / MQA)
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, causal=True):
    """Grouped-KV-head scaled-dot-product attention reference.

    q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    Head ``h`` of q attends to kv head ``h // (Hq // Hkv)``.
    Returns (B, Hq, S, D) f32.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, "query heads must be a multiple of kv heads"
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)  # (B, Hq, S, D)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) / jnp.sqrt(float(d))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Reference MoE FFN with top-k routing
# ---------------------------------------------------------------------------

def moe_ffn_ref(x, w_gate, w_up, w_down, w_router, top_k):
    """Reference mixture-of-experts FFN (leaky-SwiGLU experts, top-k routing).

    x: (T, D); w_gate/w_up: (E, D, F); w_down: (E, F, D); w_router: (D, E).
    Routing computes all experts densely and masks with renormalized
    top-k gates — numerically identical to sparse dispatch, which is what
    matters for a correctness oracle at this scale.
    """
    router_logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    # threshold = k-th largest logit per token
    sorted_logits = jnp.sort(router_logits, axis=-1)  # ascending
    threshold = sorted_logits[:, -top_k][:, None]
    mask = router_logits >= threshold  # (T, E)
    gates = jnp.where(mask, router_logits, -1e30)
    gates = jnp.exp(gates - jnp.max(gates, axis=-1, keepdims=True))
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)  # (T, E)
    h_gate = jnp.einsum("td,edf->tef", x.astype(jnp.float32),
                        w_gate.astype(jnp.float32))
    h_up = jnp.einsum("td,edf->tef", x.astype(jnp.float32),
                      w_up.astype(jnp.float32))
    h = jnp.where(h_gate > 0, h_gate, h_gate * 0.01) * h_up
    y = jnp.einsum("tef,efd->ted", h, w_down.astype(jnp.float32))
    return jnp.einsum("te,ted->td", gates, y)


# ---------------------------------------------------------------------------
# Misc layers
# ---------------------------------------------------------------------------

def rmsnorm_ref(x, gamma, eps=1e-6):
    """RMSNorm over the last axis."""
    x = x.astype(jnp.float32)
    scale = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x / scale * gamma.astype(jnp.float32)


def swiglu_ffn_ref(x, w_gate, w_up, w_down):
    """Dense (non-MoE) leaky-SwiGLU FFN reference: (T, D) -> (T, D)."""
    h_gate = x.astype(jnp.float32) @ w_gate.astype(jnp.float32)
    h_up = x.astype(jnp.float32) @ w_up.astype(jnp.float32)
    h = jnp.where(h_gate > 0, h_gate, h_gate * 0.01) * h_up
    return h @ w_down.astype(jnp.float32)
