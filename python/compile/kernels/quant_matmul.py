"""Pallas fused dequantize-matmul kernels (Layer 1 hot-spot).

The paper's inference-stage quantization options (INT8 / INT4 via
GPTQ/AWQ/SmoothQuant) all bottom out in the same hot loop at serving
time: a matmul whose weights live in memory at reduced precision and are
dequantized on the fly.  On GPUs this is a CUDA kernel staging weight
tiles through shared memory; here we re-express it for a TPU-shaped
machine (DESIGN.md §Hardware-Adaptation):

* the HBM->VMEM schedule is written with ``BlockSpec``s — a
  ``(block_m, block_k)`` activation tile, a ``(block_k, block_n)``
  quantized weight tile and a ``(1, block_n)`` scale sliver are resident
  per grid step;
* dequantization happens in registers on the tile (int -> f32 multiply by
  per-output-channel scale), feeding an MXU-shaped ``jnp.dot`` with an
  f32 accumulator that lives in the output block across the K grid axis;
* int4 weights are packed two-per-byte along K, halving the weight
  traffic; the unpack (mask/shift) is fused into the same tile load.

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls — so correctness is the claim checked here
(vs ``ref.py``) and TPU performance is estimated from the VMEM footprint
analysis in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: chosen so x-tile (64x128x4B = 32KiB), w-tile
# (128x128 int8 = 16KiB), dequantized tile (64KiB) and f32 accumulator
# (64x128x4B = 32KiB) all fit VMEM (~16MiB) with generous headroom for
# double-buffering on real hardware.  See EXPERIMENTS.md §Perf for the
# footprint table.
BLOCK_M = 64
BLOCK_N = 128
BLOCK_K = 128


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_blocks(m, k, n, block_m, block_n, block_k):
    """Shrink default blocks to the problem size (all dims must divide)."""
    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    while m % bm:
        bm -= 1
    while n % bn:
        bn -= 1
    while k % bk:
        bk -= 1
    return bm, bn, bk


# ---------------------------------------------------------------------------
# f32 tiled matmul (the FP16/"full precision" serving path)
# ---------------------------------------------------------------------------

def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def matmul_f32(x, w, *, block_m=BLOCK_M, block_n=BLOCK_N, block_k=BLOCK_K):
    """Tiled f32 matmul: (M, K) @ (K, N) -> (M, N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = _pick_blocks(m, k, n, block_m, block_n, block_k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))


# ---------------------------------------------------------------------------
# int8 fused dequant-matmul
# ---------------------------------------------------------------------------

def _quant_matmul_int8_kernel(x_ref, wq_ref, s_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequantize the weight tile in-register: int8 -> f32 * scale sliver.
    w_tile = wq_ref[...].astype(jnp.float32) * s_ref[...]
    o_ref[...] += jnp.dot(x_ref[...], w_tile,
                          preferred_element_type=jnp.float32)


def quant_matmul_int8(x, w_q, scales, *, block_m=BLOCK_M, block_n=BLOCK_N,
                      block_k=BLOCK_K):
    """Fused int8 dequant + matmul.

    x: (M, K) f32; w_q: (K, N) int8; scales: (1, N) f32 per-out-channel.
    Returns (M, N) f32.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2
    assert scales.shape == (1, n), f"scales must be (1, {n})"
    bm, bn, bk = _pick_blocks(m, k, n, block_m, block_n, block_k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _quant_matmul_int8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w_q, scales.astype(jnp.float32))


# ---------------------------------------------------------------------------
# int4 (packed) fused dequant-matmul
# ---------------------------------------------------------------------------

def _quant_matmul_int4_kernel(x_ref, wp_ref, s_ref, o_ref, *, block_k):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Unpack the (block_k//2, block_n) packed tile into (block_k, block_n)
    # centered int values, entirely in-register.  Low nibble = even K row,
    # high nibble = odd K row (see ref.quantize_int4).
    packed = wp_ref[...]
    lo = (packed & 0xF).astype(jnp.int32) - 8   # rows 0,2,4,...
    hi = ((packed >> 4) & 0xF).astype(jnp.int32) - 8  # rows 1,3,5,...
    # Interleave along K: stack on a new axis then reshape.
    half, bn = lo.shape
    w_int = jnp.stack([lo, hi], axis=1).reshape(half * 2, bn)
    w_tile = w_int.astype(jnp.float32) * s_ref[...]
    o_ref[...] += jnp.dot(x_ref[...], w_tile,
                          preferred_element_type=jnp.float32)


def quant_matmul_int4(x, w_packed, scales, *, block_m=BLOCK_M,
                      block_n=BLOCK_N, block_k=BLOCK_K):
    """Fused packed-int4 dequant + matmul.

    x: (M, K) f32; w_packed: (K//2, N) uint8 (two nibbles per byte along
    K); scales: (1, N) f32.  Returns (M, N) f32.
    """
    m, k = x.shape
    kh, n = w_packed.shape
    assert k == kh * 2, f"packed K mismatch: {k} vs 2*{kh}"
    assert scales.shape == (1, n)
    bm, bn, bk = _pick_blocks(m, k, n, block_m, block_n, block_k)
    if bk % 2:  # packed tiles need even K blocks
        bk = max(2, bk - 1)
        while k % bk:
            bk -= 2
            if bk <= 0:
                raise ValueError(f"cannot tile K={k} into even blocks")
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_quant_matmul_int4_kernel, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w_packed, scales.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Dispatch used by model.py
# ---------------------------------------------------------------------------

def linear(x, weight_pack, quant: str):
    """Apply a (possibly quantized) linear layer to (..., K) activations.

    ``weight_pack`` is the tuple produced by ``model.pack_weight``:
      fp16/fp8 -> (w,)                (fp8 is modeled as fp16 numerics;
                                       its memory effect lives in L3)
      int8     -> (w_q, scales)
      int4     -> (w_packed, scales)
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if quant in ("fp16", "fp8", "fp32"):
        (w,) = weight_pack
        y = matmul_f32(x2, w)
    elif quant == "int8":
        w_q, s = weight_pack
        y = quant_matmul_int8(x2, w_q, s)
    elif quant == "int4":
        w_p, s = weight_pack
        y = quant_matmul_int4(x2, w_p, s)
    else:
        raise ValueError(f"unknown quant mode {quant!r}")
    return y.reshape(*lead, y.shape[-1])
