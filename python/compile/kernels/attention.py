"""Pallas tiled attention kernel with grouped KV heads (Layer 1).

One kernel covers the paper's attention-architecture axis:

* **MHA**  — ``kv_heads == q_heads`` (group size 1);
* **GQA**  — ``kv_heads  < q_heads`` (group size q/kv);
* **MQA**  — ``kv_heads == 1``;
* **MLA**  — expressed at Layer 2 as a latent down-/up-projection whose
  output feeds this same kernel (the KV-cache compression happens in the
  projection, not the attention loop).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA flash-attention
formulation (one threadblock per q-tile, K/V staged through shared
memory) becomes a Pallas grid over ``(batch, q_head, q_block)`` where the
``BlockSpec`` index maps route each q head to its kv head
(``h -> h // group``), and the kernel streams K/V sequence blocks through
an online-softmax accumulator held in VMEM scratch.  GQA/MQA memory
savings show up directly as smaller KV ``BlockSpec`` footprints.

``interpret=True`` everywhere — see quant_matmul.py header.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 32
BLOCK_KV = 32
NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_kv, causal,
                      sm_scale):
    """One (batch, q-head, q-block) program.

    q_ref: (1, 1, block_q, D); k_ref/v_ref: (1, 1, S, D) — the kv head
    for this q head, full sequence; o_ref: (1, 1, block_q, D).
    Streams K/V in ``block_kv`` chunks with the online-softmax recurrence
    (running max ``m``, normalizer ``l``, unnormalized accumulator
    ``acc``).
    """
    block_q, d = q_ref.shape[2], q_ref.shape[3]
    s = k_ref.shape[2]
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale
    k_all = k_ref[0, 0]
    v_all = v_ref[0, 0]
    q_block_idx = pl.program_id(2)
    q_offset = q_block_idx * block_q

    num_kv_blocks = s // block_kv

    def body(kv_idx, carry):
        acc, m_prev, l_prev = carry
        kv_offset = kv_idx * block_kv
        k_blk = jax.lax.dynamic_slice(k_all, (kv_offset, 0),
                                      (block_kv, d)).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(v_all, (kv_offset, 0),
                                      (block_kv, d)).astype(jnp.float32)
        logits = q @ k_blk.T  # (block_q, block_kv)
        if causal:
            q_pos = q_offset + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            k_pos = kv_offset + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            logits = jnp.where(q_pos >= k_pos, logits, NEG_INF)
        m_cur = jnp.max(logits, axis=1)  # (block_q,)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # rescale of old accumulator
        p = jnp.exp(logits - m_new[:, None])  # (block_q, block_kv)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v_blk
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    if causal:
        # Blocks strictly after the diagonal contribute nothing; skip them.
        last = (q_offset + block_q + block_kv - 1) // block_kv
        upper = jnp.minimum(num_kv_blocks, last)
    else:
        upper = num_kv_blocks
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    # Causal masking guarantees l >= 1 (self-attention term), but guard
    # anyway for the non-causal empty-block edge.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, 0] = acc / l[:, None]


def attention(q, k, v, *, causal=True, block_q=BLOCK_Q, block_kv=BLOCK_KV):
    """Grouped-KV flash-style attention.

    q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    Returns (B, Hq, S, D) f32.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, f"Hq={hq} not a multiple of Hkv={hkv}"
    group = hq // hkv
    bq = min(block_q, s)
    while s % bq:
        bq -= 1
    bkv = min(block_kv, s)
    while s % bkv:
        bkv -= 1
    grid = (b, hq, s // bq)
    kernel = functools.partial(_attention_kernel, block_kv=bkv,
                               causal=causal, sm_scale=1.0 / (d ** 0.5))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # q: one (bq, d) tile per program
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
            # k/v: the kv head this q head maps to, full sequence resident
            pl.BlockSpec((1, 1, s, d),
                         lambda ib, ih, iq: (ib, ih // group, 0, 0)),
            pl.BlockSpec((1, 1, s, d),
                         lambda ib, ih, iq: (ib, ih // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda ib, ih, iq: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), jnp.float32),
        interpret=True,
    )(q, k, v)
