"""AOT pipeline: lower every model variant to HLO text + manifest.

This is the only place Python touches the system.  ``make artifacts``
runs it once; afterwards the rust coordinator is self-contained: it reads
``artifacts/manifest.json`` to discover the variants and loads
``artifacts/<name>.hlo.txt`` through ``HloModuleProto::from_text_file``.

Interchange is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The variant set covers the axes the paper's search space exercises at
inference time — attention kind × quantization × MoE × LoRA — at a scale
the CPU PJRT client executes in milliseconds, so the rust refinement loop
(Algorithm 1 line 5, "evaluate on actual hardware") performs *real*
measurements.  Each quantized variant shares its weight seed with an
fp16 sibling (``fidelity_baseline``) so the runtime can measure numeric
fidelity (quantized logits vs full-precision logits) as the accuracy
proxy.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelConfig, build_forward_fn, flops_per_token, \
    param_count, weight_bytes

WEIGHT_SEED = 1234  # shared by all variants -> fidelity is comparable


def variant_registry():
    """(name, ModelConfig, batch, seq, fidelity_baseline) for every artifact.

    The grid: 4 attention kinds × {fp16, int8, int4} quant, plus MoE,
    LoRA and a larger "serve" variant used by the batched-serving
    example.  fp16 variants are their own baseline.
    """
    out = []

    def add(name, cfg, batch=4, seq=64, baseline=None):
        out.append((name, cfg, batch, seq, baseline or name))

    for attn in ("mha", "gqa", "mqa", "mla"):
        base = f"{attn}_fp16"
        add(base, ModelConfig(attention=attn, quant="fp16"))
        for quant in ("int8", "int4"):
            add(f"{attn}_{quant}",
                ModelConfig(attention=attn, quant=quant), baseline=base)

    # MoE variants (gqa backbone).
    add("gqa_fp16_moe4",
        ModelConfig(attention="gqa", quant="fp16", moe_experts=4,
                    moe_top_k=2))
    add("gqa_int8_moe4",
        ModelConfig(attention="gqa", quant="int8", moe_experts=4,
                    moe_top_k=2), baseline="gqa_fp16_moe4")

    # LoRA variant (QLoRA-shaped: int8 base + f32 adapters).
    add("gqa_fp16_lora16",
        ModelConfig(attention="gqa", quant="fp16", lora_rank=16))
    add("gqa_int8_lora16",
        ModelConfig(attention="gqa", quant="int8", lora_rank=16),
        baseline="gqa_fp16_lora16")

    # Serving variant: bigger batch/seq for the batched-request example.
    add("serve_gqa_int8",
        ModelConfig(attention="gqa", quant="int8"), batch=8, seq=128,
        baseline="serve_gqa_fp16")
    add("serve_gqa_fp16",
        ModelConfig(attention="gqa", quant="fp16"), batch=8, seq=128)
    return out


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is essential: the default HLO printer
    elides big literals as ``constant({...})``, which the text parser on
    the rust side re-reads as *zeros* — the model's weights are baked
    into the graph as constants and must survive the round-trip.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text(print_large_constants=True)


def lower_variant(cfg: ModelConfig, batch: int, seq: int) -> str:
    fn = build_forward_fn(cfg, seed=WEIGHT_SEED)
    spec = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def _inputs_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        if "__pycache__" in dirpath:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated variant names to (re)build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    fingerprint = _inputs_fingerprint()
    stamp = os.path.join(args.out_dir, ".fingerprint")
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    all_files_present = all(
        os.path.exists(os.path.join(args.out_dir, f"{name}.hlo.txt"))
        for name, *_ in variant_registry())
    if (args.only is None and all_files_present and os.path.exists(stamp)
            and os.path.exists(manifest_path)):
        with open(stamp) as f:
            if f.read().strip() == fingerprint:
                print("artifacts up to date; nothing to do")
                return

    only = set(args.only.split(",")) if args.only else None
    entries = []
    t_all = time.time()
    for name, cfg, batch, seq, baseline in variant_registry():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        if only is None or name in only:
            t0 = time.time()
            text = lower_variant(cfg, batch, seq)
            with open(path, "w") as f:
                f.write(text)
            print(f"  {name:<22} {len(text)/1e6:6.2f} MB HLO  "
                  f"({time.time()-t0:.1f}s)")
        entries.append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "fidelity_baseline": baseline,
            "batch": batch,
            "seq": seq,
            "config": cfg.to_dict(),
            "param_count": param_count(cfg),
            "weight_bytes": weight_bytes(cfg),
            "flops_per_token": flops_per_token(cfg, seq),
        })

    with open(manifest_path, "w") as f:
        json.dump({"weight_seed": WEIGHT_SEED, "variants": entries}, f,
                  indent=2)

    # Cross-layer goldens: expected logits for a deterministic token
    # pattern, so the rust runtime can verify it reproduces the python
    # numerics exactly (integration test `golden_numerics`).
    goldens = {}
    for name in ("gqa_fp16", "gqa_int8", "mla_int4"):
        cfg, batch, seq = next((c, b, s) for n, c, b, s, _ in
                               variant_registry() if n == name)
        tokens = jnp.asarray(
            [[(i * 7 + 3) % cfg.vocab for i in range(seq)]] * batch,
            dtype=jnp.int32)
        logits = build_forward_fn(cfg, seed=WEIGHT_SEED)(tokens)[0]
        flat = [float(x) for x in jnp.ravel(logits)[:32]]
        goldens[name] = {
            "first32": flat,
            "mean_abs": float(jnp.mean(jnp.abs(logits))),
        }
    with open(os.path.join(args.out_dir, "goldens.json"), "w") as f:
        json.dump(goldens, f, indent=2)
    if only is None:  # partial rebuilds don't count as up-to-date
        with open(stamp, "w") as f:
            f.write(fingerprint)
    elif os.path.exists(stamp):
        os.remove(stamp)
    print(f"wrote {len(entries)} variants + manifest.json "
          f"({time.time()-t_all:.1f}s total)")


if __name__ == "__main__":
    main()
