"""Layer-1 correctness: Pallas grouped-KV attention vs jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as att
from compile.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _qkv(rng, b, hq, hkv, s, d):
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    return q, k, v


class TestAttentionFixed:
    @pytest.mark.parametrize("b,hq,hkv,s,d", [
        (1, 8, 8, 64, 16),   # MHA
        (2, 8, 2, 64, 16),   # GQA group 4
        (1, 8, 1, 64, 16),   # MQA
        (2, 4, 2, 96, 32),   # GQA group 2, non-pow2 seq blocks
        (1, 2, 1, 32, 8),    # tiny
        (1, 8, 4, 33, 16),   # seq not divisible by default blocks
    ])
    def test_causal_matches_ref(self, b, hq, hkv, s, d):
        rng = np.random.default_rng(b * 100 + hq + hkv + s + d)
        q, k, v = _qkv(rng, b, hq, hkv, s, d)
        np.testing.assert_allclose(att.attention(q, k, v, causal=True),
                                   ref.attention_ref(q, k, v, causal=True),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("b,hq,hkv,s,d", [
        (1, 8, 8, 64, 16), (2, 8, 2, 64, 16), (1, 4, 1, 48, 16),
    ])
    def test_non_causal_matches_ref(self, b, hq, hkv, s, d):
        rng = np.random.default_rng(s + d)
        q, k, v = _qkv(rng, b, hq, hkv, s, d)
        np.testing.assert_allclose(att.attention(q, k, v, causal=False),
                                   ref.attention_ref(q, k, v, causal=False),
                                   rtol=1e-4, atol=1e-4)

    def test_causal_first_token_is_v0(self):
        """Causal row 0 can only attend to position 0 -> output == v[0]."""
        rng = np.random.default_rng(5)
        q, k, v = _qkv(rng, 1, 2, 2, 16, 8)
        out = att.attention(q, k, v, causal=True)
        np.testing.assert_allclose(out[:, :, 0, :], v[:, :, 0, :],
                                   rtol=1e-5, atol=1e-5)

    def test_rejects_non_multiple_heads(self):
        rng = np.random.default_rng(6)
        q = jnp.asarray(rng.standard_normal((1, 6, 16, 8)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 4, 16, 8)).astype(np.float32))
        with pytest.raises(AssertionError):
            att.attention(q, k, q if False else k, causal=True)

    def test_permutation_invariance_non_causal(self):
        """Non-causal attention is invariant to KV position permutation."""
        rng = np.random.default_rng(7)
        q, k, v = _qkv(rng, 1, 4, 4, 32, 8)
        perm = np.asarray(rng.permutation(32))
        out1 = att.attention(q, k, v, causal=False)
        out2 = att.attention(q, k[:, :, perm, :], v[:, :, perm, :],
                             causal=False)
        np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-4)

    def test_uniform_values_average(self):
        """With q=0, softmax is uniform; causal output = prefix mean of v."""
        b, h, s, d = 1, 2, 16, 8
        rng = np.random.default_rng(8)
        q = jnp.zeros((b, h, s, d), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, h, s, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, h, s, d)).astype(np.float32))
        out = att.attention(q, k, v, causal=True)
        prefix_mean = jnp.cumsum(v, axis=2) / jnp.arange(
            1, s + 1, dtype=jnp.float32)[None, None, :, None]
        np.testing.assert_allclose(out, prefix_mean, rtol=1e-4, atol=1e-4)


class TestAttentionHypothesis:
    @given(
        b=st.integers(1, 2),
        hkv=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        s=st.integers(4, 80),
        d=st.sampled_from([8, 16]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_sweep(self, b, hkv, group, s, d, causal, seed):
        rng = np.random.default_rng(seed)
        q, k, v = _qkv(rng, b, hkv * group, hkv, s, d)
        np.testing.assert_allclose(
            att.attention(q, k, v, causal=causal),
            ref.attention_ref(q, k, v, causal=causal),
            rtol=1e-4, atol=1e-4)
