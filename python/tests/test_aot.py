"""AOT pipeline tests: registry coherence, manifest schema, HLO lowering."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import ModelConfig

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestRegistry:
    def test_names_unique(self):
        names = [name for name, *_ in aot.variant_registry()]
        assert len(names) == len(set(names))

    def test_all_configs_valid(self):
        for _, cfg, batch, seq, _ in aot.variant_registry():
            cfg.validate()
            assert batch >= 1 and seq >= 1

    def test_baselines_exist_and_are_fp16(self):
        reg = {name: cfg for name, cfg, *_ in aot.variant_registry()}
        for name, cfg, _, _, baseline in aot.variant_registry():
            assert baseline in reg, f"{name}: baseline {baseline} missing"
            assert reg[baseline].quant == "fp16"

    def test_baseline_shares_architecture(self):
        reg = {name: (cfg, b, s)
               for name, cfg, b, s, _ in aot.variant_registry()}
        for name, cfg, batch, seq, baseline in aot.variant_registry():
            bcfg, bb, bs = reg[baseline]
            assert bcfg.attention == cfg.attention
            assert bcfg.moe_experts == cfg.moe_experts
            assert bcfg.lora_rank == cfg.lora_rank
            assert (bb, bs) == (batch, seq), \
                f"{name}: baseline shape mismatch"

    def test_covers_all_attention_kinds(self):
        kinds = {cfg.attention for _, cfg, *_ in aot.variant_registry()}
        assert kinds == {"mha", "gqa", "mqa", "mla"}

    def test_covers_quant_grid(self):
        quants = {cfg.quant for _, cfg, *_ in aot.variant_registry()}
        assert {"fp16", "int8", "int4"} <= quants

    def test_has_moe_and_lora_variants(self):
        cfgs = [cfg for _, cfg, *_ in aot.variant_registry()]
        assert any(c.moe_experts for c in cfgs)
        assert any(c.lora_rank for c in cfgs)


class TestLowering:
    def test_lower_tiny_variant_produces_hlo_text(self):
        cfg = ModelConfig(attention="gqa", quant="int8", n_layers=1)
        text = aot.lower_variant(cfg, batch=1, seq=16)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_lowered_entry_signature(self):
        cfg = ModelConfig(n_layers=1)
        text = aot.lower_variant(cfg, batch=2, seq=16)
        # one s32[2,16] parameter, tuple of one f32[2,16,256] result
        assert "s32[2,16]" in text
        assert "f32[2,16,256]" in text

    def test_fingerprint_stable(self):
        assert aot._inputs_fingerprint() == aot._inputs_fingerprint()


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS,
                                                    "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_schema(self, manifest):
        assert "weight_seed" in manifest
        for v in manifest["variants"]:
            for key in ("name", "file", "fidelity_baseline", "batch",
                        "seq", "config", "param_count", "weight_bytes",
                        "flops_per_token"):
                assert key in v, f"{v['name']} missing {key}"

    def test_files_exist(self, manifest):
        for v in manifest["variants"]:
            assert os.path.exists(os.path.join(ARTIFACTS, v["file"]))

    def test_quant_bytes_ordering(self, manifest):
        by_name = {v["name"]: v for v in manifest["variants"]}
        assert by_name["gqa_int8"]["weight_bytes"] * 2 == \
            by_name["gqa_fp16"]["weight_bytes"]
        assert by_name["gqa_int4"]["weight_bytes"] * 4 == \
            by_name["gqa_fp16"]["weight_bytes"]

    def test_counts_match_model(self, manifest):
        from compile.model import param_count, weight_bytes, \
            flops_per_token
        for v in manifest["variants"]:
            cfg = ModelConfig(**v["config"])
            assert v["param_count"] == param_count(cfg)
            assert v["weight_bytes"] == weight_bytes(cfg)
            assert v["flops_per_token"] == flops_per_token(cfg, v["seq"])
