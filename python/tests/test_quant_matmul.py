"""Layer-1 correctness: Pallas quant-matmul kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including degenerate and non-divisible-by-block
sizes) and value distributions; every case asserts allclose against
``ref.py``.  This is the core trust anchor for the AOT artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant_matmul as qm
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray((rng.standard_normal(shape) * scale)
                       .astype(np.float32))


# ---------------------------------------------------------------------------
# Quantization helpers round-trip
# ---------------------------------------------------------------------------

class TestQuantizeHelpers:
    def test_int8_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        w = _rand(rng, 64, 32)
        w_q, s = ref.quantize_int8(w)
        w_hat = w_q.astype(jnp.float32) * s
        # max error <= half a quantization step per channel
        step = s[0]
        assert float(jnp.max(jnp.abs(w - w_hat) / step)) <= 0.5 + 1e-5

    def test_int8_dtype_and_shapes(self):
        rng = np.random.default_rng(1)
        w = _rand(rng, 10, 6)
        w_q, s = ref.quantize_int8(w)
        assert w_q.dtype == jnp.int8 and w_q.shape == (10, 6)
        assert s.shape == (1, 6)

    def test_int8_zero_column_gets_unit_scale(self):
        w = jnp.zeros((8, 3), jnp.float32)
        w_q, s = ref.quantize_int8(w)
        assert jnp.all(s == 1.0) and jnp.all(w_q == 0)

    def test_int4_pack_unpack_identity(self):
        rng = np.random.default_rng(2)
        w = _rand(rng, 32, 16)
        w_p, s = ref.quantize_int4(w)
        assert w_p.shape == (16, 16) and w_p.dtype == jnp.uint8
        unpacked = ref.unpack_int4(w_p)
        assert unpacked.shape == (32, 16)
        assert int(jnp.min(unpacked)) >= -8 and int(jnp.max(unpacked)) <= 7

    def test_int4_roundtrip_error_bound(self):
        rng = np.random.default_rng(3)
        w = _rand(rng, 64, 8)
        w_p, s = ref.quantize_int4(w)
        w_hat = ref.unpack_int4(w_p).astype(jnp.float32) * s
        assert float(jnp.max(jnp.abs(w - w_hat) / s[0])) <= 0.5 + 1e-5

    def test_int4_requires_even_k(self):
        with pytest.raises(AssertionError):
            ref.quantize_int4(jnp.ones((7, 4), jnp.float32))


# ---------------------------------------------------------------------------
# Kernel vs reference, fixed cases
# ---------------------------------------------------------------------------

class TestKernelsFixed:
    @pytest.mark.parametrize("m,k,n", [
        (8, 16, 8), (64, 128, 128), (64, 96, 80), (1, 128, 256),
        (33, 50, 17),  # awkward, non-power-of-two everything
        (128, 256, 64),
    ])
    def test_matmul_f32(self, m, k, n):
        rng = np.random.default_rng(m * 1000 + k + n)
        x, w = _rand(rng, m, k), _rand(rng, k, n)
        np.testing.assert_allclose(qm.matmul_f32(x, w),
                                   ref.matmul_f32_ref(x, w),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("m,k,n", [
        (8, 16, 8), (64, 128, 128), (48, 96, 80), (1, 64, 32),
        (33, 50, 17),
    ])
    def test_quant_matmul_int8(self, m, k, n):
        rng = np.random.default_rng(m + k + n)
        x, w = _rand(rng, m, k), _rand(rng, k, n)
        w_q, s = ref.quantize_int8(w)
        np.testing.assert_allclose(qm.quant_matmul_int8(x, w_q, s),
                                   ref.quant_matmul_int8_ref(x, w_q, s),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("m,k,n", [
        (8, 16, 8), (64, 128, 128), (48, 96, 80), (1, 64, 32),
        (32, 50, 17),  # K=50 even but not power of two
    ])
    def test_quant_matmul_int4(self, m, k, n):
        rng = np.random.default_rng(m * 7 + k + n)
        x, w = _rand(rng, m, k), _rand(rng, k, n)
        w_p, s = ref.quantize_int4(w)
        np.testing.assert_allclose(qm.quant_matmul_int4(x, w_p, s),
                                   ref.quant_matmul_int4_ref(x, w_p, s),
                                   rtol=1e-5, atol=1e-4)

    def test_int8_matches_dense_within_quant_error(self):
        """Fused kernel ~ the unquantized product within quant noise."""
        rng = np.random.default_rng(11)
        x, w = _rand(rng, 32, 64), _rand(rng, 64, 48)
        w_q, s = ref.quantize_int8(w)
        dense = ref.matmul_f32_ref(x, w)
        fused = qm.quant_matmul_int8(x, w_q, s)
        # error bounded by K * max|x| * step/2
        bound = 64 * float(jnp.max(jnp.abs(x))) * float(jnp.max(s)) * 0.5
        assert float(jnp.max(jnp.abs(dense - fused))) <= bound

    def test_linear_dispatch_all_modes(self):
        rng = np.random.default_rng(12)
        x = _rand(rng, 2, 8, 32)  # leading batch dims exercised
        w = _rand(rng, 32, 24)
        from compile.model import pack_weight
        for quant in ("fp16", "fp8", "int8", "int4"):
            pack = pack_weight(np.asarray(w), quant)
            y = qm.linear(x, pack, quant)
            assert y.shape == (2, 8, 24)

    def test_linear_rejects_unknown_mode(self):
        rng = np.random.default_rng(13)
        x, w = _rand(rng, 4, 8), _rand(rng, 8, 8)
        with pytest.raises(ValueError):
            qm.linear(x, (w,), "int2")

    def test_mismatched_inner_dim_raises(self):
        rng = np.random.default_rng(14)
        x, w = _rand(rng, 4, 8), _rand(rng, 9, 8)
        with pytest.raises(AssertionError):
            qm.matmul_f32(x, w)


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=96)
even_dims = st.integers(min_value=1, max_value=48).map(lambda v: v * 2)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestKernelsHypothesis:
    @given(m=dims, k=dims, n=dims, seed=seeds)
    def test_matmul_f32_sweep(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, w = _rand(rng, m, k), _rand(rng, k, n)
        np.testing.assert_allclose(qm.matmul_f32(x, w),
                                   ref.matmul_f32_ref(x, w),
                                   rtol=1e-5, atol=1e-4)

    @given(m=dims, k=dims, n=dims, seed=seeds,
           scale=st.floats(min_value=1e-3, max_value=100.0))
    def test_quant_matmul_int8_sweep(self, m, k, n, seed, scale):
        rng = np.random.default_rng(seed)
        x = _rand(rng, m, k)
        w = _rand(rng, k, n, scale=scale)
        w_q, s = ref.quantize_int8(w)
        np.testing.assert_allclose(qm.quant_matmul_int8(x, w_q, s),
                                   ref.quant_matmul_int8_ref(x, w_q, s),
                                   rtol=1e-4, atol=1e-3 * max(1.0, scale))

    @given(m=dims, k=even_dims, n=dims, seed=seeds)
    def test_quant_matmul_int4_sweep(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, w = _rand(rng, m, k), _rand(rng, k, n)
        w_p, s = ref.quantize_int4(w)
        np.testing.assert_allclose(qm.quant_matmul_int4(x, w_p, s),
                                   ref.quant_matmul_int4_ref(x, w_p, s),
                                   rtol=1e-4, atol=1e-3)

    @given(k=even_dims, n=dims, seed=seeds)
    def test_int4_pack_unpack_roundtrip_sweep(self, k, n, seed):
        rng = np.random.default_rng(seed)
        w = _rand(rng, k, n)
        w_p, _ = ref.quantize_int4(w)
        u = ref.unpack_int4(w_p)
        # re-pack == original packing
        lo = (u[0::2, :] + 8).astype(jnp.int32)
        hi = (u[1::2, :] + 8).astype(jnp.int32)
        repacked = (lo | (hi << 4)).astype(jnp.uint8)
        assert jnp.array_equal(repacked, w_p)
