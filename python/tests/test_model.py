"""Layer-2 correctness: configurable transformer, pallas vs reference path.

The differential test (use_pallas=True vs use_pallas=False on identical
seeds) proves the L1 kernels compose correctly inside the full graph for
every point of the architecture x quantization grid that the AOT
pipeline ships.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref

TOKENS = jnp.asarray(
    np.random.default_rng(0).integers(0, 256, size=(2, 64)), dtype=jnp.int32)


def _logits(cfg, seed=3, tokens=TOKENS):
    return M.build_forward_fn(cfg, seed=seed)(tokens)[0]


class TestConfigValidation:
    def test_defaults_valid(self):
        M.ModelConfig().validate()

    @pytest.mark.parametrize("attn,expected_kv", [
        ("mha", 8), ("gqa", 2), ("mqa", 1), ("mla", 8)])
    def test_kv_heads(self, attn, expected_kv):
        cfg = M.ModelConfig(attention=attn, n_heads=8, gqa_groups=4)
        assert cfg.kv_heads == expected_kv

    def test_head_dim(self):
        assert M.ModelConfig(d_model=128, n_heads=8).head_dim == 16

    @pytest.mark.parametrize("bad", [
        dict(attention="flash"),
        dict(quant="int2"),
        dict(moe_experts=3),
        dict(moe_experts=2, moe_top_k=4),
        dict(d_model=130, n_heads=8),
    ])
    def test_invalid_configs_rejected(self, bad):
        with pytest.raises(ValueError):
            M.ModelConfig(**bad).validate()

    def test_to_dict_roundtrip(self):
        cfg = M.ModelConfig(attention="mla", quant="int4", lora_rank=8)
        d = cfg.to_dict()
        assert d["attention"] == "mla" and d["quant"] == "int4"
        assert M.ModelConfig(**d) == cfg


class TestForwardShapes:
    @pytest.mark.parametrize("attn", ["mha", "gqa", "mqa", "mla"])
    def test_logit_shape(self, attn):
        cfg = M.ModelConfig(attention=attn, n_layers=1, use_pallas=False)
        logits = _logits(cfg)
        assert logits.shape == (2, 64, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_moe_and_lora_shapes(self):
        cfg = M.ModelConfig(moe_experts=4, moe_top_k=2, lora_rank=16,
                            n_layers=1, use_pallas=False)
        assert _logits(cfg).shape == (2, 64, 256)

    def test_deterministic_across_calls(self):
        cfg = M.ModelConfig(n_layers=1, use_pallas=False)
        np.testing.assert_array_equal(_logits(cfg), _logits(cfg))

    def test_seed_changes_logits(self):
        cfg = M.ModelConfig(n_layers=1, use_pallas=False)
        a, b = _logits(cfg, seed=1), _logits(cfg, seed=2)
        assert float(jnp.max(jnp.abs(a - b))) > 1e-3


class TestPallasVsReference:
    @pytest.mark.parametrize("attn", ["mha", "gqa", "mqa", "mla"])
    @pytest.mark.parametrize("quant", ["fp16", "int8", "int4"])
    def test_grid(self, attn, quant):
        kp = M.ModelConfig(attention=attn, quant=quant, n_layers=1,
                           use_pallas=True)
        kr = M.ModelConfig(attention=attn, quant=quant, n_layers=1,
                           use_pallas=False)
        np.testing.assert_allclose(_logits(kp), _logits(kr),
                                   rtol=1e-4, atol=2e-3)

    def test_lora_path(self):
        kp = M.ModelConfig(lora_rank=16, n_layers=1, use_pallas=True)
        kr = M.ModelConfig(lora_rank=16, n_layers=1, use_pallas=False)
        np.testing.assert_allclose(_logits(kp), _logits(kr),
                                   rtol=1e-4, atol=2e-3)

    def test_moe_path(self):
        kp = M.ModelConfig(moe_experts=4, moe_top_k=2, n_layers=1,
                           use_pallas=True)
        kr = M.ModelConfig(moe_experts=4, moe_top_k=2, n_layers=1,
                           use_pallas=False)
        np.testing.assert_allclose(_logits(kp), _logits(kr),
                                   rtol=1e-4, atol=2e-3)


class TestQuantFidelityOrdering:
    def test_int4_noisier_than_int8(self):
        """Fidelity to fp16 logits must degrade monotonically with bits.

        This ordering is the accuracy-proxy signal the rust runtime
        measures; if it breaks, the measured-evaluator's accuracy model
        is meaningless.
        """
        base = _logits(M.ModelConfig(quant="fp16", use_pallas=False))
        e8 = float(jnp.mean(jnp.abs(
            _logits(M.ModelConfig(quant="int8", use_pallas=False)) - base)))
        e4 = float(jnp.mean(jnp.abs(
            _logits(M.ModelConfig(quant="int4", use_pallas=False)) - base)))
        assert 0 < e8 < e4

    def test_lora_changes_output(self):
        base = _logits(M.ModelConfig(use_pallas=False))
        lora = _logits(M.ModelConfig(lora_rank=16, use_pallas=False))
        assert float(jnp.max(jnp.abs(base - lora))) > 1e-4


class TestMoEReference:
    def test_top1_selects_argmax_expert(self):
        rng = np.random.default_rng(9)
        t, d, e, f = 6, 8, 4, 16
        x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
        wg = jnp.asarray(rng.standard_normal((e, d, f)).astype(np.float32))
        wu = jnp.asarray(rng.standard_normal((e, d, f)).astype(np.float32))
        wd = jnp.asarray(rng.standard_normal((e, f, d)).astype(np.float32))
        wr = jnp.asarray(rng.standard_normal((d, e)).astype(np.float32))
        y = ref.moe_ffn_ref(x, wg, wu, wd, wr, top_k=1)
        # manual: each token -> single argmax expert, gate weight 1
        router = np.asarray(x @ wr)
        for t_i in range(t):
            e_i = int(np.argmax(router[t_i]))
            hg = np.asarray(x)[t_i] @ np.asarray(wg)[e_i]
            hu = np.asarray(x)[t_i] @ np.asarray(wu)[e_i]
            h = np.where(hg > 0, hg, hg * 0.01) * hu
            expected = h @ np.asarray(wd)[e_i]
            np.testing.assert_allclose(np.asarray(y)[t_i], expected,
                                       rtol=1e-4, atol=1e-4)

    def test_topk_gates_sum_to_one(self):
        rng = np.random.default_rng(10)
        t, d, e, f = 5, 8, 8, 16
        x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
        wr = jnp.asarray(rng.standard_normal((d, e)).astype(np.float32))
        logits = np.asarray(x @ wr)
        for k in (1, 2):
            thr = np.sort(logits, axis=-1)[:, -k][:, None]
            mask = logits >= thr
            g = np.where(mask, logits, -1e30)
            g = np.exp(g - g.max(-1, keepdims=True))
            g = g / g.sum(-1, keepdims=True)
            assert np.allclose(g.sum(-1), 1.0)
            assert (np.count_nonzero(g > 1e-12, axis=-1) == k).all()


class TestCostAccounting:
    def test_param_count_matches_actual_params(self):
        cfg = M.ModelConfig(attention="gqa", quant="fp16", n_layers=2)
        params = M.init_params(cfg, seed=0)
        total = params["embed"].size  # tied unembedding, counted once
        for layer in params["layers"]:
            for k, val in layer.items():
                if k in ("attn_norm", "ffn_norm"):
                    continue  # norms excluded from weight count
                if isinstance(val, tuple):
                    total += val[0].size  # the weight, not the scales
                else:
                    total += val.size
        assert total == M.param_count(cfg)

    def test_quant_reduces_weight_bytes(self):
        fp = M.weight_bytes(M.ModelConfig(quant="fp16"))
        i8 = M.weight_bytes(M.ModelConfig(quant="int8"))
        i4 = M.weight_bytes(M.ModelConfig(quant="int4"))
        assert fp == 2 * i8 == 4 * i4

    def test_mqa_fewer_flops_than_mha(self):
        f_mha = M.flops_per_token(M.ModelConfig(attention="mha"), 64)
        f_mqa = M.flops_per_token(M.ModelConfig(attention="mqa"), 64)
        assert f_mqa < f_mha

    def test_moe_topk_flops_sublinear_in_experts(self):
        dense = M.flops_per_token(M.ModelConfig(), 64)
        moe8 = M.flops_per_token(
            M.ModelConfig(moe_experts=8, moe_top_k=2), 64)
        # top-2 of 8 experts ~ 2x dense FFN cost, far below 8x
        assert moe8 < 3 * dense

    def test_int4_param_count_unaffected(self):
        assert M.param_count(M.ModelConfig(quant="int4")) == \
            M.param_count(M.ModelConfig(quant="fp16"))
