#!/usr/bin/env python3
"""Bench-regression gate: compare this run's BENCH_*.json throughput
against the previous run's artifact and fail on a large drop.

Usage: bench_gate.py --prev DIR --curr DIR [--threshold 0.8]

* Reports are matched by file name (``BENCH_<short>.json``), searched
  recursively under each directory (artifact downloads nest them one
  level deep).
* Only keys ending in ``_per_sec`` are compared — those are the
  throughput metrics of the ae-llm.bench/v1 schema (higher is better);
  wall-ms and count keys are informational.  New keys ride the glob
  automatically: e.g. BENCH_cluster.json's ``sequential_requests_per_sec``
  / ``parallel_requests_per_sec`` pair (the sharded event-core split)
  is gated by naming alone, no script change needed.
* A key regresses when ``curr < prev * threshold`` (default 0.8, i.e.
  a >20% throughput drop).  Keys present on only one side are listed
  but never fail the gate (benches gain and lose metrics across PRs).
* Comparisons are only meaningful within one mode: if the two runs'
  ``mode`` fields differ (quick vs full) the pair is skipped.
* Soft pass: no previous reports found (first run on a branch, expired
  artifact) exits 0 with a notice — the gate needs history to bite.

Writes a per-key markdown table to ``$GITHUB_STEP_SUMMARY`` when set.
"""

import argparse
import glob
import json
import os
import sys


def find_reports(root: str) -> dict:
    out = {}
    for path in sorted(glob.glob(os.path.join(root, "**", "BENCH_*.json"),
                                 recursive=True)):
        out[os.path.basename(path)] = path
    return out


def per_sec_keys(rep: dict) -> dict:
    return {
        k: float(v) for k, v in rep.items()
        if k.endswith("_per_sec") and isinstance(v, (int, float))
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", required=True)
    ap.add_argument("--curr", required=True)
    ap.add_argument("--threshold", type=float, default=0.8,
                    help="fail when curr < prev * threshold")
    args = ap.parse_args()

    prev = find_reports(args.prev)
    curr = find_reports(args.curr)
    if not curr:
        print(f"no current BENCH_*.json under {args.curr}", file=sys.stderr)
        return 2
    if not prev:
        print("no previous bench reports found — soft pass "
              "(first run, or the prior artifact expired)")
        summarize([], soft=True)
        return 0

    rows = []   # (bench, key, prev, curr, ratio, status)
    failures = 0
    for name, cpath in sorted(curr.items()):
        with open(cpath) as f:
            crep = json.load(f)
        if name not in prev:
            rows.append((name, "(new bench)", None, None, None, "new"))
            continue
        with open(prev[name]) as f:
            prep = json.load(f)
        if prep.get("mode") != crep.get("mode"):
            rows.append((name, f"(mode {prep.get('mode')} vs "
                         f"{crep.get('mode')})", None, None, None,
                         "skipped"))
            continue
        pkeys, ckeys = per_sec_keys(prep), per_sec_keys(crep)
        for key in sorted(set(pkeys) | set(ckeys)):
            p, c = pkeys.get(key), ckeys.get(key)
            if p is None or c is None:
                rows.append((name, key, p, c, None,
                             "new" if p is None else "removed"))
                continue
            ratio = c / p if p > 0 else float("inf")
            if ratio < args.threshold:
                failures += 1
                status = "REGRESSED"
            else:
                status = "ok"
            rows.append((name, key, p, c, ratio, status))

    for bench, key, p, c, ratio, status in rows:
        fmt = lambda v: "-" if v is None else f"{v:,.1f}"
        r = "-" if ratio is None else f"{ratio:.2f}x"
        print(f"{status:>9}  {bench:<22} {key:<44} "
              f"prev={fmt(p):>14} curr={fmt(c):>14} {r}")
    summarize(rows, threshold=args.threshold, failures=failures)

    if failures:
        print(f"\n{failures} throughput key(s) regressed by more than "
              f"{100 * (1 - args.threshold):.0f}% — failing the gate",
              file=sys.stderr)
        return 1
    print("\nbench gate passed")
    return 0


def summarize(rows, threshold: float = 0.8, failures: int = 0,
              soft: bool = False):
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    with open(path, "a") as f:
        f.write("## Bench regression gate\n\n")
        if soft:
            f.write("No previous bench artifact — soft pass (the gate "
                    "compares against the last successful run).\n")
            return
        f.write(f"Threshold: fail below {threshold:.2f}x of the previous "
                f"run's throughput. Result: "
                f"{'**' + str(failures) + ' regression(s)**' if failures else 'no regressions'}.\n\n")
        f.write("| bench | key | previous | current | ratio | status |\n")
        f.write("|---|---|---:|---:|---:|---|\n")
        for bench, key, p, c, ratio, status in rows:
            fmt = lambda v: "-" if v is None else f"{v:,.1f}"
            r = "-" if ratio is None else f"{ratio:.2f}x"
            flag = "❌" if status == "REGRESSED" else ""
            f.write(f"| {bench} | `{key}` | {fmt(p)} | {fmt(c)} | {r} "
                    f"| {status} {flag} |\n")


if __name__ == "__main__":
    sys.exit(main())
