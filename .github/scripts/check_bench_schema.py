#!/usr/bin/env python3
"""Validate BENCH_*.json files against the ae-llm.bench/v1 schema.

Usage: check_bench_schema.py BENCH_search.json [BENCH_serve.json ...]

Every report must carry the shared envelope written by
`rust/src/util/bench.rs::write_report` (see docs/SCHEMAS.md):

* ``schema``  == "ae-llm.bench/v1"
* ``name``    == "perf_<short>" and must match the file name
  (``BENCH_<short>.json``)
* ``mode``    in {"quick", "full"}
* legacy aliases: ``bench`` == ``name``, ``quick`` is a bool consistent
  with ``mode``
* at least one numeric ``*_per_sec`` throughput key (the regression
  gate compares exactly those)
* for benches with a known kernel inventory (``REQUIRED_PER_SEC``),
  every listed throughput key must be present — a rewrite that silently
  drops its before/after microbench would otherwise escape the gate
"""

import json
import os
import sys

SCHEMA = "ae-llm.bench/v1"

# Throughput keys each bench must emit.  "search" covers the kernel
# rewrites of DESIGN.md §15 (archive, GBT) and §17 (non-dominated sort,
# crowding, hypervolume): each ships a new/reference key pair so
# bench_gate.py tracks both sides.
REQUIRED_PER_SEC = {
    "search": [
        "nds_sort_per_sec",
        "nds_sort_ref_per_sec",
        "crowding_per_sec",
        "crowding_ref_per_sec",
        "hypervolume_per_sec",
        "hypervolume_ref_per_sec",
        "archive_insert_per_sec",
        "archive_insert_ref_per_sec",
        "gbt_fit_rows_per_sec",
        "gbt_fit_ref_rows_per_sec",
    ],
}


def check(path: str) -> list:
    errors = []
    base = os.path.basename(path)
    if not (base.startswith("BENCH_") and base.endswith(".json")):
        return [f"unexpected file name {base!r}"]
    short = base[len("BENCH_"):-len(".json")]
    with open(path) as f:
        rep = json.load(f)
    if not isinstance(rep, dict):
        return ["report is not a JSON object"]
    if rep.get("schema") != SCHEMA:
        errors.append(f"schema is {rep.get('schema')!r}, want {SCHEMA!r}")
    want_name = f"perf_{short}"
    if rep.get("name") != want_name:
        errors.append(f"name is {rep.get('name')!r}, want {want_name!r}")
    if rep.get("mode") not in ("quick", "full"):
        errors.append(f"mode is {rep.get('mode')!r}, want quick|full")
    if rep.get("bench") != rep.get("name"):
        errors.append("legacy alias 'bench' != 'name'")
    if rep.get("quick") is not (rep.get("mode") == "quick"):
        errors.append("legacy alias 'quick' inconsistent with 'mode'")
    per_sec = {
        k: v for k, v in rep.items()
        if k.endswith("_per_sec") and isinstance(v, (int, float))
    }
    if not per_sec:
        errors.append("no numeric *_per_sec throughput keys")
    for k, v in per_sec.items():
        if not (v == v and v > 0):  # NaN or non-positive
            errors.append(f"throughput key {k!r} is {v!r}")
    for k in REQUIRED_PER_SEC.get(short, []):
        if k not in per_sec:
            errors.append(f"missing required throughput key {k!r}")
    return errors


def main(argv: list) -> int:
    if not argv:
        print("usage: check_bench_schema.py BENCH_*.json", file=sys.stderr)
        return 2
    bad = 0
    for path in argv:
        errs = check(path)
        if errs:
            bad += 1
            for e in errs:
                print(f"FAIL {path}: {e}")
        else:
            n = len(json.load(open(path)))
            print(f"ok   {path} ({n} keys)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
