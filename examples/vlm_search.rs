//! Cross-modal generalization (paper §4.4): run AE-LLM on
//! vision-language models and compare the chosen configurations with
//! the LLM patterns.
//!
//! ```bash
//! cargo run --release --offline --example vlm_search
//! ```

use ae_llm::coordinator::{AeLlm, AeLlmParams};
use ae_llm::tasks;

fn main() {
    let mut vlm_scores = Vec::new();
    println!("AE-LLM on vision-language models\n");
    for model in ["LLaVA-1.5-7B", "InternVL-Chat"] {
        for task in tasks::vlm_suite() {
            // InternVL is only evaluated on VQAv2 in the paper's table
            if model == "InternVL-Chat" && task.name != "VQAv2" {
                continue;
            }
            let out = AeLlm::for_model(model)
                .unwrap()
                .task(task.name)
                .unwrap()
                .params(AeLlmParams::small())
                .seed(11)
                .run_testbed_outcome();
            println!(
                "{model:<14} {:<13} -> {}\n{:>28} acc {:.1} (default \
                 {:.1}) | {:.1} ms | {:.1} GB | eff {:.2}x",
                task.name,
                out.chosen.signature(),
                "",
                out.chosen_objectives.accuracy,
                out.reference.default.accuracy,
                out.chosen_objectives.latency_ms,
                out.chosen_objectives.memory_gb,
                out.chosen_efficiency_score,
            );
            vlm_scores.push(out.chosen_efficiency_score);
        }
    }

    // paper: VLMs see ~2.5x average efficiency improvement — the same
    // ballpark as LLMs, validating cross-modal generalization.
    let mean = ae_llm::util::stats::mean(&vlm_scores);
    println!("\naverage VLM efficiency score: {mean:.2}x (paper: ~2.5x)");
    assert!(mean > 1.3, "VLM generalization failed: {mean}");
}
