//! Quickstart: optimize the efficiency configuration of one model for
//! one deployment scenario and print the Pareto front.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use ae_llm::coordinator::{optimize, AeLlmParams, Scenario};
use ae_llm::metrics::utility;
use ae_llm::util::Rng;

fn main() {
    // 1. Describe the deployment: model, task mix, hardware, preferences.
    //    `for_model` picks the paper's hardware tier for the model scale
    //    (Mistral-7B -> A100-80GB) and the blended task mix.
    let scenario = Scenario::for_model("Mistral-7B").expect("model in zoo");
    println!(
        "optimizing {} on {} for task {:?}",
        scenario.model.name, scenario.testbed.platform.name,
        scenario.task.name
    );

    // 2. Run AE-LLM (Algorithm 1): surrogate-guided NSGA-II with
    //    hardware-in-the-loop refinement against the testbed.
    let mut rng = Rng::new(7);
    let out = optimize(&scenario, &AeLlmParams::small(), &mut rng);

    // 3. Inspect the Pareto front: each entry is a measured trade-off.
    println!("\nPareto front ({} configurations):", out.pareto.len());
    let mut entries: Vec<_> = out.pareto.entries().to_vec();
    entries.sort_by(|a, b| {
        a.objectives.latency_ms.partial_cmp(&b.objectives.latency_ms)
            .unwrap()
    });
    for e in &entries {
        println!(
            "  {:>6.1} ms | {:>5.1} GB | {:>5.2} J | acc {:>5.1} | {}",
            e.objectives.latency_ms, e.objectives.memory_gb,
            e.objectives.energy_j, e.objectives.accuracy,
            e.config.signature()
        );
    }

    // 4. The chosen configuration maximizes the Eq.-4 utility under the
    //    scenario's preference weights.
    println!(
        "\nchosen: {}\n  utility {:.3} | efficiency score {:.2}x \
         | accuracy {:.1} (default {:.1})\n  search cost: {} testbed \
         evaluations, {} surrogate predictions",
        out.chosen.signature(),
        utility(&out.chosen_objectives, &out.reference, &scenario.prefs),
        out.chosen_efficiency_score,
        out.chosen_objectives.accuracy,
        out.reference.default.accuracy,
        out.testbed_evals,
        out.surrogate_evals,
    );
}
