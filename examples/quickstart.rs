//! Quickstart: optimize the efficiency configuration of one model for
//! one deployment scenario with the builder-style session API and
//! print the Pareto front, streaming per-iteration progress through a
//! `RunObserver`.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use ae_llm::coordinator::{AeLlm, AeLlmError, AeLlmParams, FnObserver,
                          IterationEvent};
use ae_llm::metrics::utility;

fn main() -> Result<(), AeLlmError> {
    // 1. Describe the deployment: model, task mix, hardware,
    //    preferences.  `for_model` picks the paper's hardware tier for
    //    the model scale (Mistral-7B -> A100-80GB) and the blended task
    //    mix; `.task(..)` / `.platform(..)` / `.prefs(..)` override by
    //    name, with typed errors for unknown names.
    let session = AeLlm::for_model("Mistral-7B")?
        .params(AeLlmParams::small())
        .seed(7);
    let scenario = session.scenario();
    println!(
        "optimizing {} on {} for task {:?}",
        scenario.model.name, scenario.testbed.platform.name,
        scenario.task.name
    );

    // 2. Run AE-LLM (Algorithm 1): surrogate-guided NSGA-II with
    //    hardware-in-the-loop refinement against the scenario's
    //    testbed.  The observer streams one event per refinement
    //    iteration instead of leaving us staring at a silent run.
    let report = session.run_testbed_observed(&mut FnObserver(
        |e: &IterationEvent| {
            println!(
                "  refinement {}/{}: front {}, hypervolume {:.2}, \
                 {} testbed evals",
                e.iteration, e.total_iterations, e.front_size,
                e.hypervolume, e.testbed_evals
            );
        },
    ));
    let out = &report.outcome;

    // 3. Inspect the Pareto front: each entry is a measured trade-off.
    println!("\nPareto front ({} configurations):", out.pareto.len());
    let mut entries: Vec<_> = out.pareto.entries().to_vec();
    entries.sort_by(|a, b| {
        a.objectives.latency_ms.partial_cmp(&b.objectives.latency_ms)
            .unwrap()
    });
    for e in &entries {
        println!(
            "  {:>6.1} ms | {:>5.1} GB | {:>5.2} J | acc {:>5.1} | {}",
            e.objectives.latency_ms, e.objectives.memory_gb,
            e.objectives.energy_j, e.objectives.accuracy,
            e.config.signature()
        );
    }

    // 4. The chosen configuration maximizes the Eq.-4 utility under the
    //    scenario's preference weights.
    println!(
        "\nchosen: {}\n  utility {:.3} | efficiency score {:.2}x \
         | accuracy {:.1} (default {:.1})\n  search cost: {} testbed \
         evaluations, {} surrogate predictions ({:.1}s wall)",
        out.chosen.signature(),
        utility(&out.chosen_objectives, &out.reference, &scenario.prefs),
        out.chosen_efficiency_score,
        out.chosen_objectives.accuracy,
        out.reference.default.accuracy,
        out.testbed_evals,
        out.surrogate_evals,
        report.wall_ms / 1e3,
    );
    Ok(())
}
