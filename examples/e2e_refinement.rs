//! END-TO-END DRIVER: proves all three layers compose on a real
//! workload.
//!
//! 1. Load every AOT artifact (Layer 2 JAX transformer embedding the
//!    Layer 1 Pallas kernels, lowered to HLO text) through the PJRT CPU
//!    client;
//! 2. Measure them: real wall-clock per forward + real numeric fidelity
//!    (quantized vs fp16 logits) per variant family;
//! 3. Run Algorithm 1 with those measurements as the "actual hardware"
//!    evaluations (line 5), i.e. the full hardware-in-the-loop AE-LLM;
//! 4. Deploy the chosen configuration's serving variant and push a
//!    batched request workload through it, reporting latency and
//!    throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_refinement
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use ae_llm::config::Precision;
use ae_llm::coordinator::{AeLlm, AeLlmParams, FnObserver, IterationEvent,
                          Scenario};
use ae_llm::evaluator::{CachingEvaluator, RecordingEvaluator};
use ae_llm::runtime::{self, MeasuredEvaluator, Request, Server};
use ae_llm::util::Rng;

fn main() -> anyhow::Result<()> {
    let t_total = std::time::Instant::now();

    // ---- 1. load artifacts ------------------------------------------------
    let dir = runtime::artifacts_dir();
    let mut engine = runtime::Engine::new(&dir)?;
    println!("[1/4] compiling artifacts on PJRT ({})", engine.platform());
    let names = engine.load_all()?;
    println!("      {} variants compiled", names.len());

    // ---- 2. measure variants ----------------------------------------------
    println!("[2/4] measuring variants (real executions)");
    let table = runtime::measure_all(&mut engine, 1, 5)?;
    for row in table.rows.values() {
        println!(
            "      {:<18} wall {:>8.2} ms  cv {:.3}  fidelity-err {:.4}",
            row.name, row.wall_ms, row.wall_cv, row.fidelity_err
        );
    }
    // Reality checks the paper's premises depend on:
    let fid = |n: &str| table.rows[n].fidelity_err;
    assert!(fid("gqa_int4") > fid("gqa_int8"),
            "int4 must be noisier than int8 (measured!)");
    assert!(fid("gqa_int8") > 0.0);

    // ---- 3. Algorithm 1 against real measurements ---------------------------
    println!("[3/4] Algorithm 1 with PJRT-measured evaluation");
    let scenario = Scenario::for_model("LLaMA-2-7B").unwrap();
    // Decorated evaluator stack: record every measurement (replayable
    // trace) over a memo table (the measured backend is deterministic,
    // so caching repeat configs is lossless) over the PJRT-measured
    // backend, which fans each batch across the thread pool.
    let mut evaluator = RecordingEvaluator::new(CachingEvaluator::new(
        MeasuredEvaluator::new(table, scenario.testbed.clone()),
    ));
    let mut params = AeLlmParams::small();
    params.initial_sample = 150;
    let report = AeLlm::from_scenario(scenario.clone())
        .params(params)
        .seed(42)
        .run_observed(
            &mut evaluator,
            &mut FnObserver(|e: &IterationEvent| {
                println!(
                    "      refinement {}/{}: front {}, hv {:.2}, {} evals",
                    e.iteration, e.total_iterations, e.front_size,
                    e.hypervolume, e.testbed_evals
                );
            }),
        );
    let out = &report.outcome;
    println!(
        "      chosen {} | efficiency score {:.2} | accuracy {:.1} vs \
         default {:.1}\n      {} evaluations ({} unique PJRT-backed \
         measurements, {} cache hits), {} surrogate predictions, trace \
         of {} steps",
        out.chosen.signature(),
        out.chosen_efficiency_score,
        out.chosen_objectives.accuracy,
        out.reference.default.accuracy,
        out.testbed_evals,
        evaluator.inner().misses(),
        evaluator.inner().hits(),
        out.surrogate_evals,
        evaluator.trace().len(),
    );
    assert!(out.chosen_efficiency_score > 1.0,
            "E2E search failed to beat the default configuration");
    assert_eq!(evaluator.trace().len(), out.testbed_evals,
               "the trace must record every evaluation");

    // ---- 4. deploy + serve ---------------------------------------------------
    let serve_variant = match out.chosen.inf.precision {
        Precision::Fp16 | Precision::Fp8 => "serve_gqa_fp16",
        _ => "serve_gqa_int8",
    };
    println!("[4/4] serving batched requests on {serve_variant}");
    engine.load(serve_variant)?;
    let mut server = Server::new(&engine, serve_variant)?;
    let mut req_rng = Rng::new(7);
    let n_requests = 96;
    for id in 0..n_requests {
        let len = 16 + req_rng.below(100);
        let tokens: Vec<i32> =
            (0..len).map(|_| req_rng.below(256) as i32).collect();
        server.submit(Request::new(id, tokens));
    }
    server.drain()?;
    let r = server.report();
    println!(
        "      {} requests in {} batches | p50 {:.1} ms p95 {:.1} ms | \
         {:.1} req/s | {:.0} tok/s",
        r.completed, r.batches, r.p50_latency_ms, r.p95_latency_ms,
        r.throughput_rps, r.tokens_per_s
    );
    assert_eq!(r.completed as u64, n_requests);
    assert!(r.throughput_rps > 0.0);

    println!(
        "\nE2E OK: kernels -> AOT HLO -> PJRT -> Algorithm 1 -> serving \
         ({:.1}s total)",
        t_total.elapsed().as_secs_f64()
    );
    Ok(())
}
