//! Deployment advisor: the paper's Appendix C scenarios end-to-end.
//!
//! Three deployments with very different constraints:
//!   1. Mobile/edge    — LLaMA-2-7B on a 24 GB consumer card, memory-
//!      constrained preferences;
//!   2. Cloud API      — LLaMA-2-70B on the 8xH200 node, accuracy-
//!      critical preferences;
//!   3. Research       — Mistral-7B on A100, latency-critical.
//!
//! For each, AE-LLM produces a configuration card (Appendix C format).
//!
//! ```bash
//! cargo run --release --offline --example deployment_advisor
//! ```

use ae_llm::coordinator::{AeLlm, AeLlmParams, Scenario};
use ae_llm::hardware;
use ae_llm::metrics::Preferences;
use ae_llm::report::tables::scenario_card;

fn main() {
    let scenarios = [
        (
            "Scenario 1: Mobile / edge assistant (memory-constrained)",
            Scenario::for_model("LLaMA-2-7B")
                .unwrap()
                .with_platform(hardware::rtx4090())
                .with_prefs(Preferences::memory_constrained()),
        ),
        (
            "Scenario 2: Cloud API (accuracy-critical)",
            Scenario::for_model("LLaMA-2-70B")
                .unwrap()
                .with_platform(hardware::h200_cluster())
                .with_prefs(Preferences::accuracy_critical()),
        ),
        (
            "Scenario 3: Research iteration (latency-critical)",
            Scenario::for_model("Mistral-7B")
                .unwrap()
                .with_platform(hardware::a100())
                .with_prefs(Preferences::latency_critical()),
        ),
        (
            "Scenario 4: Green AI batch processing (energy-first)",
            Scenario::for_model("Qwen-14B")
                .unwrap()
                .with_prefs(Preferences::green_ai()),
        ),
    ];

    for (i, (title, scenario)) in scenarios.into_iter().enumerate() {
        let out = AeLlm::from_scenario(scenario.clone())
            .params(AeLlmParams::small())
            .seed(100 + i as u64)
            .run_testbed_outcome();
        println!("{}", scenario_card(title, &scenario, &out));

        // The advisor's sanity contract: feasible on the target platform
        // and within the paper's accuracy-preservation band.
        assert!(
            out.chosen_objectives.memory_gb
                <= scenario.testbed.platform.mem_capacity_gb,
            "advisor returned an infeasible configuration"
        );
        let acc_drop = out.reference.default.accuracy
            - out.chosen_objectives.accuracy;
        assert!(acc_drop <= 2.0, "accuracy drop {acc_drop:.2} too large");
    }
    println!("all deployment scenarios solved within constraints");
}
