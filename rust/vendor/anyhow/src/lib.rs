//! Offline shim of the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendors the small
//! subset of the real `anyhow` API that `ae_llm` uses: the type-erased
//! [`Error`], the [`Result`] alias, the blanket `From<E: std::error::Error>`
//! conversion that makes `?` work, and the `anyhow!` / `bail!` / `ensure!`
//! macros.  Semantics match the real crate for this subset; error chains
//! are flattened into the message at conversion time.

use std::fmt;

/// Type-erased error: a message plus (optionally) the flattened source
/// chain of the error it was converted from.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything printable (the real crate's `Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: like the real `anyhow::Error`, this deliberately does NOT
// implement `std::error::Error` — that is what makes the blanket
// conversion below coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the source chain into one message.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("got {x} and {}", 4);
        assert_eq!(e.to_string(), "got 3 and 4");
        let owned: Error = anyhow!(String::from("owned"));
        assert_eq!(owned.to_string(), "owned");
    }

    fn bails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {flag}");
        bail!("always fails")
    }

    #[test]
    fn bail_and_ensure() {
        assert_eq!(bails(true).unwrap_err().to_string(), "flag was true");
        assert_eq!(bails(false).unwrap_err().to_string(), "always fails");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
