//! Stub of the `xla` (PJRT) crate surface that `ae_llm::runtime` uses.
//!
//! The real vendored XLA closure is only present on the measurement
//! image; everywhere else (CI, laptops) this stub keeps the crate
//! compiling and type-checking.  Every entry point that would touch the
//! PJRT backend returns [`Error::BackendUnavailable`], which the runtime
//! layer surfaces as an ordinary `anyhow` error — all runtime tests and
//! benches already skip when `artifacts/manifest.json` is absent, and
//! `PjRtClient::cpu()` failing closes the remaining gap when artifacts
//! exist but the backend does not.
//!
//! The stub types are plain data (no interior mutability, no FFI
//! handles), so they are `Send + Sync`; the parallel serving loop relies
//! on `Engine::forward(&self, ..)` being callable from worker threads,
//! which the real PJRT client also supports (`PjRtLoadedExecutable::
//! Execute` is thread-safe).

use std::fmt;

/// Stub error: the backend is not vendored in this build.
#[derive(Clone, Debug)]
pub enum Error {
    BackendUnavailable(&'static str),
    Io(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BackendUnavailable(what) => write!(
                f,
                "xla stub: {what} requires the vendored XLA/PJRT backend \
                 (not present in this build)"
            ),
            Error::Io(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module proto (stub: retains only the source path).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    /// Parse an HLO text file.  The stub verifies the file exists (so
    /// manifest/path errors still surface early) but cannot parse it.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::metadata(path)
            .map_err(|e| Error::Io(format!("cannot stat {path:?}: {e}")))?;
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// An XLA computation built from a module proto.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    pub path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// PJRT client handle.
#[derive(Clone, Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    /// Create the CPU client.  Always fails in the stub build.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::BackendUnavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::BackendUnavailable("PjRtClient::compile"))
    }
}

/// A compiled executable (never constructible through the stub client).
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs, returning per-device, per-output
    /// buffers.  Unreachable in the stub (no executable can be built).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer holding one execution output.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::BackendUnavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub: shape metadata only, no storage).
#[derive(Clone, Debug)]
pub struct Literal {
    pub dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T>(values: &[T]) -> Literal {
        Literal { dims: vec![values.len() as i64] }
    }

    /// Reshape to the given dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let old: i64 = self.dims.iter().product();
        let new: i64 = dims.iter().product();
        if old != new {
            return Err(Error::Io(format!(
                "reshape element mismatch: {old} vs {new}"
            )));
        }
        Ok(Literal { dims: dims.to_vec() })
    }

    /// Unwrap a 1-tuple output.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::BackendUnavailable("Literal::to_tuple1"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::BackendUnavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PjRtClient::cpu"));
    }

    #[test]
    fn literal_shape_arithmetic_works() {
        let l = Literal::vec1(&[0i32; 12]);
        assert_eq!(l.dims, vec![12]);
        let r = l.reshape(&[3, 4]).unwrap();
        assert_eq!(r.dims, vec![3, 4]);
        assert!(l.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn from_text_file_checks_existence() {
        assert!(HloModuleProto::from_text_file("/no/such/file.hlo").is_err());
    }

    #[test]
    fn stub_types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<PjRtClient>();
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<Literal>();
    }
}
