//! Integration tests: the full search stack (oracle → surrogates →
//! NSGA-II → Algorithm 1) behaving as the paper claims.

use ae_llm::config::{enumerate, validity, Config, Precision};
use ae_llm::coordinator::{AeLlm, AeLlmParams, Outcome, Scenario};
use ae_llm::hardware;
use ae_llm::metrics::{efficiency_score, Preferences, Reference};
use ae_llm::oracle::Testbed;
use ae_llm::report::{run_method, Budget, Method};
use ae_llm::search::Baseline;
use ae_llm::util::prop::{forall, Config as PropConfig};
use ae_llm::util::Rng;

/// Seeded, unobserved Algorithm 1 run against the scenario's testbed
/// (tests/integration_api.rs proves this reproduces the legacy
/// `optimize` + `Rng::new(seed)` path bit for bit).
fn run(scenario: &Scenario, params: &AeLlmParams, seed: u64) -> Outcome {
    AeLlm::from_scenario(scenario.clone())
        .params(*params)
        .seed(seed)
        .run_testbed_outcome()
}

/// Paper §4.2 headline: AE-LLM beats all baselines on efficiency score
/// while staying within the accuracy band — across scales.
#[test]
fn ae_llm_wins_across_scales() {
    let budget = Budget { quick: true };
    for model in ["Phi-2", "LLaMA-2-7B", "Qwen-72B"] {
        let scenario = Scenario::for_model(model).unwrap();
        let mut scores = std::collections::BTreeMap::new();
        for method in Method::paper_order() {
            let r = run_method(method, &scenario, &budget, 11);
            scores.insert(r.method, (r.efficiency_score,
                                     r.objectives.accuracy));
        }
        let (ae, ae_acc) = scores["AdaptiveEfficientLLM"];
        let (def, def_acc) = scores["Default"];
        assert!((def - 1.0).abs() < 1e-9);
        for (name, (score, _)) in &scores {
            if *name != "AdaptiveEfficientLLM" {
                assert!(ae > score - 0.15,
                        "{model}: AE {ae:.2} vs {name} {score:.2}");
            }
        }
        assert!(ae > 1.35, "{model}: AE score only {ae:.2}");
        // §4.2: accuracy within ~1.2% of default
        assert!(def_acc - ae_acc < 2.0,
                "{model}: accuracy drop {:.2}", def_acc - ae_acc);
    }
}

/// §4.2: single-stage optimization captures only part of the gains —
/// cross-stage interactions matter.
#[test]
fn joint_beats_single_stage() {
    let scenario = Scenario::for_model("LLaMA-2-7B").unwrap();
    let budget = Budget { quick: true };
    let single = run_method(Method::Baseline(Baseline::BestSingleStage),
                            &scenario, &budget, 3);
    let joint = run_method(Method::AeLlm, &scenario, &budget, 3);
    assert!(joint.efficiency_score > single.efficiency_score,
            "joint {:.2} <= single {:.2}", joint.efficiency_score,
            single.efficiency_score);
}

/// §5.1 task-dependent patterns: quant-sensitive tasks get gentler
/// quantization than insensitive ones.
#[test]
fn task_adaptive_quantization() {
    let budget = Budget { quick: true };
    let bits_for = |task: &str| -> f64 {
        // average over seeds: chosen precision bits
        let mut bits = Vec::new();
        for seed in 0..3 {
            let scenario = Scenario::for_model("LLaMA-2-7B")
                .unwrap()
                .with_task(task)
                .unwrap();
            let out = run(&scenario, &budget.ae_params(), seed);
            bits.push(out.chosen.inf.precision.bits() as f64);
        }
        ae_llm::util::stats::mean(&bits)
    };
    let gsm = bits_for("GSM8K"); // quant sensitivity 0.9
    let hella = bits_for("HellaSwag"); // 0.25
    assert!(gsm >= hella,
            "GSM8K got fewer bits ({gsm}) than HellaSwag ({hella})");
}

/// §5.1 hardware-dependent patterns: memory-constrained platforms get
/// aggressive quantization.
#[test]
fn hardware_adaptive_quantization() {
    let budget = Budget { quick: true };
    // 70B on RTX-4090 (24 GB): must quantize to fit at all.
    let scenario = Scenario::for_model("LLaMA-2-70B")
        .unwrap()
        .with_platform(hardware::rtx4090())
        .with_prefs(Preferences::memory_constrained());
    let out = run(&scenario, &budget.ae_params(), 5);
    // 70B fp16 = 138 GB; even int4 (~35GB) misses 24 GB. The search must
    // not return anything infeasible-but-archived: chosen is just the
    // best feasible... in this extreme case only the default fallback
    // remains; accept either an error-free run with low memory or the
    // default fallback.
    assert!(validity::is_valid(&out.chosen));

    // 7B on RTX-4090 with memory prefs: low-bit weights chosen.
    let scenario = Scenario::for_model("LLaMA-2-7B")
        .unwrap()
        .with_platform(hardware::rtx4090())
        .with_prefs(Preferences::memory_constrained());
    let out = run(&scenario, &budget.ae_params(), 6);
    assert!(out.chosen.inf.precision.bits() <= 8,
            "expected low-bit weights, got {:?}", out.chosen.inf.precision);
}

/// The Pareto archive returned by Algorithm 1 is mutually non-dominated
/// and spans a real trade-off range.
#[test]
fn pareto_front_properties() {
    let scenario = Scenario::for_model("Mistral-7B").unwrap();
    let out = run(&scenario, &AeLlmParams::small(), 8);
    let entries = out.pareto.entries();
    assert!(entries.len() >= 3);
    for a in entries {
        for b in entries {
            assert!(!a.objectives.dominates(&b.objectives)
                    || a.config == b.config);
        }
        assert!(validity::is_valid(&a.config));
    }
}

/// Efficiency-score sanity across the whole zoo: the default config
/// always scores 1.0 and random configs never dominate it by 10x.
#[test]
fn efficiency_score_bounded_over_zoo() {
    let mut rng = Rng::new(9);
    for m in ae_llm::models::zoo() {
        let tb = Testbed::noiseless(hardware::tier_for_scale(m.scale));
        let t = ae_llm::tasks::blended_task();
        let reference = Reference {
            default: tb.true_objectives(&Config::default_baseline(), &m, &t),
        };
        assert!((efficiency_score(&reference.default, &reference) - 1.0)
            .abs() < 1e-9);
        for _ in 0..50 {
            let c = enumerate::sample(&mut rng);
            let es = efficiency_score(&tb.true_objectives(&c, &m, &t),
                                      &reference);
            assert!((0.0..10.0).contains(&es), "{}: es={es}", m.name);
        }
    }
}

/// Property: the surrogate-guided search never returns a structurally
/// invalid or platform-infeasible configuration, for any seed.
#[test]
fn chosen_configs_always_valid_property() {
    forall(
        PropConfig::default().cases(5),
        |rng| rng.next_u64(),
        |&seed| {
            let scenario = Scenario::for_model("LLaMA-2-7B").unwrap();
            let mut p = AeLlmParams::small();
            p.initial_sample = 60; // keep the property fast
            let out = run(&scenario, &p, seed);
            if !validity::is_valid(&out.chosen) {
                return Err(format!("invalid chosen {}", out.chosen));
            }
            if out.chosen_objectives.memory_gb
                > scenario.testbed.platform.mem_capacity_gb
            {
                return Err(format!(
                    "infeasible chosen: {} GB",
                    out.chosen_objectives.memory_gb
                ));
            }
            Ok(())
        },
    );
}

/// Green-AI preferences steer towards low-energy configurations.
#[test]
fn preference_steering() {
    let budget = Budget { quick: true };
    let run_with = |prefs: Preferences, seed: u64| {
        let scenario = Scenario::for_model("LLaMA-2-7B")
            .unwrap()
            .with_prefs(prefs);
        run(&scenario, &budget.ae_params(), seed)
    };
    let green = run_with(Preferences::green_ai(), 1);
    let accuracy = run_with(Preferences::accuracy_critical(), 1);
    assert!(green.chosen_objectives.energy_j
            <= accuracy.chosen_objectives.energy_j + 1e-9);
    assert!(accuracy.chosen_objectives.accuracy
            >= green.chosen_objectives.accuracy - 0.3);
}
