//! Integration test: the complete hardware-in-the-loop pipeline —
//! Algorithm 1 driven by real PJRT measurements through the
//! `Evaluator` trait, then deployment.  Mirrors
//! examples/e2e_refinement.rs at a reduced budget.

use ae_llm::coordinator::{AeLlm, AeLlmParams, Scenario};
use ae_llm::evaluator::{CachingEvaluator, Evaluator};
use ae_llm::runtime::{self, MeasuredEvaluator};

#[test]
fn hardware_in_the_loop_algorithm1() {
    let dir = runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut engine = runtime::Engine::new(&dir).unwrap();
    engine.load_all().unwrap();
    let table = runtime::measure_all(&mut engine, 1, 3).unwrap();

    let scenario = Scenario::for_model("LLaMA-2-7B").unwrap();
    let mut evaluator =
        MeasuredEvaluator::new(table.clone(), scenario.testbed.clone());
    let mut params = AeLlmParams::small();
    params.initial_sample = 150;
    let report = AeLlm::from_scenario(scenario.clone())
        .params(params)
        .seed(42)
        .run(&mut evaluator);
    let out = &report.outcome;
    // the search consumed real measurements
    assert!(evaluator.calls() >= 150);
    assert_eq!(out.testbed_evals, evaluator.calls());
    assert_eq!(report.evaluator_evals, evaluator.calls());
    // and produced a beneficial, deployable configuration
    assert!(out.chosen_efficiency_score > 1.0,
            "es={}", out.chosen_efficiency_score);
    assert!(out.reference.default.accuracy - out.chosen_objectives.accuracy
            < 2.5);
    // the chosen config maps onto an artifact we can actually serve
    let variant = runtime::MeasurementTable::variant_for(&out.chosen);
    assert!(engine.manifest.get(&variant).is_some(),
            "chosen config has no artifact: {variant}");

    // A cached run over the same deterministic backend reproduces the
    // outcome while measuring each distinct configuration only once.
    let mut cached = CachingEvaluator::new(MeasuredEvaluator::new(
        table, scenario.testbed.clone()));
    let report2 = AeLlm::from_scenario(scenario)
        .params(params)
        .seed(42)
        .run(&mut cached);
    assert_eq!(report2.outcome.chosen, out.chosen);
    assert_eq!(report2.outcome.testbed_evals, out.testbed_evals);
    // The coordinator mostly avoids repeats by construction, so cache
    // hits are not guaranteed for every seed — assert the accounting
    // invariant instead: every request is either a hit or a real
    // measurement on the inner backend.
    assert_eq!(cached.evals(),
               Evaluator::evals(cached.inner()) + cached.hits());
    assert_eq!(cached.evals(), report2.outcome.testbed_evals);
}
