//! Integration test: the complete hardware-in-the-loop pipeline —
//! Algorithm 1 driven by real PJRT measurements, then deployment.
//! Mirrors examples/e2e_refinement.rs at a reduced budget.

use ae_llm::config::Config;
use ae_llm::coordinator::{optimize_with, AeLlmParams, Scenario};
use ae_llm::runtime::{self, MeasuredEvaluator};
use ae_llm::util::Rng;

#[test]
fn hardware_in_the_loop_algorithm1() {
    let dir = runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut engine = runtime::Engine::new(&dir).unwrap();
    engine.load_all().unwrap();
    let table = runtime::measure_all(&mut engine, 1, 3).unwrap();

    let scenario = Scenario::for_model("LLaMA-2-7B").unwrap();
    let evaluator = MeasuredEvaluator::new(table, scenario.testbed.clone());
    let mut params = AeLlmParams::small();
    params.initial_sample = 150;
    let mut rng = Rng::new(42);
    let out = optimize_with(
        &scenario,
        &params,
        &mut |cs: &[Config], _r: &mut Rng| {
            cs.iter()
                .map(|c| {
                    evaluator.objectives(c, &scenario.model, &scenario.task)
                })
                .collect()
        },
        &mut rng,
    );
    // the search consumed real measurements
    assert!(evaluator.calls.get() >= 150);
    assert_eq!(out.testbed_evals, evaluator.calls.get());
    // and produced a beneficial, deployable configuration
    assert!(out.chosen_efficiency_score > 1.0,
            "es={}", out.chosen_efficiency_score);
    assert!(out.reference.default.accuracy - out.chosen_objectives.accuracy
            < 2.5);
    // the chosen config maps onto an artifact we can actually serve
    let variant = runtime::MeasurementTable::variant_for(&out.chosen);
    assert!(engine.manifest.get(&variant).is_some(),
            "chosen config has no artifact: {variant}");
}
