//! Conformance tests for the pluggable search-strategy layer
//! (DESIGN.md §10): every strategy is seed-reproducible, respects its
//! evaluation budget exactly (asserted through the evaluator's own
//! `Evaluator::evals` counter), runs end-to-end through the builder,
//! and the Table-2 baselines ride the same seam.

use ae_llm::config::{validity, Config};
use ae_llm::coordinator::{optimize_with_observer, optimize_with_strategy,
                          AeLlm, AeLlmParams, NullObserver, Outcome,
                          Scenario};
use ae_llm::evaluator::Evaluator;
use ae_llm::search::{Baseline, BaselineStrategy, StrategyKind};
use ae_llm::util::pool::Parallelism;
use ae_llm::util::Rng;

fn scenario() -> Scenario {
    Scenario::for_model("LLaMA-2-7B").unwrap()
}

fn small(kind: StrategyKind) -> AeLlmParams {
    AeLlmParams { strategy: kind, ..AeLlmParams::small() }
}

type Fingerprint = (Config, String, Vec<(Config, String)>, usize, usize);

fn fingerprint(out: &Outcome) -> Fingerprint {
    (
        out.chosen,
        format!("{:?}", out.chosen_objectives),
        out.pareto
            .entries()
            .iter()
            .map(|e| (e.config, format!("{:?}", e.objectives)))
            .collect(),
        out.testbed_evals,
        out.surrogate_evals,
    )
}

fn run(s: &Scenario, p: &AeLlmParams, seed: u64) -> (Outcome, usize) {
    let mut evaluator = s.testbed.clone();
    let mut rng = Rng::new(seed);
    let out = optimize_with_observer(s, p, &mut evaluator,
                                     &mut NullObserver, &mut rng);
    (out, Evaluator::evals(&evaluator))
}

/// Same seed → bit-identical archive, chosen config and eval counts,
/// for every built-in strategy; and the seed must actually reach the
/// search (verified on the cheap, warm-start-free strategies, whose
/// runs are pure functions of the seeded sampling/noise streams).
#[test]
fn every_strategy_is_seed_reproducible() {
    let s = scenario();
    for kind in StrategyKind::ALL {
        let p = small(kind);
        let (a, _) = run(&s, &p, 9);
        let (b, _) = run(&s, &p, 9);
        assert_eq!(fingerprint(&a), fingerprint(&b),
                   "{} not seed-reproducible", kind.name());
        assert_eq!(a.strategy, kind.name());
    }
    for kind in [StrategyKind::Random, StrategyKind::Racing] {
        let p = small(kind);
        let (a, _) = run(&s, &p, 9);
        let (c, _) = run(&s, &p, 10);
        assert_ne!(fingerprint(&a), fingerprint(&c),
                   "{} ignores its seed", kind.name());
    }
}

/// Strategies are parallelism-invariant end to end (the PR-1
/// determinism contract survives the extraction for the new
/// strategies too).
#[test]
fn every_strategy_is_parallelism_invariant() {
    let s = scenario();
    for kind in StrategyKind::ALL {
        let go = |par: Parallelism| {
            let p = AeLlmParams { parallelism: par, ..small(kind) };
            let (out, _) = run(&s, &p, 31);
            fingerprint(&out)
        };
        assert_eq!(go(Parallelism::Sequential), go(Parallelism::Threads(4)),
                   "{} diverges under parallelism", kind.name());
    }
}

/// Random search: `rounds × k` proposals + the Default fallback, no
/// warm-start (the strategy declines surrogates), nothing mid-round.
#[test]
fn random_strategy_budget_is_exact() {
    let s = scenario();
    let p = small(StrategyKind::Random);
    let (out, evaluator_evals) = run(&s, &p, 5);
    let rounds = p.refine_iters.max(1);
    let k = p.evals_per_iter;
    let expected = rounds * k + 1;
    assert_eq!(out.testbed_evals, expected);
    assert_eq!(evaluator_evals, expected,
               "evaluator counter disagrees with the outcome");
    assert_eq!(out.strategy_evals, 0);
    assert_eq!(out.surrogate_evals, 0, "random must not warm-start");
}

/// Successive-halving racing: per round, 4k rung-0 samples + 2·(2k)
/// rung-1 samples mid-round, then k promotions measured by the
/// coordinator — exactly `R·9k + 1` backend evaluations.
#[test]
fn racing_strategy_budget_is_exact() {
    let s = scenario();
    let p = small(StrategyKind::Racing);
    let (out, evaluator_evals) = run(&s, &p, 5);
    let rounds = p.refine_iters.max(1);
    let k = p.evals_per_iter;
    assert_eq!(out.strategy_evals, rounds * 8 * k,
               "rung samples: 4k + 2*2k per round");
    assert_eq!(out.testbed_evals, rounds * 9 * k + 1);
    assert_eq!(evaluator_evals, out.testbed_evals);
    assert_eq!(out.surrogate_evals, 0, "racing must not warm-start");
}

/// Surrogate-guided local search: warm-start + at most `rounds × k`
/// confirmations + the fallback; all exploration is surrogate-side.
#[test]
fn local_strategy_budget_is_bounded_and_surrogate_driven() {
    let s = scenario();
    let p = small(StrategyKind::Local);
    let (out, evaluator_evals) = run(&s, &p, 5);
    let rounds = p.refine_iters.max(1);
    let k = p.evals_per_iter;
    assert_eq!(out.strategy_evals, 0,
               "local search must only measure through the coordinator");
    assert!(out.testbed_evals >= p.initial_sample + 1);
    assert!(out.testbed_evals <= p.initial_sample + rounds * k + 1,
            "local evals {} exceed bound", out.testbed_evals);
    assert_eq!(evaluator_evals, out.testbed_evals);
    assert!(out.surrogate_evals > 0,
            "the climb must consult the surrogates");
    assert!(validity::is_valid(&out.chosen));
}

/// The two new strategies must actually search: end to end via the
/// builder they produce a non-trivial front, a feasible chosen config,
/// and a v2 report carrying their name.
#[test]
fn racing_and_local_run_end_to_end_via_builder() {
    for kind in [StrategyKind::Racing, StrategyKind::Local] {
        let report = AeLlm::for_model("Phi-2")
            .unwrap()
            .quick()
            .strategy(kind)
            .seed(3)
            .run_testbed();
        assert_eq!(report.strategy, kind.name());
        assert_eq!(report.outcome.strategy, kind.name());
        assert!(report.outcome.pareto.len() >= 2,
                "{}: front of {}", kind.name(),
                report.outcome.pareto.len());
        assert!(validity::is_valid(&report.outcome.chosen));
        let text = report.to_json().dump();
        assert!(text.contains("ae-llm.run-report/v2"), "{text}");
        assert!(text.contains(&format!("\"strategy\": \"{}\"",
                                       kind.name()))
                    || text.contains(&format!("\"strategy\":\"{}\"",
                                              kind.name())),
                "strategy name missing from JSON");
        // one iteration event per strategy round
        assert_eq!(report.iterations.len(),
                   report.iterations.last().unwrap().total_iterations);
    }
}

/// Informed strategies should not lose to blind random sampling at
/// equal-ish budgets (averaged over seeds to damp noise); this is the
/// seam's reason to exist.
#[test]
fn informed_strategies_beat_or_match_random() {
    let s = scenario().noiseless();
    let mean_score = |kind: StrategyKind| -> f64 {
        (0..3)
            .map(|seed| run(&s, &small(kind), 40 + seed).0
                .chosen_efficiency_score)
            .sum::<f64>()
            / 3.0
    };
    let random = mean_score(StrategyKind::Random);
    for kind in [StrategyKind::Nsga2, StrategyKind::Racing,
                 StrategyKind::Local] {
        let score = mean_score(kind);
        assert!(score >= random - 0.25,
                "{} scored {score:.2} vs random {random:.2}", kind.name());
    }
}

/// The Table-2 baselines ride the strategy seam: one round, one
/// proposal; rule-based selectors never touch the backend mid-round,
/// selector baselines report their measurements through
/// `Evaluator::evals`.
#[test]
fn baselines_run_as_degenerate_strategies() {
    let s = scenario();
    let p = AeLlmParams::small();
    for (baseline, zero_eval) in [
        (Baseline::Default, true),
        (Baseline::ManualSelection, true),
        (Baseline::EfficientLlmRec, true),
        (Baseline::BestSingleStage, false),
        (Baseline::RandomSearch { budget: 50 }, false),
    ] {
        let mut strategy = BaselineStrategy(baseline);
        let mut evaluator = s.testbed.clone();
        let mut rng = Rng::new(7);
        let out = optimize_with_strategy(&s, &p, &mut strategy,
                                         &mut evaluator, &mut NullObserver,
                                         &mut rng);
        assert_eq!(out.strategy, baseline.name());
        if zero_eval {
            assert_eq!(out.strategy_evals, 0,
                       "{} measured mid-round", baseline.name());
            // one proposal + the Default fallback, nothing else
            assert_eq!(out.testbed_evals, 2);
        } else {
            assert!(out.strategy_evals > 0,
                    "{} reported no evals", baseline.name());
            assert_eq!(out.testbed_evals, out.strategy_evals + 2);
        }
        assert_eq!(Evaluator::evals(&evaluator), out.testbed_evals);
        assert!(validity::is_valid(&out.chosen));
        assert_eq!(out.surrogate_evals, 0);
    }
}
