//! Integration tests: PJRT runtime against the real AOT artifacts,
//! including cross-layer golden numerics (rust execution must reproduce
//! the python/JAX logits bit-for-bit-ish).
//!
//! All tests skip gracefully when `make artifacts` hasn't run.

use ae_llm::runtime::{self, Engine};
use ae_llm::util::json::Json;

fn engine() -> Option<Engine> {
    let dir = runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::new(&dir).unwrap())
}

/// The deterministic token pattern shared with aot.py's golden writer.
fn golden_tokens(batch: usize, seq: usize, vocab: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        for i in 0..seq {
            out.push(((i * 7 + 3) % vocab) as i32);
        }
    }
    out
}

#[test]
fn golden_numerics_match_python() {
    let Some(mut e) = engine() else { return };
    let goldens_path = runtime::artifacts_dir().join("goldens.json");
    if !goldens_path.exists() {
        eprintln!("skipping: goldens.json not built");
        return;
    }
    let goldens =
        Json::parse(&std::fs::read_to_string(&goldens_path).unwrap())
            .unwrap();
    for name in ["gqa_fp16", "gqa_int8", "mla_int4"] {
        let Some(g) = goldens.get(name) else { continue };
        e.load(name).unwrap();
        let v = e.manifest.get(name).unwrap();
        let tokens = golden_tokens(v.batch as usize, v.seq as usize,
                                   v.config.vocab as usize);
        let fwd = e.forward(name, &tokens).unwrap();
        let expected: Vec<f64> = g
            .get("first32")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        for (i, (got, want)) in
            fwd.logits.iter().zip(&expected).enumerate()
        {
            assert!(
                (*got as f64 - want).abs() < 1e-4,
                "{name}[{i}]: rust {got} vs python {want}"
            );
        }
        let mean_abs: f64 = fwd.logits.iter()
            .map(|x| x.abs() as f64).sum::<f64>()
            / fwd.logits.len() as f64;
        let want_mean = g.req_f64("mean_abs").unwrap();
        assert!(
            (mean_abs - want_mean).abs() / want_mean < 1e-3,
            "{name}: mean |logit| {mean_abs} vs python {want_mean}"
        );
    }
}

#[test]
fn measured_fidelity_ordering_is_real() {
    let Some(mut e) = engine() else { return };
    // Only load the gqa family to keep this test quick.
    for name in ["gqa_fp16", "gqa_int8", "gqa_int4"] {
        e.load(name).unwrap();
    }
    let tokens = e.make_tokens("gqa_fp16", 3).unwrap();
    let base = e.forward("gqa_fp16", &tokens).unwrap().logits;
    let err_of = |logits: &[f32]| -> f64 {
        logits
            .iter()
            .zip(&base)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / logits.len() as f64
    };
    let e8 = err_of(&e.forward("gqa_int8", &tokens).unwrap().logits);
    let e4 = err_of(&e.forward("gqa_int4", &tokens).unwrap().logits);
    assert!(e8 > 0.0, "int8 identical to fp16?");
    assert!(e4 > 2.0 * e8, "no int8->int4 cliff: {e8} vs {e4}");
}

#[test]
fn attention_variants_differ_but_agree_roughly() {
    let Some(mut e) = engine() else { return };
    for name in ["mha_fp16", "gqa_fp16", "mqa_fp16"] {
        e.load(name).unwrap();
    }
    let tokens = e.make_tokens("mha_fp16", 4).unwrap();
    let mha = e.forward("mha_fp16", &tokens).unwrap().logits;
    let gqa = e.forward("gqa_fp16", &tokens).unwrap().logits;
    // different architectures (even with the same seed the shapes of
    // the projections differ): outputs must differ
    let diff: f32 =
        mha.iter().zip(&gqa).map(|(a, b)| (a - b).abs()).sum::<f32>();
    assert!(diff > 1.0);
    // but both are sane logit distributions
    for logits in [&mha, &gqa] {
        let mean_abs: f32 = logits.iter().map(|x| x.abs()).sum::<f32>()
            / logits.len() as f32;
        assert!(mean_abs > 0.01 && mean_abs < 10.0);
    }
}

#[test]
fn measurement_table_end_to_end() {
    let Some(mut e) = engine() else { return };
    e.load_all().unwrap();
    let table = runtime::measure_all(&mut e, 0, 2).unwrap();
    assert!(table.rows.len() >= 12);
    for row in table.rows.values() {
        assert!(row.wall_ms > 0.0, "{}: zero wall", row.name);
        if row.baseline == row.name {
            assert_eq!(row.fidelity_err, 0.0);
        }
    }
    // int8 variants must carry positive fidelity error
    assert!(table.rows["gqa_int8"].fidelity_err > 0.0);
    // the measured evaluator composes with the oracle
    let tb = ae_llm::oracle::Testbed::noiseless(ae_llm::hardware::a100());
    let eval = runtime::MeasuredEvaluator::new(table, tb);
    let m = ae_llm::models::by_name("LLaMA-2-7B").unwrap();
    let t = ae_llm::tasks::blended_task();
    let mut c = ae_llm::config::Config::default_baseline();
    let o16 = eval.objectives(&c, &m, &t);
    c.inf.precision = ae_llm::config::Precision::Int8;
    let o8 = eval.objectives(&c, &m, &t);
    assert!(o8.accuracy < o16.accuracy, "measured penalty missing");
    assert!(o8.memory_gb < o16.memory_gb);
    assert_eq!(eval.calls(), 2);
}

#[test]
fn serving_latency_scales_with_batches() {
    let Some(mut e) = engine() else { return };
    e.load("serve_gqa_int8").unwrap();
    let run = |n: usize| -> ae_llm::runtime::ServeReport {
        let mut s = runtime::Server::new(&e, "serve_gqa_int8").unwrap();
        for id in 0..n as u64 {
            s.submit(runtime::Request::new(id, vec![1; 64]));
        }
        s.drain().unwrap();
        s.report()
    };
    let small = run(8);
    let large = run(32);
    assert_eq!(small.batches, 1);
    assert_eq!(large.batches, 4);
    // queueing means later requests wait: p95 grows with queue depth
    assert!(large.p95_latency_ms > small.p95_latency_ms * 1.5,
            "p95 {} vs {}", large.p95_latency_ms, small.p95_latency_ms);
}
