//! Integration tests for the backend-generic serving subsystem
//! (DESIGN.md §11): artifact-free determinism, the dynamic batcher's
//! ordering contract, Pareto-front deployments and the adaptive-vs-
//! static comparison the serving table reports.
//!
//! Everything here runs on `SimulatedBackend` + `VirtualClock`: no XLA
//! artifacts, no wall-clock sensitivity — CI executes all of it.

use ae_llm::coordinator::AeLlm;
use ae_llm::runtime::workload::default_rate_rps;
use ae_llm::runtime::{Deployment, SloClass, Workload, WorkloadKind};
use ae_llm::search::archive::Entry;
use ae_llm::util::Parallelism;

/// One quick search + deployment, shared shape for the tests below.
fn quick_deployment(seed: u64)
                    -> (AeLlm, ae_llm::coordinator::Outcome, Deployment) {
    let session = AeLlm::for_model("Phi-2").unwrap().quick().seed(seed);
    let outcome = session.run_testbed_outcome();
    let deployment = session.deploy(&outcome).unwrap();
    (session, outcome, deployment)
}

#[test]
fn same_seed_serving_is_bit_identical_at_any_parallelism() {
    // The full artifact-free pipeline: search -> deploy -> workload ->
    // serve.  Same seed must produce byte-identical JSON whether the
    // batches execute sequentially or on 4 workers — and across two
    // independent end-to-end runs.
    let run = |par: Parallelism| {
        let (_session, outcome, deployment) = quick_deployment(9);
        let rate =
            default_rate_rps(outcome.reference.default.latency_ms);
        let requests =
            Workload::new(WorkloadKind::Bursty, rate, 300, 9).generate();
        deployment.serve(&requests, "bursty", 9, par).to_json().dump()
    };
    let a = run(Parallelism::Sequential);
    let b = run(Parallelism::Threads(4));
    let c = run(Parallelism::Sequential);
    assert_eq!(a, b, "parallelism changed the serve report");
    assert_eq!(a, c, "same seed produced different serve reports");
    assert!(a.contains("\"schema\":\"ae-llm.deploy-report/v1\""), "{a}");
}

#[test]
fn dynamic_batches_preserve_submission_order() {
    // Per slot, the completion log must follow submission order even
    // though the dynamic batcher forms variable-size batches and the
    // lane model reorders nothing.
    let (_, outcome, deployment) = quick_deployment(5);
    let rate = default_rate_rps(outcome.reference.default.latency_ms);
    let requests =
        Workload::new(WorkloadKind::HeavyTail, rate, 400, 5).generate();
    let report = deployment.serve(&requests, "heavytail", 5,
                                  Parallelism::Threads(4));
    assert_eq!(report.overall.completed, 400);

    // Reconstruct each slot's submission stream and check the batch
    // indices/ids the per-class servers logged are that stream.
    for (label, class) in [("interactive", SloClass::Interactive),
                           ("batch", SloClass::Batch),
                           ("long-context", SloClass::LongContext)] {
        let submitted: Vec<u64> = requests
            .iter()
            .filter(|r| r.slo == class)
            .map(|r| r.id)
            .collect();
        let rep = report
            .per_slot
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, r)| r)
            .unwrap();
        assert_eq!(rep.completed, submitted.len(), "{label}");
    }
    // overall merge keeps every id exactly once
    let mut ids: Vec<u64> = requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..400).collect::<Vec<_>>());

    // And at the server level: a single slot fed the raw stream logs
    // completions in exactly submission order, with a deadline small
    // enough that the batcher genuinely forms variable-size batches.
    use ae_llm::config::Config;
    use ae_llm::runtime::{Server, SimulatedBackend};
    let m = ae_llm::models::by_name("Phi-2").unwrap();
    let t = ae_llm::tasks::blended_task();
    let backend = SimulatedBackend::for_config(
        "sim", &Config::default_baseline(), &m, &t,
        &ae_llm::hardware::a100(), 8, 2048, 5);
    let mut server = Server::simulated(backend, "sim")
        .unwrap()
        .with_max_delay_ms(10.0)
        .with_parallelism(Parallelism::Threads(4));
    for r in &requests {
        server.submit(r.clone());
    }
    server.drain().unwrap();
    let logged: Vec<u64> =
        server.completions().iter().map(|c| c.id).collect();
    let submitted: Vec<u64> = requests.iter().map(|r| r.id).collect();
    assert_eq!(logged, submitted, "completion log reordered");
    let sizes: Vec<usize> = {
        let mut per_batch = std::collections::BTreeMap::new();
        for c in server.completions() {
            *per_batch.entry(c.batch_index).or_insert(0usize) += 1;
        }
        per_batch.values().copied().collect()
    };
    assert!(sizes.iter().any(|&s| s < 8),
            "deadline trigger never formed a partial batch: {sizes:?}");
    // batch indices are non-decreasing along the log (contiguous runs)
    let idxs: Vec<usize> =
        server.completions().iter().map(|c| c.batch_index).collect();
    assert!(idxs.windows(2).all(|w| w[1] >= w[0]), "batch indices \
            not in submission order");
}

#[test]
fn from_front_assigns_every_slot_a_front_config() {
    let (_, outcome, deployment) = quick_deployment(3);
    assert_eq!(deployment.slots().len(), 3);
    let front_sigs: Vec<String> = outcome
        .pareto
        .entries()
        .iter()
        .map(|e| e.config.signature())
        .collect();
    for slot in deployment.slots() {
        assert!(front_sigs.contains(&slot.config.signature()),
                "slot {} config {} not on the front",
                slot.class.name(), slot.config.signature());
    }
    // class shapes provision what each class needs
    let seq_of = |c: SloClass| {
        deployment.slots().iter().find(|s| s.class == c).unwrap().seq
    };
    assert!(seq_of(SloClass::LongContext) > seq_of(SloClass::Batch));
    assert!(seq_of(SloClass::Batch) > seq_of(SloClass::Interactive));
}

#[test]
fn adaptive_routing_beats_best_static_on_slo_violations() {
    // The acceptance bar for `table --id 8`: the fleet must beat the
    // *best* static single configuration on SLO-violation rate in at
    // least 3 of the 4 workload scenarios.
    let (session, outcome, deployment) = quick_deployment(7);
    let policy = session.slo_policy();
    let scenario = session.scenario();
    let rate = default_rate_rps(outcome.reference.default.latency_ms);

    let mut candidates: Vec<Entry> = deployment
        .slots()
        .iter()
        .map(|s| Entry { config: s.config, objectives: s.objectives })
        .collect();
    candidates.push(Entry { config: outcome.chosen,
                            objectives: outcome.chosen_objectives });

    let mut wins = 0;
    // The stationary scenarios: the drifting ones belong to the
    // adaptation controller's comparison (integration_adapt.rs).
    for (i, kind) in WorkloadKind::STATIONARY.into_iter().enumerate() {
        let requests =
            Workload::new(kind, rate, 400, 7 ^ ((i as u64 + 1) << 32))
                .generate();
        let adaptive = deployment
            .serve(&requests, kind.name(), 7, Parallelism::Auto)
            .overall
            .slo_violation_rate;
        let best_static = candidates
            .iter()
            .map(|e| {
                Deployment::static_single(
                    e, &policy, &scenario.model, &scenario.task,
                    &scenario.testbed.platform)
                    .serve(&requests, kind.name(), 7, Parallelism::Auto)
                    .overall
                    .slo_violation_rate
            })
            .fold(f64::INFINITY, f64::min);
        if adaptive < best_static {
            wins += 1;
        }
        // the static floor: every long-context prompt overflows the
        // static 512-token shape, so violations can't reach zero
        assert!(best_static > 0.0,
                "{}: static unexpectedly violation-free", kind.name());
    }
    assert!(wins >= 3, "adaptive won only {wins}/4 scenarios");
}
