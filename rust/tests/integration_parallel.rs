//! Integration tests of the parallel-evaluation subsystem's determinism
//! contract: a seeded run must produce a bit-identical Pareto front at
//! every `Parallelism` level, because evolutionary operators own the
//! RNG on the calling thread and evaluation fans out through the
//! pool's ordered reduce.

use ae_llm::config::Config;
use ae_llm::coordinator::{AeLlm, AeLlmParams, Scenario};
use ae_llm::oracle::{Objectives, Testbed};
use ae_llm::search::nsga2::{self, Nsga2Params, Toggles};
use ae_llm::util::pool::Parallelism;
use ae_llm::util::prop::{forall, Config as PropConfig};
use ae_llm::util::Rng;

/// Property: for random seeds, NSGA-II returns the *same archive, in
/// the same order*, at Parallelism = 1, 4 and 8.
#[test]
fn nsga2_front_identical_at_parallelism_1_4_8() {
    let tb = Testbed::noiseless(ae_llm::hardware::a100());
    let m = ae_llm::models::by_name("LLaMA-2-7B").unwrap();
    let t = ae_llm::tasks::blended_task();

    let front = |seed: u64, threads: usize| -> Vec<(Config, Objectives)> {
        let params = Nsga2Params {
            population: 24,
            generations: 6,
            parallelism: Parallelism::Threads(threads),
            ..Nsga2Params::default()
        };
        let evaluate = |c: &Config| tb.true_objectives(c, &m, &t);
        let mut rng = Rng::new(seed);
        let res = nsga2::run_par(
            &params,
            &Toggles::default(),
            &evaluate,
            |c| tb.feasible(c, &m, &t),
            &mut rng,
        );
        res.archive
            .entries()
            .iter()
            .map(|e| (e.config, e.objectives))
            .collect()
    };

    forall(
        PropConfig::default().cases(5),
        |rng| rng.next_u64(),
        |&seed| {
            let f1 = front(seed, 1);
            let f4 = front(seed, 4);
            let f8 = front(seed, 8);
            if f1 != f4 {
                return Err(format!(
                    "seed {seed}: front differs between 1 and 4 threads \
                     ({} vs {} entries)",
                    f1.len(),
                    f4.len()
                ));
            }
            if f4 != f8 {
                return Err(format!(
                    "seed {seed}: front differs between 4 and 8 threads"
                ));
            }
            Ok(())
        },
    );
}

/// The full coordinator (surrogates + refinement + measurement batches)
/// is parallelism-invariant end to end.
#[test]
fn algorithm1_chosen_config_invariant_under_parallelism() {
    let scenario = Scenario::for_model("LLaMA-2-7B").unwrap();
    let go = |par: Parallelism| {
        let params = AeLlmParams {
            initial_sample: 80,
            refine_iters: 1,
            evals_per_iter: 6,
            nsga: Nsga2Params { population: 24, generations: 5,
                                ..Nsga2Params::default() },
            parallelism: par,
            ..AeLlmParams::small()
        };
        let out = AeLlm::from_scenario(scenario.clone())
            .params(params)
            .seed(7)
            .run_testbed_outcome();
        (out.chosen, out.testbed_evals, out.surrogate_evals)
    };
    let seq = go(Parallelism::Sequential);
    let par4 = go(Parallelism::Threads(4));
    let par8 = go(Parallelism::Threads(8));
    assert_eq!(seq, par4);
    assert_eq!(par4, par8);
}
