//! Integration tests of the parallel-evaluation subsystem's determinism
//! contract: a seeded run must produce a bit-identical Pareto front at
//! every `Parallelism` level, because evolutionary operators own the
//! RNG on the calling thread and evaluation fans out through the
//! pool's ordered reduce.

use ae_llm::config::{enumerate, Config};
use ae_llm::coordinator::{AeLlm, AeLlmParams, Scenario};
use ae_llm::oracle::{Objectives, Testbed};
use ae_llm::search::nsga2::{self, Nsga2Params, Toggles};
use ae_llm::search::ParetoArchive;
use ae_llm::util::pool::Parallelism;
use ae_llm::util::prop::{forall, Config as PropConfig};
use ae_llm::util::Rng;

/// Property: for random seeds, NSGA-II returns the *same archive, in
/// the same order*, at Parallelism = 1, 4 and 8.
#[test]
fn nsga2_front_identical_at_parallelism_1_4_8() {
    let tb = Testbed::noiseless(ae_llm::hardware::a100());
    let m = ae_llm::models::by_name("LLaMA-2-7B").unwrap();
    let t = ae_llm::tasks::blended_task();

    let front = |seed: u64, threads: usize| -> Vec<(Config, Objectives)> {
        let params = Nsga2Params {
            population: 24,
            generations: 6,
            parallelism: Parallelism::Threads(threads),
            ..Nsga2Params::default()
        };
        let evaluate = |c: &Config| tb.true_objectives(c, &m, &t);
        let mut rng = Rng::new(seed);
        let res = nsga2::run_par(
            &params,
            &Toggles::default(),
            &evaluate,
            |c| tb.feasible(c, &m, &t),
            &mut rng,
        );
        res.archive
            .entries()
            .iter()
            .map(|e| (e.config, e.objectives))
            .collect()
    };

    forall(
        PropConfig::default().cases(5),
        |rng| rng.next_u64(),
        |&seed| {
            let f1 = front(seed, 1);
            let f4 = front(seed, 4);
            let f8 = front(seed, 8);
            if f1 != f4 {
                return Err(format!(
                    "seed {seed}: front differs between 1 and 4 threads \
                     ({} vs {} entries)",
                    f1.len(),
                    f4.len()
                ));
            }
            if f4 != f8 {
                return Err(format!(
                    "seed {seed}: front differs between 4 and 8 threads"
                ));
            }
            Ok(())
        },
    );
}

/// Property: `ParetoArchive::insert_batch` is *exactly* sequential
/// per-item insertion — same surviving entries (configs and objective
/// bits, in order) and the same per-item acceptance booleans — over
/// randomized config/objective streams, at Parallelism 1, 4 and 8.
/// Streams mix tight/roomy capacities and heavy config duplication so
/// both the parallel pre-filter and its sequential fallbacks are hit.
#[test]
fn insert_batch_equals_sequential_insert_property() {
    #[derive(Debug)]
    struct Stream {
        capacity: usize,
        items: Vec<(Config, Objectives)>,
    }

    forall(
        PropConfig::default().cases(12),
        |rng| {
            let capacity = *rng.pick(&[6usize, 24, 2048]);
            let n = 40 + rng.below(120);
            // Duplication regime: draw configs from a small pool so
            // collisions (the objective-refresh path) are common.
            let dup = rng.chance(0.5);
            let pool: Vec<Config> =
                (0..12).map(|_| enumerate::sample(rng)).collect();
            let items: Vec<(Config, Objectives)> = (0..n)
                .map(|_| {
                    let c = if dup {
                        *rng.pick(&pool)
                    } else {
                        enumerate::sample(rng)
                    };
                    let o = Objectives {
                        accuracy: 40.0 + 50.0 * rng.f64(),
                        latency_ms: 5.0 + 80.0 * rng.f64(),
                        memory_gb: 1.0 + 12.0 * rng.f64(),
                        energy_j: 0.05 + 2.0 * rng.f64(),
                    };
                    (c, o)
                })
                .collect();
            Stream { capacity, items }
        },
        |stream| {
            let key = |a: &ParetoArchive| -> Vec<(Config, String)> {
                a.entries()
                    .iter()
                    .map(|e| (e.config, format!("{:?}", e.objectives)))
                    .collect()
            };
            let mut seq = ParetoArchive::new(stream.capacity);
            let accepts_seq: Vec<bool> = stream
                .items
                .iter()
                .map(|(c, o)| seq.insert(*c, *o))
                .collect();
            for threads in [1usize, 4, 8] {
                let mut bat = ParetoArchive::new(stream.capacity);
                let accepts_bat = bat.insert_batch(
                    &stream.items, Parallelism::Threads(threads));
                if accepts_bat != accepts_seq {
                    return Err(format!(
                        "acceptance booleans diverged at {threads} \
                         threads, capacity {}",
                        stream.capacity
                    ));
                }
                if key(&bat) != key(&seq) {
                    return Err(format!(
                        "surviving entries diverged at {threads} threads, \
                         capacity {} ({} vs {} entries)",
                        stream.capacity,
                        bat.len(),
                        seq.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The full coordinator (surrogates + refinement + measurement batches)
/// is parallelism-invariant end to end.
#[test]
fn algorithm1_chosen_config_invariant_under_parallelism() {
    let scenario = Scenario::for_model("LLaMA-2-7B").unwrap();
    let go = |par: Parallelism| {
        let params = AeLlmParams {
            initial_sample: 80,
            refine_iters: 1,
            evals_per_iter: 6,
            nsga: Nsga2Params { population: 24, generations: 5,
                                ..Nsga2Params::default() },
            parallelism: par,
            ..AeLlmParams::small()
        };
        let out = AeLlm::from_scenario(scenario.clone())
            .params(params)
            .seed(7)
            .run_testbed_outcome();
        (out.chosen, out.testbed_evals, out.surrogate_evals)
    };
    let seq = go(Parallelism::Sequential);
    let par4 = go(Parallelism::Threads(4));
    let par8 = go(Parallelism::Threads(8));
    assert_eq!(seq, par4);
    assert_eq!(par4, par8);
}
