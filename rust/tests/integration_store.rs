//! Integration tests for the content-addressed artifact store
//! (DESIGN.md §14): byte-identical blob round-trips, single-bit
//! corruption detection, gc safety, and the headline contract — an
//! `adapt` warm-started from a catalog hit reproduces the in-memory
//! warm-start byte-for-byte, sequentially and on 4 workers.
//!
//! Everything runs on the simulated stack (virtual time, no
//! artifacts), so CI executes all of it.

use std::path::{Path, PathBuf};

use ae_llm::coordinator::{AdaptParams, AeLlm};
use ae_llm::runtime::WorkloadKind;
use ae_llm::store::{BlobKind, Store, StoreError};
use ae_llm::util::{Parallelism, Rng};

fn session(model: &str, seed: u64, par: Parallelism) -> AeLlm {
    let params = ae_llm::coordinator::AeLlmParams {
        parallelism: par,
        ..ae_llm::coordinator::AeLlmParams::small()
    };
    AeLlm::for_model(model).unwrap().params(params).seed(seed)
}

fn tmp_root(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("ae-llm-store-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// On-disk address of a blob — the layout contract from DESIGN.md §14
/// (`objects/<first two hex>/<remaining 62>`).
fn blob_path(root: &Path, hash: &str) -> PathBuf {
    root.join("objects").join(&hash[..2]).join(&hash[2..])
}

#[test]
fn front_and_run_report_blobs_round_trip_byte_identically() {
    let root = tmp_root("roundtrip");
    let mut store = Store::open(&root).unwrap();
    for seed in [7u64, 42] {
        let s = session("Phi-2", seed, Parallelism::Auto);
        let report = s.run_testbed();
        let key = s.store_key("-");
        let front_bytes =
            report.outcome.pareto.to_json().dump().into_bytes();
        let report_bytes = report.to_json().dump().into_bytes();

        let fh =
            store.put_front(&key, seed, &report.outcome.pareto).unwrap();
        let rh = store.put_run_report(&key, &report).unwrap();
        assert_eq!(store.blobs().get(&fh).unwrap(), front_bytes,
                   "front blob bytes (seed {seed})");
        assert_eq!(store.blobs().get(&rh).unwrap(), report_bytes,
                   "run-report blob bytes (seed {seed})");

        // parsed round trip restores the front verbatim
        let loaded = store.load_front(&fh).unwrap();
        assert_eq!(loaded.to_json().dump().into_bytes(), front_bytes);

        // content addressing: re-putting identical bytes dedups to
        // the same address
        let again =
            store.put_front(&key, seed, &report.outcome.pareto).unwrap();
        assert_eq!(again, fh);
    }
    assert!(store.verify().unwrap().ok());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn any_single_bit_flip_is_detected_on_load() {
    let root = tmp_root("bitflip");
    let mut store = Store::open(&root).unwrap();
    let s = session("Phi-2", 7, Parallelism::Auto);
    let outcome = s.run_testbed_outcome();
    let fh = store.put_front(&s.store_key("-"), 7, &outcome.pareto)
        .unwrap();

    let path = blob_path(&root, &fh);
    let clean = std::fs::read(&path).unwrap();
    // flip single bits at a spread of byte positions, first and last
    // included
    let positions =
        [0, clean.len() / 3, clean.len() / 2, clean.len() - 1];
    for &pos in &positions {
        for bit in [0u8, 3, 7] {
            let mut evil = clean.clone();
            evil[pos] ^= 1 << bit;
            std::fs::write(&path, &evil).unwrap();
            match store.load_front(&fh) {
                Err(StoreError::Corrupt { hash, .. }) => {
                    assert_eq!(hash, fh);
                }
                other => panic!(
                    "bit {bit} of byte {pos}: expected Corrupt, got \
                     {other:?}"
                ),
            }
            // verify() reports the problem instead of erroring out
            let vr = store.verify().unwrap();
            assert!(!vr.ok(), "verify missed a flip at byte {pos}");
        }
    }
    // restoring the original bytes heals the store
    std::fs::write(&path, &clean).unwrap();
    assert!(store.verify().unwrap().ok());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn gc_never_collects_a_manifest_referenced_blob() {
    let root = tmp_root("gc");
    let mut store = Store::open(&root).unwrap();
    let s = session("Phi-2", 7, Parallelism::Auto);
    let outcome = s.run_testbed_outcome();
    let fh = store.put_front(&s.store_key("-"), 7, &outcome.pareto)
        .unwrap();
    // an orphan blob, written directly past the catalog
    let orphan = store.blobs().put(b"{\"schema\":\"junk/v0\"}").unwrap();

    let gcr = store.gc().unwrap();
    assert_eq!(gcr.removed, vec![orphan.clone()]);
    assert_eq!(gcr.kept, 1);
    assert!(store.blobs().contains(&fh));
    assert!(!store.blobs().contains(&orphan));
    // the referenced front still loads byte-perfect after the sweep
    assert_eq!(store.load_front(&fh).unwrap().to_json().dump(),
               outcome.pareto.to_json().dump());
    // and a second sweep finds nothing to do
    assert!(store.gc().unwrap().removed.is_empty());
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn catalog_warm_adapt_matches_in_memory_warm_start_byte_for_byte() {
    // The ISSUE's acceptance bar: `adapt` warm-started from a catalog
    // hit must reproduce the in-memory warm-start byte-for-byte, at
    // Parallelism 1 and 4 — persistence must never perturb a result.
    let kind = WorkloadKind::RegimeShift;
    let params = AdaptParams {
        epochs: 3,
        requests_per_epoch: 120,
        ..AdaptParams::default()
    };
    let run = |tag: &str, par: Parallelism| -> (String, String) {
        let root = tmp_root(tag);
        // seed the catalog: an earlier run's front under the same
        // (model, task, platform, scenario) coordinates
        {
            let mut store = Store::open(&root).unwrap();
            let prev = session("Phi-2", 7, par);
            let front = prev.run_testbed_outcome().pareto;
            store.put_front(&prev.store_key(kind.name()), 7, &front)
                .unwrap();
        }
        let s = session("Phi-2", 11, par);
        // reference: the same warm-start wholly in memory, from the
        // identical catalog state
        let reference = {
            let store = Store::open(&root).unwrap();
            let warm = store.warm_entries(&s.store_key(kind.name()), 11)
                .unwrap();
            assert!(!warm.is_empty(), "expected a catalog hit");
            let outcome = s.run_testbed_outcome_warm(&warm);
            ae_llm::coordinator::run_adapt_from(&s, 11, kind, &params,
                                                &outcome)
                .unwrap()
                .to_json()
                .dump()
        };
        // the store-driven path
        let mut store = Store::open(&root).unwrap();
        let report = s.adapt_stored(kind, &params, &mut store).unwrap();
        // the catalog's newest front is the run's final front, verbatim
        let newest = store
            .ls()
            .iter()
            .filter(|e| e.kind == BlobKind::Front)
            .last()
            .unwrap();
        assert_eq!(store.load_front(&newest.hash).unwrap()
                       .to_json().dump(),
                   report.final_front.to_json().dump(),
                   "catalog tail must equal the report's final front");
        let stored = report.to_json().dump();
        let _ = std::fs::remove_dir_all(&root);
        (reference, stored)
    };

    let (ref_seq, stored_seq) = run("warm-seq", Parallelism::Sequential);
    assert_eq!(stored_seq, ref_seq,
               "catalog warm-start diverged from in-memory (sequential)");
    let (ref_par, stored_par) = run("warm-par4", Parallelism::Threads(4));
    assert_eq!(stored_par, ref_par,
               "catalog warm-start diverged from in-memory (4 workers)");
    assert_eq!(stored_seq, stored_par,
               "parallelism changed the stored-warm adapt report");
}

#[test]
fn stored_fronts_seed_cross_model_transfer() {
    use ae_llm::surrogate::transfer::transfer_fit;
    use ae_llm::surrogate::GbtParams;

    let root = tmp_root("transfer");
    let mut store = Store::open(&root).unwrap();
    // source: a Phi-2 front in the catalog
    let src = session("Phi-2", 7, Parallelism::Auto);
    let front = src.run_testbed_outcome().pareto;
    store.put_front(&src.store_key("-"), 7, &front).unwrap();

    // target: a different model sees the Phi-2 front as a corpus
    let tgt = session("LLaMA-2-7B", 11, Parallelism::Auto);
    let corpus = store
        .source_corpus(&tgt.store_key("-"))
        .unwrap()
        .expect("cross-model catalog hit");
    assert_eq!(corpus.model.name, "Phi-2");
    assert_eq!(corpus.evaluations.len(), front.len());

    // and it actually trains a transfer surrogate from stored data
    let sc = tgt.scenario();
    let (_set, n_evals) = transfer_fit(&corpus, &sc.testbed, &sc.model,
                                       &sc.task, 8, GbtParams::fast(),
                                       &mut Rng::new(3));
    assert_eq!(n_evals, 8,
               "transfer spends only the requested fresh evaluations");

    // the source model's own query must not see itself as a corpus
    assert!(store.source_corpus(&src.store_key("-")).unwrap().is_none());
    let _ = std::fs::remove_dir_all(&root);
}
