//! Integration tests for the continual-adaptation controller
//! (DESIGN.md §12): determinism of the full closed loop, the
//! continual-vs-one-shot comparison on drifting workloads, quiescence
//! on stationary traffic, and the warm-start search seam.
//!
//! Everything runs on the simulated stack (virtual time, no
//! artifacts), so CI executes all of it.

use ae_llm::coordinator::{optimize_with_observer, optimize_with_observer_warm,
                          AdaptParams, AeLlm, NullObserver};
use ae_llm::runtime::WorkloadKind;
use ae_llm::util::{Parallelism, Rng};

fn session(seed: u64, par: Parallelism) -> AeLlm {
    let params = ae_llm::coordinator::AeLlmParams {
        parallelism: par,
        ..ae_llm::coordinator::AeLlmParams::small()
    };
    AeLlm::for_model("Phi-2").unwrap().params(params).seed(seed)
}

#[test]
fn same_seed_adapt_is_bit_identical_at_any_parallelism() {
    // The whole closed loop — search, epoch serving, drift decisions,
    // warm re-search, hot swap — must serialize byte-identically for
    // the same seed, sequentially or on 4 workers, across independent
    // runs.
    let run = |par: Parallelism| {
        let params = AdaptParams {
            epochs: 4,
            requests_per_epoch: 150,
            ..AdaptParams::default()
        };
        session(11, par)
            .adapt(WorkloadKind::RegimeShift, &params)
            .unwrap()
            .to_json()
            .dump()
    };
    let a = run(Parallelism::Sequential);
    let b = run(Parallelism::Threads(4));
    let c = run(Parallelism::Sequential);
    assert_eq!(a, b, "parallelism changed the adapt report");
    assert_eq!(a, c, "same seed produced different adapt reports");
    assert!(a.contains("\"schema\":\"ae-llm.adapt-report/v1\""), "{a}");
    // the persistent front rides inside, under its own schema
    assert!(a.contains("\"schema\":\"ae-llm.front/v1\""), "{a}");
}

#[test]
fn event_core_adapt_matches_polled_loop_byte_for_byte() {
    // The tentpole's golden-report gate (DESIGN.md §13): driving the
    // controller's epoch loop off the event heap must reproduce the
    // PR 5 index-sliced polled loop *byte-for-byte* — same-seed
    // adapt reports identical before vs after the refactor, on both a
    // drifting and a stationary scenario.
    let s = session(11, Parallelism::Auto);
    let outcome = s.run_testbed_outcome();
    for kind in [WorkloadKind::RegimeShift, WorkloadKind::Steady] {
        let params = AdaptParams {
            epochs: 4,
            requests_per_epoch: 150,
            ..AdaptParams::default()
        };
        let event = ae_llm::coordinator::run_adapt_from(
            &s, 11, kind, &params, &outcome)
            .unwrap();
        let polled = ae_llm::coordinator::controller::run_adapt_from_polled(
            &s, 11, kind, &params, &outcome)
            .unwrap();
        assert_eq!(event.to_json().dump(), polled.to_json().dump(),
                   "event-core adapt diverged from the polled loop on {}",
                   kind.name());
    }
}

#[test]
fn continual_beats_one_shot_on_drifting_workloads() {
    // The acceptance bar for `table --id 9`: on both drifting
    // scenarios the adaptive controller must strictly beat the
    // one-shot deployment on SLO-violation rate.  No strawman: the
    // one-shot baseline gets the *same* initial search, the same
    // epoch-0 deployment and the same epoch-0 lane plan — it just
    // never re-searches or re-deploys.
    let s = session(7, Parallelism::Auto);
    // one search shared by every comparison below — the one-shot runs
    // start from literally the same epoch-0 front
    let outcome = s.run_testbed_outcome();
    for kind in WorkloadKind::DRIFTING {
        let params = AdaptParams {
            epochs: 6,
            requests_per_epoch: 250,
            ..AdaptParams::default()
        };
        let continual = s.adapt_from(&outcome, kind, &params).unwrap();
        let one_shot =
            s.adapt_from(&outcome, kind, &params.one_shot()).unwrap();

        assert_eq!(one_shot.redeployments, 0);
        assert_eq!(one_shot.searches, 1);
        assert!(continual.redeployments >= 1,
                "{}: drift never triggered a redeployment", kind.name());
        assert!(continual.searches > 1);

        // both served everything
        let n = params.epochs * params.requests_per_epoch;
        assert_eq!(continual.overall.completed, n, "{}", kind.name());
        assert_eq!(one_shot.overall.completed, n, "{}", kind.name());

        // the structural margin: the hot regime's documents overflow
        // the never-re-provisioned 2048 shape, so the one-shot fleet
        // must truncate (= violate); the controller re-provisions
        let adaptive_rate = continual.overall.slo_violation_rate;
        let static_rate = one_shot.overall.slo_violation_rate;
        assert!(static_rate > 0.10,
                "{}: one-shot unexpectedly healthy ({static_rate:.3})",
                kind.name());
        assert!(adaptive_rate < static_rate,
                "{}: continual {adaptive_rate:.3} did not beat one-shot \
                 {static_rate:.3}", kind.name());
        assert!(one_shot.overall.truncated > continual.overall.truncated,
                "{}: truncation margin missing ({} vs {})", kind.name(),
                one_shot.overall.truncated, continual.overall.truncated);

        // until the first redeployment the two runs are the same
        // system serving the same traffic
        let first_swap = continual
            .epochs
            .iter()
            .position(|e| e.redeployed)
            .expect("at least one redeploy");
        for (c, o) in continual.epochs[..=first_swap]
            .iter()
            .zip(&one_shot.epochs)
        {
            assert_eq!(c.report.slo_violations, o.report.slo_violations,
                       "{}: pre-swap epochs diverged", kind.name());
        }
    }
}

#[test]
fn unchanged_workload_triggers_no_drift_and_no_redeploys() {
    // Acceptance criterion (c): a stationary workload must sail
    // through with zero drift signals and zero re-deployments — the
    // controller's quiescence guarantee.
    let params = AdaptParams {
        epochs: 5,
        requests_per_epoch: 400,
        ..AdaptParams::default()
    };
    let report = session(13, Parallelism::Auto)
        .adapt(WorkloadKind::Steady, &params)
        .unwrap();
    assert_eq!(report.searches, 1);
    assert_eq!(report.redeployments, 0);
    for e in &report.epochs {
        assert!(!e.drifted, "epoch {} drifted (score {:.3})", e.epoch,
                e.drift_score);
        assert!(!e.redeployed);
        // sampling noise must stay well inside the immediate-fire band
        assert!(e.drift_score < 2.0 * params.drift_threshold,
                "epoch {} score {:.3} near the firing band", e.epoch,
                e.drift_score);
    }
    // and the one-shot twin is the same system end to end
    let one_shot = session(13, Parallelism::Auto)
        .adapt(WorkloadKind::Steady, &params.one_shot())
        .unwrap();
    assert_eq!(report.overall.slo_violations,
               one_shot.overall.slo_violations);
    assert_eq!(report.overall.completed, one_shot.overall.completed);
}

#[test]
fn warm_started_search_is_cold_identical_when_front_is_empty() {
    // The warm entry point with no warm entries must be byte-for-byte
    // the cold run — the seam cannot disturb the PR-1/2/3 determinism
    // contracts.
    let scenario = ae_llm::coordinator::Scenario::for_model("Phi-2")
        .unwrap();
    let params = ae_llm::coordinator::AeLlmParams::small();
    let cold = {
        let mut evaluator = scenario.testbed.clone();
        let mut rng = Rng::new(5);
        optimize_with_observer(&scenario, &params, &mut evaluator,
                               &mut NullObserver, &mut rng)
    };
    let warm_empty = {
        let mut evaluator = scenario.testbed.clone();
        let mut rng = Rng::new(5);
        optimize_with_observer_warm(&scenario, &params, &[],
                                    &mut evaluator, &mut NullObserver,
                                    &mut rng)
    };
    assert_eq!(cold.chosen, warm_empty.chosen);
    assert_eq!(cold.testbed_evals, warm_empty.testbed_evals);
    assert_eq!(cold.surrogate_evals, warm_empty.surrogate_evals);
    let key = |o: &ae_llm::coordinator::Outcome| {
        let mut front: Vec<String> = o
            .pareto
            .entries()
            .iter()
            .map(|e| format!("{} {:?}", e.config.signature(),
                             e.objectives))
            .collect();
        front.sort();
        front
    };
    assert_eq!(key(&cold), key(&warm_empty));
}

#[test]
fn warm_started_search_reuses_the_prior_front_at_no_extra_cost() {
    let scenario = ae_llm::coordinator::Scenario::for_model("Phi-2")
        .unwrap();
    let params = ae_llm::coordinator::AeLlmParams::small();
    let first = {
        let mut evaluator = scenario.testbed.clone();
        let mut rng = Rng::new(5);
        optimize_with_observer(&scenario, &params, &mut evaluator,
                               &mut NullObserver, &mut rng)
    };
    let warm: Vec<_> = first.pareto.entries().to_vec();
    assert!(!warm.is_empty() && warm.len() < params.initial_sample);
    let second = {
        let mut evaluator = scenario.testbed.clone();
        let mut rng = Rng::new(6);
        optimize_with_observer_warm(&scenario, &params, &warm,
                                    &mut evaluator, &mut NullObserver,
                                    &mut rng)
    };
    // the warm measurements replace part of the random initial sample:
    // a warm run fits the same budget ceiling as a cold one
    // (initial_sample + R*k + the Default fallback)
    let ceiling = params.initial_sample
        + params.refine_iters * params.evals_per_iter
        + 1;
    assert!(second.testbed_evals <= ceiling,
            "warm start exceeded the cold budget: {} > {ceiling}",
            second.testbed_evals);
    assert!(second.testbed_evals >= params.initial_sample,
            "warm start under-sampled: {}", second.testbed_evals);
    assert!(!second.pareto.is_empty());
}
