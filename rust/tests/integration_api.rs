//! API-equivalence conformance: the `Evaluator`-trait path — which
//! since PR 3 routes NSGA-II through the `SearchStrategy` seam — must
//! reproduce the legacy closure entry points *bit for bit* — same
//! chosen configuration, same Pareto front (configs and measured
//! objectives, in order), same testbed/surrogate eval counts — at
//! every `Parallelism` level.  This is the contract that lets
//! `optimize` / `optimize_with` survive as thin deprecated shims (now
//! reachable only at `coordinator::algorithm1::`, off the crate-root
//! surface), and that proves the NSGA-II extraction changed nothing.

use ae_llm::config::Config;
use ae_llm::coordinator::{optimize_with_observer, AeLlm, AeLlmParams,
                          CollectingObserver, NullObserver, Outcome,
                          Scenario};
use ae_llm::evaluator::{Evaluator, FnEvaluator};
use ae_llm::oracle::Objectives;
use ae_llm::util::pool::Parallelism;
use ae_llm::util::Rng;

const SEED: u64 = 7;

fn scenario() -> Scenario {
    Scenario::for_model("LLaMA-2-7B").unwrap()
}

fn params(par: Parallelism) -> AeLlmParams {
    AeLlmParams { parallelism: par, ..AeLlmParams::small() }
}

/// Everything that must match, in a comparable shape.
type Fingerprint = (Config, String, Vec<(Config, String)>, usize, usize);

fn fingerprint(out: &Outcome) -> Fingerprint {
    (
        out.chosen,
        format!("{:?}", out.chosen_objectives),
        out.pareto
            .entries()
            .iter()
            .map(|e| (e.config, format!("{:?}", e.objectives)))
            .collect(),
        out.testbed_evals,
        out.surrogate_evals,
    )
}

/// The legacy closure entry point, exactly as pre-trait callers used it
/// (kept reachable at its defining path for these bit-identity tests;
/// the crate-root re-export is gone).
#[allow(deprecated)]
fn legacy_optimize(s: &Scenario, p: &AeLlmParams) -> Outcome {
    let mut rng = Rng::new(SEED);
    ae_llm::coordinator::algorithm1::optimize(s, p, &mut rng)
}

/// The legacy `optimize_with` closure convention.
#[allow(deprecated)]
fn legacy_optimize_with(s: &Scenario, p: &AeLlmParams) -> Outcome {
    let testbed = s.testbed.clone();
    let (model, task, par) = (s.model.clone(), s.task.clone(), p.parallelism);
    let mut measure = |cs: &[Config], rng: &mut Rng| -> Vec<Objectives> {
        testbed.measure_batch(cs, &model, &task, rng, par)
    };
    let mut rng = Rng::new(SEED);
    ae_llm::coordinator::algorithm1::optimize_with(s, p, &mut measure,
                                                   &mut rng)
}

/// The trait path: the scenario's testbed used directly as an
/// `Evaluator` through the primary entry point.
fn trait_path(s: &Scenario, p: &AeLlmParams) -> (Outcome, usize) {
    let mut evaluator = s.testbed.clone();
    let mut rng = Rng::new(SEED);
    let out = optimize_with_observer(s, p, &mut evaluator,
                                     &mut NullObserver, &mut rng);
    (out, Evaluator::evals(&evaluator))
}

#[test]
fn trait_path_reproduces_legacy_optimize_bitwise() {
    let s = scenario();
    for par in [Parallelism::Sequential, Parallelism::Threads(4)] {
        let p = params(par);
        let legacy = fingerprint(&legacy_optimize(&s, &p));
        let (out, evals) = trait_path(&s, &p);
        assert_eq!(fingerprint(&out), legacy,
                   "trait path diverged from optimize() at {par:?}");
        assert_eq!(evals, out.testbed_evals,
                   "evaluator's own counter disagrees at {par:?}");
    }
}

#[test]
fn trait_path_reproduces_legacy_optimize_with_bitwise() {
    let s = scenario();
    for par in [Parallelism::Sequential, Parallelism::Threads(4)] {
        let p = params(par);
        let legacy = fingerprint(&legacy_optimize_with(&s, &p));
        let (out, _) = trait_path(&s, &p);
        assert_eq!(fingerprint(&out), legacy,
                   "trait path diverged from optimize_with() at {par:?}");
    }
}

#[test]
fn fn_evaluator_adapter_matches_closure_shim() {
    // Wrapping the same closure in `FnEvaluator` and calling the
    // primary entry point is the documented migration for
    // `optimize_with` callers; it must change nothing.
    let s = scenario();
    let p = params(Parallelism::Sequential);
    let legacy = fingerprint(&legacy_optimize_with(&s, &p));

    let testbed = s.testbed.clone();
    let (model, task, par) = (s.model.clone(), s.task.clone(), p.parallelism);
    let mut evaluator = FnEvaluator::new(move |cs: &[Config], rng: &mut Rng| {
        testbed.measure_batch(cs, &model, &task, rng, par)
    });
    let mut rng = Rng::new(SEED);
    let out = optimize_with_observer(&s, &p, &mut evaluator,
                                     &mut NullObserver, &mut rng);
    assert_eq!(fingerprint(&out), legacy);
    assert_eq!(evaluator.evals(), out.testbed_evals);
}

#[test]
fn builder_run_matches_primary_entry_point() {
    let s = scenario();
    let p = params(Parallelism::Sequential);
    let (direct, _) = trait_path(&s, &p);
    let report = AeLlm::from_scenario(s)
        .params(p)
        .seed(SEED)
        .run_testbed();
    assert_eq!(fingerprint(&report.outcome), fingerprint(&direct));
    assert_eq!(report.evaluator_evals, direct.testbed_evals);
    assert_eq!(report.seed, SEED);
    assert_eq!(report.strategy, "nsga2");
}

#[test]
fn explicit_nsga2_strategy_matches_legacy_bitwise() {
    // Selecting NSGA-II through the strategy seam — by kind on the
    // builder, or as an injected `SearchStrategy` instance — must be
    // the same bits as the pre-refactor coordinator at Parallelism
    // 1 and 4.
    use ae_llm::coordinator::optimize_with_strategy;
    use ae_llm::search::{Nsga2Strategy, StrategyKind};

    let s = scenario();
    for par in [Parallelism::Sequential, Parallelism::Threads(4)] {
        let p = params(par);
        let legacy = fingerprint(&legacy_optimize(&s, &p));

        let report = AeLlm::from_scenario(s.clone())
            .params(p)
            .strategy(StrategyKind::Nsga2)
            .seed(SEED)
            .run_testbed();
        assert_eq!(fingerprint(&report.outcome), legacy,
                   "builder .strategy(Nsga2) diverged at {par:?}");

        let mut evaluator = s.testbed.clone();
        let mut strategy = Nsga2Strategy;
        let mut rng = Rng::new(SEED);
        let out = optimize_with_strategy(
            &s, &p, &mut strategy, &mut evaluator,
            &mut NullObserver, &mut rng,
        );
        assert_eq!(fingerprint(&out), legacy,
                   "injected Nsga2Strategy diverged at {par:?}");
    }
}

#[test]
fn observed_conformance_run_is_bit_identical() {
    // Attaching an observer must not perturb the search (the events
    // are computed without touching the run's RNG).
    let s = scenario();
    let p = params(Parallelism::Threads(4));
    let (unobserved, _) = trait_path(&s, &p);
    let mut evaluator = s.testbed.clone();
    let mut obs = CollectingObserver::default();
    let mut rng = Rng::new(SEED);
    let observed = optimize_with_observer(&s, &p, &mut evaluator,
                                          &mut obs, &mut rng);
    assert_eq!(fingerprint(&observed), fingerprint(&unobserved));
    assert_eq!(obs.events.len(), p.refine_iters);
}
