//! Modified NSGA-II (paper §3.3.2).
//!
//! Differences from vanilla NSGA-II, per the paper:
//! * **constraint-aware initialization** (Eq. 6) — the initial
//!   population is filtered through the *predicted* memory/power
//!   feasibility check before any expensive evaluation;
//! * **hierarchical crossover** (Eq. 7) — per-stage recombination;
//! * **stage-specific mutation rates** (Eq. 8);
//! * **diversity preservation** via crowding distance;
//! * a **Pareto archive** across generations.
//!
//! The algorithm is generic over the objective function so it runs
//! identically against surrogate predictions (phase 2) and against the
//! testbed directly (ablation "- Predictive Models").

use crate::config::{enumerate, Config};
use crate::oracle::Objectives;
use crate::search::archive::ParetoArchive;
use crate::search::dominance::{self, MinVec};
use crate::search::operators;
use crate::util::pool::{self, Parallelism};
use crate::util::Rng;

/// Search hyper-parameters (defaults = paper Table 5).
#[derive(Clone, Copy, Debug)]
pub struct Nsga2Params {
    pub population: usize,
    pub generations: usize,
    pub crossover_rate: f64,
    pub tournament_size: usize,
    pub archive_capacity: usize,
    /// Max rejection-sampling attempts per feasible-initialization slot
    /// (Eq. 6); falls back to unconstrained samples after that.
    pub init_attempts: usize,
    /// Worker count for population evaluation fan-out (honored by
    /// [`run_par`]; [`run`] takes a `FnMut` evaluator and is inherently
    /// sequential).  Evolutionary operators always run on the calling
    /// thread with the caller's RNG, so the search trajectory — and the
    /// Pareto front — is bit-identical at every parallelism level.
    pub parallelism: Parallelism,
}

impl Default for Nsga2Params {
    fn default() -> Self {
        Nsga2Params {
            population: 100,
            generations: 50,
            crossover_rate: 0.9,
            tournament_size: 3,
            archive_capacity: 64,
            init_attempts: 50,
            parallelism: Parallelism::Auto,
        }
    }
}

impl Nsga2Params {
    /// Reduced setting for unit tests / smoke runs.
    pub fn small() -> Self {
        Nsga2Params { population: 32, generations: 12, ..Default::default() }
    }
}

/// Ablation toggles (Table 3 "Search Algorithm Components").
#[derive(Clone, Copy, Debug)]
pub struct Toggles {
    /// Eq. 6 feasibility filtering of the initial population.
    pub constraint_init: bool,
    /// Eq. 7 hierarchical crossover; off = no crossover (mutation only).
    pub hierarchical_crossover: bool,
}

impl Default for Toggles {
    fn default() -> Self {
        Toggles { constraint_init: true, hierarchical_crossover: true }
    }
}

/// Result of one NSGA-II run.
pub struct SearchResult {
    pub archive: ParetoArchive,
    pub evaluations: usize,
    pub generations_run: usize,
}

/// How a population batch gets its objective values.
///
/// The search core is written against this trait so the same loop body
/// serves both the sequential `FnMut` path ([`run`], used by the
/// direct-measurement ablation whose evaluator threads an RNG) and the
/// thread-pool path ([`run_par`], used wherever the evaluator is a pure
/// `Fn + Sync` such as surrogate prediction).
pub trait PopulationEval {
    fn evaluate(&mut self, configs: &[Config]) -> Vec<Objectives>;
}

/// Sequential adapter: any `FnMut(&Config) -> Objectives`.
pub struct SequentialEval<E>(pub E);

impl<E: FnMut(&Config) -> Objectives> PopulationEval for SequentialEval<E> {
    fn evaluate(&mut self, configs: &[Config]) -> Vec<Objectives> {
        configs.iter().map(&mut self.0).collect()
    }
}

/// Thread-pool adapter: fans a batch across workers and merges results
/// in submission order (see [`crate::util::pool`]).
pub struct ParallelEval<'f, E> {
    pub f: &'f E,
    pub par: Parallelism,
}

impl<E: Fn(&Config) -> Objectives + Sync> PopulationEval
    for ParallelEval<'_, E>
{
    fn evaluate(&mut self, configs: &[Config]) -> Vec<Objectives> {
        pool::parallel_map(self.par, configs, self.f)
    }
}

/// Run the modified NSGA-II.
///
/// * `evaluate` — objective oracle (surrogate predictions in the real
///   pipeline); called once per new individual.
/// * `feasible` — predicted Definition-3 feasibility (Eq. 6) used for
///   initialization and as a death penalty during evolution.
///
/// This entry point accepts a stateful `FnMut` evaluator and therefore
/// evaluates on the calling thread; use [`run_par`] to fan evaluation
/// across cores.  Both produce identical results for a pure evaluator.
pub fn run<E, F>(
    params: &Nsga2Params,
    toggles: &Toggles,
    evaluate: E,
    feasible: F,
    rng: &mut Rng,
) -> SearchResult
where
    E: FnMut(&Config) -> Objectives,
    F: Fn(&Config) -> bool,
{
    run_core(params, toggles, &mut SequentialEval(evaluate), &feasible, rng)
}

/// Run the modified NSGA-II with population evaluation fanned out over
/// `params.parallelism` workers.
///
/// The evaluator must be a pure function of the configuration; the
/// ordered reduce in the pool then guarantees a bit-identical search
/// trajectory (and Pareto front) at every parallelism level.
pub fn run_par<E, F>(
    params: &Nsga2Params,
    toggles: &Toggles,
    evaluate: &E,
    feasible: F,
    rng: &mut Rng,
) -> SearchResult
where
    E: Fn(&Config) -> Objectives + Sync,
    F: Fn(&Config) -> bool,
{
    run_core(
        params,
        toggles,
        &mut ParallelEval { f: evaluate, par: params.parallelism },
        &feasible,
        rng,
    )
}

fn run_core<B, F>(
    params: &Nsga2Params,
    toggles: &Toggles,
    eval: &mut B,
    feasible: &F,
    rng: &mut Rng,
) -> SearchResult
where
    B: PopulationEval,
    F: Fn(&Config) -> bool,
{
    let n = params.population;
    let mut evaluations = 0usize;

    // ---- constraint-aware initialization (Eq. 6) -----------------------
    let mut pop: Vec<Config> = Vec::with_capacity(n);
    while pop.len() < n {
        let mut candidate = enumerate::sample(rng);
        if toggles.constraint_init {
            let mut tries = 0;
            while !feasible(&candidate) && tries < params.init_attempts {
                candidate = enumerate::sample(rng);
                tries += 1;
            }
        }
        pop.push(candidate);
    }

    let mut objs: Vec<Objectives> = eval.evaluate(&pop);
    evaluations += pop.len();

    let mut archive = ParetoArchive::new(params.archive_capacity);
    insert_feasible(&mut archive, &pop, &objs, feasible, params.parallelism);

    // Kernel scratch carried across generations (DESIGN.md §17): the
    // sort's dominance bitset and crowding's argsort/column buffers
    // are allocated once per run instead of twice per generation.
    let mut sort_scratch = dominance::SortScratch::default();
    let mut crowd_scratch = dominance::CrowdingScratch::default();

    for _gen in 0..params.generations {
        // Rank + crowding of the current population (feasibility as a
        // death penalty: infeasible points get pushed behind all fronts).
        let min_vecs: Vec<MinVec> = pop
            .iter()
            .zip(&objs)
            .map(|(c, o)| penalized(c, o, feasible))
            .collect();
        let fronts =
            dominance::non_dominated_sort_with(&mut sort_scratch, &min_vecs);
        let mut rank = vec![0usize; n];
        let mut crowding = vec![0.0f64; n];
        for (r, front) in fronts.iter().enumerate() {
            let d = dominance::crowding_distance_with(&mut crowd_scratch,
                                                      &min_vecs, front);
            for (k, &i) in front.iter().enumerate() {
                rank[i] = r;
                crowding[i] = d[k];
            }
        }

        // ---- variation (sequential: owns the RNG stream) ----------------
        let offspring = operators::make_offspring(
            &pop, &rank, &crowding, params, toggles, rng,
        );
        let off_objs: Vec<Objectives> = eval.evaluate(&offspring);
        evaluations += offspring.len();
        insert_feasible(&mut archive, &offspring, &off_objs, feasible,
                        params.parallelism);

        // ---- environmental selection (mu + lambda) ----------------------
        let mut union_pop = pop;
        union_pop.extend(offspring);
        let mut union_objs = objs;
        union_objs.extend(off_objs);
        let union_vecs: Vec<MinVec> = union_pop
            .iter()
            .zip(&union_objs)
            .map(|(c, o)| penalized(c, o, feasible))
            .collect();
        let fronts =
            dominance::non_dominated_sort_with(&mut sort_scratch,
                                               &union_vecs);

        let mut next_pop = Vec::with_capacity(n);
        let mut next_objs = Vec::with_capacity(n);
        'outer: for front in &fronts {
            if next_pop.len() + front.len() <= n {
                for &i in front {
                    next_pop.push(union_pop[i]);
                    next_objs.push(union_objs[i]);
                }
            } else {
                // partial fill by descending crowding distance
                // (total_cmp: same order as the historical partial_cmp
                // on the +inf/finite values crowding produces, minus
                // the NaN abort)
                let d = dominance::crowding_distance_with(&mut crowd_scratch,
                                                          &union_vecs, front);
                let mut order: Vec<usize> = (0..front.len()).collect();
                order.sort_by(|&a, &b| d[b].total_cmp(&d[a]));
                for &k in &order {
                    if next_pop.len() >= n {
                        break 'outer;
                    }
                    next_pop.push(union_pop[front[k]]);
                    next_objs.push(union_objs[front[k]]);
                }
            }
            if next_pop.len() >= n {
                break;
            }
        }
        pop = next_pop;
        objs = next_objs;
    }

    SearchResult { archive, evaluations, generations_run: params.generations }
}

/// Feasibility-filter a freshly evaluated batch and push it into the
/// archive in submission order (exact batched insertion — see
/// [`ParetoArchive::insert_batch`]).
fn insert_feasible<F: Fn(&Config) -> bool>(
    archive: &mut ParetoArchive,
    configs: &[Config],
    objs: &[Objectives],
    feasible: &F,
    par: Parallelism,
) {
    let batch: Vec<(Config, Objectives)> = configs
        .iter()
        .zip(objs)
        .filter(|(c, _)| feasible(c))
        .map(|(c, o)| (*c, *o))
        .collect();
    archive.insert_batch(&batch, par);
}

/// Death-penalty transform: infeasible points are shifted behind every
/// feasible point in all objectives.
fn penalized<F: Fn(&Config) -> bool>(c: &Config, o: &Objectives,
                                     feasible: &F) -> MinVec {
    let mut v = o.as_min_vec();
    if !feasible(c) {
        for x in v.iter_mut() {
            *x += 1e9;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware;
    use crate::models::by_name;
    use crate::oracle::Testbed;
    use crate::tasks::blended_task;

    fn harness() -> (Testbed, crate::models::ModelSpec,
                     crate::tasks::TaskSpec) {
        (Testbed::noiseless(hardware::a100()),
         by_name("LLaMA-2-7B").unwrap(), blended_task())
    }

    #[test]
    fn finds_nondominated_front_on_oracle() {
        let (tb, m, t) = harness();
        let mut rng = Rng::new(1);
        let res = run(
            &Nsga2Params::small(),
            &Toggles::default(),
            |c| tb.true_objectives(c, &m, &t),
            |c| tb.feasible(c, &m, &t),
            &mut rng,
        );
        assert!(res.archive.len() >= 5, "archive={}", res.archive.len());
        assert_eq!(res.evaluations,
                   32 * 13 /* init + 12 gens of offspring */);
    }

    #[test]
    fn search_beats_random_sampling_on_utility() {
        let (tb, m, t) = harness();
        let util = |o: &Objectives| {
            o.accuracy - 0.2 * o.latency_ms - 0.2 * o.memory_gb
                - 5.0 * o.energy_j
        };
        let mut rng = Rng::new(2);
        let res = run(
            &Nsga2Params::small(),
            &Toggles::default(),
            |c| tb.true_objectives(c, &m, &t),
            |c| tb.feasible(c, &m, &t),
            &mut rng,
        );
        let best_search = res
            .archive
            .entries()
            .iter()
            .map(|e| util(&e.objectives))
            .fold(f64::NEG_INFINITY, f64::max);
        // random baseline with the same evaluation budget
        let mut rng2 = Rng::new(2);
        let best_random = (0..res.evaluations)
            .map(|_| {
                let c = enumerate::sample(&mut rng2);
                util(&tb.true_objectives(&c, &m, &t))
            })
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(best_search >= best_random - 0.3,
                "search={best_search} random={best_random}");
    }

    #[test]
    fn archive_members_are_feasible() {
        let (tb, m, t) = harness();
        // tight memory bound: only quantized configs fit
        let feasible = |c: &Config| {
            tb.true_objectives(c, &m, &t).memory_gb <= 8.0
        };
        let mut rng = Rng::new(3);
        let res = run(
            &Nsga2Params::small(),
            &Toggles::default(),
            |c| tb.true_objectives(c, &m, &t),
            feasible,
            &mut rng,
        );
        for e in res.archive.entries() {
            assert!(e.objectives.memory_gb <= 8.0,
                    "infeasible archived: {}", e.objectives.memory_gb);
        }
        assert!(!res.archive.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let (tb, m, t) = harness();
        let go = |seed| {
            let mut rng = Rng::new(seed);
            let res = run(
                &Nsga2Params::small(),
                &Toggles::default(),
                |c| tb.true_objectives(c, &m, &t),
                |_| true,
                &mut rng,
            );
            res.archive
                .entries()
                .iter()
                .map(|e| e.config)
                .collect::<Vec<_>>()
        };
        assert_eq!(go(7), go(7));
        assert_ne!(go(7), go(8));
    }

    #[test]
    fn run_par_matches_sequential_run_exactly() {
        let (tb, m, t) = harness();
        let fronts = |par: crate::util::Parallelism| {
            let params = Nsga2Params { parallelism: par,
                                       ..Nsga2Params::small() };
            let evaluate = |c: &Config| tb.true_objectives(c, &m, &t);
            let mut rng = Rng::new(11);
            let res = run_par(&params, &Toggles::default(), &evaluate,
                              |c| tb.feasible(c, &m, &t), &mut rng);
            res.archive
                .entries()
                .iter()
                .map(|e| (e.config, e.objectives))
                .collect::<Vec<_>>()
        };
        let seq = fronts(crate::util::Parallelism::Sequential);
        let par = fronts(crate::util::Parallelism::Threads(4));
        assert_eq!(seq, par, "parallel front must be bit-identical");
        // and the FnMut entry point agrees with both
        let mut rng = Rng::new(11);
        let res = run(
            &Nsga2Params::small(),
            &Toggles::default(),
            |c| tb.true_objectives(c, &m, &t),
            |c| tb.feasible(c, &m, &t),
            &mut rng,
        );
        let direct: Vec<_> = res.archive.entries().iter()
            .map(|e| (e.config, e.objectives)).collect();
        assert_eq!(seq, direct);
    }

    #[test]
    fn front_contains_both_accuracy_and_speed_ends() {
        let (tb, m, t) = harness();
        let mut rng = Rng::new(5);
        let res = run(
            &Nsga2Params::small(),
            &Toggles::default(),
            |c| tb.true_objectives(c, &m, &t),
            |_| true,
            &mut rng,
        );
        let accs: Vec<f64> = res.archive.entries().iter()
            .map(|e| e.objectives.accuracy).collect();
        let lats: Vec<f64> = res.archive.entries().iter()
            .map(|e| e.objectives.latency_ms).collect();
        let (acc_lo, acc_hi) = crate::util::stats::min_max(&accs);
        let (lat_lo, lat_hi) = crate::util::stats::min_max(&lats);
        // spread along the trade-off surface
        assert!(acc_hi - acc_lo > 0.5, "acc spread {acc_lo}..{acc_hi}");
        assert!(lat_hi / lat_lo > 1.3, "lat spread {lat_lo}..{lat_hi}");
    }
}
