//! Pareto archive: the non-dominated set maintained across generations
//! and refinement iterations (§3.3.2 "maintaining a Pareto archive of
//! non-dominated solutions").

use crate::config::Config;
use crate::oracle::Objectives;
use crate::search::dominance;

/// One archived solution.
#[derive(Clone, Debug)]
pub struct Entry {
    pub config: Config,
    pub objectives: Objectives,
}

/// Bounded non-dominated archive.
#[derive(Clone, Debug, Default)]
pub struct ParetoArchive {
    entries: Vec<Entry>,
    capacity: usize,
}

impl ParetoArchive {
    pub fn new(capacity: usize) -> Self {
        ParetoArchive { entries: Vec::new(), capacity }
    }

    /// Insert; returns true if the candidate made it into the archive.
    /// Dominated incumbents are evicted; duplicates (same config) are
    /// replaced by fresher objective values.
    pub fn insert(&mut self, config: Config, objectives: Objectives) -> bool {
        // Replace stale duplicate if present.
        if let Some(pos) =
            self.entries.iter().position(|e| e.config == config)
        {
            self.entries[pos].objectives = objectives;
            self.prune_dominated();
            return self.entries.iter().any(|e| e.config == config);
        }
        // Reject if dominated by anything in the archive.
        if self
            .entries
            .iter()
            .any(|e| e.objectives.dominates(&objectives))
        {
            return false;
        }
        // Evict whatever the candidate dominates.
        self.entries
            .retain(|e| !objectives.dominates(&e.objectives));
        self.entries.push(Entry { config, objectives });
        if self.entries.len() > self.capacity {
            self.truncate_by_crowding();
        }
        true
    }

    fn prune_dominated(&mut self) {
        let objs: Vec<_> =
            self.entries.iter().map(|e| e.objectives.as_min_vec()).collect();
        let keep: std::collections::BTreeSet<usize> =
            dominance::pareto_front(&objs).into_iter().collect();
        let mut i = 0;
        self.entries.retain(|_| {
            let k = keep.contains(&i);
            i += 1;
            k
        });
    }

    /// Drop the most crowded members until within capacity.
    fn truncate_by_crowding(&mut self) {
        while self.entries.len() > self.capacity {
            let objs: Vec<_> = self
                .entries
                .iter()
                .map(|e| e.objectives.as_min_vec())
                .collect();
            let front: Vec<usize> = (0..objs.len()).collect();
            let dist = dominance::crowding_distance(&objs, &front);
            let (victim, _) = dist
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            self.entries.remove(victim);
        }
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Best entry under a scalar utility (for final selection).
    pub fn best_by<F: Fn(&Entry) -> f64>(&self, utility: F) -> Option<&Entry> {
        self.entries
            .iter()
            .max_by(|a, b| utility(a).partial_cmp(&utility(b)).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(acc: f64, lat: f64) -> Objectives {
        Objectives { accuracy: acc, latency_ms: lat, memory_gb: 1.0,
                     energy_j: 1.0 }
    }

    fn cfg(seed: u64) -> Config {
        let mut rng = crate::util::Rng::new(seed);
        crate::config::enumerate::sample(&mut rng)
    }

    #[test]
    fn insert_keeps_nondominated() {
        let mut a = ParetoArchive::new(10);
        assert!(a.insert(cfg(1), obj(70.0, 10.0)));
        assert!(a.insert(cfg(2), obj(75.0, 20.0))); // trade-off: kept
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn dominated_candidate_rejected() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        assert!(!a.insert(cfg(2), obj(69.0, 11.0)));
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn dominating_candidate_evicts() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        a.insert(cfg(2), obj(75.0, 20.0));
        assert!(a.insert(cfg(3), obj(76.0, 9.0))); // dominates both
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn duplicate_config_updates_objectives() {
        let mut a = ParetoArchive::new(10);
        let c = cfg(1);
        a.insert(c, obj(70.0, 10.0));
        a.insert(c, obj(71.0, 10.0));
        assert_eq!(a.len(), 1);
        assert_eq!(a.entries()[0].objectives.accuracy, 71.0);
    }

    #[test]
    fn capacity_respected_via_crowding() {
        let mut a = ParetoArchive::new(5);
        for i in 0..20 {
            // all mutually non-dominated (line with slope -1)
            a.insert(cfg(i), obj(50.0 + i as f64, 10.0 + i as f64));
        }
        assert_eq!(a.len(), 5);
        // extremes survive crowding truncation
        let accs: Vec<f64> =
            a.entries().iter().map(|e| e.objectives.accuracy).collect();
        assert!(accs.iter().any(|&x| x == 50.0));
        assert!(accs.iter().any(|&x| x == 69.0));
    }

    #[test]
    fn archive_is_always_mutually_nondominated() {
        let mut rng = crate::util::Rng::new(5);
        let mut a = ParetoArchive::new(30);
        for i in 0..300 {
            let acc = 50.0 + 40.0 * rng.f64();
            let lat = 5.0 + 50.0 * rng.f64();
            a.insert(cfg(i), Objectives {
                accuracy: acc,
                latency_ms: lat,
                memory_gb: 1.0 + 10.0 * rng.f64(),
                energy_j: 0.1 + rng.f64(),
            });
        }
        for x in a.entries() {
            for y in a.entries() {
                assert!(!x.objectives.dominates(&y.objectives)
                    || x.config == y.config);
            }
        }
    }

    #[test]
    fn best_by_utility() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        a.insert(cfg(2), obj(80.0, 30.0));
        let fastest = a.best_by(|e| -e.objectives.latency_ms).unwrap();
        assert_eq!(fastest.objectives.latency_ms, 10.0);
        let most_accurate = a.best_by(|e| e.objectives.accuracy).unwrap();
        assert_eq!(most_accurate.objectives.accuracy, 80.0);
    }
}
