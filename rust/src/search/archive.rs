//! Pareto archive: the non-dominated set maintained across generations
//! and refinement iterations (§3.3.2 "maintaining a Pareto archive of
//! non-dominated solutions").

use crate::config::Config;
use crate::oracle::Objectives;
use crate::search::dominance;
use crate::util::json::Json;
use crate::util::pool::{self, Parallelism};

/// Schema tag of the serialized front (see
/// [`ParetoArchive::to_json`]).
pub const FRONT_SCHEMA: &str = "ae-llm.front/v1";

/// One archived solution.
#[derive(Clone, Debug)]
pub struct Entry {
    pub config: Config,
    pub objectives: Objectives,
}

/// Bounded non-dominated archive.
#[derive(Clone, Debug, Default)]
pub struct ParetoArchive {
    entries: Vec<Entry>,
    capacity: usize,
}

impl ParetoArchive {
    pub fn new(capacity: usize) -> Self {
        ParetoArchive { entries: Vec::new(), capacity }
    }

    /// Insert; returns true if the candidate made it into the archive.
    /// Dominated incumbents are evicted; duplicates (same config) are
    /// replaced by fresher objective values.
    pub fn insert(&mut self, config: Config, objectives: Objectives) -> bool {
        // Replace stale duplicate if present.
        if let Some(pos) =
            self.entries.iter().position(|e| e.config == config)
        {
            self.entries[pos].objectives = objectives;
            self.prune_dominated();
            return self.entries.iter().any(|e| e.config == config);
        }
        // Reject if dominated by anything in the archive.
        if self
            .entries
            .iter()
            .any(|e| e.objectives.dominates(&objectives))
        {
            return false;
        }
        // Evict whatever the candidate dominates.
        self.entries
            .retain(|e| !objectives.dominates(&e.objectives));
        self.entries.push(Entry { config, objectives });
        if self.entries.len() > self.capacity {
            self.truncate_by_crowding();
        }
        true
    }

    /// Insert a whole evaluated batch; returns the per-item acceptance
    /// booleans, in submission order — exactly what calling
    /// [`insert`](Self::insert) per item would have returned.
    ///
    /// Exactly equivalent to calling [`insert`](Self::insert) per item in
    /// submission order — the batch form exists so the dominance checks
    /// against the archive snapshot can fan out across the thread pool.
    ///
    /// The parallel pre-filter drops candidates dominated by the
    /// pre-batch archive.  That is provably what the sequential loop
    /// does too, but only under three conditions, all checked below;
    /// when any fails, the plain sequential loop runs instead, so the
    /// result is identical at every `Parallelism` level in all cases.
    ///
    /// 1. **No config collisions** — no batch config equals an archived
    ///    config or another batch config.  A colliding item takes
    ///    `insert`'s objective-refresh path, which can *weaken* an
    ///    incumbent mid-batch so that a later candidate it used to
    ///    dominate becomes acceptable; the snapshot check cannot see
    ///    that.
    /// 2. **No crowding truncation possible**
    ///    (`entries + batch <= capacity`) — truncation evicts
    ///    incumbents without a dominator taking their place.
    /// 3. Under 1–2, an incumbent only ever leaves the archive evicted
    ///    by a point that dominates it; dominance is transitive, so a
    ///    candidate dominated by the snapshot is still dominated by
    ///    something at its own turn.
    pub fn insert_batch(&mut self, items: &[(Config, Objectives)],
                        par: Parallelism) -> Vec<bool> {
        // Below this size the pre-filter costs more than it saves.
        const MIN_PARALLEL_BATCH: usize = 32;
        // Cheap guards first; the collision scan allocates and is only
        // worth computing once the batch could actually take the
        // parallel path.
        let has_collision = || {
            let archived: std::collections::BTreeSet<&Config> =
                self.entries.iter().map(|e| &e.config).collect();
            let mut seen = std::collections::BTreeSet::new();
            items
                .iter()
                .any(|(c, _)| archived.contains(c) || !seen.insert(c))
        };
        if items.len() < MIN_PARALLEL_BATCH
            || !par.is_parallel()
            || self.entries.len() + items.len() > self.capacity
            || has_collision()
        {
            return items
                .iter()
                .map(|(c, o)| self.insert(*c, *o))
                .collect();
        }
        let snapshot: Vec<Objectives> =
            self.entries.iter().map(|e| e.objectives).collect();
        let keep: Vec<bool> = pool::parallel_map(par, items, |(_, o)| {
            !snapshot.iter().any(|e| e.dominates(o))
        });
        // A pre-filtered candidate is dominated by the pre-batch
        // snapshot, so the sequential loop would also have returned
        // `false` for it (dominance is transitive; see conditions 1–3).
        items
            .iter()
            .zip(&keep)
            .map(|((c, o), &k)| k && self.insert(*c, *o))
            .collect()
    }

    fn prune_dominated(&mut self) {
        let objs: Vec<_> =
            self.entries.iter().map(|e| e.objectives.as_min_vec()).collect();
        let keep: std::collections::BTreeSet<usize> =
            dominance::pareto_front(&objs).into_iter().collect();
        let mut i = 0;
        self.entries.retain(|_| {
            let k = keep.contains(&i);
            i += 1;
            k
        });
    }

    /// Drop the most crowded members until within capacity.
    fn truncate_by_crowding(&mut self) {
        while self.entries.len() > self.capacity {
            let objs: Vec<_> = self
                .entries
                .iter()
                .map(|e| e.objectives.as_min_vec())
                .collect();
            let front: Vec<usize> = (0..objs.len()).collect();
            let dist = dominance::crowding_distance(&objs, &front);
            let (victim, _) = dist
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            self.entries.remove(victim);
        }
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Best entry under a scalar utility (for final selection).
    pub fn best_by<F: Fn(&Entry) -> f64>(&self, utility: F) -> Option<&Entry> {
        self.entries
            .iter()
            .max_by(|a, b| utility(a).partial_cmp(&utility(b)).unwrap())
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Serialize the archive (schema [`FRONT_SCHEMA`]): capacity plus
    /// the entries in archive order, each as (signature, objectives).
    /// This is what makes the Pareto front a *persistent* artifact the
    /// adaptation controller can warm-start re-searches from.  Field
    /// reference in docs/SCHEMAS.md.
    pub fn to_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        root.insert("schema".into(), Json::Str(FRONT_SCHEMA.into()));
        root.insert("capacity".into(), Json::Num(self.capacity as f64));
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("signature".into(), Json::Str(e.config.signature()));
                m.insert("objectives".into(), e.objectives.to_json());
                Json::Obj(m)
            })
            .collect();
        root.insert("entries".into(), Json::Arr(entries));
        Json::Obj(root)
    }

    /// Parse an archive back from [`to_json`](Self::to_json)'s form
    /// (schema-checked).  Entries are restored verbatim — same order,
    /// same objective values — rather than re-inserted, so a round trip
    /// preserves the archive exactly (a serialized front is already
    /// mutually non-dominated; re-insertion would only re-derive that).
    /// Capacity behavior survives too: later insertions truncate by
    /// crowding at the original capacity.
    pub fn from_json(j: &Json) -> Result<ParetoArchive, String> {
        let schema = j.req_str("schema")?;
        if schema != FRONT_SCHEMA {
            return Err(format!("unexpected schema {schema:?}"));
        }
        let capacity = j.req_u64("capacity")? as usize;
        let raw = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("missing/invalid entries array")?;
        let mut entries = Vec::with_capacity(raw.len());
        for e in raw {
            let sig = e.req_str("signature")?;
            let config = Config::from_signature(&sig)?;
            let objectives = Objectives::from_json(
                e.get("objectives").ok_or("entry missing objectives")?)?;
            entries.push(Entry { config, objectives });
        }
        Ok(ParetoArchive { entries, capacity })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(acc: f64, lat: f64) -> Objectives {
        Objectives { accuracy: acc, latency_ms: lat, memory_gb: 1.0,
                     energy_j: 1.0 }
    }

    fn cfg(seed: u64) -> Config {
        let mut rng = crate::util::Rng::new(seed);
        crate::config::enumerate::sample(&mut rng)
    }

    #[test]
    fn insert_keeps_nondominated() {
        let mut a = ParetoArchive::new(10);
        assert!(a.insert(cfg(1), obj(70.0, 10.0)));
        assert!(a.insert(cfg(2), obj(75.0, 20.0))); // trade-off: kept
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn dominated_candidate_rejected() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        assert!(!a.insert(cfg(2), obj(69.0, 11.0)));
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn dominating_candidate_evicts() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        a.insert(cfg(2), obj(75.0, 20.0));
        assert!(a.insert(cfg(3), obj(76.0, 9.0))); // dominates both
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn duplicate_config_updates_objectives() {
        let mut a = ParetoArchive::new(10);
        let c = cfg(1);
        a.insert(c, obj(70.0, 10.0));
        a.insert(c, obj(71.0, 10.0));
        assert_eq!(a.len(), 1);
        assert_eq!(a.entries()[0].objectives.accuracy, 71.0);
    }

    #[test]
    fn capacity_respected_via_crowding() {
        let mut a = ParetoArchive::new(5);
        for i in 0..20 {
            // all mutually non-dominated (line with slope -1)
            a.insert(cfg(i), obj(50.0 + i as f64, 10.0 + i as f64));
        }
        assert_eq!(a.len(), 5);
        // extremes survive crowding truncation
        let accs: Vec<f64> =
            a.entries().iter().map(|e| e.objectives.accuracy).collect();
        assert!(accs.iter().any(|&x| x == 50.0));
        assert!(accs.iter().any(|&x| x == 69.0));
    }

    #[test]
    fn archive_is_always_mutually_nondominated() {
        let mut rng = crate::util::Rng::new(5);
        let mut a = ParetoArchive::new(30);
        for i in 0..300 {
            let acc = 50.0 + 40.0 * rng.f64();
            let lat = 5.0 + 50.0 * rng.f64();
            a.insert(cfg(i), Objectives {
                accuracy: acc,
                latency_ms: lat,
                memory_gb: 1.0 + 10.0 * rng.f64(),
                energy_j: 0.1 + rng.f64(),
            });
        }
        for x in a.entries() {
            for y in a.entries() {
                assert!(!x.objectives.dominates(&y.objectives)
                    || x.config == y.config);
            }
        }
    }

    #[test]
    fn insert_batch_is_exactly_sequential_insertion() {
        // Three regimes: (roomy capacity, distinct configs) exercises
        // the parallel pre-filter; (roomy, duplicated configs) the
        // collision-safe sequential fallback; tight capacity the
        // truncation-safe fallback.
        for (capacity, dup) in [(2048usize, false), (2048, true), (12, true)] {
            let mut rng = crate::util::Rng::new(9);
            let mut seq = ParetoArchive::new(capacity);
            let mut bat = ParetoArchive::new(capacity);
            for round in 0..4u64 {
                let mut items = Vec::new();
                for i in 0..120u64 {
                    // distinct config per item across all rounds, or
                    // heavy duplication, depending on the regime
                    let c = if dup {
                        cfg(round * 7 + i % 40)
                    } else {
                        cfg(1000 * round + i)
                    };
                    items.push((c, Objectives {
                        accuracy: 50.0 + 40.0 * rng.f64(),
                        latency_ms: 5.0 + 50.0 * rng.f64(),
                        memory_gb: 1.0 + 10.0 * rng.f64(),
                        energy_j: 0.1 + rng.f64(),
                    }));
                }
                let accepts_seq: Vec<bool> =
                    items.iter().map(|(c, o)| seq.insert(*c, *o)).collect();
                let accepts_bat = bat.insert_batch(&items,
                                                   Parallelism::Threads(4));
                assert_eq!(accepts_seq, accepts_bat,
                           "acceptance booleans diverged at capacity \
                            {capacity} dup {dup} round {round}");
                let key = |a: &ParetoArchive| -> Vec<(Config, String)> {
                    a.entries()
                        .iter()
                        .map(|e| (e.config, format!("{:?}", e.objectives)))
                        .collect()
                };
                assert_eq!(key(&seq), key(&bat),
                           "diverged at capacity {capacity} dup {dup} \
                            round {round}");
            }
        }
    }

    /// Entry-level equality key for round-trip comparisons (Objectives
    /// is PartialEq; Debug-format it so tuples are Eq-comparable).
    fn key(a: &ParetoArchive) -> Vec<(Config, String)> {
        a.entries()
            .iter()
            .map(|e| (e.config, format!("{:?}", e.objectives)))
            .collect()
    }

    #[test]
    fn json_roundtrip_preserves_entries_and_order() {
        // Property: from_json(to_json(a)) == a — entries, ordering and
        // capacity — over randomized archives, including duplicate
        // configs (refreshed objectives) and tight capacities.
        for (seed, capacity, dup) in
            [(1u64, 30usize, false), (2, 8, false), (3, 30, true)]
        {
            let mut rng = crate::util::Rng::new(seed);
            let mut a = ParetoArchive::new(capacity);
            for i in 0..150u64 {
                let c = if dup { cfg(i % 25) } else { cfg(i) };
                a.insert(c, Objectives {
                    accuracy: 50.0 + 40.0 * rng.f64(),
                    latency_ms: 5.0 + 50.0 * rng.f64(),
                    memory_gb: 1.0 + 10.0 * rng.f64(),
                    energy_j: 0.1 + rng.f64(),
                });
            }
            // through the Json value AND through its text form (the
            // on-disk path): both must restore the archive exactly
            let back = ParetoArchive::from_json(&a.to_json()).unwrap();
            assert_eq!(key(&a), key(&back), "seed {seed}");
            assert_eq!(back.capacity(), capacity);
            let text = a.to_json().dump();
            let reparsed = ParetoArchive::from_json(
                &crate::util::json::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(key(&a), key(&reparsed), "seed {seed} (text)");
        }
    }

    #[test]
    fn json_roundtrip_empty_front_and_duplicate_objectives() {
        // Empty front: entries [] and capacity survive.
        let empty = ParetoArchive::new(7);
        let back = ParetoArchive::from_json(&empty.to_json()).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.capacity(), 7);

        // Distinct configs with byte-identical objectives (mutually
        // non-dominating duplicates) all survive, in order.
        let mut a = ParetoArchive::new(10);
        let o = obj(70.0, 10.0);
        a.insert(cfg(1), o);
        a.insert(cfg(2), o);
        a.insert(cfg(3), o);
        assert_eq!(a.len(), 3, "equal objectives are mutually \
                                non-dominated and must all be kept");
        let back = ParetoArchive::from_json(&a.to_json()).unwrap();
        assert_eq!(key(&a), key(&back));
    }

    #[test]
    fn json_roundtrip_preserves_capacity_behavior() {
        // After a round trip, inserting past capacity truncates by
        // crowding exactly like the original would.
        let mut a = ParetoArchive::new(5);
        for i in 0..3 {
            a.insert(cfg(i), obj(50.0 + i as f64, 10.0 + i as f64));
        }
        let mut b = ParetoArchive::from_json(&a.to_json()).unwrap();
        for i in 3..20 {
            let o = obj(50.0 + i as f64, 10.0 + i as f64);
            a.insert(cfg(i), o);
            b.insert(cfg(i), o);
        }
        assert_eq!(a.len(), 5);
        assert_eq!(key(&a), key(&b));
    }

    #[test]
    fn from_json_rejects_wrong_schema_and_garbage() {
        let mut wrong = std::collections::BTreeMap::new();
        wrong.insert("schema".to_string(),
                     crate::util::json::Json::Str("nope".into()));
        assert!(ParetoArchive::from_json(
            &crate::util::json::Json::Obj(wrong)).is_err());
        let j = crate::util::json::Json::parse(
            r#"{"schema":"ae-llm.front/v1","capacity":4,
                "entries":[{"signature":"bogus","objectives":
                {"accuracy":1,"latency_ms":1,"memory_gb":1,"energy_j":1}}]}"#,
        )
        .unwrap();
        assert!(ParetoArchive::from_json(&j).is_err());
    }

    #[test]
    fn best_by_utility() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        a.insert(cfg(2), obj(80.0, 30.0));
        let fastest = a.best_by(|e| -e.objectives.latency_ms).unwrap();
        assert_eq!(fastest.objectives.latency_ms, 10.0);
        let most_accurate = a.best_by(|e| e.objectives.accuracy).unwrap();
        assert_eq!(most_accurate.objectives.accuracy, 80.0);
    }
}
