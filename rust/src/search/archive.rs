//! Pareto archive: the non-dominated set maintained across generations
//! and refinement iterations (§3.3.2 "maintaining a Pareto archive of
//! non-dominated solutions").
//!
//! Two implementations live here (DESIGN.md §15 "Hot-path inventory"):
//!
//! * [`ParetoArchive`] — the production archive.  It keeps two caches
//!   alongside the entry list: a persistent `Config -> position` index
//!   (duplicate detection in O(log n) instead of a linear scan per
//!   candidate) and the min-convention objective matrix
//!   (`Objectives::as_min_vec` computed once per entry, not once per
//!   dominance comparison or eviction round).  `insert_batch`
//!   additionally sorts the cached matrix by first objective once per
//!   batch so the parallel pre-filter scans only the prefix that could
//!   possibly dominate each candidate.
//! * [`ReferenceArchive`] — the pre-index implementation, retained
//!   verbatim as the differential-testing oracle and the "before" row
//!   of `benches/perf_search.rs` (same idiom as
//!   `Server::drain_polled`).  The `indexed_archive_matches_reference*`
//!   property tests hold the two against each other — identical
//!   acceptance booleans, entry order and eviction victims — across
//!   dup-heavy and tight-capacity streams at every parallelism level.

use std::collections::BTreeMap;

use crate::config::Config;
use crate::oracle::Objectives;
use crate::search::dominance::{self, first_coord_key, MinVec};
use crate::util::json::Json;
use crate::util::pool::{self, Parallelism};

/// Schema tag of the serialized front (see
/// [`ParetoArchive::to_json`]).
pub const FRONT_SCHEMA: &str = "ae-llm.front/v1";

/// One archived solution.
#[derive(Clone, Debug)]
pub struct Entry {
    pub config: Config,
    pub objectives: Objectives,
}

/// Bounded non-dominated archive (indexed; see module docs).
///
/// Invariants (checked by the differential tests):
/// * `min_vecs[i] == entries[i].objectives.as_min_vec()` for every i;
/// * `index[c] == i` iff `entries[i].config == c`, for every entry.
#[derive(Clone, Debug, Default)]
pub struct ParetoArchive {
    entries: Vec<Entry>,
    capacity: usize,
    /// Cached min-convention objective vectors, parallel to `entries`.
    min_vecs: Vec<MinVec>,
    /// Persistent duplicate-config index: config -> position.
    index: BTreeMap<Config, usize>,
    /// Monotone mutation counter: bumped by every [`insert`](Self::insert)
    /// that changes the archive (accepted candidates and duplicate-config
    /// refreshes; rejections leave it untouched).  Derived values that
    /// are pure functions of the entry list — the observer's
    /// per-iteration hypervolume — key their memoization on it (see
    /// `coordinator::algorithm1::HvGate`).
    version: u64,
}

// The first-objective prefix-pruning key (`first_coord_key`) is shared
// with the dominance kernels; see `dominance::first_coord_key`.

impl ParetoArchive {
    pub fn new(capacity: usize) -> Self {
        ParetoArchive {
            entries: Vec::new(),
            capacity,
            min_vecs: Vec::new(),
            index: BTreeMap::new(),
            version: 0,
        }
    }

    /// Rebuild the caches from an entry list (deserialization path).
    fn from_parts(entries: Vec<Entry>, capacity: usize) -> ParetoArchive {
        let min_vecs =
            entries.iter().map(|e| e.objectives.as_min_vec()).collect();
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.config, i))
            .collect();
        ParetoArchive { entries, capacity, min_vecs, index, version: 0 }
    }

    /// Drop every entry whose `keep` flag is false, preserving order,
    /// fixing both caches in the same single pass (replaces the old
    /// `Vec::retain` + full re-scan).
    fn compact(&mut self, keep: &[bool]) {
        debug_assert_eq!(keep.len(), self.entries.len());
        let mut w = 0;
        for r in 0..keep.len() {
            if keep[r] {
                if w != r {
                    self.entries.swap(w, r);
                    self.min_vecs.swap(w, r);
                }
                *self.index.get_mut(&self.entries[w].config).unwrap() = w;
                w += 1;
            } else {
                self.index.remove(&self.entries[r].config);
            }
        }
        self.entries.truncate(w);
        self.min_vecs.truncate(w);
    }

    /// Insert; returns true if the candidate made it into the archive.
    /// Dominated incumbents are evicted; duplicates (same config) are
    /// replaced by fresher objective values.
    pub fn insert(&mut self, config: Config, objectives: Objectives) -> bool {
        // Replace stale duplicate if present (O(log n) via the index;
        // previously a linear scan per candidate).
        if let Some(&pos) = self.index.get(&config) {
            self.version += 1;
            self.entries[pos].objectives = objectives;
            self.min_vecs[pos] = objectives.as_min_vec();
            self.prune_dominated();
            return self.index.contains_key(&config);
        }
        let cand = objectives.as_min_vec();
        // Reject if dominated by anything in the archive (cached
        // min-vec matrix; `dominance::dominates` on min-vecs is exactly
        // `Objectives::dominates`, NaN cases included).
        if self.min_vecs.iter().any(|mv| dominance::dominates(mv, &cand)) {
            return false;
        }
        // Evict whatever the candidate dominates.
        if self.min_vecs.iter().any(|mv| dominance::dominates(&cand, mv)) {
            let keep: Vec<bool> = self
                .min_vecs
                .iter()
                .map(|mv| !dominance::dominates(&cand, mv))
                .collect();
            self.compact(&keep);
        }
        self.version += 1;
        self.index.insert(config, self.entries.len());
        self.entries.push(Entry { config, objectives });
        self.min_vecs.push(cand);
        if self.entries.len() > self.capacity {
            self.truncate_by_crowding();
        }
        true
    }

    /// Monotone mutation counter (see the field docs): equal versions
    /// of the *same* archive instance guarantee identical entries, so
    /// derived pure functions of the entry list can be change-gated on
    /// it.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Insert a whole evaluated batch; returns the per-item acceptance
    /// booleans, in submission order — exactly what calling
    /// [`insert`](Self::insert) per item would have returned.
    ///
    /// Exactly equivalent to calling [`insert`](Self::insert) per item in
    /// submission order — the batch form exists so the dominance checks
    /// against the archive snapshot can fan out across the thread pool.
    ///
    /// The parallel pre-filter drops candidates dominated by the
    /// pre-batch archive.  That is provably what the sequential loop
    /// does too, but only under three conditions, all checked below;
    /// when any fails, the plain sequential loop runs instead, so the
    /// result is identical at every `Parallelism` level in all cases.
    ///
    /// 1. **No config collisions** — no batch config equals an archived
    ///    config or another batch config.  A colliding item takes
    ///    `insert`'s objective-refresh path, which can *weaken* an
    ///    incumbent mid-batch so that a later candidate it used to
    ///    dominate becomes acceptable; the snapshot check cannot see
    ///    that.
    /// 2. **No crowding truncation possible**
    ///    (`entries + batch <= capacity`) — truncation evicts
    ///    incumbents without a dominator taking their place.
    /// 3. Under 1–2, an incumbent only ever leaves the archive evicted
    ///    by a point that dominates it; dominance is transitive, so a
    ///    candidate dominated by the snapshot is still dominated by
    ///    something at its own turn.
    ///
    /// The snapshot scan is pruned by first objective: the cached
    /// min-vec matrix is sorted by its first coordinate once per batch,
    /// and each candidate only scans the prefix with first coordinate
    /// `<=` its own — a dominator must be `<=` in *every* coordinate,
    /// so nothing outside that prefix can dominate (NaN coordinates
    /// sort into the prefix conservatively; see [`first_coord_key`]).
    pub fn insert_batch(&mut self, items: &[(Config, Objectives)],
                        par: Parallelism) -> Vec<bool> {
        // Below this size the pre-filter costs more than it saves.
        const MIN_PARALLEL_BATCH: usize = 32;
        // Cheap guards first; the collision scan is only worth
        // computing once the batch could actually take the parallel
        // path (archived configs come straight off the persistent
        // index now — no per-call set rebuild).
        let has_collision = || {
            let mut seen = std::collections::BTreeSet::new();
            items
                .iter()
                .any(|(c, _)| self.index.contains_key(c) || !seen.insert(*c))
        };
        if items.len() < MIN_PARALLEL_BATCH
            || !par.is_parallel()
            || self.entries.len() + items.len() > self.capacity
            || has_collision()
        {
            return items
                .iter()
                .map(|(c, o)| self.insert(*c, *o))
                .collect();
        }
        let mut sorted: Vec<(f64, MinVec)> =
            Vec::with_capacity(self.min_vecs.len());
        sorted.extend(self.min_vecs.iter().map(|mv| (first_coord_key(mv[0]),
                                                     *mv)));
        sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
        let keep: Vec<bool> = pool::parallel_map(par, items, |(_, o)| {
            let cand = o.as_min_vec();
            let hi = if cand[0].is_nan() { f64::INFINITY } else { cand[0] };
            let prefix = sorted.partition_point(|(k, _)| *k <= hi);
            !sorted[..prefix]
                .iter()
                .any(|(_, mv)| dominance::dominates(mv, &cand))
        });
        // A pre-filtered candidate is dominated by the pre-batch
        // snapshot, so the sequential loop would also have returned
        // `false` for it (dominance is transitive; see conditions 1–3).
        items
            .iter()
            .zip(&keep)
            .map(|((c, o), &k)| k && self.insert(*c, *o))
            .collect()
    }

    fn prune_dominated(&mut self) {
        let keep_set: std::collections::BTreeSet<usize> =
            dominance::pareto_front(&self.min_vecs).into_iter().collect();
        if keep_set.len() == self.entries.len() {
            return;
        }
        let keep: Vec<bool> =
            (0..self.entries.len()).map(|i| keep_set.contains(&i)).collect();
        self.compact(&keep);
    }

    /// Drop the most crowded member when over capacity.  `insert` adds
    /// one entry at a time, so this runs exactly one crowding pass per
    /// overflow (the `while` guards the general case); the pass reuses
    /// the cached min-vec matrix instead of re-collecting
    /// `as_min_vec` per round, and fixes the config index in the same
    /// sweep that removes the victim.
    fn truncate_by_crowding(&mut self) {
        while self.entries.len() > self.capacity {
            let front: Vec<usize> = (0..self.min_vecs.len()).collect();
            let dist = dominance::crowding_distance(&self.min_vecs, &front);
            // First minimum — `Iterator::min_by` semantics, which the
            // reference implementation relies on for victim ties
            // (total_cmp: same victim as the historical partial_cmp on
            // the +inf/finite distances crowding produces, minus the
            // NaN abort).
            let (victim, _) = dist
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .unwrap();
            self.index.remove(&self.entries[victim].config);
            self.entries.remove(victim);
            self.min_vecs.remove(victim);
            for (i, e) in self.entries.iter().enumerate().skip(victim) {
                *self.index.get_mut(&e.config).unwrap() = i;
            }
        }
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Best entry under a scalar utility (for final selection).
    pub fn best_by<F: Fn(&Entry) -> f64>(&self, utility: F) -> Option<&Entry> {
        self.entries
            .iter()
            .max_by(|a, b| utility(a).partial_cmp(&utility(b)).unwrap())
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Serialize the archive (schema [`FRONT_SCHEMA`]): capacity plus
    /// the entries in archive order, each as (signature, objectives).
    /// This is what makes the Pareto front a *persistent* artifact the
    /// adaptation controller can warm-start re-searches from.  Field
    /// reference in docs/SCHEMAS.md.
    pub fn to_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        root.insert("schema".into(), Json::Str(FRONT_SCHEMA.into()));
        root.insert("capacity".into(), Json::Num(self.capacity as f64));
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("signature".into(), Json::Str(e.config.signature()));
                m.insert("objectives".into(), e.objectives.to_json());
                Json::Obj(m)
            })
            .collect();
        root.insert("entries".into(), Json::Arr(entries));
        Json::Obj(root)
    }

    /// Parse an archive back from [`to_json`](Self::to_json)'s form
    /// (schema-checked).  Entries are restored verbatim — same order,
    /// same objective values — rather than re-inserted, so a round trip
    /// preserves the archive exactly (a serialized front is already
    /// mutually non-dominated; re-insertion would only re-derive that).
    /// Capacity behavior survives too: later insertions truncate by
    /// crowding at the original capacity.
    pub fn from_json(j: &Json) -> Result<ParetoArchive, String> {
        let schema = j.req_str("schema")?;
        if schema != FRONT_SCHEMA {
            return Err(format!("unexpected schema {schema:?}"));
        }
        let capacity = j.req_u64("capacity")? as usize;
        let raw = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("missing/invalid entries array")?;
        let mut entries = Vec::with_capacity(raw.len());
        for e in raw {
            let sig = e.req_str("signature")?;
            let config = Config::from_signature(&sig)?;
            let objectives = Objectives::from_json(
                e.get("objectives").ok_or("entry missing objectives")?)?;
            entries.push(Entry { config, objectives });
        }
        Ok(ParetoArchive::from_parts(entries, capacity))
    }

    /// Cache-consistency check used by the differential tests.
    #[cfg(test)]
    fn check_invariants(&self) {
        assert_eq!(self.entries.len(), self.min_vecs.len());
        assert_eq!(self.entries.len(), self.index.len());
        for (i, e) in self.entries.iter().enumerate() {
            assert_eq!(self.min_vecs[i], e.objectives.as_min_vec());
            assert_eq!(self.index.get(&e.config), Some(&i));
        }
    }
}

// ---------------------------------------------------------------------------
// ReferenceArchive: the retained pre-index implementation
// ---------------------------------------------------------------------------

/// The pre-index archive, retained verbatim as the differential-testing
/// oracle and the "before" row of the `perf_search` archive-insertion
/// microbench (DESIGN.md §15).  Not for production use: every insert
/// pays a linear duplicate scan, every batch rebuilds its config set,
/// and every eviction round re-collects the objective matrix.
#[derive(Clone, Debug, Default)]
pub struct ReferenceArchive {
    entries: Vec<Entry>,
    capacity: usize,
}

impl ReferenceArchive {
    pub fn new(capacity: usize) -> Self {
        ReferenceArchive { entries: Vec::new(), capacity }
    }

    /// [`ParetoArchive::insert`], pre-index implementation.
    pub fn insert(&mut self, config: Config, objectives: Objectives) -> bool {
        if let Some(pos) =
            self.entries.iter().position(|e| e.config == config)
        {
            self.entries[pos].objectives = objectives;
            self.prune_dominated();
            return self.entries.iter().any(|e| e.config == config);
        }
        if self
            .entries
            .iter()
            .any(|e| e.objectives.dominates(&objectives))
        {
            return false;
        }
        self.entries
            .retain(|e| !objectives.dominates(&e.objectives));
        self.entries.push(Entry { config, objectives });
        if self.entries.len() > self.capacity {
            self.truncate_by_crowding();
        }
        true
    }

    /// [`ParetoArchive::insert_batch`], pre-index implementation
    /// (per-call config-set rebuild, unsorted full-snapshot scan).
    pub fn insert_batch(&mut self, items: &[(Config, Objectives)],
                        par: Parallelism) -> Vec<bool> {
        const MIN_PARALLEL_BATCH: usize = 32;
        let has_collision = || {
            let archived: std::collections::BTreeSet<&Config> =
                self.entries.iter().map(|e| &e.config).collect();
            let mut seen = std::collections::BTreeSet::new();
            items
                .iter()
                .any(|(c, _)| archived.contains(c) || !seen.insert(c))
        };
        if items.len() < MIN_PARALLEL_BATCH
            || !par.is_parallel()
            || self.entries.len() + items.len() > self.capacity
            || has_collision()
        {
            return items
                .iter()
                .map(|(c, o)| self.insert(*c, *o))
                .collect();
        }
        let snapshot: Vec<Objectives> =
            self.entries.iter().map(|e| e.objectives).collect();
        let keep: Vec<bool> = pool::parallel_map(par, items, |(_, o)| {
            !snapshot.iter().any(|e| e.dominates(o))
        });
        items
            .iter()
            .zip(&keep)
            .map(|((c, o), &k)| k && self.insert(*c, *o))
            .collect()
    }

    fn prune_dominated(&mut self) {
        let objs: Vec<_> =
            self.entries.iter().map(|e| e.objectives.as_min_vec()).collect();
        let keep: std::collections::BTreeSet<usize> =
            dominance::pareto_front(&objs).into_iter().collect();
        let mut i = 0;
        self.entries.retain(|_| {
            let k = keep.contains(&i);
            i += 1;
            k
        });
    }

    fn truncate_by_crowding(&mut self) {
        while self.entries.len() > self.capacity {
            let objs: Vec<_> = self
                .entries
                .iter()
                .map(|e| e.objectives.as_min_vec())
                .collect();
            let front: Vec<usize> = (0..objs.len()).collect();
            let dist = dominance::crowding_distance(&objs, &front);
            let (victim, _) = dist
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            self.entries.remove(victim);
        }
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(acc: f64, lat: f64) -> Objectives {
        Objectives { accuracy: acc, latency_ms: lat, memory_gb: 1.0,
                     energy_j: 1.0 }
    }

    fn cfg(seed: u64) -> Config {
        let mut rng = crate::util::Rng::new(seed);
        crate::config::enumerate::sample(&mut rng)
    }

    #[test]
    fn insert_keeps_nondominated() {
        let mut a = ParetoArchive::new(10);
        assert!(a.insert(cfg(1), obj(70.0, 10.0)));
        assert!(a.insert(cfg(2), obj(75.0, 20.0))); // trade-off: kept
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn dominated_candidate_rejected() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        assert!(!a.insert(cfg(2), obj(69.0, 11.0)));
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn dominating_candidate_evicts() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        a.insert(cfg(2), obj(75.0, 20.0));
        assert!(a.insert(cfg(3), obj(76.0, 9.0))); // dominates both
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn duplicate_config_updates_objectives() {
        let mut a = ParetoArchive::new(10);
        let c = cfg(1);
        a.insert(c, obj(70.0, 10.0));
        a.insert(c, obj(71.0, 10.0));
        assert_eq!(a.len(), 1);
        assert_eq!(a.entries()[0].objectives.accuracy, 71.0);
    }

    #[test]
    fn capacity_respected_via_crowding() {
        let mut a = ParetoArchive::new(5);
        for i in 0..20 {
            // all mutually non-dominated (line with slope -1)
            a.insert(cfg(i), obj(50.0 + i as f64, 10.0 + i as f64));
        }
        assert_eq!(a.len(), 5);
        // extremes survive crowding truncation
        let accs: Vec<f64> =
            a.entries().iter().map(|e| e.objectives.accuracy).collect();
        assert!(accs.iter().any(|&x| x == 50.0));
        assert!(accs.iter().any(|&x| x == 69.0));
    }

    #[test]
    fn archive_is_always_mutually_nondominated() {
        let mut rng = crate::util::Rng::new(5);
        let mut a = ParetoArchive::new(30);
        for i in 0..300 {
            let acc = 50.0 + 40.0 * rng.f64();
            let lat = 5.0 + 50.0 * rng.f64();
            a.insert(cfg(i), Objectives {
                accuracy: acc,
                latency_ms: lat,
                memory_gb: 1.0 + 10.0 * rng.f64(),
                energy_j: 0.1 + rng.f64(),
            });
        }
        for x in a.entries() {
            for y in a.entries() {
                assert!(!x.objectives.dominates(&y.objectives)
                    || x.config == y.config);
            }
        }
        a.check_invariants();
    }

    #[test]
    fn insert_batch_is_exactly_sequential_insertion() {
        // Three regimes: (roomy capacity, distinct configs) exercises
        // the parallel pre-filter; (roomy, duplicated configs) the
        // collision-safe sequential fallback; tight capacity the
        // truncation-safe fallback.
        for (capacity, dup) in [(2048usize, false), (2048, true), (12, true)] {
            let mut rng = crate::util::Rng::new(9);
            let mut seq = ParetoArchive::new(capacity);
            let mut bat = ParetoArchive::new(capacity);
            for round in 0..4u64 {
                let mut items = Vec::new();
                for i in 0..120u64 {
                    // distinct config per item across all rounds, or
                    // heavy duplication, depending on the regime
                    let c = if dup {
                        cfg(round * 7 + i % 40)
                    } else {
                        cfg(1000 * round + i)
                    };
                    items.push((c, Objectives {
                        accuracy: 50.0 + 40.0 * rng.f64(),
                        latency_ms: 5.0 + 50.0 * rng.f64(),
                        memory_gb: 1.0 + 10.0 * rng.f64(),
                        energy_j: 0.1 + rng.f64(),
                    }));
                }
                let accepts_seq: Vec<bool> =
                    items.iter().map(|(c, o)| seq.insert(*c, *o)).collect();
                let accepts_bat = bat.insert_batch(&items,
                                                   Parallelism::Threads(4));
                assert_eq!(accepts_seq, accepts_bat,
                           "acceptance booleans diverged at capacity \
                            {capacity} dup {dup} round {round}");
                let key = |a: &ParetoArchive| -> Vec<(Config, String)> {
                    a.entries()
                        .iter()
                        .map(|e| (e.config, format!("{:?}", e.objectives)))
                        .collect()
                };
                assert_eq!(key(&seq), key(&bat),
                           "diverged at capacity {capacity} dup {dup} \
                            round {round}");
                seq.check_invariants();
                bat.check_invariants();
            }
        }
    }

    /// The satellite property test: the indexed archive against the
    /// retained reference, per-item inserts and whole batches, across
    /// dup-heavy and tight-capacity streams at Parallelism 1/4/8 —
    /// identical acceptance booleans, entry order (hence identical
    /// eviction victims) and final contents, every round.
    #[test]
    fn indexed_archive_matches_reference_archive() {
        let pars = [Parallelism::Sequential,
                    Parallelism::Threads(4),
                    Parallelism::Threads(8)];
        let regimes = [(2048usize, false), (64, true), (12, true)];
        for par in pars {
            for (capacity, dup) in regimes {
                let mut rng = crate::util::Rng::new(31);
                let mut fast = ParetoArchive::new(capacity);
                let mut refr = ReferenceArchive::new(capacity);
                for round in 0..5u64 {
                    let items: Vec<(Config, Objectives)> = (0..90u64)
                        .map(|i| {
                            let c = if dup {
                                cfg(round * 3 + i % 25)
                            } else {
                                cfg(10_000 * round + i)
                            };
                            (c, Objectives {
                                accuracy: 50.0 + 40.0 * rng.f64(),
                                latency_ms: 5.0 + 50.0 * rng.f64(),
                                memory_gb: 1.0 + 10.0 * rng.f64(),
                                energy_j: 0.1 + rng.f64(),
                            })
                        })
                        .collect();
                    // Alternate between the batch API and per-item
                    // inserts so both code paths face both archives.
                    let (a_fast, a_ref): (Vec<bool>, Vec<bool>) =
                        if round % 2 == 0 {
                            (fast.insert_batch(&items, par),
                             refr.insert_batch(&items, par))
                        } else {
                            (items.iter()
                                  .map(|(c, o)| fast.insert(*c, *o))
                                  .collect(),
                             items.iter()
                                  .map(|(c, o)| refr.insert(*c, *o))
                                  .collect())
                        };
                    assert_eq!(a_fast, a_ref,
                               "acceptance diverged: par {par:?} capacity \
                                {capacity} dup {dup} round {round}");
                    let kf: Vec<(Config, String)> = fast
                        .entries()
                        .iter()
                        .map(|e| (e.config, format!("{:?}", e.objectives)))
                        .collect();
                    let kr: Vec<(Config, String)> = refr
                        .entries()
                        .iter()
                        .map(|e| (e.config, format!("{:?}", e.objectives)))
                        .collect();
                    assert_eq!(kf, kr,
                               "entries diverged: par {par:?} capacity \
                                {capacity} dup {dup} round {round}");
                    fast.check_invariants();
                }
            }
        }
    }

    /// Entry-level equality key for round-trip comparisons (Objectives
    /// is PartialEq; Debug-format it so tuples are Eq-comparable).
    fn key(a: &ParetoArchive) -> Vec<(Config, String)> {
        a.entries()
            .iter()
            .map(|e| (e.config, format!("{:?}", e.objectives)))
            .collect()
    }

    #[test]
    fn json_roundtrip_preserves_entries_and_order() {
        // Property: from_json(to_json(a)) == a — entries, ordering and
        // capacity — over randomized archives, including duplicate
        // configs (refreshed objectives) and tight capacities.
        for (seed, capacity, dup) in
            [(1u64, 30usize, false), (2, 8, false), (3, 30, true)]
        {
            let mut rng = crate::util::Rng::new(seed);
            let mut a = ParetoArchive::new(capacity);
            for i in 0..150u64 {
                let c = if dup { cfg(i % 25) } else { cfg(i) };
                a.insert(c, Objectives {
                    accuracy: 50.0 + 40.0 * rng.f64(),
                    latency_ms: 5.0 + 50.0 * rng.f64(),
                    memory_gb: 1.0 + 10.0 * rng.f64(),
                    energy_j: 0.1 + rng.f64(),
                });
            }
            // through the Json value AND through its text form (the
            // on-disk path): both must restore the archive exactly
            let back = ParetoArchive::from_json(&a.to_json()).unwrap();
            assert_eq!(key(&a), key(&back), "seed {seed}");
            assert_eq!(back.capacity(), capacity);
            back.check_invariants();
            let text = a.to_json().dump();
            let reparsed = ParetoArchive::from_json(
                &crate::util::json::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(key(&a), key(&reparsed), "seed {seed} (text)");
        }
    }

    #[test]
    fn json_roundtrip_empty_front_and_duplicate_objectives() {
        // Empty front: entries [] and capacity survive.
        let empty = ParetoArchive::new(7);
        let back = ParetoArchive::from_json(&empty.to_json()).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.capacity(), 7);

        // Distinct configs with byte-identical objectives (mutually
        // non-dominating duplicates) all survive, in order.
        let mut a = ParetoArchive::new(10);
        let o = obj(70.0, 10.0);
        a.insert(cfg(1), o);
        a.insert(cfg(2), o);
        a.insert(cfg(3), o);
        assert_eq!(a.len(), 3, "equal objectives are mutually \
                                non-dominated and must all be kept");
        let back = ParetoArchive::from_json(&a.to_json()).unwrap();
        assert_eq!(key(&a), key(&back));
    }

    #[test]
    fn json_roundtrip_preserves_capacity_behavior() {
        // After a round trip, inserting past capacity truncates by
        // crowding exactly like the original would.
        let mut a = ParetoArchive::new(5);
        for i in 0..3 {
            a.insert(cfg(i), obj(50.0 + i as f64, 10.0 + i as f64));
        }
        let mut b = ParetoArchive::from_json(&a.to_json()).unwrap();
        for i in 3..20 {
            let o = obj(50.0 + i as f64, 10.0 + i as f64);
            a.insert(cfg(i), o);
            b.insert(cfg(i), o);
        }
        assert_eq!(a.len(), 5);
        assert_eq!(key(&a), key(&b));
    }

    #[test]
    fn from_json_rejects_wrong_schema_and_garbage() {
        let mut wrong = std::collections::BTreeMap::new();
        wrong.insert("schema".to_string(),
                     crate::util::json::Json::Str("nope".into()));
        assert!(ParetoArchive::from_json(
            &crate::util::json::Json::Obj(wrong)).is_err());
        let j = crate::util::json::Json::parse(
            r#"{"schema":"ae-llm.front/v1","capacity":4,
                "entries":[{"signature":"bogus","objectives":
                {"accuracy":1,"latency_ms":1,"memory_gb":1,"energy_j":1}}]}"#,
        )
        .unwrap();
        assert!(ParetoArchive::from_json(&j).is_err());
    }

    #[test]
    fn best_by_utility() {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), obj(70.0, 10.0));
        a.insert(cfg(2), obj(80.0, 30.0));
        let fastest = a.best_by(|e| -e.objectives.latency_ms).unwrap();
        assert_eq!(fastest.objectives.latency_ms, 10.0);
        let most_accurate = a.best_by(|e| e.objectives.accuracy).unwrap();
        assert_eq!(most_accurate.objectives.accuracy, 80.0);
    }
}
