//! Retained reference implementations of the search kernels
//! (DESIGN.md §15/§17 idiom): the textbook non-dominated sort, crowding
//! distance and WFG-style hypervolume exactly as they ran before the
//! speed pass, kept as the differential-testing oracle and the
//! "before" rows of `benches/perf_search.rs`.
//!
//! Not for production use: the sort allocates `Vec<Vec<usize>>`
//! adjacency lists and tests every pair in both directions, crowding
//! re-sorts through two levels of indirection per comparison, and the
//! hypervolume recursion clones `Vec<Vec<f64>>` at every level.
//!
//! The only deliberate difference from the historical text is the
//! comparator: `f64::total_cmp` instead of `partial_cmp(..).unwrap()`,
//! the same NaN-abort fix the production kernels carry, so the
//! differential tests can include NaN regimes.  On every input that
//! did not previously panic the ordering is unchanged (modulo the
//! `-0.0 < +0.0` distinction noted in [`super::dominance`]).
//!
//! These are `pub` rather than `#[cfg(test)]` because the bench
//! binaries compile against the library without its test cfg.

use super::dominance::{dominates, MinVec};

/// [`super::dominance::non_dominated_sort`], pre-rewrite
/// implementation: per-call adjacency lists, both dominance directions
/// tested per pair.
pub fn ref_non_dominated_sort(objs: &[MinVec]) -> Vec<Vec<usize>> {
    let n = objs.len();
    if n == 0 {
        return Vec::new();
    }
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&objs[i], &objs[j]) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if dominates(&objs[j], &objs[i]) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> =
        (0..n).filter(|&i| dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// [`super::dominance::crowding_distance`], pre-rewrite
/// implementation: the argsort comparator reads
/// `objs[front[a]][obj]` through both indirections on every
/// comparison.
pub fn ref_crowding_distance(objs: &[MinVec], front: &[usize]) -> Vec<f64> {
    let n = front.len();
    let mut dist = vec![0.0f64; n];
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    let m = objs[0].len();
    let mut order: Vec<usize> = (0..n).collect();
    for obj in 0..m {
        order.sort_by(|&a, &b| {
            objs[front[a]][obj].total_cmp(&objs[front[b]][obj])
        });
        let lo = objs[front[order[0]]][obj];
        let hi = objs[front[order[n - 1]]][obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[n - 1]] = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for k in 1..n - 1 {
            let prev = objs[front[order[k - 1]]][obj];
            let next = objs[front[order[k + 1]]][obj];
            dist[order[k]] += (next - prev) / span;
        }
    }
    dist
}

/// [`super::dominance::pareto_front`], pre-rewrite implementation
/// (front 0 of the full sort).
pub fn ref_pareto_front(objs: &[MinVec]) -> Vec<usize> {
    ref_non_dominated_sort(objs).into_iter().next().unwrap_or_default()
}

/// [`super::hypervolume::hypervolume`], pre-rewrite implementation:
/// clones the point set into `Vec<Vec<f64>>` and re-clones at every
/// recursion level.
pub fn ref_hypervolume(points: &[MinVec], r: &MinVec) -> f64 {
    let pts: Vec<Vec<f64>> = points
        .iter()
        .filter(|p| p.iter().zip(r).all(|(a, b)| a <= b))
        .map(|p| p.to_vec())
        .collect();
    ref_hv_rec(&pts, &r.to_vec())
}

fn ref_hv_rec(points: &[Vec<f64>], r: &[f64]) -> f64 {
    let d = r.len();
    if points.is_empty() {
        return 0.0;
    }
    if d == 1 {
        let best = points
            .iter()
            .map(|p| p[0])
            .fold(f64::INFINITY, f64::min);
        return (r[0] - best).max(0.0);
    }
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| points[a][d - 1].total_cmp(&points[b][d - 1]));
    let mut volume = 0.0;
    let mut active: Vec<Vec<f64>> = Vec::new();
    for (k, &i) in order.iter().enumerate() {
        active.push(points[i][..d - 1].to_vec());
        let z_lo = points[i][d - 1];
        let z_hi = if k + 1 < order.len() {
            points[order[k + 1]][d - 1]
        } else {
            r[d - 1]
        };
        if z_hi > z_lo {
            let slice =
                ref_hv_rec(&ref_nondominated(&active), &r[..d - 1].to_vec());
            volume += slice * (z_hi - z_lo);
        }
    }
    volume
}

/// Strip dominated points (minimization, arbitrary dimension) — the
/// pre-rewrite helper with its O(n²) `keep.contains` duplicate scan.
fn ref_nondominated(points: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let mut keep = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && dominates_vec(q, p) {
                continue 'outer;
            }
        }
        if !keep.contains(p) {
            keep.push(p.clone());
        }
    }
    keep
}

fn dominates_vec(a: &[f64], b: &[f64]) -> bool {
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::dominance::{
        crowding_distance_with, non_dominated_sort_with, pareto_front,
        CrowdingScratch, SortScratch,
    };
    use crate::search::hypervolume::{hypervolume_with, HvScratch};
    use crate::util::Rng;

    /// The tie/duplicate regimes the differential tests sweep.  Regime
    /// 1 is all-tied (one repeated point), 2 a strictly dominated
    /// chain, 3 quantized coordinates (heavy per-coordinate ties and
    /// exact duplicate points), 4 sprinkles NaN coordinates.
    fn gen_objs(rng: &mut Rng, n: usize, regime: u8) -> Vec<MinVec> {
        (0..n)
            .map(|i| match regime {
                0 => [rng.f64(), rng.f64(), rng.f64(), rng.f64()],
                1 => [0.5, 0.25, 0.75, 0.125],
                2 => {
                    let x = i as f64;
                    [x, x, x, x]
                }
                3 => {
                    let mut q = || (rng.f64() * 4.0).floor() / 4.0;
                    [q(), q(), q(), q()]
                }
                _ => {
                    let mut v = || {
                        let x = rng.f64();
                        if x < 0.15 { f64::NAN } else { x }
                    };
                    [v(), v(), v(), v()]
                }
            })
            .collect()
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The satellite property test: the pruned bitset sort returns the
    /// *identical* `Vec<Vec<usize>>` — front order included — as the
    /// retained reference, across random/tied/dominated/duplicate/NaN
    /// objective sets and the n=0/1/2 edges, with one scratch reused
    /// across every case.
    #[test]
    fn sort_matches_reference_exactly() {
        let mut scratch = SortScratch::default();
        for regime in 0..5u8 {
            for &n in &[0usize, 1, 2, 3, 17, 64, 200] {
                for seed in 0..3u64 {
                    let mut rng = Rng::new(1000 * seed + n as u64 + 7);
                    let objs = gen_objs(&mut rng, n, regime);
                    let new = non_dominated_sort_with(&mut scratch, &objs);
                    let old = ref_non_dominated_sort(&objs);
                    assert_eq!(new, old,
                               "sort diverged: regime {regime} n {n} \
                                seed {seed}");
                    let total: usize = new.iter().map(|f| f.len()).sum();
                    assert_eq!(total, n, "fronts must partition the set");
                }
            }
        }
    }

    /// Crowding distances are `.to_bits()`-exact against the reference
    /// on every front of every regime (same comparator, same float add
    /// order), with one scratch reused throughout.
    #[test]
    fn crowding_matches_reference_bitwise() {
        let mut scratch = CrowdingScratch::default();
        for regime in 0..5u8 {
            for &n in &[0usize, 1, 2, 3, 17, 64, 200] {
                let mut rng = Rng::new(40 + n as u64);
                let objs = gen_objs(&mut rng, n, regime);
                // every front of the decomposition, plus the whole set
                // as one synthetic front
                let mut fronts = ref_non_dominated_sort(&objs);
                fronts.push((0..n).collect());
                for front in &fronts {
                    let new =
                        crowding_distance_with(&mut scratch, &objs, front);
                    let old = ref_crowding_distance(&objs, front);
                    assert_eq!(bits(&new), bits(&old),
                               "crowding diverged: regime {regime} n {n} \
                                front len {}", front.len());
                }
            }
        }
    }

    #[test]
    fn pareto_front_matches_reference() {
        for regime in 0..5u8 {
            for &n in &[0usize, 1, 2, 3, 17, 64, 200] {
                let mut rng = Rng::new(90 + n as u64);
                let objs = gen_objs(&mut rng, n, regime);
                assert_eq!(pareto_front(&objs), ref_pareto_front(&objs),
                           "pareto_front diverged: regime {regime} n {n}");
            }
        }
    }

    /// Hypervolume is `.to_bits()`-exact against the reference (same
    /// sweep order, same slab-sum order), with one arena reused across
    /// every case.
    #[test]
    fn hypervolume_matches_reference_bitwise() {
        let mut scratch = HvScratch::default();
        let r: MinVec = [60.0, 60.0, 60.0, 60.0];
        for regime in 0..5u8 {
            for &n in &[0usize, 1, 2, 3, 17, 48] {
                for seed in 0..2u64 {
                    let mut rng = Rng::new(500 * seed + n as u64 + 13);
                    let objs = gen_objs(&mut rng, n, regime);
                    let new = hypervolume_with(&mut scratch, &objs, &r);
                    let old = ref_hypervolume(&objs, &r);
                    assert_eq!(new.to_bits(), old.to_bits(),
                               "hv diverged: regime {regime} n {n} seed \
                                {seed} ({new} vs {old})");
                    assert!(new >= 0.0 || new.is_nan());
                }
            }
        }
    }

    /// The public throwaway-scratch wrappers agree with the `_with`
    /// forms (and therefore with the references) on a mixed workload.
    #[test]
    fn wrappers_agree_with_scratch_forms() {
        use crate::search::dominance::{crowding_distance,
                                       non_dominated_sort};
        use crate::search::hypervolume::hypervolume;
        let mut rng = Rng::new(77);
        let objs = gen_objs(&mut rng, 64, 3);
        assert_eq!(non_dominated_sort(&objs),
                   ref_non_dominated_sort(&objs));
        let front: Vec<usize> = (0..objs.len()).collect();
        assert_eq!(bits(&crowding_distance(&objs, &front)),
                   bits(&ref_crowding_distance(&objs, &front)));
        let r = [60.0; 4];
        assert_eq!(hypervolume(&objs, &r).to_bits(),
                   ref_hypervolume(&objs, &r).to_bits());
    }
}
