//! S8: baseline configuration selectors (paper §4.1 "Baselines").
//!
//! * **Default** — vanilla MHA/dense/Full-FT/FP16;
//! * **Best Single-Stage** — optimize one lifecycle stage exhaustively
//!   while holding the others at default, return the best of the three
//!   single-stage optima (no cross-stage interaction captured);
//! * **Manual Selection** — an "experienced practitioner" rule set;
//! * **EfficientLLM Recommended** — static per-scale recommendation
//!   aggregated across tasks (no task-specific adaptation);
//! * **Random Search** — budgeted random sampling (Table 3 ablation
//!   "- Predictive Models").
//!
//! All five run through the [`Evaluator`] backend trait — the selector
//! baselines measure their candidates in one `measure_batch` call, so
//! they inherit parallel fan-out, caching decorators and
//! [`Evaluator::evals`] counting; the rule-based baselines never touch
//! the backend at all (that is their handicap, and their eval count is
//! provably zero).  The pre-PR-3 bespoke `FnMut(&Config) -> Objectives`
//! closure convention is gone.

use crate::config::{
    enumerate, validity, ArchConfig, Attention, Config, FtConfig, FtMethod,
    InfConfig, KvCache, MoE, Precision, QuantMethod,
};
use crate::evaluator::{EvalContext, Evaluator};
use crate::hardware::Platform;
use crate::metrics::{utility, Preferences, Reference};
use crate::models::{ModelSpec, Scale};
use crate::oracle::Objectives;
use crate::tasks::{Category, TaskSpec};
use crate::util::Rng;

/// The five comparison methods of Table 2 (AE-LLM itself lives in
/// `coordinator`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Baseline {
    Default,
    BestSingleStage,
    ManualSelection,
    EfficientLlmRec,
    RandomSearch { budget: usize },
}

impl Baseline {
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::Default => "Default",
            Baseline::BestSingleStage => "Best Single-Stage",
            Baseline::ManualSelection => "Manual Selection",
            Baseline::EfficientLlmRec => "EfficientLLM Rec.",
            Baseline::RandomSearch { .. } => "Random Search",
        }
    }
}

/// Select a configuration with the given baseline method.
///
/// `evaluator` plays the role of running candidates on the testbed:
/// selector baselines measure a limited candidate batch through it
/// (their cost shows up in [`Evaluator::evals`]); rule-based baselines
/// don't evaluate at all.  `rng` drives both candidate sampling and
/// the backend's measurement noise.
pub fn select<F>(
    baseline: Baseline,
    m: &ModelSpec,
    t: &TaskSpec,
    platform: &Platform,
    reference: &Reference,
    prefs: &Preferences,
    evaluator: &mut dyn Evaluator,
    feasible: &F,
    ctx: &EvalContext,
    rng: &mut Rng,
) -> Config
where
    F: Fn(&Config) -> bool,
{
    match baseline {
        Baseline::Default => Config::default_baseline(),
        Baseline::BestSingleStage => {
            best_single_stage(reference, prefs, evaluator, feasible, ctx,
                              rng)
        }
        Baseline::ManualSelection => manual_selection(m, t, platform),
        Baseline::EfficientLlmRec => efficient_llm_rec(m),
        Baseline::RandomSearch { budget } => {
            random_search(budget, reference, prefs, evaluator, feasible,
                          ctx, rng)
        }
    }
}

/// Candidate configs that vary exactly one stage from default.
pub fn single_stage_candidates() -> Vec<Config> {
    let d = Config::default_baseline();
    let mut out = Vec::new();
    // architecture stage
    for &attention in &Attention::ALL {
        for &moe in &MoE::ALL {
            out.push(Config { arch: ArchConfig { attention, moe }, ..d });
        }
    }
    // fine-tuning stage
    for &method in &FtMethod::ALL {
        if method.is_peft() {
            for &rank in &crate::config::RANKS {
                for &alpha_mult in &crate::config::ALPHA_MULTS {
                    out.push(Config {
                        ft: FtConfig { method, rank, alpha_mult },
                        ..d
                    });
                }
            }
        } else {
            out.push(Config { ft: FtConfig::full(), ..d });
        }
    }
    // inference stage
    for &precision in &Precision::ALL {
        for &quant_method in &QuantMethod::ALL {
            for &kv_cache in &KvCache::ALL {
                out.push(Config {
                    inf: InfConfig { precision, quant_method, kv_cache },
                    ..d
                });
            }
        }
    }
    out.retain(|c| validity::is_valid(c));
    out.dedup();
    out
}

/// Pick the utility-argmax of one measured candidate batch (first
/// candidate wins ties, matching the old sequential `>` comparison).
fn best_of_batch(
    candidates: &[Config],
    evaluator: &mut dyn Evaluator,
    reference: &Reference,
    prefs: &Preferences,
    ctx: &EvalContext,
    rng: &mut Rng,
) -> Config {
    debug_assert!(!candidates.is_empty());
    let objectives = evaluator.measure_batch(candidates, ctx, rng);
    let mut best = candidates[0];
    let mut best_u = utility(&objectives[0], reference, prefs);
    for (c, o) in candidates.iter().zip(&objectives).skip(1) {
        let u = utility(o, reference, prefs);
        if u > best_u {
            best_u = u;
            best = *c;
        }
    }
    best
}

fn best_single_stage<F>(
    reference: &Reference,
    prefs: &Preferences,
    evaluator: &mut dyn Evaluator,
    feasible: &F,
    ctx: &EvalContext,
    rng: &mut Rng,
) -> Config
where
    F: Fn(&Config) -> bool,
{
    // Default first so it wins ties, then every feasible single-stage
    // variant — measured as one batch (parallel backends fan it out).
    let mut candidates = vec![Config::default_baseline()];
    candidates.extend(
        single_stage_candidates().into_iter().filter(|c| feasible(c)),
    );
    best_of_batch(&candidates, evaluator, reference, prefs, ctx, rng)
}

/// Expert rule set: sensible, interaction-blind heuristics (paper §4.2
/// finds it 15–25% behind automated search).
fn manual_selection(m: &ModelSpec, t: &TaskSpec,
                    platform: &Platform) -> Config {
    let mut c = Config::default_baseline();

    // Practitioners deploy INT8 by default (industry standard), INT4
    // only under hard memory pressure, FP16 never for serving cost.
    let fp16_gb = m.params_b * 2.0;
    let pressure = fp16_gb / platform.mem_capacity_gb;
    c.inf.precision = if pressure > 0.8 {
        Precision::Int4
    } else {
        Precision::Int8
    };
    c.inf.quant_method = QuantMethod::Awq; // practitioners' favourite
    if t.quant_sensitivity > 0.8 && c.inf.precision == Precision::Int4 {
        // experts know GSM8K-style tasks break under INT4
        c.inf.precision = Precision::Int8;
    }

    // GQA attention everywhere; long-context also gets a KV policy.
    c.arch.attention = Attention::Gqa;
    if t.category == Category::LongContext || t.seq_len >= 4096 {
        c.inf.kv_cache = KvCache::MqaStyle;
    }

    // PEFT by scale (the folklore table).
    c.ft = match m.scale {
        Scale::Small => FtConfig::full(),
        Scale::Medium => FtConfig {
            method: FtMethod::LoRA, rank: 32, alpha_mult: 2,
        },
        Scale::Large => FtConfig {
            method: FtMethod::LoRA, rank: 64, alpha_mult: 2,
        },
    };

    // Experts reach for MoE on routing-friendly workloads at scale.
    if t.moe_affinity > 0.6 && m.scale == Scale::Large {
        c.arch.moe = MoE::Sparse { experts: 4, top_k: 2 };
    }

    debug_assert!(validity::is_valid(&c), "manual rule produced {c}");
    c
}

/// EfficientLLM benchmark recommendations: static per-scale settings
/// aggregated over tasks (Yuan et al. 2025), as summarized in the paper
/// (§5.1: GQA + LoRA-32 for 7B, RSLoRA-64+ at 30B+, INT8 as the safe
/// default quantization).
fn efficient_llm_rec(m: &ModelSpec) -> Config {
    let mut c = Config::default_baseline();
    c.arch.attention = Attention::Gqa;
    c.inf.precision = Precision::Int8;
    c.inf.quant_method = QuantMethod::Awq;
    c.inf.kv_cache = KvCache::Full;
    c.ft = match m.scale {
        Scale::Small => FtConfig {
            method: FtMethod::LoRA, rank: 16, alpha_mult: 2,
        },
        Scale::Medium => FtConfig {
            method: FtMethod::LoRA, rank: 32, alpha_mult: 2,
        },
        Scale::Large => FtConfig {
            method: FtMethod::RsLoRA, rank: 64, alpha_mult: 2,
        },
    };
    debug_assert!(validity::is_valid(&c));
    c
}

fn random_search<F>(
    budget: usize,
    reference: &Reference,
    prefs: &Preferences,
    evaluator: &mut dyn Evaluator,
    feasible: &F,
    ctx: &EvalContext,
    rng: &mut Rng,
) -> Config
where
    F: Fn(&Config) -> bool,
{
    // Default first (tie-winner), then `budget` samples filtered to the
    // feasible ones — measured as one batch.
    let mut candidates = vec![Config::default_baseline()];
    for _ in 0..budget {
        let c = enumerate::sample(rng);
        if feasible(&c) {
            candidates.push(c);
        }
    }
    best_of_batch(&candidates, evaluator, reference, prefs, ctx, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware;
    use crate::models::by_name;
    use crate::oracle::Testbed;
    use crate::tasks::{blended_task, by_name as task};
    use crate::util::Parallelism;

    struct Env {
        tb: Testbed,
        m: ModelSpec,
        t: TaskSpec,
        reference: Reference,
    }

    fn env(model: &str) -> Env {
        let m = by_name(model).unwrap();
        let tb = Testbed::noiseless(hardware::tier_for_scale(m.scale));
        let t = blended_task();
        let reference = Reference {
            default: tb.true_objectives(&Config::default_baseline(), &m, &t),
        };
        Env { tb, m, t, reference }
    }

    fn run_baseline_counting(b: Baseline, e: &Env) -> (Config, usize) {
        let mut rng = Rng::new(1);
        let mut evaluator = e.tb.clone();
        let ctx = EvalContext::new(&e.m, &e.t, Parallelism::Sequential);
        let c = select(
            b,
            &e.m,
            &e.t,
            &e.tb.platform,
            &e.reference,
            &Preferences::default(),
            &mut evaluator,
            &|c: &Config| e.tb.feasible(c, &e.m, &e.t),
            &ctx,
            &mut rng,
        );
        (c, Evaluator::evals(&evaluator))
    }

    fn run_baseline(b: Baseline, e: &Env) -> Config {
        run_baseline_counting(b, e).0
    }

    #[test]
    fn default_baseline_returns_default() {
        let e = env("LLaMA-2-7B");
        assert_eq!(run_baseline(Baseline::Default, &e),
                   Config::default_baseline());
    }

    #[test]
    fn rule_based_baselines_never_touch_the_evaluator() {
        let e = env("LLaMA-2-7B");
        for b in [Baseline::Default, Baseline::ManualSelection,
                  Baseline::EfficientLlmRec] {
            let (_, evals) = run_baseline_counting(b, &e);
            assert_eq!(evals, 0, "{} measured {evals} configs", b.name());
        }
    }

    #[test]
    fn selector_baselines_report_eval_counts() {
        let e = env("LLaMA-2-7B");
        let (_, evals) = run_baseline_counting(Baseline::BestSingleStage, &e);
        // default + every feasible single-stage candidate
        assert!(evals > 50, "best-single-stage evals {evals}");
        let (_, evals) =
            run_baseline_counting(Baseline::RandomSearch { budget: 50 }, &e);
        // default + the feasible subset of 50 samples
        assert!(evals >= 1 && evals <= 51, "random-search evals {evals}");
        assert!(evals > 10, "random-search evals suspiciously low: {evals}");
    }

    #[test]
    fn single_stage_candidates_valid_and_single_stage() {
        let d = Config::default_baseline();
        let cands = single_stage_candidates();
        assert!(cands.len() > 50);
        for c in &cands {
            assert!(validity::is_valid(c));
            let stages_changed = [c.arch != d.arch, c.ft != d.ft,
                                  c.inf != d.inf];
            assert!(stages_changed.iter().filter(|&&x| x).count() <= 1,
                    "{c} changes multiple stages");
        }
    }

    #[test]
    fn best_single_stage_beats_default() {
        let e = env("LLaMA-2-7B");
        let c = run_baseline(Baseline::BestSingleStage, &e);
        let u_best = utility(&e.tb.true_objectives(&c, &e.m, &e.t),
                             &e.reference, &Preferences::default());
        let u_def = utility(&e.reference.default, &e.reference,
                            &Preferences::default());
        assert!(u_best > u_def, "best={u_best} default={u_def}");
    }

    #[test]
    fn selection_is_parallelism_invariant() {
        // The batch goes through `measure_batch`, whose RNG discipline
        // makes results identical at every parallelism level.
        let e = env("LLaMA-2-7B");
        let noisy = Testbed::new(hardware::a100());
        let go = |par: Parallelism| {
            let mut evaluator = noisy.clone();
            let ctx = EvalContext::new(&e.m, &e.t, par);
            select(
                Baseline::BestSingleStage,
                &e.m,
                &e.t,
                &e.tb.platform,
                &e.reference,
                &Preferences::default(),
                &mut evaluator,
                &|c: &Config| e.tb.feasible(c, &e.m, &e.t),
                &ctx,
                &mut Rng::new(11),
            )
        };
        assert_eq!(go(Parallelism::Sequential), go(Parallelism::Threads(4)));
    }

    #[test]
    fn manual_selection_adapts_to_memory_pressure() {
        let small = env("LLaMA-2-7B"); // A100: no pressure at 13GB/80GB
        let c7 = run_baseline(Baseline::ManualSelection, &small);
        // 7B on A100 -> fp16 or int8, not int4
        assert_ne!(c7.inf.precision, Precision::Int4);

        // 70B on its tier is fine, but force consumer platform:
        let m70 = by_name("LLaMA-2-70B").unwrap();
        let c = manual_selection(&m70, &blended_task(),
                                 &hardware::rtx4090());
        assert_eq!(c.inf.precision, Precision::Int4);
    }

    #[test]
    fn manual_selection_avoids_int4_on_sensitive_tasks() {
        let m70 = by_name("LLaMA-2-70B").unwrap();
        let gsm = task("GSM8K").unwrap();
        let c = manual_selection(&m70, &gsm, &hardware::rtx4090());
        assert_ne!(c.inf.precision, Precision::Int4);
    }

    #[test]
    fn efficient_llm_rec_is_scale_dependent_not_task_dependent() {
        let m7 = by_name("LLaMA-2-7B").unwrap();
        let m70 = by_name("LLaMA-2-70B").unwrap();
        let c7 = efficient_llm_rec(&m7);
        let c70 = efficient_llm_rec(&m70);
        assert_eq!(c7.ft.method, FtMethod::LoRA);
        assert_eq!(c70.ft.method, FtMethod::RsLoRA);
        assert!(c70.ft.rank > c7.ft.rank);
        // task-independence: same config whatever the task
        assert_eq!(efficient_llm_rec(&m7), efficient_llm_rec(&m7));
    }

    #[test]
    fn random_search_improves_with_budget() {
        let e = env("LLaMA-2-7B");
        let u_of = |c: &Config| {
            utility(&e.tb.true_objectives(c, &e.m, &e.t), &e.reference,
                    &Preferences::default())
        };
        let small = run_baseline(Baseline::RandomSearch { budget: 10 }, &e);
        let big = run_baseline(Baseline::RandomSearch { budget: 400 }, &e);
        assert!(u_of(&big) >= u_of(&small));
    }

    #[test]
    fn all_baselines_return_feasible_configs() {
        for model in ["LLaMA-2-1B", "LLaMA-2-7B", "LLaMA-2-70B"] {
            let e = env(model);
            for b in [Baseline::Default, Baseline::BestSingleStage,
                      Baseline::ManualSelection, Baseline::EfficientLlmRec,
                      Baseline::RandomSearch { budget: 50 }] {
                let c = run_baseline(b, &e);
                assert!(validity::is_valid(&c), "{model} {:?}", b.name());
                assert!(e.tb.feasible(&c, &e.m, &e.t),
                        "{model} {} infeasible", b.name());
            }
        }
    }
}
