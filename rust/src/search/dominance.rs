//! Pareto dominance machinery: fast non-dominated sorting and crowding
//! distance (Deb et al. 2002), the core of the modified NSGA-II.
//!
//! Two generations of kernels live in the search layer (DESIGN.md §15
//! "Hot-path inventory", §17 "Search-kernel inventory"):
//!
//! * this module — the production kernels.  `non_dominated_sort` sorts
//!   candidates by first objective once so the pairwise dominance pass
//!   tests one direction per pair instead of two (a dominator must be
//!   `<=` in *every* coordinate, so only the key-`<=` half-space can
//!   dominate), and stores the dominance graph in a reusable flat
//!   bitset ([`SortScratch`]) instead of per-call `Vec<Vec<usize>>`
//!   adjacency lists.  `crowding_distance` gathers each objective
//!   column into a reusable scratch ([`CrowdingScratch`]) so the
//!   per-objective argsort re-sorts a flat key array instead of
//!   chasing `objs[front[a]][obj]` through two indirections per
//!   comparison.  Both are *bit-identical* to the retained textbook
//!   implementations in [`super::reference`] — front order, tie
//!   order and every float — which the differential tests enforce
//!   with exact `.to_bits()` equality.
//! * [`super::reference`] — the pre-rewrite kernels, retained verbatim
//!   as the differential-testing oracle and the "before" rows of
//!   `benches/perf_search.rs`.
//!
//! Comparator note: these kernels order floats with `f64::total_cmp`
//! where the references used `partial_cmp(..).unwrap()`.  The orders
//! agree on every input that did not previously panic, except that
//! `total_cmp` distinguishes `-0.0 < +0.0` where `partial_cmp` ties
//! them (objective vectors never produce a meaningful ±0 split), and a
//! NaN objective now sorts deterministically instead of aborting the
//! process (see `nan_objectives_do_not_panic`).

/// Objective vectors are in *minimization* convention ([f64; 4] from
/// `Objectives::as_min_vec`).
pub type MinVec = [f64; 4];

/// True iff `a` dominates `b` (<= everywhere, < somewhere).
pub fn dominates(a: &MinVec, b: &MinVec) -> bool {
    let mut strict = false;
    for i in 0..a.len() {
        if a[i] > b[i] {
            return false;
        }
        if a[i] < b[i] {
            strict = true;
        }
    }
    strict
}

/// Sort key for the first-objective prefix pruning: NaN maps to -inf so
/// a NaN-coordinate entry is always inside the scanned prefix (the
/// prefix must be a *superset* of possible dominators; the exact
/// dominance test runs on everything it admits).  Shared with the
/// archive's batched pre-filter.
pub(crate) fn first_coord_key(x: f64) -> f64 {
    if x.is_nan() { f64::NEG_INFINITY } else { x }
}

/// Reusable scratch for [`non_dominated_sort_with`]: the first-objective
/// sort keys, the sorted candidate order, the dominance graph as a flat
/// bitset (row i = the set of indices i dominates) and the per-index
/// dominator counts.  One instance amortizes every allocation across
/// the generations of a search run; [`non_dominated_sort`] wraps a
/// throwaway one for call sites without a loop to carry it through.
#[derive(Clone, Debug, Default)]
pub struct SortScratch {
    keys: Vec<f64>,
    order: Vec<u32>,
    bits: Vec<u64>,
    dom_count: Vec<u32>,
}

/// Fast non-dominated sort: returns fronts as index lists, best first.
/// O(M·N²) pairwise tests as in the paper's complexity analysis, but
/// the candidates are sorted by first objective once so each pair is
/// tested in one direction only (the reverse direction is impossible
/// unless the first coordinates tie; NaN first coordinates are handled
/// conservatively via [`first_coord_key`]).
///
/// The front decomposition — *including the index order within each
/// front and the order of the fronts* — is bit-identical to
/// [`super::reference::ref_non_dominated_sort`].  That order is part
/// of the contract: environmental selection in `nsga2.rs` walks fronts
/// in order and breaks capacity ties by stable crowding sorts, so any
/// reordering here would change search trajectories.  The flat bitset
/// reproduces it exactly because a bitset row is iterated in ascending
/// index order, which is provably the order the reference's adjacency
/// lists are built in (dominators at outer index i push smaller
/// indices before larger ones).
pub fn non_dominated_sort_with(s: &mut SortScratch,
                               objs: &[MinVec]) -> Vec<Vec<usize>> {
    let n = objs.len();
    if n == 0 {
        return Vec::new();
    }
    s.keys.clear();
    s.keys.extend(objs.iter().map(|o| first_coord_key(o[0])));
    s.order.clear();
    s.order.extend(0..n as u32);
    {
        let keys = &s.keys;
        // Stable, so equal keys stay in ascending index order.
        s.order.sort_by(|&a, &b| {
            keys[a as usize].total_cmp(&keys[b as usize])
        });
    }
    let wpr = (n + 63) / 64; // bitset words per row
    s.bits.clear();
    s.bits.resize(n * wpr, 0);
    s.dom_count.clear();
    s.dom_count.resize(n, 0);
    for q in 1..n {
        let iq = s.order[q] as usize;
        let oq = &objs[iq];
        let kq = s.keys[iq];
        for p in 0..q {
            let ip = s.order[p] as usize;
            let op = &objs[ip];
            if dominates(op, oq) {
                s.bits[ip * wpr + (iq >> 6)] |= 1u64 << (iq & 63);
                s.dom_count[iq] += 1;
            } else if (s.keys[ip] == kq || op[0].is_nan())
                && dominates(oq, op)
            {
                // The later-sorted point can only dominate the earlier
                // one when their first-coordinate keys tie, or when the
                // earlier point's first coordinate is NaN (it compares
                // false against everything, so it constrains nothing).
                s.bits[iq * wpr + (ip >> 6)] |= 1u64 << (ip & 63);
                s.dom_count[ip] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> =
        (0..n).filter(|&i| s.dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            let row = &s.bits[i * wpr..(i + 1) * wpr];
            for (w, &bits) in row.iter().enumerate() {
                let mut word = bits;
                while word != 0 {
                    let j = (w << 6) | word.trailing_zeros() as usize;
                    word &= word - 1;
                    s.dom_count[j] -= 1;
                    if s.dom_count[j] == 0 {
                        next.push(j);
                    }
                }
            }
        }
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// [`non_dominated_sort_with`] through a throwaway scratch, for call
/// sites without a search loop to amortize one across.
pub fn non_dominated_sort(objs: &[MinVec]) -> Vec<Vec<usize>> {
    non_dominated_sort_with(&mut SortScratch::default(), objs)
}

/// Reusable scratch for [`crowding_distance_with`]: the cumulative
/// argsort permutation and the gathered objective column.
#[derive(Clone, Debug, Default)]
pub struct CrowdingScratch {
    order: Vec<u32>,
    keys: Vec<f64>,
}

/// Crowding distance of each member within one front (diversity
/// preservation §3.3.2).  Boundary solutions get +inf.
///
/// Bit-identical to [`super::reference::ref_crowding_distance`]: the
/// argsort permutation is initialized to identity once per call and
/// then *cumulatively* re-sorted per objective (stable sorts of the
/// previous permutation — resetting it would change tie ordering), and
/// the distance contributions are added in the same order with the
/// same operands, so every output float matches to the bit.
pub fn crowding_distance_with(s: &mut CrowdingScratch, objs: &[MinVec],
                              front: &[usize]) -> Vec<f64> {
    let n = front.len();
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    let mut dist = vec![0.0f64; n];
    let m = objs[0].len();
    s.order.clear();
    s.order.extend(0..n as u32);
    for obj in 0..m {
        s.keys.clear();
        s.keys.extend(front.iter().map(|&i| objs[i][obj]));
        let keys = &s.keys;
        s.order.sort_by(|&a, &b| {
            keys[a as usize].total_cmp(&keys[b as usize])
        });
        let lo = keys[s.order[0] as usize];
        let hi = keys[s.order[n - 1] as usize];
        dist[s.order[0] as usize] = f64::INFINITY;
        dist[s.order[n - 1] as usize] = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for k in 1..n - 1 {
            let prev = keys[s.order[k - 1] as usize];
            let next = keys[s.order[k + 1] as usize];
            dist[s.order[k] as usize] += (next - prev) / span;
        }
    }
    dist
}

/// [`crowding_distance_with`] through a throwaway scratch.
pub fn crowding_distance(objs: &[MinVec], front: &[usize]) -> Vec<f64> {
    crowding_distance_with(&mut CrowdingScratch::default(), objs, front)
}

/// Extract the non-dominated subset of a set of objective vectors
/// (indices into `objs`, ascending — exactly front 0 of
/// [`non_dominated_sort`], computed without building the full front
/// decomposition: each candidate scans only the first-objective prefix
/// that could dominate it).
pub fn pareto_front(objs: &[MinVec]) -> Vec<usize> {
    let n = objs.len();
    if n == 0 {
        return Vec::new();
    }
    let mut by_key: Vec<(f64, u32)> = (0..n)
        .map(|i| (first_coord_key(objs[i][0]), i as u32))
        .collect();
    by_key.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut front = Vec::new();
    'cand: for i in 0..n {
        let hi = if objs[i][0].is_nan() {
            f64::INFINITY
        } else {
            objs[i][0]
        };
        let prefix = by_key.partition_point(|&(k, _)| k <= hi);
        for &(_, j) in &by_key[..prefix] {
            if j as usize != i && dominates(&objs[j as usize], &objs[i]) {
                continue 'cand;
            }
        }
        front.push(i);
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominates_basics() {
        let a = [1.0, 1.0, 1.0, 1.0];
        let b = [2.0, 1.0, 1.0, 1.0];
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &a)); // equality is not domination
    }

    #[test]
    fn incomparable_points() {
        let a = [1.0, 2.0, 0.0, 0.0];
        let b = [2.0, 1.0, 0.0, 0.0];
        assert!(!dominates(&a, &b));
        assert!(!dominates(&b, &a));
    }

    #[test]
    fn sort_splits_into_correct_fronts() {
        // (0) and (1) trade off; (2) dominated by (0); (3) dominated by all
        let objs = vec![
            [1.0, 2.0, 0.0, 0.0],
            [2.0, 1.0, 0.0, 0.0],
            [2.0, 3.0, 0.0, 0.0],
            [3.0, 4.0, 0.0, 0.0],
        ];
        let fronts = non_dominated_sort(&objs);
        assert_eq!(fronts.len(), 3);
        let f0: std::collections::BTreeSet<_> =
            fronts[0].iter().collect();
        assert_eq!(f0, [0usize, 1].iter().collect());
        assert_eq!(fronts[1], vec![2]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn sort_handles_empty_and_single() {
        assert!(non_dominated_sort(&[]).is_empty());
        let one = non_dominated_sort(&[[1.0, 1.0, 1.0, 1.0]]);
        assert_eq!(one, vec![vec![0]]);
    }

    #[test]
    fn fronts_partition_population() {
        let mut rng = crate::util::Rng::new(3);
        let objs: Vec<MinVec> = (0..100)
            .map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()])
            .collect();
        let fronts = non_dominated_sort(&objs);
        let total: usize = fronts.iter().map(|f| f.len()).sum();
        assert_eq!(total, 100);
        // no member of front k is dominated by any member of front k
        for front in &fronts {
            for &i in front {
                for &j in front {
                    assert!(!dominates(&objs[i], &objs[j]) || i == j
                            || !front.contains(&i));
                }
            }
        }
        // every member of front 1 dominated by someone in front 0
        if fronts.len() > 1 {
            for &j in &fronts[1] {
                assert!(fronts[0].iter().any(|&i| dominates(&objs[i],
                                                            &objs[j])));
            }
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        // The same SortScratch carried across differently-sized calls
        // must behave exactly like a fresh one each time.
        let mut rng = crate::util::Rng::new(8);
        let mut scratch = SortScratch::default();
        let mut crowd = CrowdingScratch::default();
        for n in [40usize, 7, 0, 120, 1, 40] {
            let objs: Vec<MinVec> = (0..n)
                .map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()])
                .collect();
            let reused = non_dominated_sort_with(&mut scratch, &objs);
            let fresh = non_dominated_sort(&objs);
            assert_eq!(reused, fresh, "n={n}");
            for front in &fresh {
                let a = crowding_distance_with(&mut crowd, &objs, front);
                let b = crowding_distance(&objs, front);
                let bits = |v: &[f64]| -> Vec<u64> {
                    v.iter().map(|x| x.to_bits()).collect()
                };
                assert_eq!(bits(&a), bits(&b), "n={n}");
            }
        }
    }

    #[test]
    fn crowding_boundaries_infinite() {
        let objs = vec![
            [0.0, 3.0, 0.0, 0.0],
            [1.0, 2.0, 0.0, 0.0],
            [2.0, 1.0, 0.0, 0.0],
            [3.0, 0.0, 0.0, 0.0],
        ];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&objs, &front);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[2].is_finite());
        assert!(d[1] > 0.0);
    }

    #[test]
    fn crowding_prefers_isolated_points() {
        // three interior points: the middle one is crowded
        let objs = vec![
            [0.0, 10.0, 0.0, 0.0],
            [4.9, 5.1, 0.0, 0.0],
            [5.0, 5.0, 0.0, 0.0],
            [5.1, 4.9, 0.0, 0.0],
            [10.0, 0.0, 0.0, 0.0],
        ];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&objs, &front);
        assert!(d[1] > d[2] || d[3] > d[2]);
    }

    #[test]
    fn crowding_small_fronts_infinite() {
        let objs = vec![[0.0; 4], [1.0; 4]];
        let d = crowding_distance(&objs, &[0, 1]);
        assert!(d.iter().all(|x| x.is_infinite()));
    }

    #[test]
    fn pareto_front_of_random_cloud_is_mutually_nondominated() {
        let mut rng = crate::util::Rng::new(4);
        let objs: Vec<MinVec> = (0..200)
            .map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()])
            .collect();
        let front = pareto_front(&objs);
        assert!(!front.is_empty());
        for &i in &front {
            for &j in &front {
                assert!(!dominates(&objs[i], &objs[j]) || i == j);
            }
        }
    }

    #[test]
    fn pareto_front_is_front_zero_of_the_sort() {
        let mut rng = crate::util::Rng::new(12);
        for n in [0usize, 1, 2, 33, 150] {
            let objs: Vec<MinVec> = (0..n)
                .map(|_| {
                    // quantized to force duplicate coordinates and ties
                    let q = |v: f64| (v * 8.0).floor() / 8.0;
                    [q(rng.f64()), q(rng.f64()), q(rng.f64()), q(rng.f64())]
                })
                .collect();
            let direct = pareto_front(&objs);
            let via_sort = non_dominated_sort(&objs)
                .into_iter()
                .next()
                .unwrap_or_default();
            assert_eq!(direct, via_sort, "n={n}");
        }
    }

    /// Satellite regression: a NaN objective used to abort the process
    /// through `partial_cmp(..).unwrap()` in the crowding comparator.
    /// With `total_cmp` the kernels stay total-ordered and terminate.
    #[test]
    fn nan_objectives_do_not_panic() {
        let objs = vec![
            [0.1, 0.9, 0.2, 0.3],
            [f64::NAN, 0.5, 0.5, 0.5],
            [0.4, f64::NAN, 0.1, 0.9],
            [0.4, 0.4, 0.4, 0.4],
            [f64::NAN, f64::NAN, f64::NAN, f64::NAN],
        ];
        let fronts = non_dominated_sort(&objs);
        let total: usize = fronts.iter().map(|f| f.len()).sum();
        assert_eq!(total, objs.len());
        let front: Vec<usize> = (0..objs.len()).collect();
        let d = crowding_distance(&objs, &front);
        assert_eq!(d.len(), objs.len());
        let pf = pareto_front(&objs);
        assert!(!pf.is_empty());
    }
}
