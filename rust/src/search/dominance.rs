//! Pareto dominance machinery: fast non-dominated sorting and crowding
//! distance (Deb et al. 2002), the core of the modified NSGA-II.

/// Objective vectors are in *minimization* convention ([f64; 4] from
/// `Objectives::as_min_vec`).
pub type MinVec = [f64; 4];

/// True iff `a` dominates `b` (<= everywhere, < somewhere).
pub fn dominates(a: &MinVec, b: &MinVec) -> bool {
    let mut strict = false;
    for i in 0..a.len() {
        if a[i] > b[i] {
            return false;
        }
        if a[i] < b[i] {
            strict = true;
        }
    }
    strict
}

/// Fast non-dominated sort: returns fronts as index lists, best first.
/// O(M·N²) as in the paper's complexity analysis.
pub fn non_dominated_sort(objs: &[MinVec]) -> Vec<Vec<usize>> {
    let n = objs.len();
    if n == 0 {
        return Vec::new();
    }
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n]; // how many dominate i
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&objs[i], &objs[j]) {
                dominated_by[i].push(j);
                dom_count[j] += 1;
            } else if dominates(&objs[j], &objs[i]) {
                dominated_by[j].push(i);
                dom_count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> =
        (0..n).filter(|&i| dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::replace(&mut current, next));
    }
    fronts
}

/// Crowding distance of each member within one front (diversity
/// preservation §3.3.2).  Boundary solutions get +inf.
pub fn crowding_distance(objs: &[MinVec], front: &[usize]) -> Vec<f64> {
    let n = front.len();
    let mut dist = vec![0.0f64; n];
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    let m = objs[0].len();
    let mut order: Vec<usize> = (0..n).collect();
    for obj in 0..m {
        order.sort_by(|&a, &b| {
            objs[front[a]][obj]
                .partial_cmp(&objs[front[b]][obj])
                .unwrap()
        });
        let lo = objs[front[order[0]]][obj];
        let hi = objs[front[order[n - 1]]][obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[n - 1]] = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for k in 1..n - 1 {
            let prev = objs[front[order[k - 1]]][obj];
            let next = objs[front[order[k + 1]]][obj];
            dist[order[k]] += (next - prev) / span;
        }
    }
    dist
}

/// Extract the non-dominated subset of a set of objective vectors
/// (indices into `objs`).
pub fn pareto_front(objs: &[MinVec]) -> Vec<usize> {
    non_dominated_sort(objs).into_iter().next().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominates_basics() {
        let a = [1.0, 1.0, 1.0, 1.0];
        let b = [2.0, 1.0, 1.0, 1.0];
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &a)); // equality is not domination
    }

    #[test]
    fn incomparable_points() {
        let a = [1.0, 2.0, 0.0, 0.0];
        let b = [2.0, 1.0, 0.0, 0.0];
        assert!(!dominates(&a, &b));
        assert!(!dominates(&b, &a));
    }

    #[test]
    fn sort_splits_into_correct_fronts() {
        // (0) and (1) trade off; (2) dominated by (0); (3) dominated by all
        let objs = vec![
            [1.0, 2.0, 0.0, 0.0],
            [2.0, 1.0, 0.0, 0.0],
            [2.0, 3.0, 0.0, 0.0],
            [3.0, 4.0, 0.0, 0.0],
        ];
        let fronts = non_dominated_sort(&objs);
        assert_eq!(fronts.len(), 3);
        let f0: std::collections::BTreeSet<_> =
            fronts[0].iter().collect();
        assert_eq!(f0, [0usize, 1].iter().collect());
        assert_eq!(fronts[1], vec![2]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn sort_handles_empty_and_single() {
        assert!(non_dominated_sort(&[]).is_empty());
        let one = non_dominated_sort(&[[1.0, 1.0, 1.0, 1.0]]);
        assert_eq!(one, vec![vec![0]]);
    }

    #[test]
    fn fronts_partition_population() {
        let mut rng = crate::util::Rng::new(3);
        let objs: Vec<MinVec> = (0..100)
            .map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()])
            .collect();
        let fronts = non_dominated_sort(&objs);
        let total: usize = fronts.iter().map(|f| f.len()).sum();
        assert_eq!(total, 100);
        // no member of front k is dominated by any member of front k
        for front in &fronts {
            for &i in front {
                for &j in front {
                    assert!(!dominates(&objs[i], &objs[j]) || i == j
                            || !front.contains(&i));
                }
            }
        }
        // every member of front 1 dominated by someone in front 0
        if fronts.len() > 1 {
            for &j in &fronts[1] {
                assert!(fronts[0].iter().any(|&i| dominates(&objs[i],
                                                            &objs[j])));
            }
        }
    }

    #[test]
    fn crowding_boundaries_infinite() {
        let objs = vec![
            [0.0, 3.0, 0.0, 0.0],
            [1.0, 2.0, 0.0, 0.0],
            [2.0, 1.0, 0.0, 0.0],
            [3.0, 0.0, 0.0, 0.0],
        ];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&objs, &front);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[2].is_finite());
        assert!(d[1] > 0.0);
    }

    #[test]
    fn crowding_prefers_isolated_points() {
        // three interior points: the middle one is crowded
        let objs = vec![
            [0.0, 10.0, 0.0, 0.0],
            [4.9, 5.1, 0.0, 0.0],
            [5.0, 5.0, 0.0, 0.0],
            [5.1, 4.9, 0.0, 0.0],
            [10.0, 0.0, 0.0, 0.0],
        ];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&objs, &front);
        assert!(d[1] > d[2] || d[3] > d[2]);
    }

    #[test]
    fn crowding_small_fronts_infinite() {
        let objs = vec![[0.0; 4], [1.0; 4]];
        let d = crowding_distance(&objs, &[0, 1]);
        assert!(d.iter().all(|x| x.is_infinite()));
    }

    #[test]
    fn pareto_front_of_random_cloud_is_mutually_nondominated() {
        let mut rng = crate::util::Rng::new(4);
        let objs: Vec<MinVec> = (0..200)
            .map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()])
            .collect();
        let front = pareto_front(&objs);
        assert!(!front.is_empty());
        for &i in &front {
            for &j in &front {
                assert!(!dominates(&objs[i], &objs[j]) || i == j);
            }
        }
    }
}
