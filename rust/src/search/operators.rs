//! Genetic operators: hierarchical crossover and stage-specific
//! mutation (paper §3.3.2, Eqs. 7–8).
//!
//! The crossover respects the three-stage structure — recombination
//! happens *within* each stage independently (`c1.arch ⊕ c2.arch`, …) so
//! beneficial within-stage combinations survive.  Mutation rates differ
//! per stage (Eq. 8: arch 0.1, ft 0.2, inf 0.15), the higher fine-tuning
//! rate reflecting its larger accuracy-efficiency impact.

use crate::config::{
    enumerate, validity, Attention, Config, FtConfig, FtMethod, KvCache,
    MoE, Precision, QuantMethod, ALPHA_MULTS, RANKS,
};
use crate::util::Rng;

/// Paper Eq. 8 mutation rates.
pub const P_MUT_ARCH: f64 = 0.1;
pub const P_MUT_FT: f64 = 0.2;
pub const P_MUT_INF: f64 = 0.15;

/// Hierarchical crossover (Eq. 7): per-stage uniform recombination of
/// the stage's axes.  Invalid children are repaired by resampling the
/// offending stage from a parent.
pub fn crossover(a: &Config, b: &Config, rng: &mut Rng) -> Config {
    let arch = crate::config::ArchConfig {
        attention: if rng.chance(0.5) { a.arch.attention } else { b.arch.attention },
        moe: if rng.chance(0.5) { a.arch.moe } else { b.arch.moe },
    };
    let ft = if rng.chance(0.5) {
        // methods carry their rank/alpha as a unit half the time...
        if rng.chance(0.5) { a.ft } else { b.ft }
    } else {
        // ...and recombine axis-wise otherwise
        let method = if rng.chance(0.5) { a.ft.method } else { b.ft.method };
        if method.is_peft() {
            let donor_rank = if a.ft.method.is_peft() { a.ft } else { b.ft };
            let donor_alpha = if rng.chance(0.5) { a.ft } else { b.ft };
            FtConfig {
                method,
                rank: if donor_rank.rank > 0 { donor_rank.rank } else { 32 },
                alpha_mult: if donor_alpha.alpha_mult > 0 {
                    donor_alpha.alpha_mult
                } else {
                    2
                },
            }
        } else {
            FtConfig::full()
        }
    };
    let inf = crate::config::InfConfig {
        precision: if rng.chance(0.5) { a.inf.precision } else { b.inf.precision },
        quant_method: if rng.chance(0.5) {
            a.inf.quant_method
        } else {
            b.inf.quant_method
        },
        kv_cache: if rng.chance(0.5) { a.inf.kv_cache } else { b.inf.kv_cache },
    };
    repair(Config { arch, ft, inf }, a, b, rng)
}

/// Stage-specific mutation (Eq. 8).  Each stage mutates independently
/// with its own rate; a mutated stage has one of its axes resampled.
pub fn mutate(c: &Config, rng: &mut Rng) -> Config {
    let mut out = *c;
    if rng.chance(P_MUT_ARCH) {
        match rng.below(2) {
            0 => out.arch.attention = *rng.pick(&Attention::ALL),
            _ => out.arch.moe = *rng.pick(&MoE::ALL),
        }
    }
    if rng.chance(P_MUT_FT) {
        // rank/alpha moves are meaningless on Full FT; always switch
        // method in that case so the ft rate of Eq. 8 is effective.
        let branch = if out.ft.method.is_peft() { rng.below(3) } else { 0 };
        match branch {
            0 => {
                let method = *rng.pick(&FtMethod::ALL);
                out.ft = if method.is_peft() {
                    FtConfig {
                        method,
                        rank: if out.ft.rank > 0 {
                            out.ft.rank
                        } else {
                            *rng.pick(&RANKS)
                        },
                        alpha_mult: if out.ft.method.is_peft() {
                            out.ft.alpha_mult
                        } else {
                            *rng.pick(&ALPHA_MULTS)
                        },
                    }
                } else {
                    FtConfig::full()
                };
            }
            1 => {
                if out.ft.method.is_peft() {
                    // neighbourhood move on the rank ladder
                    let pos = RANKS.iter().position(|&r| r == out.ft.rank)
                        .unwrap_or(2);
                    let np = if rng.chance(0.5) {
                        pos.saturating_sub(1)
                    } else {
                        (pos + 1).min(RANKS.len() - 1)
                    };
                    out.ft.rank = RANKS[np];
                }
            }
            _ => {
                if out.ft.method.is_peft() {
                    out.ft.alpha_mult = *rng.pick(&ALPHA_MULTS);
                }
            }
        }
    }
    if rng.chance(P_MUT_INF) {
        match rng.below(3) {
            0 => out.inf.precision = *rng.pick(&Precision::ALL),
            1 => out.inf.quant_method = *rng.pick(&QuantMethod::ALL),
            _ => out.inf.kv_cache = *rng.pick(&KvCache::ALL),
        }
    }
    if validity::is_valid(&out) {
        out
    } else {
        repair_single(out, rng)
    }
}

/// Repair an invalid child by substituting parent stages, falling back
/// to a fresh sample.
fn repair(child: Config, a: &Config, b: &Config, rng: &mut Rng) -> Config {
    if validity::is_valid(&child) {
        return child;
    }
    for candidate in [
        Config { ft: a.ft, ..child },
        Config { ft: b.ft, ..child },
        Config { inf: a.inf, ..child },
        Config { inf: b.inf, ..child },
        Config { arch: a.arch, ..child },
        *a,
    ] {
        if validity::is_valid(&candidate) {
            return candidate;
        }
    }
    enumerate::sample(rng)
}

/// Repair a mutated config by targeted fixes, then fall back to resample.
fn repair_single(mut c: Config, rng: &mut Rng) -> Config {
    use crate::config::validity::Violation;
    for v in validity::violations(&c) {
        match v {
            Violation::RankInconsistent => {
                if c.ft.method.is_peft() {
                    c.ft.rank = *rng.pick(&RANKS);
                } else {
                    c.ft = FtConfig::full();
                }
            }
            Violation::QloraNeedsQuantBase => {
                c.inf.precision = if rng.chance(0.5) {
                    Precision::Int8
                } else {
                    Precision::Int4
                };
            }
            Violation::Int4MoeTop1Unstable => {
                if let MoE::Sparse { experts, .. } = c.arch.moe {
                    c.arch.moe = MoE::Sparse { experts, top_k: 2 };
                }
            }
            Violation::KvCacheRedundant => {
                c.inf.kv_cache = KvCache::Full;
            }
        }
    }
    if validity::is_valid(&c) {
        c
    } else {
        enumerate::sample(rng)
    }
}

/// Produce one generation of offspring: tournament selection,
/// hierarchical crossover (Eq. 7) and stage-specific mutation (Eq. 8).
///
/// This is deliberately sequential — it owns the evolutionary RNG
/// stream, which is the determinism anchor of the whole search.  The
/// expensive part of a generation is *scoring* the returned batch, and
/// that is what `nsga2` fans out over the thread pool; keeping variation
/// on one thread with one RNG is what makes the Pareto front
/// bit-identical at every `Parallelism` level.
pub fn make_offspring(
    pop: &[Config],
    rank: &[usize],
    crowding: &[f64],
    params: &crate::search::nsga2::Nsga2Params,
    toggles: &crate::search::nsga2::Toggles,
    rng: &mut Rng,
) -> Vec<Config> {
    let n = pop.len();
    let mut offspring: Vec<Config> = Vec::with_capacity(n);
    while offspring.len() < n {
        let p1 = tournament(rng, n, rank, crowding, params.tournament_size);
        let child = if toggles.hierarchical_crossover
            && rng.chance(params.crossover_rate)
        {
            let p2 = tournament(rng, n, rank, crowding,
                                params.tournament_size);
            crossover(&pop[p1], &pop[p2], rng)
        } else {
            pop[p1]
        };
        offspring.push(mutate(&child, rng));
    }
    offspring
}

/// Binary tournament selection by (rank, crowding) — smaller rank wins,
/// ties broken by larger crowding distance (Deb 2002).
pub fn tournament(
    rng: &mut Rng,
    n: usize,
    rank: &[usize],
    crowding: &[f64],
    tournament_size: usize,
) -> usize {
    let mut best = rng.below(n);
    for _ in 1..tournament_size {
        let challenger = rng.below(n);
        let better = rank[challenger] < rank[best]
            || (rank[challenger] == rank[best]
                && crowding[challenger] > crowding[best]);
        if better {
            best = challenger;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config as PropConfig};

    fn two_parents(rng: &mut Rng) -> (Config, Config) {
        (enumerate::sample(rng), enumerate::sample(rng))
    }

    #[test]
    fn crossover_children_always_valid() {
        forall(PropConfig::default().cases(500), two_parents, |(a, b)| {
            let mut rng = Rng::new(a.ft.rank as u64 * 31 + b.ft.rank as u64);
            let child = crossover(a, b, &mut rng);
            if validity::is_valid(&child) {
                Ok(())
            } else {
                Err(format!("invalid child {child}"))
            }
        });
    }

    #[test]
    fn crossover_stage_genes_come_from_parents() {
        // architecture axes must come from one of the parents (the repair
        // path can fall back, but on valid recombinations inheritance
        // should hold; verify on a case where all combinations are valid)
        let a = Config::default_baseline();
        let mut b = Config::default_baseline();
        b.arch.attention = Attention::Gqa;
        b.inf.precision = Precision::Int8;
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let child = crossover(&a, &b, &mut rng);
            assert!(child.arch.attention == a.arch.attention
                || child.arch.attention == b.arch.attention);
            assert!(child.inf.precision == a.inf.precision
                || child.inf.precision == b.inf.precision);
        }
    }

    #[test]
    fn mutation_children_always_valid() {
        forall(
            PropConfig::default().cases(1000),
            |rng| enumerate::sample(rng),
            |c| {
                let mut rng = Rng::new(c.ft.rank as u64 + 17);
                let m = mutate(c, &mut rng);
                if validity::is_valid(&m) {
                    Ok(())
                } else {
                    Err(format!("invalid mutant {m}"))
                }
            },
        );
    }

    #[test]
    fn mutation_changes_something_sometimes() {
        let mut rng = Rng::new(4);
        let c = Config::default_baseline();
        let changed = (0..200)
            .filter(|_| mutate(&c, &mut rng) != c)
            .count();
        // with rates .1/.2/.15 ~ 38% of mutations should touch something
        assert!(changed > 40 && changed < 150, "changed={changed}");
    }

    #[test]
    fn ft_mutates_more_often_than_arch() {
        let mut rng = Rng::new(5);
        let c = Config::default_baseline();
        let mut arch_changes = 0;
        let mut ft_changes = 0;
        for _ in 0..3000 {
            let m = mutate(&c, &mut rng);
            if m.arch != c.arch {
                arch_changes += 1;
            }
            if m.ft != c.ft {
                ft_changes += 1;
            }
        }
        assert!(ft_changes > arch_changes,
                "ft={ft_changes} arch={arch_changes}");
    }

    #[test]
    fn repair_fixes_each_violation_kind() {
        let mut rng = Rng::new(6);
        // QLoRA + FP16
        let mut c = Config::default_baseline();
        c.ft = FtConfig { method: FtMethod::QLoRA, rank: 16, alpha_mult: 2 };
        let fixed = repair_single(c, &mut rng);
        assert!(validity::is_valid(&fixed));
        // int4 + top1
        let mut c = Config::default_baseline();
        c.arch.moe = MoE::Sparse { experts: 4, top_k: 1 };
        c.inf.precision = Precision::Int4;
        assert!(validity::is_valid(&repair_single(c, &mut rng)));
        // redundant KV
        let mut c = Config::default_baseline();
        c.arch.attention = Attention::Mqa;
        c.inf.kv_cache = KvCache::MqaStyle;
        assert!(validity::is_valid(&repair_single(c, &mut rng)));
    }

    #[test]
    fn tournament_prefers_lower_rank() {
        let mut rng = Rng::new(7);
        let rank = vec![3, 0, 2, 1];
        let crowding = vec![0.0; 4];
        let mut wins = [0usize; 4];
        for _ in 0..2000 {
            wins[tournament(&mut rng, 4, &rank, &crowding, 3)] += 1;
        }
        assert!(wins[1] > wins[3] && wins[3] > wins[0]);
    }

    #[test]
    fn tournament_ties_broken_by_crowding() {
        let mut rng = Rng::new(8);
        let rank = vec![0, 0];
        let crowding = vec![0.1, 5.0];
        let mut wins = [0usize; 2];
        for _ in 0..2000 {
            wins[tournament(&mut rng, 2, &rank, &crowding, 2)] += 1;
        }
        assert!(wins[1] > wins[0] * 2, "{wins:?}");
    }
}
