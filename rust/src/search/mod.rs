//! S7/S8/S14: multi-objective search — the modified NSGA-II (§3.3.2),
//! its dominance/crowding machinery, genetic operators, the
//! cross-iteration Pareto archive, the comparison baselines of §4.1,
//! and the pluggable [`strategy::SearchStrategy`] layer that makes the
//! search procedure itself a swappable axis (DESIGN.md §10).

pub mod archive;
pub mod baselines;
pub mod dominance;
pub mod hypervolume;
pub mod nsga2;
pub mod operators;
pub mod reference;
pub mod strategy;

pub use archive::{Entry, ParetoArchive, FRONT_SCHEMA};
pub use baselines::Baseline;
pub use nsga2::{Nsga2Params, SearchResult, Toggles};
pub use strategy::{BaselineStrategy, LocalSearchStrategy, Nsga2Strategy,
                   RacingStrategy, RandomStrategy, SearchStrategy,
                   StrategyCx, StrategyKind, StrategyOutcome};
