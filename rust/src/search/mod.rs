//! S7/S8: multi-objective search — the modified NSGA-II (§3.3.2), its
//! dominance/crowding machinery, genetic operators, the cross-iteration
//! Pareto archive, and the comparison baselines of §4.1.

pub mod archive;
pub mod baselines;
pub mod dominance;
pub mod hypervolume;
pub mod nsga2;
pub mod operators;

pub use archive::{Entry, ParetoArchive};
pub use baselines::Baseline;
pub use nsga2::{Nsga2Params, SearchResult, Toggles};
