//! Hypervolume indicator: the standard scalar quality measure of a
//! Pareto front (volume of objective space dominated by the front,
//! bounded by a reference point).  Used by the ablation benches to
//! compare search variants beyond the single chosen-config score, and
//! by tests as a convergence invariant.
//!
//! Exact computation in 4-D is implemented by recursive dimension
//! sweep (WFG-style slicing) — fine for front sizes ≤ a few hundred.

use super::dominance::MinVec;

/// Exact hypervolume of `points` (minimization convention) with respect
/// to reference point `r` (must be dominated by every point).
/// Points outside the reference box are clipped.
pub fn hypervolume(points: &[MinVec], r: &MinVec) -> f64 {
    // Keep only points that strictly dominate the reference somewhere.
    let pts: Vec<Vec<f64>> = points
        .iter()
        .filter(|p| p.iter().zip(r).all(|(a, b)| a <= b))
        .map(|p| p.to_vec())
        .collect();
    hv_rec(&pts, &r.to_vec())
}

fn hv_rec(points: &[Vec<f64>], r: &[f64]) -> f64 {
    let d = r.len();
    if points.is_empty() {
        return 0.0;
    }
    if d == 1 {
        let best = points
            .iter()
            .map(|p| p[0])
            .fold(f64::INFINITY, f64::min);
        return (r[0] - best).max(0.0);
    }
    // Ascending sweep over the last dimension: after including the k-th
    // point, the slab [z_k, z_{k+1}) (z_{n+1} = r_z) has a cross-section
    // equal to the (d-1)-dim hypervolume of the first k points.
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| {
        points[a][d - 1].partial_cmp(&points[b][d - 1]).unwrap()
    });
    let mut volume = 0.0;
    let mut active: Vec<Vec<f64>> = Vec::new();
    for (k, &i) in order.iter().enumerate() {
        active.push(points[i][..d - 1].to_vec());
        let z_lo = points[i][d - 1];
        let z_hi = if k + 1 < order.len() {
            points[order[k + 1]][d - 1]
        } else {
            r[d - 1]
        };
        if z_hi > z_lo {
            let slice = hv_rec(&nondominated(&active), &r[..d - 1].to_vec());
            volume += slice * (z_hi - z_lo);
        }
    }
    volume
}

/// Strip dominated points (minimization, arbitrary dimension).
fn nondominated(points: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let mut keep = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && dominates_vec(q, p) {
                continue 'outer;
            }
        }
        if !keep.contains(p) {
            keep.push(p.clone());
        }
    }
    keep
}

fn dominates_vec(a: &[f64], b: &[f64]) -> bool {
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Hypervolume of a measured Pareto archive with a normalized reference
/// (1.1× the worst value per objective across the front).
pub fn archive_hypervolume(archive: &super::archive::ParetoArchive) -> f64 {
    if archive.is_empty() {
        return 0.0;
    }
    let pts: Vec<MinVec> = archive
        .entries()
        .iter()
        .map(|e| e.objectives.as_min_vec())
        .collect();
    let mut r = [f64::NEG_INFINITY; 4];
    for p in &pts {
        for k in 0..4 {
            r[k] = r[k].max(p[k]);
        }
    }
    for v in r.iter_mut() {
        *v = if *v >= 0.0 { *v * 1.1 + 1e-6 } else { *v * 0.9 + 1e-6 };
    }
    hypervolume(&pts, &r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_box() {
        let hv = hypervolume(&[[1.0, 1.0, 0.0, 0.0]],
                             &[3.0, 2.0, 1.0, 1.0]);
        // (3-1) * (2-1) * (1-0) * (1-0) = 2
        assert!((hv - 2.0).abs() < 1e-9, "hv={hv}");
    }

    #[test]
    fn two_disjoint_points_union() {
        // 2-D embedded in 4-D (extra dims at 0 with ref 1)
        let pts = [
            [0.0, 2.0, 0.0, 0.0],
            [2.0, 0.0, 0.0, 0.0],
        ];
        let hv = hypervolume(&pts, &[3.0, 3.0, 1.0, 1.0]);
        // union: 3*3 box minus non-dominated corner: each point covers
        // (3-0)*(3-2)=3 and (3-2)*(3-0)=3, overlap (3-2)*(3-2)=1 -> 5
        assert!((hv - 5.0).abs() < 1e-9, "hv={hv}");
    }

    #[test]
    fn dominated_point_adds_nothing() {
        let base = hypervolume(&[[1.0, 1.0, 0.0, 0.0]],
                               &[3.0, 3.0, 1.0, 1.0]);
        let with = hypervolume(
            &[[1.0, 1.0, 0.0, 0.0], [2.0, 2.0, 0.5, 0.5]],
            &[3.0, 3.0, 1.0, 1.0]);
        assert!((base - with).abs() < 1e-9);
    }

    #[test]
    fn adding_nondominated_point_grows_hv() {
        let r = [4.0, 4.0, 1.0, 1.0];
        let a = hypervolume(&[[1.0, 3.0, 0.0, 0.0]], &r);
        let b = hypervolume(
            &[[1.0, 3.0, 0.0, 0.0], [3.0, 1.0, 0.0, 0.0]], &r);
        assert!(b > a);
    }

    #[test]
    fn point_outside_reference_clipped() {
        let hv = hypervolume(&[[5.0, 5.0, 5.0, 5.0]],
                             &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(hv, 0.0);
    }

    #[test]
    fn monotone_under_point_improvement() {
        let r = [2.0, 2.0, 2.0, 2.0];
        let worse = hypervolume(&[[1.0, 1.0, 1.0, 1.0]], &r);
        let better = hypervolume(&[[0.5, 1.0, 1.0, 1.0]], &r);
        assert!(better > worse);
    }

    #[test]
    fn archive_hypervolume_positive_for_real_search() {
        use crate::coordinator::{AeLlm, AeLlmParams, Scenario};
        let scenario = Scenario::for_model("Phi-2").unwrap();
        let mut p = AeLlmParams::small();
        p.initial_sample = 60;
        let out = AeLlm::from_scenario(scenario)
            .params(p)
            .seed(3)
            .run_testbed_outcome();
        let hv = archive_hypervolume(&out.pareto);
        assert!(hv > 0.0);
    }
}
