//! Hypervolume indicator: the standard scalar quality measure of a
//! Pareto front (volume of objective space dominated by the front,
//! bounded by a reference point).  Used by the ablation benches to
//! compare search variants beyond the single chosen-config score, by
//! the observer's per-iteration convergence snapshot, and by tests as
//! a convergence invariant.
//!
//! Exact computation in 4-D is implemented by recursive dimension
//! sweep (WFG-style slicing) — fine for front sizes ≤ a few hundred.
//! Since the search-kernel speed pass (DESIGN.md §17) the recursion
//! runs on an arena of flat row-major buffers ([`HvScratch`]), one per
//! recursion level, instead of cloning `Vec<Vec<f64>>` at every level;
//! the sweep order, the slab-sum order and every float operation are
//! unchanged, so the result is bit-identical to the retained
//! [`super::reference::ref_hypervolume`] (differential-tested with
//! exact `.to_bits()` equality).

use super::dominance::MinVec;

/// Per-recursion-level buffers: the active point set accumulated by
/// the sweep, its non-dominated subset (rebuilt per slab), and the
/// argsort over the level's last dimension.  All flat row-major with
/// the level's point width as stride.
#[derive(Clone, Debug, Default)]
struct LevelScratch {
    active: Vec<f64>,
    nd: Vec<f64>,
    order: Vec<usize>,
}

/// Reusable arena for [`hypervolume_with`]: the clipped top-level point
/// set plus one [`LevelScratch`] per recursion level below the top.
/// One instance amortizes every allocation across repeated hypervolume
/// queries (the observer loop, benches); [`hypervolume`] wraps a
/// throwaway one.
#[derive(Clone, Debug)]
pub struct HvScratch {
    top: Vec<f64>,
    levels: Vec<LevelScratch>,
}

impl HvScratch {
    pub fn new() -> Self {
        HvScratch {
            top: Vec::new(),
            // Levels are consumed at d = 4, 3, 2 (d = 1 is the closed
            // base case), so three suffice for MinVec input.
            levels: (0..3).map(|_| LevelScratch::default()).collect(),
        }
    }
}

impl Default for HvScratch {
    fn default() -> Self {
        HvScratch::new()
    }
}

/// Exact hypervolume of `points` (minimization convention) with respect
/// to reference point `r` (must be dominated by every point).
/// Points outside the reference box are clipped.
pub fn hypervolume(points: &[MinVec], r: &MinVec) -> f64 {
    hypervolume_with(&mut HvScratch::default(), points, r)
}

/// [`hypervolume`] through a caller-owned arena — the zero-allocation
/// form for call sites with a loop to amortize one across.
pub fn hypervolume_with(s: &mut HvScratch, points: &[MinVec],
                        r: &MinVec) -> f64 {
    // Keep only points inside the reference box (a NaN coordinate
    // fails the `<=` test, so NaN points never enter the recursion).
    s.top.clear();
    for p in points {
        if p.iter().zip(r).all(|(a, b)| a <= b) {
            s.top.extend_from_slice(p);
        }
    }
    hv_level(&s.top, 4, r, &mut s.levels)
}

/// One recursion level over flat rows of width `d`.  Mirrors the
/// reference `ref_hv_rec` exactly: ascending sweep over the last
/// dimension — after including the k-th point, the slab
/// [z_k, z_{k+1}) (z_{n+1} = r_z) has a cross-section equal to the
/// (d-1)-dim hypervolume of the first k points.
fn hv_level(pts: &[f64], d: usize, r: &[f64],
            levels: &mut [LevelScratch]) -> f64 {
    let n = pts.len() / d;
    if n == 0 {
        return 0.0;
    }
    if d == 1 {
        let best = pts.iter().copied().fold(f64::INFINITY, f64::min);
        return (r[0] - best).max(0.0);
    }
    let (level, rest) = levels.split_first_mut()
        .expect("HvScratch arena shallower than the recursion");
    level.order.clear();
    level.order.extend(0..n);
    level.order.sort_by(|&a, &b| {
        pts[a * d + d - 1].total_cmp(&pts[b * d + d - 1])
    });
    level.active.clear();
    let mut volume = 0.0;
    for k in 0..n {
        let i = level.order[k];
        level.active.extend_from_slice(&pts[i * d..i * d + d - 1]);
        let z_lo = pts[i * d + d - 1];
        let z_hi = if k + 1 < n {
            pts[level.order[k + 1] * d + d - 1]
        } else {
            r[d - 1]
        };
        if z_hi > z_lo {
            nondominated_into(&level.active, d - 1, &mut level.nd);
            let slice = hv_level(&level.nd, d - 1, &r[..d - 1], rest);
            volume += slice * (z_hi - z_lo);
        }
    }
    volume
}

/// Write the non-dominated subset of `pts` (flat rows of width `d`)
/// into `out`, preserving row order and keeping the first of any run
/// of duplicate rows.  One fused scan replaces the reference's
/// dominance pass + `keep.contains` duplicate re-scan: a duplicate of
/// a *dominated* row is itself dominated (dominance depends only on
/// coordinate values), so dropping a row when an earlier *equal* row
/// exists filters exactly the duplicates the reference's kept-set
/// lookup did.
fn nondominated_into(pts: &[f64], d: usize, out: &mut Vec<f64>) {
    out.clear();
    let n = pts.len() / d;
    'outer: for i in 0..n {
        let p = &pts[i * d..(i + 1) * d];
        for j in 0..n {
            if j == i {
                continue;
            }
            let q = &pts[j * d..(j + 1) * d];
            if dominates_vec(q, p) || (j < i && q == p) {
                continue 'outer;
            }
        }
        out.extend_from_slice(p);
    }
}

fn dominates_vec(a: &[f64], b: &[f64]) -> bool {
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Hypervolume of a measured Pareto archive with a normalized reference
/// (1.1× the worst value per objective across the front).
pub fn archive_hypervolume(archive: &super::archive::ParetoArchive) -> f64 {
    if archive.is_empty() {
        return 0.0;
    }
    let pts: Vec<MinVec> = archive
        .entries()
        .iter()
        .map(|e| e.objectives.as_min_vec())
        .collect();
    let mut r = [f64::NEG_INFINITY; 4];
    for p in &pts {
        for k in 0..4 {
            r[k] = r[k].max(p[k]);
        }
    }
    for v in r.iter_mut() {
        *v = if *v >= 0.0 { *v * 1.1 + 1e-6 } else { *v * 0.9 + 1e-6 };
    }
    hypervolume(&pts, &r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_box() {
        let hv = hypervolume(&[[1.0, 1.0, 0.0, 0.0]],
                             &[3.0, 2.0, 1.0, 1.0]);
        // (3-1) * (2-1) * (1-0) * (1-0) = 2
        assert!((hv - 2.0).abs() < 1e-9, "hv={hv}");
    }

    #[test]
    fn two_disjoint_points_union() {
        // 2-D embedded in 4-D (extra dims at 0 with ref 1)
        let pts = [
            [0.0, 2.0, 0.0, 0.0],
            [2.0, 0.0, 0.0, 0.0],
        ];
        let hv = hypervolume(&pts, &[3.0, 3.0, 1.0, 1.0]);
        // union: 3*3 box minus non-dominated corner: each point covers
        // (3-0)*(3-2)=3 and (3-2)*(3-0)=3, overlap (3-2)*(3-2)=1 -> 5
        assert!((hv - 5.0).abs() < 1e-9, "hv={hv}");
    }

    #[test]
    fn dominated_point_adds_nothing() {
        let base = hypervolume(&[[1.0, 1.0, 0.0, 0.0]],
                               &[3.0, 3.0, 1.0, 1.0]);
        let with = hypervolume(
            &[[1.0, 1.0, 0.0, 0.0], [2.0, 2.0, 0.5, 0.5]],
            &[3.0, 3.0, 1.0, 1.0]);
        assert!((base - with).abs() < 1e-9);
    }

    #[test]
    fn adding_nondominated_point_grows_hv() {
        let r = [4.0, 4.0, 1.0, 1.0];
        let a = hypervolume(&[[1.0, 3.0, 0.0, 0.0]], &r);
        let b = hypervolume(
            &[[1.0, 3.0, 0.0, 0.0], [3.0, 1.0, 0.0, 0.0]], &r);
        assert!(b > a);
    }

    #[test]
    fn point_outside_reference_clipped() {
        let hv = hypervolume(&[[5.0, 5.0, 5.0, 5.0]],
                             &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(hv, 0.0);
    }

    #[test]
    fn monotone_under_point_improvement() {
        let r = [2.0, 2.0, 2.0, 2.0];
        let worse = hypervolume(&[[1.0, 1.0, 1.0, 1.0]], &r);
        let better = hypervolume(&[[0.5, 1.0, 1.0, 1.0]], &r);
        assert!(better > worse);
    }

    #[test]
    fn duplicate_points_add_nothing() {
        let r = [3.0, 3.0, 1.0, 1.0];
        let one = hypervolume(&[[1.0, 1.0, 0.0, 0.0]], &r);
        let two = hypervolume(
            &[[1.0, 1.0, 0.0, 0.0], [1.0, 1.0, 0.0, 0.0]], &r);
        assert_eq!(one.to_bits(), two.to_bits());
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        let mut rng = crate::util::Rng::new(21);
        let mut scratch = HvScratch::default();
        let r = [2.0, 2.0, 2.0, 2.0];
        for n in [0usize, 1, 5, 40, 12] {
            let pts: Vec<MinVec> = (0..n)
                .map(|_| [rng.f64(), rng.f64(), rng.f64(), rng.f64()])
                .collect();
            let reused = hypervolume_with(&mut scratch, &pts, &r);
            let fresh = hypervolume(&pts, &r);
            assert_eq!(reused.to_bits(), fresh.to_bits(), "n={n}");
        }
    }

    #[test]
    fn nan_points_are_clipped_not_fatal() {
        let r = [3.0, 3.0, 1.0, 1.0];
        let clean = hypervolume(&[[1.0, 1.0, 0.0, 0.0]], &r);
        let with_nan = hypervolume(
            &[[1.0, 1.0, 0.0, 0.0], [f64::NAN, 0.5, 0.5, 0.5]], &r);
        assert_eq!(clean.to_bits(), with_nan.to_bits());
    }

    #[test]
    fn archive_hypervolume_positive_for_real_search() {
        use crate::coordinator::{AeLlm, AeLlmParams, Scenario};
        let scenario = Scenario::for_model("Phi-2").unwrap();
        let mut p = AeLlmParams::small();
        p.initial_sample = 60;
        let out = AeLlm::from_scenario(scenario)
            .params(p)
            .seed(3)
            .run_testbed_outcome();
        let hv = archive_hypervolume(&out.pareto);
        assert!(hv > 0.0);
    }
}
