//! S14: the pluggable search-strategy layer.
//!
//! AE-LLM's core claim is that an *efficient search procedure* over the
//! combinatorial technique space finds configurations static choices
//! miss — yet until this layer existed, the search procedure itself was
//! static: NSGA-II hardwired into the coordinator, and the Table-2
//! baselines running through a bespoke closure convention that bypassed
//! the [`Evaluator`] backend entirely.  [`SearchStrategy`] makes the
//! procedure a first-class swappable axis, the same way PR 2 made the
//! evaluation backend one.
//!
//! ## Trait shape: round-based ask/tell
//!
//! A strategy implements one method, [`SearchStrategy::propose`]: given
//! the run's read-only state ([`StrategyCx`]) and the evaluation
//! backend, return the candidates the coordinator should measure at
//! full fidelity this refinement round ([`StrategyOutcome`]).  The
//! coordinator keeps the rest of Algorithm 1 — surrogate warm-start,
//! the line-5 measurement batch, the measured Pareto archive, surrogate
//! updates, observer events — so every strategy inherits caching, eval
//! counting, parallel `measure_batch` fan-out and observer streaming
//! for free.  `propose` is the "ask" half; the coordinator's
//! measure-and-update step is the "tell" (strategies read its outcome
//! through `cx.measured` / `cx.seen` next round).
//!
//! Why rounds rather than per-candidate ask/tell: line 5 is a batch
//! fan-out point (DESIGN.md §8), and the extracted NSGA-II must stay
//! bit-identical to the pre-refactor coordinator — which consumed the
//! run RNG in whole-round units (one evolutionary search, then one
//! measurement batch).  A per-candidate protocol would force a
//! different RNG interleaving and break the PR-1 determinism contract.
//! See DESIGN.md §10 for the full rationale.
//!
//! ## In-tree strategies
//!
//! | [`StrategyKind`] | Procedure |
//! |---|---|
//! | `nsga2` | the paper's surrogate-guided NSGA-II (extracted from the coordinator; bit-identical) |
//! | `random` | budgeted random sampling of unseen configurations |
//! | `racing` | successive-halving over measurement fidelities (4k → 2k → k survivors) |
//! | `local` | hill-climb over one-technique mutations ranked by surrogate predictions |
//!
//! The Table-2 baselines ride the same seam as [`BaselineStrategy`]:
//! rule-based selectors are degenerate zero-eval strategies, selector
//! baselines perform their measurements through the backend and are
//! counted by [`Evaluator::evals`].

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use crate::config::{
    enumerate, validity, ArchConfig, Attention, Config, FtConfig, FtMethod,
    InfConfig, KvCache, MoE, Precision, QuantMethod, ALPHA_MULTS, RANKS,
};
use crate::coordinator::algorithm1::AeLlmParams;
use crate::coordinator::scenario::Scenario;
use crate::evaluator::{EvalContext, Evaluator};
use crate::metrics::{utility, Preferences, Reference};
use crate::oracle::Objectives;
use crate::search::archive::{Entry, ParetoArchive};
use crate::search::baselines::{self, Baseline};
use crate::search::nsga2::{self, Nsga2Params};
use crate::surrogate::SurrogateSet;
use crate::util::pool;
use crate::util::Rng;

/// Read-only view of one Algorithm-1 run, handed to
/// [`SearchStrategy::propose`] each refinement round.
pub struct StrategyCx<'a> {
    pub scenario: &'a Scenario,
    pub params: &'a AeLlmParams,
    /// Default-configuration reference used for utility normalization.
    pub reference: &'a Reference,
    /// Trained surrogates, when the run warm-started them (the
    /// coordinator fits them only if `params.use_surrogates` *and*
    /// [`SearchStrategy::uses_surrogates`] agree).
    pub surrogates: Option<&'a SurrogateSet>,
    /// Measured Pareto archive accumulated by previous rounds.
    pub measured: &'a ParetoArchive,
    /// Every configuration already measured at full fidelity;
    /// strategies should not re-propose members.
    pub seen: &'a BTreeSet<Config>,
    /// 0-based refinement round index.
    pub iteration: usize,
    /// Total rounds this run will perform ([`SearchStrategy::rounds`]).
    pub rounds: usize,
}

impl<'a> StrategyCx<'a> {
    /// The evaluation context strategies must pass to any
    /// [`Evaluator`] call they make themselves, so backend fan-out
    /// honors the coordinator's parallelism knob.
    pub fn eval_ctx(&self) -> EvalContext<'_> {
        EvalContext::new(&self.scenario.model, &self.scenario.task,
                         self.params.parallelism)
    }
}

/// What one [`SearchStrategy::propose`] round returns.
pub struct StrategyOutcome {
    /// Candidates for the coordinator's full-fidelity measurement batch
    /// (at most `params.evals_per_iter`, already deduplicated and not
    /// in `cx.seen`).
    pub proposals: Vec<Config>,
    /// Cheap surrogate predictions consumed this round.
    pub surrogate_evals: usize,
    /// Expensive backend measurements the strategy performed itself
    /// mid-round (racing rungs, direct-measurement NSGA-II); the
    /// coordinator adds these to the run's testbed-eval total.
    pub strategy_evals: usize,
}

/// A pluggable search procedure for Algorithm 1's proposal step
/// (lines 3–4: search the space, pick the candidates worth measuring).
///
/// Contract (the PR-1 determinism rules apply): `propose` must consume
/// `rng` identically at every `Parallelism` level, must only perform
/// backend measurements through `evaluator` (reported in
/// [`StrategyOutcome::strategy_evals`]), and must never return a
/// configuration in `cx.seen`.
///
/// A custom strategy plugs straight into Algorithm 1 via
/// [`crate::coordinator::optimize_with_strategy`]:
///
/// ```
/// use ae_llm::config::enumerate;
/// use ae_llm::coordinator::{optimize_with_strategy, AeLlmParams,
///                           NullObserver, Scenario};
/// use ae_llm::evaluator::Evaluator;
/// use ae_llm::search::{SearchStrategy, StrategyCx, StrategyOutcome};
/// use ae_llm::util::Rng;
///
/// /// One random unseen configuration per round — the smallest
/// /// possible custom procedure.
/// struct OneRandom;
///
/// impl SearchStrategy for OneRandom {
///     fn name(&self) -> &'static str {
///         "one-random"
///     }
///     fn uses_surrogates(&self) -> bool {
///         false
///     }
///     fn propose(&mut self, cx: &StrategyCx,
///                _evaluator: &mut dyn Evaluator, rng: &mut Rng)
///                -> StrategyOutcome {
///         let mut c = cx.params.mask.clamp(enumerate::sample(rng));
///         while cx.seen.contains(&c) {
///             c = cx.params.mask.clamp(enumerate::sample(rng));
///         }
///         StrategyOutcome {
///             proposals: vec![c],
///             surrogate_evals: 0,
///             strategy_evals: 0,
///         }
///     }
/// }
///
/// let scenario = Scenario::for_model("Phi-2").unwrap();
/// let params = AeLlmParams::small();
/// let mut evaluator = scenario.testbed.clone();
/// let mut rng = Rng::new(7);
/// let outcome = optimize_with_strategy(&scenario, &params, &mut OneRandom,
///                                      &mut evaluator, &mut NullObserver,
///                                      &mut rng);
/// assert!(!outcome.pareto.is_empty());
/// ```
pub trait SearchStrategy {
    /// Stable lowercase identifier (CLI `--strategy` value, report
    /// rows, `RunReport.strategy`).
    fn name(&self) -> &'static str;

    /// Whether the coordinator should warm-start and refit surrogates
    /// for this strategy.  Strategies that never read
    /// `cx.surrogates` return `false` so their runs skip the
    /// initial-sample measurement cost entirely.
    fn uses_surrogates(&self) -> bool {
        true
    }

    /// Refinement rounds this strategy wants under `params`.
    fn rounds(&self, params: &AeLlmParams) -> usize {
        params.refine_iters.max(1)
    }

    /// Warm-start hook (DESIGN.md §12): called once, before round 0,
    /// when the coordinator seeds a run from a prior Pareto front
    /// (continual adaptation re-search).  The coordinator itself
    /// re-measures the prior entries under the new scenario and seeds
    /// the measured archive with them, so strategies that climb from
    /// `cx.measured` (local search) or avoid `cx.seen` inherit the
    /// warm start for free; override to bias proposals further (e.g.
    /// seeding an evolutionary population).  Never called on cold
    /// runs, so implementations cannot perturb the cold-start RNG
    /// stream.
    fn warm_start(&mut self, _prior: &[Entry]) {}

    /// Produce this round's measurement candidates.
    fn propose(&mut self, cx: &StrategyCx, evaluator: &mut dyn Evaluator,
               rng: &mut Rng) -> StrategyOutcome;
}

// ---------------------------------------------------------------------------
// StrategyKind: name-addressed construction
// ---------------------------------------------------------------------------

/// The built-in strategies, by CLI name.  Lives on [`AeLlmParams`] so
/// strategy selection threads through the builder, the CLI and the
/// serialized `RunReport` without the params losing `Copy`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    /// Surrogate-guided NSGA-II (the paper's Algorithm 1 default).
    Nsga2,
    /// Budgeted random sampling.
    Random,
    /// Successive-halving racing over measurement fidelities.
    Racing,
    /// Surrogate-guided local search over one-technique mutations.
    Local,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 4] = [
        StrategyKind::Nsga2,
        StrategyKind::Random,
        StrategyKind::Racing,
        StrategyKind::Local,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Nsga2 => "nsga2",
            StrategyKind::Random => "random",
            StrategyKind::Racing => "racing",
            StrategyKind::Local => "local",
        }
    }

    /// Lookup by CLI name.
    pub fn by_name(name: &str) -> Option<StrategyKind> {
        StrategyKind::ALL.iter().copied().find(|k| k.name() == name)
    }

    /// Instantiate the strategy (all built-ins are stateless).
    pub fn build(&self) -> Box<dyn SearchStrategy> {
        match self {
            StrategyKind::Nsga2 => Box::new(Nsga2Strategy),
            StrategyKind::Random => Box::new(RandomStrategy),
            StrategyKind::Racing => Box::new(RacingStrategy),
            StrategyKind::Local => Box::new(LocalSearchStrategy),
        }
    }
}

// ---------------------------------------------------------------------------
// NSGA-II: the extracted coordinator search (bit-identical)
// ---------------------------------------------------------------------------

/// The paper's modified NSGA-II, extracted verbatim from the
/// pre-refactor coordinator loop: surrogate-predicted evolution when
/// surrogates are available (uncertainty-ranked candidate selection),
/// budget-capped direct measurement otherwise (the "- Predictive
/// Models" ablation).  `tests/integration_api.rs` proves this path is
/// bit-identical to the legacy `optimize`/`optimize_with` entry points
/// at `Parallelism` 1 and 4.
pub struct Nsga2Strategy;

impl SearchStrategy for Nsga2Strategy {
    fn name(&self) -> &'static str {
        "nsga2"
    }

    fn rounds(&self, params: &AeLlmParams) -> usize {
        // Direct-measurement mode runs one capped NSGA-II only (its
        // evaluation budget is the search itself).
        if params.use_surrogates {
            params.refine_iters.max(1)
        } else {
            1
        }
    }

    fn propose(&mut self, cx: &StrategyCx, evaluator: &mut dyn Evaluator,
               rng: &mut Rng) -> StrategyOutcome {
        let scenario = cx.scenario;
        let params = cx.params;
        let m = &scenario.model;
        let t = &scenario.task;
        let tb = &scenario.testbed;
        let mask = params.mask;
        let par = params.parallelism;
        let nsga_params = Nsga2Params { parallelism: par, ..params.nsga };
        let power_ok = |c: &Config| {
            tb.power_w(c, m, t) <= tb.platform.power_budget_w
        };
        let mut surrogate_evals = 0usize;
        let mut strategy_evals = 0usize;

        // ---- line 3: NSGA-II against the current surrogates -------------
        let surrogate_archive = {
            let mask_ref = &mask;
            match cx.surrogates {
                Some(sur) => {
                    // §Perf: populations revisit configurations heavily
                    // (tournament winners, crossover clones), so predict
                    // through a memo table — ~3x fewer GBT traversals,
                    // see EXPERIMENTS.md §Perf.  The table is a Mutex'd
                    // map so the prediction fan-out can share it; the
                    // cached value is a pure function of the config, so
                    // racing fills are benign and results stay
                    // deterministic at any parallelism level.
                    let cache: Mutex<BTreeMap<Config, Objectives>> =
                        Default::default();
                    let cached_predict = |c: &Config| -> Objectives {
                        let c = mask_ref.clamp(*c);
                        if let Some(o) = cache.lock().unwrap().get(&c) {
                            return *o;
                        }
                        let o = sur.predict(&c, m, t).objectives;
                        cache.lock().unwrap().insert(c, o);
                        o
                    };
                    let evaluate = |c: &Config| cached_predict(c);
                    let res = nsga2::run_par(
                        &nsga_params,
                        &params.toggles,
                        &evaluate,
                        |c| {
                            let mem = cached_predict(c).memory_gb;
                            mem <= tb.platform.mem_capacity_gb
                                && power_ok(&mask_ref.clamp(*c))
                        },
                        rng,
                    );
                    surrogate_evals += res.evaluations;
                    res.archive
                }
                None => {
                    // Ablation: NSGA-II evaluates the backend directly
                    // with a tightly capped budget (random-search tier).
                    // The evaluator threads the measurement RNG, so this
                    // path stays on the sequential `run` entry point.
                    let budget_params = Nsga2Params {
                        population: params.nsga.population.min(24),
                        generations: params.nsga.generations.min(8),
                        // nsga_params so the coordinator-level
                        // parallelism override reaches archive batching
                        ..nsga_params
                    };
                    // separate measurement noise stream: `rng` drives the
                    // evolutionary operators inside nsga2::run
                    let mut noise_rng = rng.split();
                    let eval_ctx = cx.eval_ctx();
                    let res = nsga2::run(
                        &budget_params,
                        &params.toggles,
                        |c| {
                            strategy_evals += 1;
                            evaluator.measure_batch(
                                &[mask_ref.clamp(*c)], &eval_ctx,
                                &mut noise_rng,
                            )[0]
                        },
                        |c| {
                            let c = mask_ref.clamp(*c);
                            tb.true_objectives(&c, m, t).memory_gb
                                <= tb.platform.mem_capacity_gb
                                && power_ok(&c)
                        },
                        rng,
                    );
                    res.archive
                }
            }
        };

        // ---- line 4: pick top-k uncertain candidates from P_r ------------
        let mut candidates: Vec<Config> = surrogate_archive
            .entries()
            .iter()
            .map(|e| mask.clamp(e.config))
            .filter(|c| !cx.seen.contains(c))
            .collect();
        candidates.sort();
        candidates.dedup();
        if let Some(sur) = cx.surrogates {
            // Uncertainty scoring fans out; the sort itself runs on
            // precomputed keys so its comparisons stay O(1) and the
            // ordering is deterministic.
            let uncertainty: Vec<f64> = pool::parallel_map(
                par,
                &candidates,
                |c| sur.predict(c, m, t).total_relative_uncertainty(),
            );
            let mut order: Vec<usize> = (0..candidates.len()).collect();
            order.sort_by(|&a, &b| {
                uncertainty[b].partial_cmp(&uncertainty[a]).unwrap()
            });
            candidates = order.into_iter().map(|i| candidates[i]).collect();
        }
        candidates.truncate(params.evals_per_iter.max(1));

        StrategyOutcome {
            proposals: candidates,
            surrogate_evals,
            strategy_evals,
        }
    }
}

// ---------------------------------------------------------------------------
// Random: budgeted sampling
// ---------------------------------------------------------------------------

/// Budgeted random sampling: each round proposes exactly
/// `evals_per_iter` distinct unseen configurations for measurement.
/// Zero surrogate and zero mid-round evaluations — the cheapest
/// possible proposal step, and the floor every informed strategy must
/// beat.
pub struct RandomStrategy;

impl SearchStrategy for RandomStrategy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn uses_surrogates(&self) -> bool {
        false
    }

    fn propose(&mut self, cx: &StrategyCx, _evaluator: &mut dyn Evaluator,
               rng: &mut Rng) -> StrategyOutcome {
        let k = cx.params.evals_per_iter.max(1);
        let mask = cx.params.mask;
        StrategyOutcome {
            proposals: sample_unseen(k, &mask, cx.seen, rng),
            surrogate_evals: 0,
            strategy_evals: 0,
        }
    }
}

/// Draw `n` distinct masked configurations not in `seen` (guarded
/// against pathological exhaustion of small masked spaces).
fn sample_unseen(n: usize, mask: &crate::coordinator::scenario::SpaceMask,
                 seen: &BTreeSet<Config>, rng: &mut Rng) -> Vec<Config> {
    let mut out: Vec<Config> = Vec::with_capacity(n);
    let mut guard = 0usize;
    while out.len() < n && guard < n * 400 {
        let c = mask.clamp(enumerate::sample(rng));
        if !seen.contains(&c) && !out.contains(&c) {
            out.push(c);
        }
        guard += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Racing: successive halving over measurement fidelities
// ---------------------------------------------------------------------------

/// Entrants per racing round, as a multiple of `evals_per_iter`.
pub const RACING_ENTRANT_FACTOR: usize = 4;

/// Successive-halving racing (multi-fidelity search).
///
/// Fidelity model: the backend returns noisy single measurements, so
/// fidelity = number of repeated measurements averaged per candidate —
/// more samples, less noise (DESIGN.md §10).  Each round:
///
/// * **rung 0** — `4k` fresh entrants, one cheap sample each;
/// * **rung 1** — the top `2k` by utility of the running mean get two
///   more samples each;
/// * **promotion** — the top `k` survivors are proposed to the
///   coordinator, whose line-5 batch is the full-fidelity measurement
///   that enters the Pareto archive (rung measurements never do).
///
/// Budget accounting reuses `AeLlmParams`: with `k = evals_per_iter`
/// and `R = refine_iters`, a run consumes exactly `R·(8k + k) + 1`
/// backend evaluations (8k mid-round rung samples + k promotions per
/// round + the final Default fallback) — asserted by
/// `tests/integration_strategy.rs`.
pub struct RacingStrategy;

impl SearchStrategy for RacingStrategy {
    fn name(&self) -> &'static str {
        "racing"
    }

    fn uses_surrogates(&self) -> bool {
        false
    }

    fn propose(&mut self, cx: &StrategyCx, evaluator: &mut dyn Evaluator,
               rng: &mut Rng) -> StrategyOutcome {
        let params = cx.params;
        let k = params.evals_per_iter.max(1);
        let mask = params.mask;
        let prefs = &cx.scenario.prefs;
        let eval_ctx = cx.eval_ctx();
        let mut strategy_evals = 0usize;

        // Rung 0: one cheap sample for each fresh entrant.
        let entrants =
            sample_unseen(RACING_ENTRANT_FACTOR * k, &mask, cx.seen, rng);
        let first = evaluator.measure_batch(&entrants, &eval_ctx, rng);
        strategy_evals += entrants.len();
        let state: Vec<(Config, Objectives, usize)> = entrants
            .into_iter()
            .zip(first)
            .map(|(c, o)| (c, o, 1))
            .collect();

        // Rung 1: top half survive; two more samples each, scored on
        // the running mean.
        let survivors =
            top_by_utility(state, 2 * k, cx.reference, prefs);
        let cfgs: Vec<Config> =
            survivors.iter().map(|(c, _, _)| *c).collect();
        let s1 = evaluator.measure_batch(&cfgs, &eval_ctx, rng);
        let s2 = evaluator.measure_batch(&cfgs, &eval_ctx, rng);
        strategy_evals += 2 * cfgs.len();
        let refined: Vec<(Config, Objectives, usize)> = survivors
            .into_iter()
            .zip(s1.iter().zip(&s2))
            .map(|((c, mean, n), (a, b))| {
                (c, blend_mean(&mean, n, &[a, b]), n + 2)
            })
            .collect();

        // Promotion: the top k go to full-fidelity measurement.
        let finalists = top_by_utility(refined, k, cx.reference, prefs);
        StrategyOutcome {
            proposals: finalists.into_iter().map(|(c, _, _)| c).collect(),
            surrogate_evals: 0,
            strategy_evals,
        }
    }
}

/// Keep the `n` highest-utility entries (ties broken by config order so
/// the cut is deterministic at every parallelism level).
fn top_by_utility(
    state: Vec<(Config, Objectives, usize)>,
    n: usize,
    reference: &Reference,
    prefs: &Preferences,
) -> Vec<(Config, Objectives, usize)> {
    let keys: Vec<f64> = state
        .iter()
        .map(|(_, o, _)| utility(o, reference, prefs))
        .collect();
    let mut order: Vec<usize> = (0..state.len()).collect();
    order.sort_by(|&a, &b| {
        keys[b]
            .partial_cmp(&keys[a])
            .unwrap()
            .then_with(|| state[a].0.cmp(&state[b].0))
    });
    order.into_iter().take(n).map(|i| state[i]).collect()
}

/// Running mean of `mean` (over `n` samples) extended by `fresh`.
fn blend_mean(mean: &Objectives, n: usize, fresh: &[&Objectives])
              -> Objectives {
    let total = (n + fresh.len()) as f64;
    let comb = |get: fn(&Objectives) -> f64| {
        (get(mean) * n as f64 + fresh.iter().map(|o| get(o)).sum::<f64>())
            / total
    };
    Objectives {
        accuracy: comb(|o| o.accuracy),
        latency_ms: comb(|o| o.latency_ms),
        memory_gb: comb(|o| o.memory_gb),
        energy_j: comb(|o| o.energy_j),
    }
}

// ---------------------------------------------------------------------------
// Local: surrogate-guided hill-climb over one-technique mutations
// ---------------------------------------------------------------------------

/// Maximum hill-climb steps per refinement round.
pub const LOCAL_SEARCH_STEPS: usize = 8;

/// Surrogate-guided local search.
///
/// Each round climbs from the best *measured* configuration so far
/// (round 1: the Default baseline): enumerate every one-technique
/// mutation of the current point ([`neighbors`]), rank the feasible
/// ones by surrogate-predicted utility, and move to the best neighbor
/// while prediction keeps improving.  Only the top-`k` predicted
/// configurations encountered along the climb are proposed for real
/// measurement — the surrogate does the exploration, the backend only
/// confirms.  Without surrogates (the "- Predictive Models" ablation)
/// it degenerates to proposing random one-technique mutations of the
/// start point.
pub struct LocalSearchStrategy;

impl SearchStrategy for LocalSearchStrategy {
    fn name(&self) -> &'static str {
        "local"
    }

    fn propose(&mut self, cx: &StrategyCx, _evaluator: &mut dyn Evaluator,
               rng: &mut Rng) -> StrategyOutcome {
        let params = cx.params;
        let k = params.evals_per_iter.max(1);
        let mask = params.mask;
        let scenario = cx.scenario;
        let m = &scenario.model;
        let t = &scenario.task;
        let tb = &scenario.testbed;
        let prefs = &scenario.prefs;

        // Climb from the best measured point so far; the Default
        // configuration seeds round 1.
        let start = mask.clamp(
            cx.measured
                .best_by(|e| utility(&e.objectives, cx.reference, prefs))
                .map(|e| e.config)
                .unwrap_or_else(Config::default_baseline),
        );

        let Some(sur) = cx.surrogates else {
            // Degenerate fallback: random one-technique mutations.
            let mut nbrs: Vec<Config> = neighbors(&start)
                .into_iter()
                .map(|c| mask.clamp(c))
                .filter(|c| *c != start && !cx.seen.contains(c))
                .collect();
            nbrs.sort();
            nbrs.dedup();
            rng.shuffle(&mut nbrs);
            nbrs.truncate(k);
            return StrategyOutcome {
                proposals: nbrs,
                surrogate_evals: 0,
                strategy_evals: 0,
            };
        };

        let predict_util = |c: &Config| -> f64 {
            utility(&sur.predict(c, m, t).objectives, cx.reference, prefs)
        };
        let mut surrogate_evals = 1usize;
        let mut current = start;
        let mut current_u = predict_util(&current);
        let mut visited: BTreeSet<Config> = BTreeSet::new();
        visited.insert(current);
        // Predicted utility per scored config.  Doubles as a memo: a
        // config adjacent to two climb-path points is predicted once,
        // not once per step (same trick as Nsga2Strategy's table).
        let mut scored: BTreeMap<Config, f64> = BTreeMap::new();
        scored.insert(current, current_u);

        for _step in 0..LOCAL_SEARCH_STEPS {
            let mut nbrs: Vec<Config> = neighbors(&current)
                .into_iter()
                .map(|c| mask.clamp(c))
                .filter(|c| *c != current && !visited.contains(c))
                .collect();
            nbrs.sort();
            nbrs.dedup();
            // Predicted Definition-3 power feasibility, as in the
            // NSGA-II constraint-aware initialization.
            nbrs.retain(|c| {
                tb.power_w(c, m, t) <= tb.platform.power_budget_w
            });
            if nbrs.is_empty() {
                break;
            }
            let fresh: Vec<Config> = nbrs
                .iter()
                .copied()
                .filter(|c| !scored.contains_key(c))
                .collect();
            let fresh_utils: Vec<f64> = pool::parallel_map(
                params.parallelism, &fresh, |c| predict_util(c),
            );
            surrogate_evals += fresh.len();
            for (c, u) in fresh.iter().zip(&fresh_utils) {
                scored.insert(*c, *u);
            }
            let (best_i, best_u) = nbrs
                .iter()
                .enumerate()
                .map(|(i, c)| (i, scored[c]))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .expect("non-empty neighborhood");
            if best_u <= current_u {
                break; // local optimum under the surrogate
            }
            current = nbrs[best_i];
            current_u = best_u;
            visited.insert(current);
        }

        // Measure only the top-k predicted, unseen configurations
        // encountered anywhere along the climb (the start point is the
        // coordinator's business — it is either already measured or the
        // Default fallback).
        let mut ranked: Vec<(Config, f64)> = scored
            .into_iter()
            .filter(|(c, _)| *c != start && !cx.seen.contains(c))
            .collect();
        ranked.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(&b.0))
        });
        ranked.truncate(k);
        StrategyOutcome {
            proposals: ranked.into_iter().map(|(c, _)| c).collect(),
            surrogate_evals,
            strategy_evals: 0,
        }
    }
}

/// Every valid configuration differing from `c` in exactly one
/// technique axis (the "one-technique mutation" neighborhood of the
/// local-search strategy; also a useful unit of ablation).
pub fn neighbors(c: &Config) -> Vec<Config> {
    let mut out = Vec::new();
    for &attention in &Attention::ALL {
        out.push(Config { arch: ArchConfig { attention, ..c.arch }, ..*c });
    }
    for &moe in &MoE::ALL {
        out.push(Config { arch: ArchConfig { moe, ..c.arch }, ..*c });
    }
    for &method in &FtMethod::ALL {
        let ft = if method.is_peft() {
            FtConfig {
                method,
                rank: if c.ft.method.is_peft() { c.ft.rank } else { 32 },
                alpha_mult: if c.ft.method.is_peft() {
                    c.ft.alpha_mult
                } else {
                    2
                },
            }
        } else {
            FtConfig::full()
        };
        out.push(Config { ft, ..*c });
    }
    if c.ft.method.is_peft() {
        for &rank in &RANKS {
            out.push(Config { ft: FtConfig { rank, ..c.ft }, ..*c });
        }
        for &alpha_mult in &ALPHA_MULTS {
            out.push(Config { ft: FtConfig { alpha_mult, ..c.ft }, ..*c });
        }
    }
    for &precision in &Precision::ALL {
        out.push(Config { inf: InfConfig { precision, ..c.inf }, ..*c });
    }
    for &quant_method in &QuantMethod::ALL {
        out.push(Config {
            inf: InfConfig { quant_method, ..c.inf },
            ..*c
        });
    }
    for &kv_cache in &KvCache::ALL {
        out.push(Config { inf: InfConfig { kv_cache, ..c.inf }, ..*c });
    }
    out.retain(|x| x != c && validity::is_valid(x));
    out.sort();
    out.dedup();
    out
}

// ---------------------------------------------------------------------------
// Baselines as degenerate strategies
// ---------------------------------------------------------------------------

/// A Table-2 baseline selector as a [`SearchStrategy`]: one round, one
/// proposal.  Rule-based baselines (Default, Manual Selection,
/// EfficientLLM Rec.) are zero-eval strategies — their handicap *is*
/// never measuring.  Selector baselines (Best Single-Stage, Random
/// Search) perform their budgeted measurements through the backend, so
/// they inherit caching, parallel fan-out and [`Evaluator::evals`]
/// counting like every other strategy.
pub struct BaselineStrategy(pub Baseline);

impl SearchStrategy for BaselineStrategy {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn uses_surrogates(&self) -> bool {
        false
    }

    fn rounds(&self, _params: &AeLlmParams) -> usize {
        1
    }

    fn propose(&mut self, cx: &StrategyCx, evaluator: &mut dyn Evaluator,
               rng: &mut Rng) -> StrategyOutcome {
        let scenario = cx.scenario;
        let tb = &scenario.testbed;
        let m = &scenario.model;
        let t = &scenario.task;
        let eval_ctx = cx.eval_ctx();
        let before = evaluator.evals();
        let chosen = baselines::select(
            self.0,
            m,
            t,
            &tb.platform,
            cx.reference,
            &scenario.prefs,
            evaluator,
            &|c: &Config| tb.feasible(c, m, t),
            &eval_ctx,
            rng,
        );
        StrategyOutcome {
            proposals: vec![cx.params.mask.clamp(chosen)],
            surrogate_evals: 0,
            strategy_evals: evaluator.evals() - before,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware;

    #[test]
    fn strategy_kind_round_trips_names() {
        for kind in StrategyKind::ALL {
            assert_eq!(StrategyKind::by_name(kind.name()), Some(kind));
            assert_eq!(kind.build().name(), kind.name());
        }
        assert_eq!(StrategyKind::by_name("nsga3"), None);
        assert_eq!(StrategyKind::by_name(""), None);
    }

    #[test]
    fn neighbors_are_valid_single_axis_mutations() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let c = enumerate::sample(&mut rng);
            let nbrs = neighbors(&c);
            assert!(nbrs.len() > 10, "only {} neighbors of {c}", nbrs.len());
            for n in &nbrs {
                assert!(validity::is_valid(n), "invalid neighbor {n}");
                assert_ne!(*n, c);
                // exactly one stage changed, and within it one axis
                // moved (method switches may carry rank/alpha defaults,
                // so we only assert the stage count here)
                let stages = [n.arch != c.arch, n.ft != c.ft,
                              n.inf != c.inf];
                assert_eq!(stages.iter().filter(|&&x| x).count(), 1,
                           "{n} differs from {c} in several stages");
            }
        }
    }

    #[test]
    fn neighbors_of_default_include_known_moves() {
        let d = Config::default_baseline();
        let nbrs = neighbors(&d);
        let mut gqa = d;
        gqa.arch.attention = Attention::Gqa;
        assert!(nbrs.contains(&gqa));
        let mut int8 = d;
        int8.inf.precision = Precision::Int8;
        assert!(nbrs.contains(&int8));
    }

    #[test]
    fn top_by_utility_is_deterministic_and_ranked() {
        let reference = Reference {
            default: Objectives {
                accuracy: 70.0,
                latency_ms: 50.0,
                memory_gb: 10.0,
                energy_j: 1.0,
            },
        };
        let prefs = Preferences::default();
        let mut rng = Rng::new(4);
        let state: Vec<(Config, Objectives, usize)> = (0..20)
            .map(|_| {
                let c = enumerate::sample(&mut rng);
                let o = Objectives {
                    accuracy: 50.0 + 30.0 * rng.f64(),
                    latency_ms: 20.0 + 60.0 * rng.f64(),
                    memory_gb: 4.0 + 10.0 * rng.f64(),
                    energy_j: 0.2 + rng.f64(),
                };
                (c, o, 1)
            })
            .collect();
        let a = top_by_utility(state.clone(), 5, &reference, &prefs);
        let b = top_by_utility(state.clone(), 5, &reference, &prefs);
        assert_eq!(a.len(), 5);
        assert_eq!(
            a.iter().map(|(c, _, _)| *c).collect::<Vec<_>>(),
            b.iter().map(|(c, _, _)| *c).collect::<Vec<_>>()
        );
        let us: Vec<f64> = a
            .iter()
            .map(|(_, o, _)| utility(o, &reference, &prefs))
            .collect();
        for w in us.windows(2) {
            assert!(w[0] >= w[1], "not sorted: {us:?}");
        }
    }

    #[test]
    fn blend_mean_averages_componentwise() {
        let a = Objectives { accuracy: 60.0, latency_ms: 30.0,
                             memory_gb: 6.0, energy_j: 0.6 };
        let b = Objectives { accuracy: 66.0, latency_ms: 36.0,
                             memory_gb: 9.0, energy_j: 0.9 };
        let c = Objectives { accuracy: 72.0, latency_ms: 42.0,
                             memory_gb: 12.0, energy_j: 1.2 };
        let m = blend_mean(&a, 1, &[&b, &c]);
        assert!((m.accuracy - 66.0).abs() < 1e-12);
        assert!((m.latency_ms - 36.0).abs() < 1e-12);
        assert!((m.memory_gb - 9.0).abs() < 1e-12);
        assert!((m.energy_j - 0.9).abs() < 1e-12);
    }

    #[test]
    fn sample_unseen_respects_seen_and_distinctness() {
        let mask = crate::coordinator::SpaceMask::default();
        let mut rng = Rng::new(5);
        let mut seen: BTreeSet<Config> = BTreeSet::new();
        for _ in 0..30 {
            seen.insert(enumerate::sample(&mut rng));
        }
        let got = sample_unseen(40, &mask, &seen, &mut rng);
        assert_eq!(got.len(), 40);
        let distinct: BTreeSet<_> = got.iter().collect();
        assert_eq!(distinct.len(), 40);
        for c in &got {
            assert!(!seen.contains(c));
            assert!(validity::is_valid(c));
        }
    }

    #[test]
    fn racing_rung_budget_shape() {
        // The per-round arithmetic behind the exact-budget contract:
        // 4k entrants + 2·(2k) rung-1 samples = 8k strategy evals.
        let k = 8usize;
        assert_eq!(RACING_ENTRANT_FACTOR * k + 2 * (2 * k), 8 * k);
    }

    #[test]
    fn local_search_proposes_from_scratch_scenario() {
        // Smoke the strategy directly against a real scenario context.
        let scenario = Scenario::for_model("Phi-2").unwrap().noiseless();
        let params = AeLlmParams::small();
        let reference = Reference {
            default: scenario.testbed.true_objectives(
                &Config::default_baseline(), &scenario.model,
                &scenario.task),
        };
        let measured = ParetoArchive::new(16);
        let seen = BTreeSet::new();
        let cx = StrategyCx {
            scenario: &scenario,
            params: &params,
            reference: &reference,
            surrogates: None,
            measured: &measured,
            seen: &seen,
            iteration: 0,
            rounds: 1,
        };
        let mut evaluator =
            crate::oracle::Testbed::noiseless(hardware::a100());
        let mut rng = Rng::new(7);
        let out = LocalSearchStrategy.propose(&cx, &mut evaluator, &mut rng);
        assert!(!out.proposals.is_empty());
        assert!(out.proposals.len() <= params.evals_per_iter);
        assert_eq!(out.strategy_evals, 0);
        for c in &out.proposals {
            assert!(validity::is_valid(c));
        }
    }
}
