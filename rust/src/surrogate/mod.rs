//! S6: surrogate performance models (paper §3.3.1).
//!
//! Gradient-boosted regression trees predict each objective from the
//! (configuration, model, task) encoding without touching the testbed;
//! bagged ensembles expose the prediction variance the refinement loop
//! uses to pick which configurations to actually measure (§3.4).

pub mod ensemble;
pub mod gbt;
pub mod matrix;
pub mod reference;
pub mod transfer;
pub mod tree;

pub use ensemble::{collect_samples, Ensemble, Prediction, Sample,
                   SurrogateSet, ENSEMBLE_SIZE};
pub use gbt::{Gbt, GbtParams};
pub use matrix::Matrix;
pub use tree::{Tree, TreeParams};
