//! Flat row-major feature matrix for the surrogate stack (DESIGN.md
//! §15).
//!
//! The tree and boosting fits used to take `&[Vec<f64>]` — one heap
//! allocation per training row, so every split scan pointer-chased
//! through scattered `Vec` headers.  [`Matrix`] stores all features
//! contiguously (`data[row * cols + col]`), converted **once** per
//! ensemble fit and shared by every tree; `row(i)` hands out plain
//! slices, so predictions and split scans walk one cache-friendly
//! buffer.

/// A dense row-major `n_rows x cols` matrix of `f64` features.
#[derive(Clone, Debug)]
pub struct Matrix {
    data: Vec<f64>,
    cols: usize,
}

impl Matrix {
    /// An empty matrix with `cols` columns.
    pub fn new(cols: usize) -> Matrix {
        assert!(cols > 0, "feature matrix needs at least one column");
        Matrix { data: Vec::new(), cols }
    }

    /// Flatten a row-of-Vec feature set (all rows must share a width).
    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        assert!(!rows.is_empty(), "empty feature set");
        let cols = rows[0].len();
        let mut m = Matrix {
            data: Vec::with_capacity(rows.len() * cols),
            cols,
        };
        for r in rows {
            m.push_row(r);
        }
        m
    }

    /// Append one row (must match the column count).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols, "row width mismatch");
        self.data.extend_from_slice(row);
    }

    /// Row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Single cell (row-major).
    #[inline]
    pub fn get(&self, row: usize, col: usize) -> f64 {
        self.data[row * self.cols + col]
    }

    pub fn n_rows(&self) -> usize {
        self.data.len() / self.cols
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_rows_round_trips() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let m = Matrix::from_rows(&rows);
        assert_eq!(m.n_rows(), 3);
        assert_eq!(m.cols(), 2);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(m.row(i), r.as_slice());
        }
        assert_eq!(m.get(2, 1), 6.0);
    }

    #[test]
    fn push_row_extends() {
        let mut m = Matrix::new(3);
        assert!(m.is_empty());
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut m = Matrix::new(2);
        m.push_row(&[1.0, 2.0, 3.0]);
    }
}
