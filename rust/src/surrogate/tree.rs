//! Regression trees: the base learner of the gradient-boosted ensemble.
//!
//! CART-style binary trees with variance-reduction splits over candidate
//! thresholds.  Candidate thresholds come from feature quantiles
//! (histogram-style), which both bounds the split search cost and
//! handles the one-hot/ordinal mix of the configuration encoding well.
//!
//! Hot-path layout (DESIGN.md §15): fits read a flat row-major
//! [`Matrix`] instead of `&[Vec<f64>]`; each fit stable-sorts every
//! feature column **once** at the root and filters those index
//! permutations down the split recursion (a filtered stable permutation
//! of a parent list *is* the stable sort of the child's subset, so
//! every split, threshold and floating-point accumulation is
//! bit-identical to sorting per node — `surrogate::reference` holds the
//! old implementation against this one in exact-equality tests).  The
//! fitted tree is a flat [`struct@Node`] array with children in adjacent
//! slots, so traversal picks a child by arithmetic instead of matching
//! an enum.

use super::matrix::Matrix;
use crate::util::Rng;

/// A fitted regression tree (flattened node array).
#[derive(Clone, Debug)]
pub struct Tree {
    nodes: Vec<Node>,
}

/// Flattened node.  Slot 0 is always the root and never a child, so
/// `left == 0` marks a leaf (`value` holds the prediction).  Split
/// children always occupy the adjacent pair `(left, left + 1)`, which
/// is what lets [`Tree::predict`] index the next node arithmetically.
#[derive(Clone, Copy, Debug)]
struct Node {
    feature: u32,
    threshold: f64,
    value: f64,
    left: u32,
}

impl Node {
    fn leaf(value: f64) -> Node {
        Node { feature: 0, threshold: 0.0, value, left: 0 }
    }
}

/// Tree-growing hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Fraction of features considered per split (colsample).
    pub colsample: f64,
    /// Number of candidate thresholds per feature.
    pub n_bins: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 8,
            min_samples_leaf: 3,
            colsample: 0.8,
            n_bins: 16,
        }
    }
}

impl Tree {
    /// Fit to (features, targets) where `m.row(i)` is a feature vector.
    /// `indices` selects the subsample of rows used (bagging); its
    /// members must be distinct (the boosting loop's `sample_indices`
    /// guarantees that).
    pub fn fit(
        m: &Matrix,
        targets: &[f64],
        indices: &[usize],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> Tree {
        assert_eq!(m.n_rows(), targets.len());
        assert!(!indices.is_empty(), "empty training subsample");
        // One stable sort per feature column for the whole fit; split
        // recursion filters these instead of re-sorting per node.
        let perms: Vec<Vec<usize>> = (0..m.cols())
            .map(|f| {
                let mut p = indices.to_vec();
                p.sort_by(|&a, &b| {
                    m.get(a, f).partial_cmp(&m.get(b, f)).unwrap()
                });
                p
            })
            .collect();
        let mut tree = Tree { nodes: vec![Node::leaf(0.0)] };
        tree.grow(0, m, targets, indices.to_vec(), perms, 0, params, rng);
        tree
    }

    #[allow(clippy::too_many_arguments)]
    fn grow(
        &mut self,
        into: usize,
        m: &Matrix,
        targets: &[f64],
        indices: Vec<usize>,
        perms: Vec<Vec<usize>>,
        depth: usize,
        params: &TreeParams,
        rng: &mut Rng,
    ) {
        let mean: f64 = indices.iter().map(|&i| targets[i]).sum::<f64>()
            / indices.len() as f64;

        if depth >= params.max_depth
            || indices.len() < 2 * params.min_samples_leaf
        {
            self.nodes[into] = Node::leaf(mean);
            return;
        }

        match best_split(m, targets, &indices, &perms, params, rng) {
            None => {
                self.nodes[into] = Node::leaf(mean);
            }
            Some((feature, threshold)) => {
                let (li, ri): (Vec<usize>, Vec<usize>) = indices
                    .iter()
                    .partition(|&&i| m.get(i, feature) <= threshold);
                if li.len() < params.min_samples_leaf
                    || ri.len() < params.min_samples_leaf
                {
                    self.nodes[into] = Node::leaf(mean);
                    return;
                }
                // Split each feature permutation by the same predicate:
                // a filtered stable permutation is exactly the stable
                // sort of the child subset.
                let mut lp = Vec::with_capacity(perms.len());
                let mut rp = Vec::with_capacity(perms.len());
                for p in &perms {
                    let mut l = Vec::with_capacity(li.len());
                    let mut r = Vec::with_capacity(ri.len());
                    for &i in p {
                        if m.get(i, feature) <= threshold {
                            l.push(i);
                        } else {
                            r.push(i);
                        }
                    }
                    lp.push(l);
                    rp.push(r);
                }
                drop(perms);
                // Reserve the adjacent child pair, then grow into it.
                let base = self.nodes.len();
                self.nodes.push(Node::leaf(0.0));
                self.nodes.push(Node::leaf(0.0));
                self.nodes[into] = Node {
                    feature: feature as u32,
                    threshold,
                    value: mean,
                    left: base as u32,
                };
                self.grow(base, m, targets, li, lp, depth + 1, params, rng);
                self.grow(base + 1, m, targets, ri, rp, depth + 1, params,
                          rng);
            }
        }
    }

    /// Predict a single feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut idx = 0usize;
        loop {
            let n = self.nodes[idx];
            if n.left == 0 {
                return n.value;
            }
            // Children are adjacent: left for `<= threshold`, left + 1
            // otherwise (the negated `<=` keeps NaN routing identical
            // to the reference implementation).
            idx = n.left as usize
                + !(x[n.feature as usize] <= n.threshold) as usize;
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> usize {
        fn rec(nodes: &[Node], idx: usize) -> usize {
            let n = nodes[idx];
            if n.left == 0 {
                0
            } else {
                let l = n.left as usize;
                1 + rec(nodes, l).max(rec(nodes, l + 1))
            }
        }
        rec(&self.nodes, 0)
    }
}

/// Find the (feature, threshold) with the best variance reduction.
/// `perms[f]` is this node's index list stably sorted by feature `f`,
/// inherited pre-sorted from the parent (see [`Tree::fit`]).
fn best_split(
    m: &Matrix,
    targets: &[f64],
    indices: &[usize],
    perms: &[Vec<usize>],
    params: &TreeParams,
    rng: &mut Rng,
) -> Option<(usize, f64)> {
    let n_features = m.cols();
    let n_consider =
        ((n_features as f64 * params.colsample).ceil() as usize).clamp(1, n_features);
    let features = rng.sample_indices(n_features, n_consider);

    let total_sum: f64 = indices.iter().map(|&i| targets[i]).sum();
    let total_sq: f64 = indices.iter().map(|&i| targets[i] * targets[i]).sum();
    let n = indices.len() as f64;
    let parent_score = total_sq - total_sum * total_sum / n;

    let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, score)

    for &feature in &features {
        let perm = &perms[feature];
        if m.get(perm[0], feature) == m.get(perm[perm.len() - 1], feature) {
            continue; // constant feature
        }

        // Candidate thresholds at quantile positions (histogram split).
        let step = (perm.len() / (params.n_bins + 1)).max(1);
        let mut left_sum = 0.0;
        let mut left_sq = 0.0;
        let mut left_n = 0.0;
        let mut next_check = step;
        for (pos, &i) in perm.iter().enumerate() {
            let t = targets[i];
            left_sum += t;
            left_sq += t * t;
            left_n += 1.0;
            if pos + 1 >= perm.len() {
                break;
            }
            if pos + 1 >= next_check {
                next_check += step;
                let v = m.get(i, feature);
                let nv = m.get(perm[pos + 1], feature);
                if nv == v {
                    continue; // can't split between equal values
                }
                let right_n = n - left_n;
                if left_n < params.min_samples_leaf as f64
                    || right_n < params.min_samples_leaf as f64
                {
                    continue;
                }
                let right_sum = total_sum - left_sum;
                let right_sq = total_sq - left_sq;
                let score = (left_sq - left_sum * left_sum / left_n)
                    + (right_sq - right_sum * right_sum / right_n);
                if score < best.map_or(parent_score - 1e-12, |b| b.2) {
                    best = Some((feature, (v + nv) / 2.0, score));
                }
            }
        }
    }
    best.map(|(f, t, _)| (f, t))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        // y = xor(x0 > .5, x1 > .5) — needs depth 2, not linear.
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..400 {
            let a = rng.f64();
            let b = rng.f64();
            rows.push(vec![a, b]);
            ys.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { 0.0 });
        }
        (rows, ys)
    }

    #[test]
    fn fits_constant_target() {
        let rows: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let ys = vec![3.5; 20];
        let idx: Vec<usize> = (0..20).collect();
        let t = Tree::fit(&Matrix::from_rows(&rows), &ys, &idx,
                          &TreeParams::default(), &mut Rng::new(0));
        assert_eq!(t.predict(&[7.0]), 3.5);
    }

    #[test]
    fn fits_step_function() {
        let rows: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> =
            (0..100).map(|i| if i < 50 { -1.0 } else { 1.0 }).collect();
        let idx: Vec<usize> = (0..100).collect();
        let t = Tree::fit(&Matrix::from_rows(&rows), &ys, &idx,
                          &TreeParams::default(), &mut Rng::new(0));
        assert_eq!(t.predict(&[10.0]), -1.0);
        assert_eq!(t.predict(&[90.0]), 1.0);
    }

    #[test]
    fn learns_xor_interaction() {
        let (rows, ys) = xor_data();
        let idx: Vec<usize> = (0..rows.len()).collect();
        let params = TreeParams { colsample: 1.0, ..Default::default() };
        let t = Tree::fit(&Matrix::from_rows(&rows), &ys, &idx, &params,
                          &mut Rng::new(0));
        let preds: Vec<f64> = rows.iter().map(|r| t.predict(r)).collect();
        let r2 = crate::util::stats::r_squared(&ys, &preds);
        assert!(r2 > 0.9, "r2={r2}");
    }

    #[test]
    fn respects_max_depth() {
        let (rows, ys) = xor_data();
        let idx: Vec<usize> = (0..rows.len()).collect();
        let params = TreeParams { max_depth: 3, ..Default::default() };
        let t = Tree::fit(&Matrix::from_rows(&rows), &ys, &idx, &params,
                          &mut Rng::new(0));
        assert!(t.depth() <= 3, "depth={}", t.depth());
    }

    #[test]
    fn depth_zero_gives_single_leaf() {
        let (rows, ys) = xor_data();
        let idx: Vec<usize> = (0..rows.len()).collect();
        let params = TreeParams { max_depth: 0, ..Default::default() };
        let t = Tree::fit(&Matrix::from_rows(&rows), &ys, &idx, &params,
                          &mut Rng::new(0));
        assert_eq!(t.n_nodes(), 1);
        let mean = crate::util::stats::mean(&ys);
        assert!((t.predict(&[0.3, 0.4]) - mean).abs() < 1e-12);
    }

    #[test]
    fn subsample_only_uses_given_indices() {
        let rows: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let mut ys = vec![0.0; 10];
        ys[9] = 1000.0; // excluded outlier
        let idx: Vec<usize> = (0..9).collect();
        let t = Tree::fit(&Matrix::from_rows(&rows), &ys, &idx,
                          &TreeParams::default(), &mut Rng::new(0));
        assert_eq!(t.predict(&[9.0]), 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (rows, ys) = xor_data();
        let m = Matrix::from_rows(&rows);
        let idx: Vec<usize> = (0..rows.len()).collect();
        let t1 = Tree::fit(&m, &ys, &idx, &TreeParams::default(),
                           &mut Rng::new(5));
        let t2 = Tree::fit(&m, &ys, &idx, &TreeParams::default(),
                           &mut Rng::new(5));
        for r in rows.iter().take(50) {
            assert_eq!(t1.predict(r), t2.predict(r));
        }
    }

    #[test]
    fn children_are_adjacent_slots() {
        // The layout invariant predict() relies on: every split's right
        // child is its left child + 1, and no child ever points at the
        // root slot.
        let (rows, ys) = xor_data();
        let idx: Vec<usize> = (0..rows.len()).collect();
        let t = Tree::fit(&Matrix::from_rows(&rows), &ys, &idx,
                          &TreeParams::default(), &mut Rng::new(2));
        assert!(t.n_nodes() % 2 == 1, "root + adjacent child pairs");
        for n in &t.nodes {
            if n.left != 0 {
                // right child (left + 1) must be a valid slot
                assert!((n.left as usize) + 1 < t.nodes.len());
            }
        }
    }
}
