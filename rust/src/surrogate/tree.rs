//! Regression trees: the base learner of the gradient-boosted ensemble.
//!
//! CART-style binary trees with variance-reduction splits over candidate
//! thresholds.  Candidate thresholds come from feature quantiles
//! (histogram-style), which both bounds the split search cost and
//! handles the one-hot/ordinal mix of the configuration encoding well.

use crate::util::Rng;

/// A fitted regression tree (flattened node array).
#[derive(Clone, Debug)]
pub struct Tree {
    nodes: Vec<Node>,
}

#[derive(Clone, Debug)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        /// children indices into `nodes`
        left: usize,
        right: usize,
    },
}

/// Tree-growing hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Fraction of features considered per split (colsample).
    pub colsample: f64,
    /// Number of candidate thresholds per feature.
    pub n_bins: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 8,
            min_samples_leaf: 3,
            colsample: 0.8,
            n_bins: 16,
        }
    }
}

impl Tree {
    /// Fit to (rows, targets) where `rows[i]` is a feature vector.
    /// `indices` selects the subsample of rows used (bagging).
    pub fn fit(
        rows: &[Vec<f64>],
        targets: &[f64],
        indices: &[usize],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> Tree {
        assert_eq!(rows.len(), targets.len());
        assert!(!indices.is_empty(), "empty training subsample");
        let mut tree = Tree { nodes: Vec::new() };
        tree.grow(rows, targets, indices.to_vec(), 0, params, rng);
        tree
    }

    fn grow(
        &mut self,
        rows: &[Vec<f64>],
        targets: &[f64],
        indices: Vec<usize>,
        depth: usize,
        params: &TreeParams,
        rng: &mut Rng,
    ) -> usize {
        let mean: f64 =
            indices.iter().map(|&i| targets[i]).sum::<f64>() / indices.len() as f64;

        if depth >= params.max_depth
            || indices.len() < 2 * params.min_samples_leaf
        {
            self.nodes.push(Node::Leaf { value: mean });
            return self.nodes.len() - 1;
        }

        match best_split(rows, targets, &indices, params, rng) {
            None => {
                self.nodes.push(Node::Leaf { value: mean });
                self.nodes.len() - 1
            }
            Some((feature, threshold)) => {
                let (li, ri): (Vec<usize>, Vec<usize>) = indices
                    .iter()
                    .partition(|&&i| rows[i][feature] <= threshold);
                if li.len() < params.min_samples_leaf
                    || ri.len() < params.min_samples_leaf
                {
                    self.nodes.push(Node::Leaf { value: mean });
                    return self.nodes.len() - 1;
                }
                // reserve our slot, then grow children
                let my = self.nodes.len();
                self.nodes.push(Node::Leaf { value: mean }); // placeholder
                let left = self.grow(rows, targets, li, depth + 1, params, rng);
                let right = self.grow(rows, targets, ri, depth + 1, params, rng);
                self.nodes[my] = Node::Split { feature, threshold, left, right };
                my
            }
        }
    }

    /// Predict a single feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut idx = 0;
        loop {
            match &self.nodes[idx] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    idx = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> usize {
        fn rec(nodes: &[Node], idx: usize) -> usize {
            match &nodes[idx] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => {
                    1 + rec(nodes, *left).max(rec(nodes, *right))
                }
            }
        }
        rec(&self.nodes, 0)
    }
}

/// Find the (feature, threshold) with the best variance reduction.
fn best_split(
    rows: &[Vec<f64>],
    targets: &[f64],
    indices: &[usize],
    params: &TreeParams,
    rng: &mut Rng,
) -> Option<(usize, f64)> {
    let n_features = rows[0].len();
    let n_consider =
        ((n_features as f64 * params.colsample).ceil() as usize).clamp(1, n_features);
    let features = rng.sample_indices(n_features, n_consider);

    let total_sum: f64 = indices.iter().map(|&i| targets[i]).sum();
    let total_sq: f64 = indices.iter().map(|&i| targets[i] * targets[i]).sum();
    let n = indices.len() as f64;
    let parent_score = total_sq - total_sum * total_sum / n;

    let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, score)

    let mut vals: Vec<(f64, f64)> = Vec::with_capacity(indices.len());
    for &feature in &features {
        vals.clear();
        vals.extend(indices.iter().map(|&i| (rows[i][feature], targets[i])));
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if vals[0].0 == vals[vals.len() - 1].0 {
            continue; // constant feature
        }

        // Candidate thresholds at quantile positions (histogram split).
        let step = (vals.len() / (params.n_bins + 1)).max(1);
        let mut left_sum = 0.0;
        let mut left_sq = 0.0;
        let mut left_n = 0.0;
        let mut next_check = step;
        for (pos, &(v, t)) in vals.iter().enumerate() {
            left_sum += t;
            left_sq += t * t;
            left_n += 1.0;
            if pos + 1 >= vals.len() {
                break;
            }
            if pos + 1 >= next_check {
                next_check += step;
                let nv = vals[pos + 1].0;
                if nv == v {
                    continue; // can't split between equal values
                }
                let right_n = n - left_n;
                if left_n < params.min_samples_leaf as f64
                    || right_n < params.min_samples_leaf as f64
                {
                    continue;
                }
                let right_sum = total_sum - left_sum;
                let right_sq = total_sq - left_sq;
                let score = (left_sq - left_sum * left_sum / left_n)
                    + (right_sq - right_sum * right_sum / right_n);
                if score < best.map_or(parent_score - 1e-12, |b| b.2) {
                    best = Some((feature, (v + nv) / 2.0, score));
                }
            }
        }
    }
    best.map(|(f, t, _)| (f, t))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        // y = xor(x0 > .5, x1 > .5) — needs depth 2, not linear.
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..400 {
            let a = rng.f64();
            let b = rng.f64();
            rows.push(vec![a, b]);
            ys.push(if (a > 0.5) ^ (b > 0.5) { 1.0 } else { 0.0 });
        }
        (rows, ys)
    }

    #[test]
    fn fits_constant_target() {
        let rows: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let ys = vec![3.5; 20];
        let idx: Vec<usize> = (0..20).collect();
        let t = Tree::fit(&rows, &ys, &idx, &TreeParams::default(),
                          &mut Rng::new(0));
        assert_eq!(t.predict(&[7.0]), 3.5);
    }

    #[test]
    fn fits_step_function() {
        let rows: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> =
            (0..100).map(|i| if i < 50 { -1.0 } else { 1.0 }).collect();
        let idx: Vec<usize> = (0..100).collect();
        let t = Tree::fit(&rows, &ys, &idx, &TreeParams::default(),
                          &mut Rng::new(0));
        assert_eq!(t.predict(&[10.0]), -1.0);
        assert_eq!(t.predict(&[90.0]), 1.0);
    }

    #[test]
    fn learns_xor_interaction() {
        let (rows, ys) = xor_data();
        let idx: Vec<usize> = (0..rows.len()).collect();
        let params = TreeParams { colsample: 1.0, ..Default::default() };
        let t = Tree::fit(&rows, &ys, &idx, &params, &mut Rng::new(0));
        let preds: Vec<f64> = rows.iter().map(|r| t.predict(r)).collect();
        let r2 = crate::util::stats::r_squared(&ys, &preds);
        assert!(r2 > 0.9, "r2={r2}");
    }

    #[test]
    fn respects_max_depth() {
        let (rows, ys) = xor_data();
        let idx: Vec<usize> = (0..rows.len()).collect();
        let params = TreeParams { max_depth: 3, ..Default::default() };
        let t = Tree::fit(&rows, &ys, &idx, &params, &mut Rng::new(0));
        assert!(t.depth() <= 3, "depth={}", t.depth());
    }

    #[test]
    fn depth_zero_gives_single_leaf() {
        let (rows, ys) = xor_data();
        let idx: Vec<usize> = (0..rows.len()).collect();
        let params = TreeParams { max_depth: 0, ..Default::default() };
        let t = Tree::fit(&rows, &ys, &idx, &params, &mut Rng::new(0));
        assert_eq!(t.n_nodes(), 1);
        let mean = crate::util::stats::mean(&ys);
        assert!((t.predict(&[0.3, 0.4]) - mean).abs() < 1e-12);
    }

    #[test]
    fn subsample_only_uses_given_indices() {
        let rows: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let mut ys = vec![0.0; 10];
        ys[9] = 1000.0; // excluded outlier
        let idx: Vec<usize> = (0..9).collect();
        let t = Tree::fit(&rows, &ys, &idx, &TreeParams::default(),
                          &mut Rng::new(0));
        assert_eq!(t.predict(&[9.0]), 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (rows, ys) = xor_data();
        let idx: Vec<usize> = (0..rows.len()).collect();
        let t1 = Tree::fit(&rows, &ys, &idx, &TreeParams::default(),
                           &mut Rng::new(5));
        let t2 = Tree::fit(&rows, &ys, &idx, &TreeParams::default(),
                           &mut Rng::new(5));
        for r in rows.iter().take(50) {
            assert_eq!(t1.predict(r), t2.predict(r));
        }
    }
}
