//! Surrogate ensembles with predictive uncertainty (paper §3.4:
//! "uncertainty is measured using the variance of predictions from an
//! ensemble of surrogate models").
//!
//! [`Ensemble`] bags several differently-seeded GBTs per objective;
//! [`SurrogateSet`] bundles the four objective predictors the search
//! uses (accuracy, latency, memory, energy) with incremental re-training
//! for the refinement loop.

use super::gbt::{Gbt, GbtParams};
use super::matrix::Matrix;
use crate::config::encode;
use crate::config::Config;
use crate::models::ModelSpec;
use crate::oracle::Objectives;
use crate::tasks::TaskSpec;
use crate::util::pool::{self, Parallelism};
use crate::util::{stats, Rng};

/// Number of ensemble members.
pub const ENSEMBLE_SIZE: usize = 4;

/// Bagged GBT ensemble for one objective.
#[derive(Clone, Debug)]
pub struct Ensemble {
    members: Vec<Gbt>,
}

/// Fit one GBT per `(target index, pre-split RNG)` job, fanned across
/// `params.parallelism` workers.  This is the single implementation of
/// the determinism-critical fan-out both [`Ensemble::fit`] and
/// [`SurrogateSet::fit`] use: callers split the job RNGs off the master
/// stream *sequentially before* calling, so the fitted models are
/// bit-identical to a sequential fit at any parallelism level.  Workers
/// fit whole models, so nested within-fit parallelism is disabled to
/// keep the pool from oversubscribing.
fn fit_jobs(m: &Matrix, targets: &[&[f64]], jobs: &[(usize, Rng)],
            params: &GbtParams) -> Vec<Gbt> {
    let inner = GbtParams {
        parallelism: Parallelism::Sequential,
        ..*params
    };
    pool::parallel_map(params.parallelism, jobs, |(target, seed)| {
        let mut child = seed.clone();
        Gbt::fit_matrix(m, targets[*target], &inner, &mut child)
    })
}

impl Ensemble {
    /// Fit the bagged members in parallel (see [`fit_jobs`]).
    pub fn fit(rows: &[Vec<f64>], targets: &[f64], params: &GbtParams,
               rng: &mut Rng) -> Ensemble {
        let jobs: Vec<(usize, Rng)> =
            (0..ENSEMBLE_SIZE).map(|_| (0, rng.split())).collect();
        // Flatten once; every member fit shares the matrix.
        let m = Matrix::from_rows(rows);
        Ensemble { members: fit_jobs(&m, &[targets], &jobs, params) }
    }

    /// Mean prediction.
    pub fn predict(&self, x: &[f64]) -> f64 {
        self.members.iter().map(|m| m.predict(x)).sum::<f64>()
            / self.members.len() as f64
    }

    /// (mean, std) across ensemble members — std is the §3.4 uncertainty.
    pub fn predict_with_uncertainty(&self, x: &[f64]) -> (f64, f64) {
        let preds: Vec<f64> =
            self.members.iter().map(|m| m.predict(x)).collect();
        (stats::mean(&preds), stats::std_dev(&preds))
    }

    pub fn r2(&self, rows: &[Vec<f64>], targets: &[f64]) -> f64 {
        let preds: Vec<f64> = rows.iter().map(|r| self.predict(r)).collect();
        stats::r_squared(targets, &preds)
    }
}

/// A labelled training example for the surrogates.
#[derive(Clone, Debug)]
pub struct Sample {
    pub features: Vec<f64>,
    pub objectives: Objectives,
}

/// The four-objective surrogate bundle (Eq. 5's {f_o}).
pub struct SurrogateSet {
    pub accuracy: Ensemble,
    pub latency: Ensemble,
    pub memory: Ensemble,
    pub energy: Ensemble,
    /// Training set (kept so refinement can append + refit).
    samples: Vec<Sample>,
    params: GbtParams,
}

/// Predicted objectives with per-objective uncertainties.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub objectives: Objectives,
    /// std-devs in the same order (accuracy, latency, memory, energy)
    pub uncertainty: [f64; 4],
}

impl Prediction {
    /// Scalar uncertainty score: relative std summed over objectives.
    pub fn total_relative_uncertainty(&self) -> f64 {
        let o = &self.objectives;
        let rel = |s: f64, v: f64| if v.abs() > 1e-9 { s / v.abs() } else { s };
        rel(self.uncertainty[0], o.accuracy)
            + rel(self.uncertainty[1], o.latency_ms)
            + rel(self.uncertainty[2], o.memory_gb)
            + rel(self.uncertainty[3], o.energy_j)
    }
}

impl SurrogateSet {
    /// Fit from labelled samples.
    pub fn fit(samples: Vec<Sample>, params: GbtParams,
               rng: &mut Rng) -> SurrogateSet {
        assert!(!samples.is_empty());
        // Flatten the features once (row-major Matrix); all 16 member
        // fits below share it instead of re-chasing row pointers.
        let mut rows = Matrix::new(samples[0].features.len());
        for s in &samples {
            rows.push_row(&s.features);
        }
        // Latency/energy are trained in log space: they span orders of
        // magnitude across models and the multiplicative noise becomes
        // additive there.
        let acc: Vec<f64> =
            samples.iter().map(|s| s.objectives.accuracy).collect();
        let lat: Vec<f64> = samples
            .iter()
            .map(|s| s.objectives.latency_ms.max(1e-6).ln())
            .collect();
        let mem: Vec<f64> = samples
            .iter()
            .map(|s| s.objectives.memory_gb.max(1e-6).ln())
            .collect();
        let en: Vec<f64> = samples
            .iter()
            .map(|s| s.objectives.energy_j.max(1e-9).ln())
            .collect();

        // All 4 objectives × ENSEMBLE_SIZE members fit as one flat job
        // batch on the pool (via the shared `fit_jobs` fan-out).  The
        // per-member RNG streams are split off sequentially in exactly
        // the order the old objective-by-objective code consumed them,
        // so the fitted set is bit-identical to a sequential fit.
        let targets: [&[f64]; 4] = [&acc, &lat, &mem, &en];
        let mut jobs: Vec<(usize, Rng)> =
            Vec::with_capacity(targets.len() * ENSEMBLE_SIZE);
        for obj in 0..targets.len() {
            for _ in 0..ENSEMBLE_SIZE {
                jobs.push((obj, rng.split()));
            }
        }
        let fitted = fit_jobs(&rows, &targets, &jobs, &params);
        let mut members = fitted.into_iter();
        let mut next_ensemble = || Ensemble {
            members: members.by_ref().take(ENSEMBLE_SIZE).collect(),
        };
        SurrogateSet {
            accuracy: next_ensemble(),
            latency: next_ensemble(),
            memory: next_ensemble(),
            energy: next_ensemble(),
            samples,
            params,
        }
    }

    /// Predict objectives + uncertainty for an encoded feature vector.
    pub fn predict_features(&self, x: &[f64]) -> Prediction {
        let (a, sa) = self.accuracy.predict_with_uncertainty(x);
        let (l, sl) = self.latency.predict_with_uncertainty(x);
        let (m, sm) = self.memory.predict_with_uncertainty(x);
        let (e, se) = self.energy.predict_with_uncertainty(x);
        let (l, sl) = (l.exp(), l.exp() * sl); // delta method back-transform
        let (m, sm) = (m.exp(), m.exp() * sm);
        let (e, se) = (e.exp(), e.exp() * se);
        Prediction {
            objectives: Objectives {
                accuracy: a,
                latency_ms: l,
                memory_gb: m,
                energy_j: e,
            },
            uncertainty: [sa, sl, sm, se],
        }
    }

    /// Predict for a configuration in a (model, task) context.
    pub fn predict(&self, c: &Config, m: &ModelSpec,
                   t: &TaskSpec) -> Prediction {
        self.predict_features(&encode::encode(c, m, t))
    }

    /// Refinement-loop update (Algorithm 1 line 6): append freshly
    /// measured samples and refit.
    pub fn update(&mut self, new_samples: Vec<Sample>, rng: &mut Rng) {
        self.samples.extend(new_samples);
        let refit = SurrogateSet::fit(
            std::mem::take(&mut self.samples),
            self.params,
            rng,
        );
        *self = refit;
    }

    pub fn n_samples(&self) -> usize {
        self.samples.len()
    }

    /// Held-out R² per objective on a labelled set (order: acc, lat(log),
    /// mem(log), energy(log)).
    pub fn r2_report(&self, test: &[Sample]) -> [f64; 4] {
        let rows: Vec<Vec<f64>> =
            test.iter().map(|s| s.features.clone()).collect();
        let acc: Vec<f64> =
            test.iter().map(|s| s.objectives.accuracy).collect();
        let lat: Vec<f64> = test
            .iter()
            .map(|s| s.objectives.latency_ms.max(1e-6).ln())
            .collect();
        let mem: Vec<f64> = test
            .iter()
            .map(|s| s.objectives.memory_gb.max(1e-6).ln())
            .collect();
        let en: Vec<f64> = test
            .iter()
            .map(|s| s.objectives.energy_j.max(1e-9).ln())
            .collect();
        [
            self.accuracy.r2(&rows, &acc),
            self.latency.r2(&rows, &lat),
            self.memory.r2(&rows, &mem),
            self.energy.r2(&rows, &en),
        ]
    }
}

/// Collect a labelled sample set by measuring `n` random configurations
/// on the testbed (the paper's "500 randomly sampled configurations").
pub fn collect_samples(
    testbed: &crate::oracle::Testbed,
    m: &ModelSpec,
    t: &TaskSpec,
    n: usize,
    rng: &mut Rng,
) -> Vec<Sample> {
    let configs = crate::config::enumerate::sample_distinct(rng, n);
    configs
        .into_iter()
        .map(|c| Sample {
            features: encode::encode(&c, m, t),
            objectives: testbed.measure(&c, m, t, rng),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware;
    use crate::models::by_name;
    use crate::oracle::Testbed;
    use crate::tasks::blended_task;

    fn train_set(n: usize, seed: u64) -> Vec<Sample> {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = blended_task();
        let tb = Testbed::new(hardware::a100());
        let mut rng = Rng::new(seed);
        collect_samples(&tb, &m, &t, n, &mut rng)
    }

    #[test]
    fn surrogates_reach_paper_r2_on_heldout() {
        let train = train_set(400, 1);
        let test = train_set(120, 2);
        let mut rng = Rng::new(3);
        let s = SurrogateSet::fit(train, GbtParams::fast(), &mut rng);
        let r2 = s.r2_report(&test);
        // Paper §3.5: "R^2 > 0.85 on held-out configurations for all
        // objectives".
        for (i, v) in r2.iter().enumerate() {
            assert!(*v > 0.85, "objective {i} r2={v} (all={r2:?})");
        }
    }

    #[test]
    fn predictions_close_to_oracle_truth() {
        let train = train_set(400, 4);
        let mut rng = Rng::new(5);
        let s = SurrogateSet::fit(train, GbtParams::fast(), &mut rng);
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = blended_task();
        let tb = Testbed::noiseless(hardware::a100());
        let mut err_lat = 0.0;
        let n = 50;
        let mut rng2 = Rng::new(6);
        for _ in 0..n {
            let c = crate::config::enumerate::sample(&mut rng2);
            let truth = tb.true_objectives(&c, &m, &t);
            let pred = s.predict(&c, &m, &t).objectives;
            err_lat += ((pred.latency_ms - truth.latency_ms)
                / truth.latency_ms)
                .abs();
        }
        let mape = err_lat / n as f64;
        assert!(mape < 0.15, "latency MAPE={mape}");
    }

    #[test]
    fn uncertainty_positive_and_finite() {
        let train = train_set(150, 7);
        let mut rng = Rng::new(8);
        let s = SurrogateSet::fit(train, GbtParams::fast(), &mut rng);
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = blended_task();
        let mut rng2 = Rng::new(9);
        for _ in 0..20 {
            let c = crate::config::enumerate::sample(&mut rng2);
            let p = s.predict(&c, &m, &t);
            assert!(p.uncertainty.iter().all(|u| u.is_finite() && *u >= 0.0));
            assert!(p.total_relative_uncertainty().is_finite());
        }
    }

    #[test]
    fn parallel_fit_bit_identical_to_sequential() {
        let train = train_set(150, 20);
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = blended_task();
        let fit_with = |par: Parallelism| {
            let params = GbtParams { parallelism: par, ..GbtParams::fast() };
            SurrogateSet::fit(train.clone(), params, &mut Rng::new(21))
        };
        let seq = fit_with(Parallelism::Sequential);
        let par = fit_with(Parallelism::Threads(4));
        let mut rng = Rng::new(22);
        for _ in 0..25 {
            let c = crate::config::enumerate::sample(&mut rng);
            let a = seq.predict(&c, &m, &t);
            let b = par.predict(&c, &m, &t);
            assert_eq!(a.objectives, b.objectives);
            assert_eq!(a.uncertainty, b.uncertainty);
        }
    }

    #[test]
    fn update_appends_and_refits() {
        let train = train_set(100, 10);
        let mut rng = Rng::new(11);
        let mut s = SurrogateSet::fit(train, GbtParams::fast(), &mut rng);
        assert_eq!(s.n_samples(), 100);
        s.update(train_set(50, 12), &mut rng);
        assert_eq!(s.n_samples(), 150);
    }

    #[test]
    fn more_data_does_not_hurt_much() {
        let test = train_set(100, 13);
        let mut rng = Rng::new(14);
        let small = SurrogateSet::fit(train_set(60, 15), GbtParams::fast(),
                                      &mut rng);
        let big = SurrogateSet::fit(train_set(400, 16), GbtParams::fast(),
                                    &mut rng);
        let r2s = small.r2_report(&test)[1];
        let r2b = big.r2_report(&test)[1];
        assert!(r2b > r2s - 0.02, "small={r2s} big={r2b}");
    }
}
