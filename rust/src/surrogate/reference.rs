//! The retained pre-Matrix surrogate implementation (DESIGN.md §15).
//!
//! [`RefTree`] and [`ref_gbt_fit`] are the row-of-Vec, enum-node,
//! sort-per-node implementations that `surrogate::tree` /
//! `surrogate::gbt` replaced, kept verbatim as (a) the exact-equality
//! oracle — the tests below require the flat-matrix fits to reproduce
//! their predictions **bit for bit** on seeded data — and (b) the
//! "before" rows of the `perf_search` GBT microbenches (same idiom as
//! `Server::drain_polled` and `search::archive::ReferenceArchive`).
//! Not for production use.

use super::gbt::GbtParams;
use super::tree::TreeParams;
use crate::util::stats;
use crate::util::Rng;

/// The pre-Matrix regression tree (enum nodes, per-node sorting).
#[derive(Clone, Debug)]
pub struct RefTree {
    nodes: Vec<RefNode>,
}

#[derive(Clone, Debug)]
enum RefNode {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

impl RefTree {
    /// The pre-Matrix `Tree::fit`: row-of-Vec features, fresh `vals`
    /// sort per (node, feature).
    pub fn fit(
        rows: &[Vec<f64>],
        targets: &[f64],
        indices: &[usize],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> RefTree {
        assert_eq!(rows.len(), targets.len());
        assert!(!indices.is_empty(), "empty training subsample");
        let mut tree = RefTree { nodes: Vec::new() };
        tree.grow(rows, targets, indices.to_vec(), 0, params, rng);
        tree
    }

    fn grow(
        &mut self,
        rows: &[Vec<f64>],
        targets: &[f64],
        indices: Vec<usize>,
        depth: usize,
        params: &TreeParams,
        rng: &mut Rng,
    ) -> usize {
        let mean: f64 = indices.iter().map(|&i| targets[i]).sum::<f64>()
            / indices.len() as f64;

        if depth >= params.max_depth
            || indices.len() < 2 * params.min_samples_leaf
        {
            self.nodes.push(RefNode::Leaf { value: mean });
            return self.nodes.len() - 1;
        }

        match best_split(rows, targets, &indices, params, rng) {
            None => {
                self.nodes.push(RefNode::Leaf { value: mean });
                self.nodes.len() - 1
            }
            Some((feature, threshold)) => {
                let (li, ri): (Vec<usize>, Vec<usize>) = indices
                    .iter()
                    .partition(|&&i| rows[i][feature] <= threshold);
                if li.len() < params.min_samples_leaf
                    || ri.len() < params.min_samples_leaf
                {
                    self.nodes.push(RefNode::Leaf { value: mean });
                    return self.nodes.len() - 1;
                }
                // reserve our slot, then grow children
                let my = self.nodes.len();
                self.nodes.push(RefNode::Leaf { value: mean }); // placeholder
                let left = self.grow(rows, targets, li, depth + 1, params, rng);
                let right = self.grow(rows, targets, ri, depth + 1, params, rng);
                self.nodes[my] =
                    RefNode::Split { feature, threshold, left, right };
                my
            }
        }
    }

    /// Predict a single feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut idx = 0;
        loop {
            match &self.nodes[idx] {
                RefNode::Leaf { value } => return *value,
                RefNode::Split { feature, threshold, left, right } => {
                    idx = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// The pre-Matrix `best_split`: allocates and sorts a fresh `vals` Vec
/// per (node, feature).
fn best_split(
    rows: &[Vec<f64>],
    targets: &[f64],
    indices: &[usize],
    params: &TreeParams,
    rng: &mut Rng,
) -> Option<(usize, f64)> {
    let n_features = rows[0].len();
    let n_consider =
        ((n_features as f64 * params.colsample).ceil() as usize).clamp(1, n_features);
    let features = rng.sample_indices(n_features, n_consider);

    let total_sum: f64 = indices.iter().map(|&i| targets[i]).sum();
    let total_sq: f64 = indices.iter().map(|&i| targets[i] * targets[i]).sum();
    let n = indices.len() as f64;
    let parent_score = total_sq - total_sum * total_sum / n;

    let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, score)

    let mut vals: Vec<(f64, f64)> = Vec::with_capacity(indices.len());
    for &feature in &features {
        vals.clear();
        vals.extend(indices.iter().map(|&i| (rows[i][feature], targets[i])));
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if vals[0].0 == vals[vals.len() - 1].0 {
            continue; // constant feature
        }

        let step = (vals.len() / (params.n_bins + 1)).max(1);
        let mut left_sum = 0.0;
        let mut left_sq = 0.0;
        let mut left_n = 0.0;
        let mut next_check = step;
        for (pos, &(v, t)) in vals.iter().enumerate() {
            left_sum += t;
            left_sq += t * t;
            left_n += 1.0;
            if pos + 1 >= vals.len() {
                break;
            }
            if pos + 1 >= next_check {
                next_check += step;
                let nv = vals[pos + 1].0;
                if nv == v {
                    continue; // can't split between equal values
                }
                let right_n = n - left_n;
                if left_n < params.min_samples_leaf as f64
                    || right_n < params.min_samples_leaf as f64
                {
                    continue;
                }
                let right_sum = total_sum - left_sum;
                let right_sq = total_sq - left_sq;
                let score = (left_sq - left_sum * left_sum / left_n)
                    + (right_sq - right_sum * right_sum / right_n);
                if score < best.map_or(parent_score - 1e-12, |b| b.2) {
                    best = Some((feature, (v + nv) / 2.0, score));
                }
            }
        }
    }
    best.map(|(f, t, _)| (f, t))
}

/// The pre-Matrix boosted ensemble: base prediction plus shrunken tree
/// sum, exactly as `Gbt::fit` builds it.
#[derive(Clone, Debug)]
pub struct RefGbt {
    base: f64,
    trees: Vec<RefTree>,
    learning_rate: f64,
}

impl RefGbt {
    pub fn predict(&self, x: &[f64]) -> f64 {
        self.base
            + self.learning_rate
                * self.trees.iter().map(|t| t.predict(x)).sum::<f64>()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

/// The pre-Matrix `Gbt::fit` boosting loop: identical RNG consumption
/// (one `sample_indices` per round, per-node feature sampling inside
/// the tree fit), identical residual arithmetic, identical early-stop.
/// The residual refresh runs sequentially — it is element-wise, so the
/// pooled refresh in the production fit is bit-identical to it.
pub fn ref_gbt_fit(rows: &[Vec<f64>], targets: &[f64], params: &GbtParams,
                   rng: &mut Rng) -> RefGbt {
    assert_eq!(rows.len(), targets.len());
    assert!(!rows.is_empty(), "empty training set");
    let n = rows.len();
    let base = stats::mean(targets);
    let mut residuals: Vec<f64> = targets.iter().map(|t| t - base).collect();
    let mut trees = Vec::new();
    let mut last_rmse = f64::INFINITY;
    let mut stall = 0;

    for _round in 0..params.n_estimators {
        let k = ((n as f64 * params.subsample).round() as usize).clamp(1, n);
        let indices = rng.sample_indices(n, k);
        let tree = RefTree::fit(rows, &residuals, &indices, &params.tree, rng);
        for (j, r) in residuals.iter_mut().enumerate() {
            *r -= params.learning_rate * tree.predict(&rows[j]);
        }
        trees.push(tree);

        if params.early_stop_tol > 0.0 {
            let rmse = (residuals.iter().map(|r| r * r).sum::<f64>()
                / n as f64)
                .sqrt();
            if last_rmse - rmse < params.early_stop_tol * last_rmse.max(1e-12) {
                stall += 1;
                if stall >= 10 {
                    break;
                }
            } else {
                stall = 0;
            }
            last_rmse = rmse;
        }
    }
    RefGbt { base, trees, learning_rate: params.learning_rate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::gbt::Gbt;
    use crate::surrogate::matrix::Matrix;
    use crate::surrogate::tree::Tree;
    use crate::util::Parallelism;

    /// Synthetic surface with categorical-like features, interactions,
    /// curvature and duplicated feature values (tie-ordering stress).
    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let cat = rng.below(4) as f64;
            let a = rng.f64();
            let b = rng.f64();
            let dup = rng.below(8) as f64; // few distinct values: ties
            let x = vec![cat, a, b, dup];
            let y = 3.0 * (cat == 2.0) as u8 as f64 + 2.0 * a * b
                + (4.0 * a).sin() - 0.5 * b + 0.25 * dup;
            rows.push(x);
            ys.push(y);
        }
        (rows, ys)
    }

    #[test]
    fn flat_tree_predictions_exactly_equal_reference() {
        // The satellite exact-equality test: same seed, same subsample
        // -> the flat-matrix pre-sorted tree must produce the *same
        // f64 bits* as the row-of-Vec sort-per-node reference, across
        // parameter shapes that exercise depth limits, tie-heavy
        // features and colsample randomness.
        let (rows, ys) = synth(500, 11);
        let m = Matrix::from_rows(&rows);
        let idx: Vec<usize> = (0..rows.len()).collect();
        let shapes = [
            TreeParams::default(),
            TreeParams { max_depth: 3, ..Default::default() },
            TreeParams { colsample: 0.5, ..Default::default() },
            TreeParams { n_bins: 4, min_samples_leaf: 7,
                         ..Default::default() },
        ];
        for (si, params) in shapes.iter().enumerate() {
            for seed in [0u64, 1, 9] {
                let new = Tree::fit(&m, &ys, &idx, params,
                                    &mut Rng::new(seed));
                let old = RefTree::fit(&rows, &ys, &idx, params,
                                       &mut Rng::new(seed));
                assert_eq!(new.n_nodes(), old.n_nodes(),
                           "shape {si} seed {seed}: node counts differ");
                for (i, r) in rows.iter().enumerate() {
                    let (p_new, p_old) = (new.predict(r), old.predict(r));
                    assert!(p_new == p_old,
                            "shape {si} seed {seed} row {i}: \
                             {p_new:?} != {p_old:?} (bitwise)");
                }
            }
        }
    }

    #[test]
    fn flat_gbt_predictions_exactly_equal_reference() {
        let (rows, ys) = synth(400, 12);
        let (test_rows, _) = synth(150, 13);
        for seed in [0u64, 7] {
            let params = GbtParams {
                n_estimators: 40,
                parallelism: Parallelism::Sequential,
                ..GbtParams::fast()
            };
            let new = Gbt::fit(&rows, &ys, &params, &mut Rng::new(seed));
            let old = ref_gbt_fit(&rows, &ys, &params, &mut Rng::new(seed));
            assert_eq!(new.n_trees(), old.n_trees(),
                       "seed {seed}: early stop diverged");
            for (i, r) in rows.iter().chain(test_rows.iter()).enumerate() {
                let (p_new, p_old) = (new.predict(r), old.predict(r));
                assert!(p_new == p_old,
                        "seed {seed} row {i}: {p_new:?} != {p_old:?}");
            }
        }
    }

    #[test]
    fn flat_gbt_matches_reference_under_parallel_refresh() {
        // The pooled residual refresh engages above 2 x 4096 rows; the
        // flat fit must still match the sequential reference bitwise.
        let (rows, ys) = synth(9000, 14);
        let params = GbtParams {
            n_estimators: 4,
            parallelism: Parallelism::Threads(4),
            ..GbtParams::fast()
        };
        let new = Gbt::fit(&rows, &ys, &params, &mut Rng::new(3));
        let seq_params =
            GbtParams { parallelism: Parallelism::Sequential, ..params };
        let old = ref_gbt_fit(&rows, &ys, &seq_params, &mut Rng::new(3));
        for r in rows.iter().take(100) {
            assert_eq!(new.predict(r), old.predict(r));
        }
    }
}
