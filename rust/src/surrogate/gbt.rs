//! Gradient-boosted regression trees (the paper's XGBoost surrogate,
//! §3.3.1 + Table 5, re-implemented from scratch).
//!
//! Squared-error boosting: each round fits a tree to the residuals of
//! the current ensemble, added with shrinkage.  Row subsampling and
//! per-split column subsampling follow Table 5 (subsample 0.8,
//! colsample 0.8, eta 0.05, depth 8, 500 estimators — tests and search
//! use fewer rounds since the target functions here are smoother than
//! real benchmark surfaces).

use super::matrix::Matrix;
use super::tree::{Tree, TreeParams};
use crate::util::pool::{self, Parallelism};
use crate::util::stats;
use crate::util::Rng;

/// Boosting hyperparameters (defaults = paper Table 5).
#[derive(Clone, Copy, Debug)]
pub struct GbtParams {
    pub n_estimators: usize,
    pub learning_rate: f64,
    pub subsample: f64,
    pub tree: TreeParams,
    /// Early-stop when the training RMSE improves less than this
    /// (relative) over 10 rounds; 0 disables.
    pub early_stop_tol: f64,
    /// Worker count for the fit/predict fan-outs.  Boosting rounds are
    /// inherently sequential; parallelism applies across ensemble
    /// members (see `surrogate::ensemble`), across large prediction
    /// batches, and to the per-round residual refresh on big training
    /// sets.  Every parallel section is element-wise, so results are
    /// bit-identical at any level.
    pub parallelism: Parallelism,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams {
            n_estimators: 500,
            learning_rate: 0.05,
            subsample: 0.8,
            tree: TreeParams::default(),
            early_stop_tol: 1e-5,
            parallelism: Parallelism::Auto,
        }
    }
}

impl GbtParams {
    /// Smaller, faster setting used inside the search loop where the
    /// surrogate is retrained every refinement iteration.
    pub fn fast() -> Self {
        GbtParams {
            n_estimators: 120,
            learning_rate: 0.1,
            ..Default::default()
        }
    }
}

/// A fitted gradient-boosted model.
#[derive(Clone, Debug)]
pub struct Gbt {
    base: f64,
    trees: Vec<Tree>,
    learning_rate: f64,
}

impl Gbt {
    /// Fit to (rows, targets) — flattens the rows into a [`Matrix`]
    /// once and defers to [`fit_matrix`](Self::fit_matrix).
    pub fn fit(rows: &[Vec<f64>], targets: &[f64], params: &GbtParams,
               rng: &mut Rng) -> Gbt {
        assert!(!rows.is_empty(), "empty training set");
        Gbt::fit_matrix(&Matrix::from_rows(rows), targets, params, rng)
    }

    /// Fit to a pre-flattened feature matrix (the ensemble layer
    /// converts once and shares the matrix across every member fit).
    pub fn fit_matrix(m: &Matrix, targets: &[f64], params: &GbtParams,
                      rng: &mut Rng) -> Gbt {
        assert_eq!(m.n_rows(), targets.len());
        assert!(!m.is_empty(), "empty training set");
        let n = m.n_rows();
        let base = stats::mean(targets);
        let mut residuals: Vec<f64> = targets.iter().map(|t| t - base).collect();
        let mut trees = Vec::new();
        let mut last_rmse = f64::INFINITY;
        let mut stall = 0;

        for _round in 0..params.n_estimators {
            let k = ((n as f64 * params.subsample).round() as usize).clamp(1, n);
            let indices = rng.sample_indices(n, k);
            let tree = Tree::fit(m, &residuals, &indices, &params.tree, rng);
            // Residual refresh is element-wise, so it can fan out over
            // row chunks without changing a single bit of the result.
            // Only worth it on big training sets; the chunk floor keeps
            // small fits on the calling thread.
            pool::parallel_chunks_mut(
                params.parallelism,
                &mut residuals,
                4096,
                |offset, chunk| {
                    for (j, r) in chunk.iter_mut().enumerate() {
                        *r -= params.learning_rate
                            * tree.predict(m.row(offset + j));
                    }
                },
            );
            trees.push(tree);

            if params.early_stop_tol > 0.0 {
                let rmse = (residuals.iter().map(|r| r * r).sum::<f64>()
                    / n as f64)
                    .sqrt();
                if last_rmse - rmse < params.early_stop_tol * last_rmse.max(1e-12) {
                    stall += 1;
                    if stall >= 10 {
                        break;
                    }
                } else {
                    stall = 0;
                }
                last_rmse = rmse;
            }
        }
        Gbt { base, trees, learning_rate: params.learning_rate }
    }

    /// Predict one feature vector.
    pub fn predict(&self, x: &[f64]) -> f64 {
        self.base
            + self.learning_rate
                * self.trees.iter().map(|t| t.predict(x)).sum::<f64>()
    }

    /// Predict a batch.
    pub fn predict_batch(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict(r)).collect()
    }

    /// Predict a batch with the fan-out of the thread pool; results are
    /// in row order, identical to [`predict_batch`](Self::predict_batch).
    pub fn predict_batch_par(&self, rows: &[Vec<f64>],
                             par: Parallelism) -> Vec<f64> {
        pool::parallel_map(par, rows, |r| self.predict(r))
    }

    /// R² on a labelled set.
    pub fn r2(&self, rows: &[Vec<f64>], targets: &[f64]) -> f64 {
        stats::r_squared(targets, &self.predict_batch(rows))
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic function with categorical-like features, interactions
    /// and curvature — the shape of our real target surfaces.
    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let cat = rng.below(4) as f64; // one-hot-ish
            let a = rng.f64();
            let b = rng.f64();
            let x = vec![cat, a, b];
            let y = 3.0 * (cat == 2.0) as u8 as f64 + 2.0 * a * b
                + (4.0 * a).sin() - 0.5 * b;
            rows.push(x);
            ys.push(y);
        }
        (rows, ys)
    }

    #[test]
    fn fits_synthetic_function_well() {
        let (rows, ys) = synth(600, 1);
        let (test_rows, test_ys) = synth(200, 2);
        let params = GbtParams { n_estimators: 200, ..Default::default() };
        let g = Gbt::fit(&rows, &ys, &params, &mut Rng::new(0));
        let r2 = g.r2(&test_rows, &test_ys);
        // paper reports R^2 > 0.85 for its surrogates; require the same
        assert!(r2 > 0.85, "held-out r2={r2}");
    }

    #[test]
    fn boosting_beats_single_tree() {
        let (rows, ys) = synth(400, 3);
        let (tr, ty) = synth(150, 4);
        let single = GbtParams { n_estimators: 1, learning_rate: 1.0,
                                 ..Default::default() };
        let many = GbtParams { n_estimators: 150, ..Default::default() };
        let g1 = Gbt::fit(&rows, &ys, &single, &mut Rng::new(0));
        let gm = Gbt::fit(&rows, &ys, &many, &mut Rng::new(0));
        assert!(gm.r2(&tr, &ty) > g1.r2(&tr, &ty));
    }

    #[test]
    fn constant_target_learned_exactly() {
        let rows: Vec<Vec<f64>> = (0..30).map(|i| vec![i as f64]).collect();
        let ys = vec![7.0; 30];
        let g = Gbt::fit(&rows, &ys, &GbtParams::fast(), &mut Rng::new(0));
        assert!((g.predict(&[5.0]) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn early_stop_truncates_ensemble() {
        let rows: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let ys = vec![1.0; 50]; // nothing to learn after round 1
        let params = GbtParams { n_estimators: 300, ..Default::default() };
        let g = Gbt::fit(&rows, &ys, &params, &mut Rng::new(0));
        assert!(g.n_trees() < 50, "n_trees={}", g.n_trees());
    }

    #[test]
    fn deterministic_given_seed() {
        let (rows, ys) = synth(200, 5);
        let g1 = Gbt::fit(&rows, &ys, &GbtParams::fast(), &mut Rng::new(9));
        let g2 = Gbt::fit(&rows, &ys, &GbtParams::fast(), &mut Rng::new(9));
        for r in rows.iter().take(20) {
            assert_eq!(g1.predict(r), g2.predict(r));
        }
    }

    #[test]
    fn different_seeds_give_different_models() {
        let (rows, ys) = synth(200, 6);
        let g1 = Gbt::fit(&rows, &ys, &GbtParams::fast(), &mut Rng::new(1));
        let g2 = Gbt::fit(&rows, &ys, &GbtParams::fast(), &mut Rng::new(2));
        let diff: f64 = rows
            .iter()
            .map(|r| (g1.predict(r) - g2.predict(r)).abs())
            .sum();
        assert!(diff > 1e-9);
    }

    #[test]
    #[should_panic(expected = "empty training set")]
    fn rejects_empty_training() {
        let _ = Gbt::fit(&[], &[], &GbtParams::fast(), &mut Rng::new(0));
    }

    #[test]
    fn parallel_fit_and_predict_bit_identical_to_sequential() {
        // The chunk floor is 4096 rows per worker, so 2+ workers (the
        // actual parallel path) need >= 8192 rows to engage.
        let (rows, ys) = synth(9000, 7);
        let fit_with = |par: crate::util::Parallelism| {
            let params = GbtParams {
                n_estimators: 8,
                parallelism: par,
                ..GbtParams::fast()
            };
            Gbt::fit(&rows, &ys, &params, &mut Rng::new(3))
        };
        let seq = fit_with(crate::util::Parallelism::Sequential);
        let par = fit_with(crate::util::Parallelism::Threads(4));
        for r in rows.iter().take(50) {
            assert_eq!(seq.predict(r), par.predict(r));
        }
        assert_eq!(
            seq.predict_batch(&rows[..200]),
            par.predict_batch_par(&rows[..200],
                                  crate::util::Parallelism::Threads(4))
        );
    }
}
