//! Transfer learning across models (paper §3.5): "surrogate models
//! trained on smaller models are fine-tuned on a small sample of
//! evaluations from the target model, achieving comparable accuracy
//! with 10× fewer evaluations."
//!
//! Mechanism: the source model's surrogate supplies a *prior
//! prediction*; the target surrogate is trained on the pooled set of
//! (a) the source's samples re-encoded with the target's phi(M)
//! features and re-centered by the observed source→target offset, and
//! (b) the few real target evaluations.  Because the GBT consumes
//! phi(M) as features, the pooled fit learns the model-conditional
//! correction instead of starting cold.

use crate::config::{encode, enumerate, Config};
use crate::models::ModelSpec;
use crate::oracle::{Objectives, Testbed};
use crate::tasks::TaskSpec;
use crate::util::{stats, Rng};

use super::ensemble::{Sample, SurrogateSet};
use super::gbt::GbtParams;

/// A reusable, source-model training corpus.
pub struct SourceCorpus {
    pub model: ModelSpec,
    pub task: TaskSpec,
    /// (config, objectives) pairs measured on the source model.
    pub evaluations: Vec<(Config, Objectives)>,
}

impl SourceCorpus {
    /// Measure `n` random configurations on the source model's testbed.
    pub fn collect(testbed: &Testbed, model: &ModelSpec, task: &TaskSpec,
                   n: usize, rng: &mut Rng) -> SourceCorpus {
        let configs = enumerate::sample_distinct(rng, n);
        let evaluations = configs
            .into_iter()
            .map(|c| (c, testbed.measure(&c, model, task, rng)))
            .collect();
        SourceCorpus { model: model.clone(), task: task.clone(), evaluations }
    }

    /// Build a corpus from already-measured archive entries — how a
    /// stored Pareto front ([`crate::store::Store::source_corpus`])
    /// becomes transfer training data without spending a single fresh
    /// source-model evaluation.  Front entries are fewer but *better*
    /// than random samples: they trace the non-dominated surface,
    /// which is exactly the region the target search will explore.
    pub fn from_entries(model: ModelSpec, task: TaskSpec,
                        entries: &[crate::search::archive::Entry])
                        -> SourceCorpus {
        let evaluations = entries
            .iter()
            .map(|e| (e.config, e.objectives))
            .collect();
        SourceCorpus { model, task, evaluations }
    }
}

/// Fit a surrogate for `target` using the source corpus plus only
/// `n_target` fresh target evaluations.
///
/// Scale correction: source samples' efficiency objectives are
/// multiplied by the median target/source ratio estimated from the
/// overlapping fresh evaluations (latency/memory/energy are roughly
/// scale-multiplicative across models); accuracy gets an additive
/// offset.  The pooled set is then fit as usual — the GBT's phi(M)
/// features let it keep residual model-specific structure.
pub fn transfer_fit(
    corpus: &SourceCorpus,
    target_testbed: &Testbed,
    target: &ModelSpec,
    task: &TaskSpec,
    n_target: usize,
    params: GbtParams,
    rng: &mut Rng,
) -> (SurrogateSet, usize) {
    // 1. Fresh target evaluations (the expensive part — kept small).
    let fresh_configs = enumerate::sample_distinct(rng, n_target);
    let fresh: Vec<(Config, Objectives)> = fresh_configs
        .into_iter()
        .map(|c| (c, target_testbed.measure(&c, target, task, rng)))
        .collect();

    // 2. Estimate source→target scale factors on the fresh set by
    //    comparing with the *source-measured* values of the same
    //    configs when available, otherwise against corpus medians.
    let ratio = |f: &dyn Fn(&Objectives) -> f64| -> f64 {
        let src: Vec<f64> =
            corpus.evaluations.iter().map(|(_, o)| f(o)).collect();
        let dst: Vec<f64> = fresh.iter().map(|(_, o)| f(o)).collect();
        let (ms, md) = (stats::median(&src), stats::median(&dst));
        if ms > 0.0 {
            md / ms
        } else {
            1.0
        }
    };
    let r_lat = ratio(&|o| o.latency_ms);
    let r_mem = ratio(&|o| o.memory_gb);
    let r_en = ratio(&|o| o.energy_j);
    let d_acc = {
        let src: Vec<f64> =
            corpus.evaluations.iter().map(|(_, o)| o.accuracy).collect();
        let dst: Vec<f64> = fresh.iter().map(|(_, o)| o.accuracy).collect();
        stats::median(&dst) - stats::median(&src)
    };

    // 3. Pool: re-encoded + re-scaled source samples + fresh samples.
    let mut samples: Vec<Sample> = corpus
        .evaluations
        .iter()
        .map(|(c, o)| Sample {
            features: encode::encode(c, target, task),
            objectives: Objectives {
                accuracy: (o.accuracy + d_acc).max(0.0),
                latency_ms: o.latency_ms * r_lat,
                memory_gb: o.memory_gb * r_mem,
                energy_j: o.energy_j * r_en,
            },
        })
        .collect();
    samples.extend(fresh.iter().map(|(c, o)| Sample {
        features: encode::encode(c, target, task),
        objectives: *o,
    }));

    let n_evals = n_target;
    (SurrogateSet::fit(samples, params, rng), n_evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware;
    use crate::models::by_name;
    use crate::surrogate::collect_samples;
    use crate::tasks::blended_task;

    /// §3.5's claim, measurably: transfer from LLaMA-2-7B to
    /// LLaMA-2-13B with 40 target evaluations rivals a cold-start
    /// surrogate trained on 300.
    #[test]
    fn transfer_matches_cold_start_with_fewer_evals() {
        let task = blended_task();
        let src_model = by_name("LLaMA-2-7B").unwrap();
        let dst_model = by_name("LLaMA-2-13B").unwrap();
        let tb = Testbed::new(hardware::a100());
        let mut rng = Rng::new(1);

        let corpus = SourceCorpus::collect(&tb, &src_model, &task, 300,
                                           &mut rng);
        let (transferred, n_evals) = transfer_fit(
            &corpus, &tb, &dst_model, &task, 40, GbtParams::fast(),
            &mut rng);
        assert_eq!(n_evals, 40);

        let cold = {
            let samples = collect_samples(&tb, &dst_model, &task, 300,
                                          &mut rng);
            SurrogateSet::fit(samples, GbtParams::fast(), &mut rng)
        };

        // held-out target-model test set
        let test = collect_samples(&Testbed::noiseless(hardware::a100()),
                                   &dst_model, &task, 100, &mut rng);
        let r2_transfer = transferred.r2_report(&test);
        let r2_cold = cold.r2_report(&test);
        // latency/memory/energy transfer nearly losslessly; accuracy is
        // the hardest (different robustness) — allow a gap there.
        for i in [1usize, 2, 3] {
            assert!(
                r2_transfer[i] > r2_cold[i] - 0.08,
                "objective {i}: transfer {:.3} vs cold {:.3}",
                r2_transfer[i], r2_cold[i]
            );
            assert!(r2_transfer[i] > 0.8, "objective {i} too weak");
        }
    }

    #[test]
    fn transfer_beats_tiny_cold_start() {
        // With the same 40-eval budget, transfer >> cold start.
        let task = blended_task();
        let src_model = by_name("LLaMA-2-7B").unwrap();
        let dst_model = by_name("Qwen-14B").unwrap();
        let tb = Testbed::new(hardware::a100());
        let mut rng = Rng::new(2);
        let corpus = SourceCorpus::collect(&tb, &src_model, &task, 250,
                                           &mut rng);
        let (transferred, _) = transfer_fit(
            &corpus, &tb, &dst_model, &task, 40, GbtParams::fast(),
            &mut rng);
        let tiny_cold = {
            let samples = collect_samples(&tb, &dst_model, &task, 40,
                                          &mut rng);
            SurrogateSet::fit(samples, GbtParams::fast(), &mut rng)
        };
        let test = collect_samples(&Testbed::noiseless(hardware::a100()),
                                   &dst_model, &task, 80, &mut rng);
        let r_t = transferred.r2_report(&test);
        let r_c = tiny_cold.r2_report(&test);
        let mean_t = (r_t[1] + r_t[2] + r_t[3]) / 3.0;
        let mean_c = (r_c[1] + r_c[2] + r_c[3]) / 3.0;
        assert!(mean_t >= mean_c - 0.02,
                "transfer {mean_t:.3} vs tiny-cold {mean_c:.3}");
    }
}
