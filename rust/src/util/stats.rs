//! Small statistics helpers shared by the oracle, surrogates, measure-
//! ment code and the bench harness (criterion is unavailable offline —
//! `benches/` uses these for its own timing statistics).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for slices shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation (std/mean); 0.0 when mean == 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// Geometric mean of strictly positive values; 0.0 if any value <= 0
/// or the slice is empty.  Used for the paper's Efficiency Score
/// ("geometric mean of normalized efficiency metrics").
pub fn geometric_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-quantile (0..=1) by linear interpolation on a sorted copy.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = p.clamp(0.0, 1.0);
    let idx = p * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (idx - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Coefficient of determination of predictions vs targets.
/// R² = 1 - SS_res / SS_tot; returns 1.0 for perfect fit, can be
/// negative for fits worse than the mean predictor.
pub fn r_squared(targets: &[f64], predictions: &[f64]) -> f64 {
    assert_eq!(targets.len(), predictions.len());
    if targets.is_empty() {
        return 0.0;
    }
    let m = mean(targets);
    let ss_tot: f64 = targets.iter().map(|t| (t - m).powi(2)).sum();
    let ss_res: f64 = targets
        .iter()
        .zip(predictions)
        .map(|(t, p)| (t - p).powi(2))
        .sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Pearson correlation; 0.0 if either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx.sqrt() * vy.sqrt())
    }
}

/// Spearman rank correlation (ties get average ranks).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

/// Average ranks (1-based, ties averaged).
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// One exponentially-weighted moving-average update:
/// `alpha * x + (1 - alpha) * prev`.  `alpha` is clamped to [0, 1];
/// `alpha = 0` keeps the baseline frozen, `alpha = 1` tracks the
/// latest sample exactly.  The drift detector's building block.
pub fn ewma_step(prev: f64, x: f64, alpha: f64) -> f64 {
    let a = alpha.clamp(0.0, 1.0);
    a * x + (1.0 - a) * prev
}

/// EWMA over a whole sequence, seeded from the first sample
/// (`s_0 = x_0`, `s_i = alpha*x_i + (1-alpha)*s_{i-1}`).  Returns 0.0
/// for an empty slice; a single sample is its own average at every
/// alpha.
pub fn ewma(xs: &[f64], alpha: f64) -> f64 {
    let Some((&first, rest)) = xs.split_first() else {
        return 0.0;
    };
    rest.iter().fold(first, |s, &x| ewma_step(s, x, alpha))
}

/// min/max of a slice, NaN-free input assumed.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn variance_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
        assert_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geometric_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geometric_mean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geometric_mean(&[1.0, 0.0]), 0.0);
        assert_eq!(geometric_mean(&[]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn quantile_interpolation_at_small_n() {
        // n = 1: every quantile is the single sample
        for p in [0.0, 0.5, 0.95, 1.0] {
            assert_eq!(quantile(&[7.5], p), 7.5);
        }
        // n = 2: linear interpolation between the two samples
        assert_eq!(quantile(&[1.0, 3.0], 0.5), 2.0);
        assert!((quantile(&[1.0, 3.0], 0.95) - 2.9).abs() < 1e-12);
        assert_eq!(quantile(&[3.0, 1.0], 0.0), 1.0); // sorts first
        // n = 3: idx = p * 2; p95 lands between the 2nd and 3rd sample
        assert_eq!(quantile(&[1.0, 2.0, 4.0], 0.5), 2.0);
        assert!((quantile(&[4.0, 1.0, 2.0], 0.95) - 3.8).abs() < 1e-12);
        // out-of-range p clamps
        assert_eq!(quantile(&[1.0, 2.0], 1.5), 2.0);
        assert_eq!(quantile(&[1.0, 2.0], -0.5), 1.0);
        // empty input stays defined
        assert_eq!(quantile(&[], 0.95), 0.0);
    }

    #[test]
    fn r2_perfect_and_mean_predictor() {
        let t = [1.0, 2.0, 3.0];
        assert_eq!(r_squared(&t, &t), 1.0);
        let mean_pred = [2.0, 2.0, 2.0];
        assert!(r_squared(&t, &mean_pred).abs() < 1e-12);
    }

    #[test]
    fn pearson_signs() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y_up = [2.0, 4.0, 6.0, 8.0];
        let y_dn = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &y_up) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &y_dn) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&x, &[5.0; 4]), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y: Vec<f64> = x.iter().map(|v: &f64| v.exp()).collect();
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_with_ties() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn min_max_basic() {
        assert_eq!(min_max(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
    }

    #[test]
    fn cv_basic() {
        assert_eq!(cv(&[5.0, 5.0, 5.0]), 0.0);
        assert!(cv(&[1.0, 9.0]) > 0.5);
    }

    #[test]
    fn ewma_small_n_edges() {
        // n = 0: stays defined (mirrors quantile's empty-input rule)
        assert_eq!(ewma(&[], 0.3), 0.0);
        // n = 1: the single sample is the average at every alpha
        for alpha in [0.0, 0.3, 1.0] {
            assert_eq!(ewma(&[7.5], alpha), 7.5);
        }
    }

    #[test]
    fn ewma_alpha_extremes() {
        let xs = [1.0, 5.0, 9.0];
        // alpha = 0: frozen at the seed sample
        assert_eq!(ewma(&xs, 0.0), 1.0);
        // alpha = 1: tracks the latest sample exactly
        assert_eq!(ewma(&xs, 1.0), 9.0);
        // in between: strictly between seed and latest
        let mid = ewma(&xs, 0.5);
        assert!(mid > 1.0 && mid < 9.0, "mid={mid}");
        // hand-checked: 0.5*9 + 0.5*(0.5*5 + 0.5*1) = 6.0
        assert!((mid - 6.0).abs() < 1e-12);
    }

    #[test]
    fn ewma_step_clamps_alpha() {
        assert_eq!(ewma_step(2.0, 10.0, -1.0), 2.0);
        assert_eq!(ewma_step(2.0, 10.0, 2.0), 10.0);
        assert!((ewma_step(2.0, 10.0, 0.25) - 4.0).abs() < 1e-12);
    }
}
