//! ASCII table rendering for the paper-style reports.
//!
//! The report module prints every reproduced table in the same row/column
//! structure the paper uses; this is the tiny layout engine behind that.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple ASCII table builder.
#[derive(Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    separators: Vec<usize>, // row indices after which a rule is drawn
    title: Option<String>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
            separators: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        if col < self.aligns.len() {
            self.aligns[col] = a;
        }
        self
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    /// Insert a horizontal rule after the last added row (section break).
    pub fn rule(&mut self) {
        self.separators.push(self.rows.len());
    }

    /// A full-width section label row.
    pub fn section(&mut self, label: &str) {
        self.rule();
        let mut cells = vec![format!("— {label} —")];
        cells.extend(std::iter::repeat_with(String::new)
            .take(self.headers.len() - 1));
        self.rows.push(cells);
        self.rule();
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * (ncol - 1) + 4;
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("{t}\n"));
        }
        let rule: String = format!("+{}+\n", "-".repeat(total - 2));
        out.push_str(&rule);
        out.push_str(&self.fmt_row(&self.headers, &widths));
        out.push_str(&rule);
        for (i, row) in self.rows.iter().enumerate() {
            if self.separators.contains(&i) {
                out.push_str(&rule);
            }
            out.push_str(&self.fmt_row(row, &widths));
        }
        out.push_str(&rule);
        out
    }

    fn fmt_row(&self, cells: &[String], widths: &[usize]) -> String {
        let mut line = String::from("| ");
        for (i, cell) in cells.iter().enumerate() {
            let pad = widths[i].saturating_sub(cell.chars().count());
            match self.aligns[i] {
                Align::Left => {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
                Align::Right => {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                }
            }
            line.push_str(if i + 1 == cells.len() { " |\n" } else { " | " });
        }
        line
    }
}

/// Format a float with `d` decimals.
pub fn fnum(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_headers_and_rows() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["alpha", "1.0"]);
        t.row_strs(&["beta", "2.5"]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.contains("alpha"));
        assert!(s.contains("2.5"));
        assert_eq!(s.lines().filter(|l| l.starts_with('|')).count(), 3);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn column_widths_accommodate_long_cells() {
        let mut t = Table::new(&["x"]);
        t.row_strs(&["a-very-long-cell-value"]);
        let s = t.render();
        for line in s.lines().filter(|l| l.starts_with('|')) {
            assert!(line.len() >= "a-very-long-cell-value".len());
        }
    }

    #[test]
    fn sections_add_rules() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["1", "2"]);
        t.section("part two");
        t.row_strs(&["3", "4"]);
        let s = t.render();
        assert!(s.contains("part two"));
        assert!(s.lines().filter(|l| l.starts_with('+')).count() >= 4);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(2.0, 1), "2.0");
    }

    #[test]
    fn title_rendered() {
        let t = Table::new(&["a"]).with_title("Table 9: test");
        assert!(t.render().starts_with("Table 9: test\n"));
    }
}
