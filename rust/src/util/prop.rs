//! Mini property-based testing harness.
//!
//! `proptest` is not in the offline vendor set, so this provides the
//! subset the test suite needs: run a property over N randomly generated
//! cases from a seeded RNG, and on failure greedily shrink the failing
//! case before reporting.  Generators are plain closures over
//! [`crate::util::rng::Rng`], shrinkers are optional.
//!
//! ```
//! use ae_llm::util::prop::{forall, Config};
//! forall(Config::default().cases(200), |rng| rng.below(100), |&x| {
//!     if x < 100 { Ok(()) } else { Err(format!("{x} out of range")) }
//! });
//! ```

use crate::util::rng::Rng;

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 100,
            // Stable default so CI failures reproduce; override per test
            // when exploring.
            seed: 0xAE11,
            max_shrink_steps: 200,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `property` over `config.cases` values from `gen`.
/// Panics with the (first) failing case and its error.
pub fn forall<T, G, P>(config: Config, mut gen: G, property: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let value = gen(&mut rng);
        if let Err(msg) = property(&value) {
            panic!(
                "property failed on case {case}/{}: {msg}\n  input: {value:?}",
                config.cases
            );
        }
    }
}

/// Like [`forall`] but with a shrinker: on failure, repeatedly apply
/// `shrink` (which proposes smaller candidates) and keep any candidate
/// that still fails, reporting the smallest found.
pub fn forall_shrink<T, G, S, P>(config: Config, mut gen: G, shrink: S, property: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let value = gen(&mut rng);
        if let Err(first_msg) = property(&value) {
            // Greedy shrink.
            let mut best = value.clone();
            let mut best_msg = first_msg;
            let mut steps = 0;
            'outer: while steps < config.max_shrink_steps {
                for candidate in shrink(&best) {
                    steps += 1;
                    if let Err(msg) = property(&candidate) {
                        best = candidate;
                        best_msg = msg;
                        continue 'outer;
                    }
                    if steps >= config.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed on case {case}/{} (shrunk, {steps} steps): \
                 {best_msg}\n  input: {best:?}",
                config.cases
            );
        }
    }
}

/// Standard shrinker for a Vec: try removing each element and halving.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    for i in 0..v.len().min(8) {
        let mut smaller = v.to_vec();
        smaller.remove(i);
        out.push(smaller);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(Config::default().cases(50), |rng| rng.below(10), |&x| {
            if x < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(Config::default().cases(50), |rng| rng.below(10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err(format!("{x} >= 5"))
            }
        });
    }

    #[test]
    fn shrinker_reduces_vec() {
        let caught = std::panic::catch_unwind(|| {
            forall_shrink(
                Config::default().cases(50),
                |rng| {
                    let n = rng.below(20) + 1;
                    (0..n).map(|_| rng.below(100)).collect::<Vec<_>>()
                },
                |v| shrink_vec(v),
                |v: &Vec<usize>| {
                    if v.iter().any(|&x| x >= 90) {
                        Err("contains >= 90".into())
                    } else {
                        Ok(())
                    }
                },
            );
        });
        // With 50 random vectors of up to 20 values in [0,100), hitting a
        // >= 90 element is overwhelmingly likely; the shrunk witness
        // should be small.
        let err = caught.expect_err("property should have failed");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("shrunk"), "got: {msg}");
    }

    #[test]
    fn shrink_vec_proposals_are_smaller() {
        let v = vec![1, 2, 3, 4];
        for s in shrink_vec(&v) {
            assert!(s.len() < v.len());
        }
    }
}
