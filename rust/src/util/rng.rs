//! Deterministic pseudo-random number generation.
//!
//! The offline build image vendors only the `xla` crate closure, so the
//! `rand` family is unavailable; this is a small, well-tested substitute
//! built on SplitMix64 (seeding / stream splitting) and xoshiro256++
//! (bulk generation).  Everything in the search stack that needs
//! randomness threads one of these through explicitly — there is no
//! global RNG, which keeps every experiment reproducible from its seed.

/// SplitMix64 step: the canonical 64-bit mix used for seeding.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.  Fast, 256-bit state, passes BigCrush; more than
/// adequate for evolutionary search and noise simulation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Build from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for parallel workers or
    /// per-component noise channels).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n).  Uses Lemire's method (no modulo bias).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; generation is not on any hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Pick a uniform element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_one_is_zero() {
        let mut r = Rng::new(10);
        for _ in 0..10 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn range_i64_inclusive() {
        let mut r = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let x = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&x));
            lo_seen |= x == -3;
            hi_seen |= x == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(12);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(14);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn sample_indices_k_ge_n() {
        let mut r = Rng::new(15);
        let s = r.sample_indices(5, 10);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Rng::new(16);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        // streams shouldn't be identical
        let a: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(17);
        assert!(!(0..100).any(|_| r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}
