//! Minimal JSON parsing + emission (serde is unavailable offline).
//!
//! The only JSON this project touches is `artifacts/manifest.json`
//! (written by python's `json.dump`) and the figure/report exports, so
//! a compact recursive-descent parser with full escape handling is all
//! that's needed.  It parses the complete JSON grammar; numbers are kept
//! as f64 (the manifest's integers are all < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field helpers (error messages name the missing key).
    pub fn req_str(&self, key: &str) -> Result<String, String> {
        self.get(key)
            .and_then(Json::as_str)
            .map(String::from)
            .ok_or_else(|| format!("missing/invalid string field {key:?}"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("missing/invalid number field {key:?}"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.req_f64(key).map(|x| x as u64)
    }

    // -- emission ----------------------------------------------------------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.pos += 4;
                            // Surrogate pairs: rare in our data but handled.
                            if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or("bad surrogate")?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|e| e.to_string())?,
                                        16,
                                    )
                                    .map_err(|e| e.to_string())?;
                                    self.pos += 6;
                                    // The second escape must be a low
                                    // surrogate, or `low - 0xDC00`
                                    // underflows.
                                    if !(0xDC00..0xE000).contains(&low) {
                                        return Err("lone surrogate".into());
                                    }
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00);
                                    out.push(
                                        char::from_u32(c).ok_or("bad codepoint")?,
                                    );
                                } else {
                                    return Err("lone surrogate".into());
                                }
                            } else {
                                out.push(char::from_u32(code).ok_or("bad codepoint")?);
                            }
                        }
                        other => {
                            return Err(format!("bad escape \\{}", other as char))
                        }
                    }
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_dump_parse() {
        let src = r#"{"x": 1, "y": [true, "s\"q"], "z": {"n": -2.5}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn escaping_round_trips_hostile_strings() {
        // Strings that can land in catalog key fields (model / task /
        // platform / scenario names) must dump → parse byte-stably:
        // control chars, quotes, backslashes, non-ASCII, and the
        // astral plane.
        let hostile = [
            "plain",
            "with \"quotes\" and \\backslashes\\",
            "tabs\tnewlines\nreturns\r",
            "low controls \u{1} \u{b} \u{1f}",
            "del \u{7f} is legal unescaped",
            "non-ascii: café-β-模型",
            "astral: 😀𐍈",
            "",
        ];
        for s in hostile {
            let j = Json::Str(s.to_string());
            let dumped = j.dump();
            let back = Json::parse(&dumped)
                .unwrap_or_else(|e| panic!("{dumped}: {e}"));
            assert_eq!(back, j, "round-trip of {s:?}");
            // byte-stable: dumping the re-parsed value is identical,
            // so content addresses of catalog blobs are well-defined
            assert_eq!(back.dump(), dumped, "canonical form of {s:?}");
        }
    }

    #[test]
    fn escaped_control_chars_use_canonical_forms() {
        // Named short escapes for the common controls...
        assert_eq!(Json::Str("a\nb\tc\rd\"e\\f".into()).dump(),
                   r#""a\nb\tc\rd\"e\\f""#);
        // ...\u00xx for the rest, and raw UTF-8 for non-ASCII.
        assert_eq!(Json::Str("\u{1}".into()).dump(), r#""\u0001""#);
        assert_eq!(Json::Str("é".into()).dump(), "\"é\"");
    }

    #[test]
    fn surrogate_escapes_parse_or_fail_cleanly() {
        // A valid surrogate pair decodes to the astral char.
        assert_eq!(Json::parse(r#""\ud83d\ude00""#).unwrap(),
                   Json::Str("😀".into()));
        // A high surrogate not followed by a low one is an error, not
        // a panic or a corrupted string.
        assert!(Json::parse(r#""\ud800""#).is_err());
        assert!(Json::parse(r#""\ud800A""#).is_err());
        assert!(Json::parse(r#""\udc00""#).is_err());
    }

    #[test]
    fn integers_dump_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }

    #[test]
    fn req_helpers_error_messages() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(j.req_f64("a").unwrap(), 1.0);
        assert!(j.req_str("missing").unwrap_err().contains("missing"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"weight_seed": 1234, "variants": [
            {"name": "gqa_int8", "file": "gqa_int8.hlo.txt",
             "batch": 4, "seq": 64,
             "config": {"attention": "gqa", "quant": "int8"},
             "param_count": 1000000, "weight_bytes": 1000000,
             "flops_per_token": 2000000,
             "fidelity_baseline": "gqa_fp16"}]}"#;
        let j = Json::parse(src).unwrap();
        let v = &j.get("variants").unwrap().as_arr().unwrap()[0];
        assert_eq!(v.req_str("name").unwrap(), "gqa_int8");
        assert_eq!(v.req_u64("param_count").unwrap(), 1_000_000);
        assert_eq!(
            v.get("config").unwrap().req_str("attention").unwrap(),
            "gqa"
        );
    }
}
