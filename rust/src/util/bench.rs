//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each `benches/*.rs` binary with `harness = false`;
//! those binaries use [`time_it`] / [`time_once`] for their measurements
//! so output format and methodology are uniform.
//!
//! The same binaries serve CI smoke runs and full local measurements:
//! * `AE_LLM_BENCH_QUICK=1` (or a `--quick` argument) divides iteration
//!   counts by 10 and caps warmup — CI uses this;
//! * `AE_LLM_BENCH_ITERS=N` hard-caps the per-case iteration count.
//!
//! Both apply inside [`time_it`], so individual benches don't need any
//! plumbing; [`quick`] is public for benches that want to also shrink
//! their workload shape (fewer generations, smaller populations).

use std::collections::BTreeMap;
use std::time::Instant;

use super::json::Json;

/// Schema tag every `benches/perf_*.rs` report carries (see
/// docs/SCHEMAS.md): a flat JSON object with `schema`, `name`, `mode`
/// (`"quick"` or `"full"`) and free-form numeric metric keys.  Keys
/// ending in `_per_sec` are throughput (higher is better) — the CI
/// bench-regression gate (`.github/scripts/bench_gate.py`) compares
/// exactly those against the previous run's artifact and fails on a
/// >20% drop.  Legacy keys stay alongside as aliases for longitudinal
/// comparability.
pub const BENCH_SCHEMA: &str = "ae-llm.bench/v1";

/// Stamp the shared envelope fields onto a bench report and write it
/// as `BENCH_<name>.json` to `$AE_LLM_BENCH_OUT` (or the current
/// directory).  `name` is the bench's short name (`"search"`,
/// `"serve"`, ...).  The legacy `bench`/`quick` keys are kept as
/// aliases of `name`/`mode`.
pub fn write_report(name: &str, mut report: BTreeMap<String, Json>) {
    let q = quick();
    report.insert("schema".into(), Json::Str(BENCH_SCHEMA.into()));
    report.insert("name".into(), Json::Str(format!("perf_{name}")));
    report.insert("mode".into(),
                  Json::Str(if q { "quick" } else { "full" }.into()));
    // Legacy aliases (pre-v1 reports carried only these two).
    report.insert("bench".into(), Json::Str(format!("perf_{name}")));
    report.insert("quick".into(), Json::Bool(q));
    let out = std::env::var("AE_LLM_BENCH_OUT").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&out).join(format!("BENCH_{name}.json"));
    match std::fs::write(&path, Json::Obj(report).dump()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// Throughput in operations per wall-clock second (guards ms == 0).
pub fn per_sec(ops: f64, wall_ms: f64) -> f64 {
    ops / (wall_ms / 1e3).max(1e-9)
}

/// True when the process runs in reduced-iteration smoke mode
/// (`AE_LLM_BENCH_QUICK=1` / `true` / `yes`, or a `--quick` argument).
pub fn quick() -> bool {
    let env_on = std::env::var("AE_LLM_BENCH_QUICK")
        .map(|v| matches!(v.as_str(), "1" | "true" | "yes"))
        .unwrap_or(false);
    env_on || std::env::args().any(|a| a == "--quick")
}

/// Optional hard cap on per-case iterations (`AE_LLM_BENCH_ITERS`).
pub fn iters_override() -> Option<usize> {
    std::env::var("AE_LLM_BENCH_ITERS").ok()?.parse().ok()
}

/// Apply the smoke-mode scaling and the iteration cap to a requested
/// iteration count (never returns 0).
pub fn scaled(iters: usize) -> usize {
    let mut n = iters;
    if quick() {
        n /= 10;
    }
    if let Some(cap) = iters_override() {
        n = n.min(cap);
    }
    n.max(1)
}

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl Timing {
    pub fn print(&self) {
        println!(
            "  {:<44} {:>10.3} ms/iter  (median {:.3}, min {:.3}, max \
             {:.3}, n={})",
            self.name, self.mean_ms, self.median_ms, self.min_ms,
            self.max_ms, self.iters
        );
    }
}

/// Run `f` `iters` times after `warmup` discarded runs; report stats.
/// Counts pass through [`scaled`], so smoke mode shrinks every case
/// uniformly.
pub fn time_it<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                           mut f: F) -> Timing {
    let iters = scaled(iters);
    let warmup = if quick() { warmup.min(2) } else { warmup };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let t = Timing {
        name: name.to_string(),
        iters: samples.len(),
        mean_ms: super::stats::mean(&samples),
        median_ms: super::stats::median(&samples),
        min_ms: super::stats::min_max(&samples).0,
        max_ms: super::stats::min_max(&samples).1,
    };
    t.print();
    t
}

/// Time a single (expensive) run.
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("  {name:<44} {ms:>10.1} ms (single run)");
    (out, ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts_iters() {
        let mut n = 0;
        let t = time_it("noop", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.iters, 5);
        assert!(t.min_ms <= t.median_ms && t.median_ms <= t.max_ms);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, ms) = time_once("compute", || 6 * 7);
        assert_eq!(v, 42);
        assert!(ms >= 0.0);
    }

    #[test]
    fn scaled_never_zero() {
        // Without the env overrides set, scaled() is identity except
        // for the >=1 clamp.
        if std::env::var("AE_LLM_BENCH_QUICK").is_err()
            && std::env::var("AE_LLM_BENCH_ITERS").is_err()
        {
            assert_eq!(scaled(50), 50);
        }
        assert!(scaled(0) >= 1);
    }
}
