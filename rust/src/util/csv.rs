//! CSV emission for figure data series.
//!
//! Every reproduced figure writes its raw series to
//! `reports/figN_*.csv` so the plots can be regenerated with any
//! external tool; this is the tiny writer behind that.

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// A CSV document under construction.
#[derive(Debug, Default)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "ragged CSV row");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: anything Display-able.
    pub fn push_row(&mut self, cells: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", join_escaped(&self.header));
        for row in &self.rows {
            let _ = writeln!(out, "{}", join_escaped(row));
        }
        out
    }

    pub fn write_to(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.render())
    }
}

fn join_escaped(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| escape(c))
        .collect::<Vec<_>>()
        .join(",")
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.push_row(&[&1, &2.5]);
        let s = c.render();
        assert_eq!(s, "a,b\n1,2.5\n");
    }

    #[test]
    fn escapes_commas_and_quotes() {
        let mut c = Csv::new(&["x"]);
        c.row(&["va,l\"ue".to_string()]);
        assert_eq!(c.render(), "x\n\"va,l\"\"ue\"\n");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn rejects_ragged() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".to_string()]);
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("ae_llm_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::new(&["a"]);
        c.push_row(&[&42]);
        c.write_to(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a\n42\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
