//! Scoped thread-pool over `std::thread` + `mpsc` with a deterministic
//! ordered reduce.
//!
//! The build image vendors no external crates, so this provides the
//! `rayon` subset the project needs: fan a slice of work items across
//! worker threads and merge the results **in submission order**, so a
//! seeded run is bit-identical whatever the thread count.  Three
//! guarantees every caller relies on:
//!
//! 1. **Ordered reduce** — `parallel_map(par, items, f)[i] == f(&items[i])`
//!    regardless of which worker computed which item or in what order
//!    they finished.  Reductions over the output therefore fold in
//!    submission order (see [`parallel_map_reduce`]).
//! 2. **Determinism contract** — `f` must be a pure function of its
//!    item (callers that need randomness pre-split one RNG per item
//!    *sequentially* before fanning out, e.g.
//!    `oracle::Testbed::measure_batch`).  Under that contract the result
//!    is identical for every [`Parallelism`] level, including
//!    `Sequential`.
//! 3. **Panic propagation** — a panic in any worker resurfaces on the
//!    calling thread (via `std::thread::scope`), it is never swallowed.
//!
//! Work is distributed by an atomic cursor (work stealing at item
//! granularity), so an expensive straggler item does not serialize the
//! batch the way fixed pre-chunking would.

use std::num::NonZeroUsize;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Degree of parallelism for a parallel section.
///
/// `Auto` (the default everywhere a knob is exposed) resolves to the
/// number of available cores at the call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Run on the calling thread; spawns nothing.
    Sequential,
    /// One worker per available core (`std::thread::available_parallelism`).
    Auto,
    /// Exactly `n` workers (clamped to at least 1).
    Threads(usize),
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::Auto
    }
}

impl Parallelism {
    /// Number of worker threads this level resolves to on this host.
    pub fn threads(self) -> usize {
        match self {
            Parallelism::Sequential => 1,
            Parallelism::Threads(n) => n.max(1),
            Parallelism::Auto => std::thread::available_parallelism()
                .map(NonZeroUsize::get)
                .unwrap_or(1),
        }
    }

    /// True when this level would actually fan out.
    pub fn is_parallel(self) -> bool {
        self.threads() > 1
    }
}

/// Map `f` over `items` on up to `par.threads()` workers; results are
/// returned in submission order (`out[i] == f(&items[i])`).
///
/// Falls back to a plain sequential map when one worker (or one item)
/// would be used, so the sequential path has zero threading overhead.
pub fn parallel_map<T, U, F>(par: Parallelism, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let workers = par.threads().min(items.len());
    if workers <= 1 {
        return items.iter().map(f).collect();
    }

    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<U>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), || None);
    let (tx, rx) = mpsc::channel::<(usize, U)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let out = f(&items[i]);
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        // Ordered reduce: completion order is arbitrary, slot order is
        // submission order.  If a worker panics its sender drops without
        // filling every slot; the scope re-raises the panic on join, so
        // the expect() below is unreachable in that case.
        for (i, out) in rx {
            slots[i] = Some(out);
        }
    });

    slots
        .into_iter()
        .map(|s| s.expect("pool: worker exited without result"))
        .collect()
}

/// [`parallel_map`] followed by a sequential fold **in submission
/// order** — the deterministic ordered-reduce primitive.
pub fn parallel_map_reduce<T, U, A, F, R>(
    par: Parallelism,
    items: &[T],
    f: F,
    init: A,
    mut reduce: R,
) -> A
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
    R: FnMut(A, U) -> A,
{
    parallel_map(par, items, f)
        .into_iter()
        .fold(init, |acc, u| reduce(acc, u))
}

/// Apply `f` to disjoint chunks of `data` in parallel.  `f` receives the
/// chunk's offset into `data` plus the mutable chunk; chunks are at
/// least `min_chunk` long, so small inputs stay on the calling thread.
///
/// Element-wise updates through this helper are deterministic: every
/// element is written by exactly one worker and no accumulation crosses
/// a chunk boundary.
pub fn parallel_chunks_mut<T, F>(
    par: Parallelism,
    data: &mut [T],
    min_chunk: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let workers = par.threads().min(data.len() / min_chunk.max(1)).max(1);
    if workers <= 1 {
        f(0, data);
        return;
    }
    let chunk = data.len().div_ceil(workers);
    std::thread::scope(|scope| {
        for (k, piece) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(k * chunk, piece));
        }
    });
}

/// Apply `f` to every element of `data` in parallel, passing the
/// element's global index.  [`parallel_chunks_mut`] at per-element
/// granularity — the cluster layer's shard primitive: each fleet node
/// is an independent `&mut` shard, visited by exactly one worker, so
/// per-node mutation is deterministic at every [`Parallelism`] level.
pub fn parallel_for_each_mut<T, F>(par: Parallelism, data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    parallel_chunks_mut(par, data, 1, |offset, chunk| {
        for (k, item) in chunk.iter_mut().enumerate() {
            f(offset + k, item);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn map_preserves_submission_order() {
        let items: Vec<usize> = (0..257).collect();
        for par in [
            Parallelism::Sequential,
            Parallelism::Threads(4),
            Parallelism::Threads(8),
            Parallelism::Auto,
        ] {
            let out = parallel_map(par, &items, |&x| x * 3);
            assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn parallel_equals_sequential_with_uneven_work() {
        // Straggler items must not perturb result order.
        let items: Vec<u64> = (0..64).collect();
        let f = |&x: &u64| {
            if x % 13 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x * x
        };
        assert_eq!(
            parallel_map(Parallelism::Threads(7), &items, f),
            parallel_map(Parallelism::Sequential, &items, f)
        );
    }

    #[test]
    fn every_item_computed_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(Parallelism::Threads(4), &items, |&x| {
            counter.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 100);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(Parallelism::Threads(8), &empty, |&x| x)
            .is_empty());
        assert_eq!(
            parallel_map(Parallelism::Threads(8), &[41u32], |&x| x + 1),
            vec![42]
        );
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let items: Vec<usize> = (0..32).collect();
        let res = std::panic::catch_unwind(|| {
            parallel_map(Parallelism::Threads(4), &items, |&x| {
                if x == 17 {
                    panic!("boom at {x}");
                }
                x
            })
        });
        assert!(res.is_err(), "worker panic must reach the caller");
    }

    #[test]
    fn panic_propagates_from_sequential_path_too() {
        let items = [1usize];
        let res = std::panic::catch_unwind(|| {
            parallel_map(Parallelism::Sequential, &items, |_| -> usize {
                panic!("boom")
            })
        });
        assert!(res.is_err());
    }

    #[test]
    fn reduce_folds_in_submission_order() {
        let items: Vec<usize> = (0..50).collect();
        let concat = parallel_map_reduce(
            Parallelism::Threads(6),
            &items,
            |&x| x.to_string(),
            String::new(),
            |mut acc, s| {
                acc.push_str(&s);
                acc.push(',');
                acc
            },
        );
        let expected: String =
            items.iter().map(|x| format!("{x},")).collect();
        assert_eq!(concat, expected);
    }

    #[test]
    fn chunks_mut_touches_every_element_once() {
        let mut data: Vec<usize> = (0..1000).collect();
        parallel_chunks_mut(Parallelism::Threads(4), &mut data, 8,
                            |offset, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                assert_eq!(*v, offset + k, "offset bookkeeping");
                *v += 1;
            }
        });
        assert_eq!(data, (1..1001).collect::<Vec<_>>());
    }

    #[test]
    fn chunks_mut_small_input_stays_sequential() {
        let mut data = vec![0u8; 4];
        parallel_chunks_mut(Parallelism::Threads(8), &mut data, 64,
                            |offset, chunk| {
            assert_eq!(offset, 0);
            assert_eq!(chunk.len(), 4);
            chunk.fill(7);
        });
        assert_eq!(data, vec![7; 4]);
    }

    #[test]
    fn for_each_mut_visits_every_element_once_with_its_index() {
        for par in [
            Parallelism::Sequential,
            Parallelism::Threads(3),
            Parallelism::Threads(8),
        ] {
            let mut data: Vec<usize> = (0..37).collect();
            let visits = AtomicUsize::new(0);
            parallel_for_each_mut(par, &mut data, |i, v| {
                assert_eq!(*v, i, "index bookkeeping");
                visits.fetch_add(1, Ordering::Relaxed);
                *v = i * 10;
            });
            assert_eq!(visits.load(Ordering::Relaxed), 37);
            assert_eq!(data,
                       (0..37).map(|i| i * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn threads_resolution() {
        assert_eq!(Parallelism::Sequential.threads(), 1);
        assert_eq!(Parallelism::Threads(0).threads(), 1);
        assert_eq!(Parallelism::Threads(5).threads(), 5);
        assert!(Parallelism::Auto.threads() >= 1);
        assert!(!Parallelism::Sequential.is_parallel());
    }
}
