//! Utility substrate: deterministic RNG, statistics, table/CSV/JSON
//! emission, a mini property-testing harness, and the scoped thread
//! pool ([`pool`]) every parallel hot path fans out through.
//!
//! Exists because the offline build image vendors only the `xla` crate
//! closure — `rand`, `serde`, `proptest` and `criterion` are all
//! unavailable, so the pieces of them this project needs are implemented
//! (and tested) here.

pub mod bench;
pub mod csv;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use pool::Parallelism;
pub use rng::Rng;
