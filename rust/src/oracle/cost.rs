//! Roofline cost model: latency / memory / energy of a configuration.
//!
//! This is the *physics* half of the testbed oracle (S5).  It computes
//! raw quantities from first principles (compute-bound prefill,
//! bandwidth-bound decode, weight/KV residency, power-over-time energy);
//! `oracle::Testbed` then rescales raw values so the Default
//! configuration reproduces the paper's Table 2 anchors, which means
//! *relative* technique effects — the thing the search navigates — come
//! from this model, not from copied numbers.

use crate::config::{Config, MoE, Precision};
use crate::hardware::Platform;
use crate::models::ModelSpec;
use crate::tasks::TaskSpec;

/// Paper A.2: measurements fix 512 input tokens and 128 output tokens.
pub const INPUT_TOKENS: f64 = 512.0;
pub const OUTPUT_TOKENS: f64 = 128.0;
/// Achievable fraction of peak compute (kernel efficiency).
const COMPUTE_EFF: f64 = 0.45;
/// Achievable fraction of peak bandwidth.
const BW_EFF: f64 = 0.75;
/// Fraction of a dense model's parameters living in FFN blocks.
const FFN_FRAC: f64 = 2.0 / 3.0;
/// Per-expert bookkeeping overhead as a fraction of total params
/// (§5.4: "memory overhead continues to grow linearly" with experts).
const MOE_OVERHEAD_PER_EXPERT: f64 = 0.015;
/// Activation workspace as a fraction of weight bytes.
const ACTIVATION_FRAC: f64 = 0.06;

/// Fraction of parameters *active* per token under the MoE setting.
/// MoE here re-partitions the FFN into `e` experts with top-k routing
/// (total capacity unchanged, activation sparse) — matching the paper's
/// Appendix C where a 70B 8-expert config *fits in less memory* than
/// dense FP16 would.
pub fn active_param_fraction(c: &Config, m: &ModelSpec) -> f64 {
    match c.arch.moe {
        MoE::Dense => 1.0,
        MoE::Sparse { experts, top_k } => {
            if m.native_moe {
                // Native-MoE models already route; config tunes k/e.
                let frac = top_k as f64 / experts as f64;
                (1.0 - FFN_FRAC) + FFN_FRAC * frac.max(0.25 * 0.28 / FFN_FRAC)
            } else {
                (1.0 - FFN_FRAC) + FFN_FRAC * (top_k as f64 / experts as f64)
            }
        }
    }
}

/// Active fraction as *felt by latency*: batched serving activates the
/// union of experts across the batch, so the wall-clock saving is
/// weaker than the per-token active fraction (this is why the paper's
/// MoE speedups are modest rather than proportional to top-k/E).
pub fn latency_active_fraction(c: &Config, m: &ModelSpec) -> f64 {
    let f = active_param_fraction(c, m);
    f + 0.45 * (1.0 - f)
}

/// Effective KV fraction: min of what the architecture stores and what
/// the cache policy keeps.
pub fn kv_fraction(c: &Config) -> f64 {
    c.arch.attention
        .kv_fraction()
        .min(c.inf.kv_cache.fraction())
}

/// KV-cache bytes for one sequence of `seq` tokens (fp16 cache).
pub fn kv_bytes(c: &Config, m: &ModelSpec, seq: f64) -> f64 {
    let full = 2.0 * m.n_layers as f64 * m.d_model as f64 * seq * 2.0;
    full * kv_fraction(c)
}

/// Resident weight bytes under the precision + MoE setting.
pub fn weight_bytes(c: &Config, m: &ModelSpec) -> f64 {
    let p = m.params_b * 1e9;
    let moe_overhead = match c.arch.moe {
        MoE::Dense => 0.0,
        MoE::Sparse { experts, .. } => {
            p * MOE_OVERHEAD_PER_EXPERT * experts as f64
        }
    };
    (p + moe_overhead) * c.inf.precision.bytes_per_weight()
}

/// LoRA adapter bytes (f32 adapters on attention projections).
pub fn adapter_bytes(c: &Config, m: &ModelSpec) -> f64 {
    if !c.ft.method.is_peft() || c.ft.rank == 0 {
        return 0.0;
    }
    // 4 projections per layer, two matrices (d x r) + (r x d) each, f32.
    8.0 * m.n_layers as f64 * m.d_model as f64 * c.ft.rank as f64 * 4.0
}

/// Peak memory in GB (Definition 2's `Mem`).
pub fn memory_gb(c: &Config, m: &ModelSpec, t: &TaskSpec) -> f64 {
    let w = weight_bytes(c, m);
    let kv = kv_bytes(c, m, t.seq_len as f64);
    let act = w * ACTIVATION_FRAC;
    (w + kv + act + adapter_bytes(c, m)) / 1e9
}

/// End-to-end request latency in ms (Definition 2's `Lat`):
/// compute-bound prefill over the task's prompt + bandwidth-bound decode
/// of OUTPUT_TOKENS, each step reading active weights + the KV cache.
pub fn latency_ms(c: &Config, m: &ModelSpec, t: &TaskSpec,
                  h: &Platform) -> f64 {
    let active = m.params_b * 1e9 * latency_active_fraction(c, m);
    let speedup = h.precision_speedup(c.inf.precision.bits());
    let flops_rate = h.peak_tflops * 1e12 * COMPUTE_EFF * speedup;
    let bw = h.mem_bandwidth_gbs * 1e9 * BW_EFF;

    // Prefill: process the prompt; attention quadratic term is folded
    // into the 2*P MAC estimate (small at these sequence lengths).
    let prompt = (t.seq_len as f64).min(INPUT_TOKENS * 4.0).max(64.0);
    let t_prefill = 2.0 * active * prompt / flops_rate;

    // Decode: every output token streams active weights once and the
    // KV cache once (grows with position; use final length).  Low-bit
    // reads pay a dequantization tax (unpack + scale fusion is not
    // free), so the effective traffic reduction is sub-proportional —
    // this matches the moderate speedups the paper reports.
    let dequant_tax = match c.inf.precision {
        Precision::Fp16 => 1.0,
        Precision::Fp8 => 1.12,
        Precision::Int8 => 1.18,
        Precision::Int4 => 1.45,
    };
    let w_active = active * c.inf.precision.bytes_per_weight() * dequant_tax;
    let kv = kv_bytes(c, m, prompt + OUTPUT_TOKENS);
    let t_read = (w_active + kv) / bw;
    let t_compute = 2.0 * active / flops_rate;
    let t_decode = t_read.max(t_compute);

    // Fixed per-request scheduling/launch overhead.
    let overhead = 2.0e-3;
    (t_prefill + OUTPUT_TOKENS * t_decode + overhead) * 1e3
}

/// Energy per request in Joules (Definition 2's `Energy`).
pub fn energy_j(c: &Config, m: &ModelSpec, t: &TaskSpec,
                h: &Platform) -> f64 {
    let t_s = latency_ms(c, m, t, h) / 1e3;
    // Dynamic power scales with switched capacitance: narrower datapaths
    // draw less; quantization is "the most effective energy lever" (§5.6).
    let width_factor = (c.inf.precision.bits() as f64 / 16.0).powf(0.35);
    let util = 0.65 * width_factor;
    let power = h.power_budget_w
        * (h.idle_power_frac + (1.0 - h.idle_power_frac) * util);
    t_s * power
}

/// Average sustained power draw in W (Definition 3's `Power`).
pub fn power_w(c: &Config, m: &ModelSpec, t: &TaskSpec,
               h: &Platform) -> f64 {
    let e = energy_j(c, m, t, h);
    let t_s = latency_ms(c, m, t, h) / 1e3;
    e / t_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Attention, FtConfig, FtMethod, KvCache};
    use crate::hardware::a100;
    use crate::models::by_name;
    use crate::tasks::blended_task;

    fn llama7b() -> ModelSpec {
        by_name("LLaMA-2-7B").unwrap()
    }

    fn base() -> Config {
        Config::default_baseline()
    }

    #[test]
    fn default_memory_near_2x_params() {
        let m = llama7b();
        let gb = memory_gb(&base(), &m, &blended_task());
        assert!((13.0..16.5).contains(&gb), "got {gb}");
    }

    #[test]
    fn int8_halves_int4_quarters_weights() {
        let m = llama7b();
        let t = blended_task();
        let mut c8 = base();
        c8.inf.precision = Precision::Int8;
        let mut c4 = base();
        c4.inf.precision = Precision::Int4;
        let w16 = weight_bytes(&base(), &m);
        assert_eq!(weight_bytes(&c8, &m), w16 / 2.0);
        assert_eq!(weight_bytes(&c4, &m), w16 / 4.0);
        assert!(memory_gb(&c4, &m, &t) < memory_gb(&c8, &m, &t));
    }

    #[test]
    fn quantization_reduces_latency_and_energy() {
        let m = llama7b();
        let t = blended_task();
        let h = a100();
        let mut c = base();
        let l16 = latency_ms(&c, &m, &t, &h);
        let e16 = energy_j(&c, &m, &t, &h);
        c.inf.precision = Precision::Int8;
        assert!(latency_ms(&c, &m, &t, &h) < l16);
        assert!(energy_j(&c, &m, &t, &h) < e16);
    }

    #[test]
    fn gqa_reduces_kv_and_memory() {
        let m = llama7b();
        let t = crate::tasks::by_name("LongBench").unwrap();
        let mut c = base();
        let mem_mha = memory_gb(&c, &m, &t);
        c.arch.attention = Attention::Gqa;
        let mem_gqa = memory_gb(&c, &m, &t);
        assert!(mem_gqa < mem_mha);
        // effect should be visible on long-context (8k) tasks
        assert!(mem_mha - mem_gqa > 0.5, "delta={}", mem_mha - mem_gqa);
    }

    #[test]
    fn kv_policy_composes_with_architecture() {
        let mut c = base();
        c.arch.attention = Attention::Gqa; // 0.25
        c.inf.kv_cache = KvCache::MqaStyle; // 0.125
        assert_eq!(kv_fraction(&c), 0.125);
        c.inf.kv_cache = KvCache::Full;
        assert_eq!(kv_fraction(&c), 0.25);
    }

    #[test]
    fn sparse_moe_cuts_active_params_not_capacity() {
        let m = llama7b();
        let mut c = base();
        c.arch.moe = MoE::Sparse { experts: 4, top_k: 2 };
        let frac = active_param_fraction(&c, &m);
        assert!(frac < 1.0 && frac > 0.3, "frac={frac}");
        // memory slightly above dense (router overhead), not 4x
        let t = blended_task();
        let dense_mem = memory_gb(&base(), &m, &t);
        let moe_mem = memory_gb(&c, &m, &t);
        assert!(moe_mem > dense_mem);
        assert!(moe_mem < dense_mem * 1.25);
    }

    #[test]
    fn moe_reduces_latency() {
        let m = llama7b();
        let t = blended_task();
        let h = a100();
        let mut c = base();
        let dense = latency_ms(&c, &m, &t, &h);
        c.arch.moe = MoE::Sparse { experts: 8, top_k: 2 };
        assert!(latency_ms(&c, &m, &t, &h) < dense);
    }

    #[test]
    fn bigger_models_slower_and_hungrier() {
        let small = by_name("LLaMA-2-1B").unwrap();
        let big = by_name("LLaMA-2-70B").unwrap();
        let t = blended_task();
        let h = a100();
        let c = base();
        assert!(latency_ms(&c, &big, &t, &h) > latency_ms(&c, &small, &t, &h));
        assert!(memory_gb(&c, &big, &t) > memory_gb(&c, &small, &t));
        assert!(energy_j(&c, &big, &t, &h) > energy_j(&c, &small, &t, &h));
    }

    #[test]
    fn lora_adds_small_memory() {
        let m = llama7b();
        let t = blended_task();
        let mut c = base();
        c.ft = FtConfig { method: FtMethod::LoRA, rank: 64, alpha_mult: 2 };
        let with = memory_gb(&c, &m, &t);
        let without = memory_gb(&base(), &m, &t);
        assert!(with > without);
        assert!(with < without * 1.02); // adapters are tiny
    }

    #[test]
    fn power_within_platform_budget() {
        let m = llama7b();
        let t = blended_task();
        let h = a100();
        let p = power_w(&base(), &m, &t, &h);
        assert!(p > 0.0 && p <= h.power_budget_w);
    }

    #[test]
    fn faster_platform_is_faster() {
        let m = llama7b();
        let t = blended_task();
        let c = base();
        let slow = latency_ms(&c, &m, &t, &crate::hardware::rtx4090());
        let fast = latency_ms(&c, &m, &t, &crate::hardware::h200_cluster());
        assert!(fast < slow);
    }
}
