//! Accuracy model: task score of (configuration, model, task).
//!
//! The *shape* of this model is the paper's §5 analysis, implemented as
//! a composable set of effects on top of a saturating capability scaling
//! law.  It is the ground truth the surrogates must learn and the search
//! must navigate — including the cross-stage interactions (§3.5, §5.5)
//! that make joint optimization beat single-stage tuning.

use crate::config::{
    Attention, Config, FtMethod, KvCache, MoE, Precision, QuantMethod,
};
use crate::models::{ModelSpec, Scale};
use crate::tasks::{Category, TaskSpec};

/// Reference effective capacity (LLaMA-2-7B).
const REF_PARAMS_B: f64 = 6.7;
/// Headroom decay exponent of the saturating scaling law.
const DELTA: f64 = 0.25;

/// Score ceiling per unit (percent-like metrics saturate at 100; CIDEr
/// at ~200; MT-Bench at 10).
fn ceiling(t: &TaskSpec) -> f64 {
    match t.unit {
        "CIDEr" => 200.0,
        "/10" => 10.0,
        _ => 100.0,
    }
}

/// Default-configuration score: saturating law anchored at the task's
/// 7B base score — `score = C - (C - base) * (P_eff / P_ref)^-delta`.
pub fn default_score(m: &ModelSpec, t: &TaskSpec) -> f64 {
    let c = ceiling(t);
    let ratio = (m.effective_params_b() / REF_PARAMS_B).max(0.01);
    (c - (c - t.base_score_7b) * ratio.powf(-DELTA)).max(0.5)
}

/// Signed relative quality delta (fraction of current score) introduced
/// by the configuration's techniques.  Deterministic; noise is added by
/// the Testbed on top.
pub fn quality_delta(c: &Config, m: &ModelSpec, t: &TaskSpec) -> f64 {
    let mut d = 0.0;

    // ---- inference: quantization (§5.3, §5.4) --------------------------
    // Graceful FP16->INT8, cliff INT8->INT4; scaled by task sensitivity
    // and the model's robustness; calibration method modulates it.
    let bits_loss = match c.inf.precision {
        Precision::Fp16 => 0.0,
        Precision::Fp8 => 0.004,
        Precision::Int8 => 0.009,
        Precision::Int4 => 0.048,
    };
    let method_factor = match c.inf.quant_method {
        QuantMethod::Gptq => 1.0,
        QuantMethod::Awq => 0.80, // activation-aware: least degradation
        QuantMethod::SmoothQuant => 0.90,
    };
    let robustness = 1.0 - 0.6 * m.quant_robustness;
    d -= bits_loss * method_factor * robustness
        * (0.5 + 1.5 * t.quant_sensitivity);

    // ---- architecture: attention quality ordering (§5.1) ---------------
    d += match c.arch.attention {
        Attention::Mla => 0.004,  // best quality (latent bottleneck helps)
        Attention::Mha => 0.0,
        Attention::Gqa => -0.002,
        Attention::Mqa => -0.009,
    };
    // KV-cache policy degrades long-context tasks most.
    let kv_tax = match c.inf.kv_cache {
        KvCache::Full => 0.0,
        KvCache::GqaStyle => 0.003,
        KvCache::MqaStyle => 0.008,
    };
    let long_ctx = if t.category == Category::LongContext { 2.5 } else { 1.0 };
    d -= kv_tax * long_ctx;

    // ---- architecture: MoE (§5.3) --------------------------------------
    if let MoE::Sparse { experts, top_k } = c.arch.moe {
        // Diminishing returns in expert count; benefit gated by the
        // task's routing affinity; top-1 routing is brittle.
        let gain = match experts {
            2 => 0.004,
            4 => 0.009,
            8 => 0.011,
            _ => 0.0,
        };
        let routing_tax = if top_k == 1 { 0.004 } else { 0.0 };
        d += gain * (0.3 + 1.4 * t.moe_affinity) - routing_tax;
        // §5.5 cross-stage conflict: aggressive quantization destabilizes
        // routing (top-1/INT4 is excluded by validity; top-2/INT4 pays).
        if c.inf.precision == Precision::Int4 {
            d -= 0.006;
        }
        // MLA pairs well with sparse MoE (DeepSeek-style affinity).
        if c.arch.attention == Attention::Mla {
            d += 0.002;
        }
    }

    // ---- fine-tuning (§5.1, §5.4) ---------------------------------------
    d += ft_delta(c, m);

    // ---- cross-stage: quantization shifts the optimal rank (§3.5) ------
    // Low-bit bases need more adapter capacity to recover; reward higher
    // ranks under INT4/INT8 beyond what ft_delta alone gives.
    if c.ft.method.is_peft() {
        let bits = c.inf.precision.bits() as f64;
        if bits <= 8.0 && c.ft.rank >= 64 {
            d += 0.002;
        }
        if bits <= 4.0 && c.ft.rank <= 16 {
            d -= 0.004;
        }
    }

    d
}

/// Fine-tuning method/rank contribution.
fn ft_delta(c: &Config, m: &ModelSpec) -> f64 {
    // Optimal rank grows with scale (§5.4): 16 / 32 / 96.
    let opt_rank: f64 = match m.scale {
        Scale::Small => 16.0,
        Scale::Medium => 32.0,
        Scale::Large => 96.0,
    };
    match c.ft.method {
        // Full fine-tuning is the Default baseline: delta 0 by anchoring.
        FtMethod::Full => 0.0,
        method => {
            let r = c.ft.rank as f64;
            // Log-parabola around the scale-appropriate optimum:
            // saturating gains up to opt, slow decay beyond.
            let x = (r / opt_rank).ln();
            // Full FT is competitive for small models (§5.1): PEFT's
            // peak gain shrinks with decreasing scale.
            let peak = match m.scale {
                Scale::Small => 0.000,
                Scale::Medium => 0.003,
                Scale::Large => 0.004,
            };
            let rank_curve = peak - 0.003 * x * x;
            let method_bonus = match method {
                FtMethod::RsLoRA => {
                    // better scaling behaviour on large models (§5.3)
                    if m.scale == Scale::Large { 0.003 } else { -0.001 }
                }
                FtMethod::DoRA => 0.001,
                FtMethod::QLoRA => -0.001,
                _ => 0.0,
            };
            // Alpha: 2r is the sweet spot; 4r over-amplifies at high rank.
            let alpha_tax = match c.ft.alpha_mult {
                2 => 0.0,
                1 => -0.0005,
                _ => {
                    if r >= 64.0 {
                        -0.002
                    } else {
                        -0.0005
                    }
                }
            };
            rank_curve + method_bonus + alpha_tax
        }
    }
}

/// Final deterministic score.
pub fn score(c: &Config, m: &ModelSpec, t: &TaskSpec) -> f64 {
    let base = default_score(m, t);
    (base * (1.0 + quality_delta(c, m, t))).clamp(0.0, ceiling(t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FtConfig;
    use crate::models::by_name;
    use crate::tasks::{by_name as task, suite};

    fn base_cfg() -> Config {
        Config::default_baseline()
    }

    #[test]
    fn default_delta_is_zero() {
        let m = by_name("LLaMA-2-7B").unwrap();
        for t in suite() {
            assert_eq!(
                score(&base_cfg(), &m, &t),
                default_score(&m, &t),
                "{}", t.name
            );
        }
    }

    #[test]
    fn scaling_law_monotone_in_params() {
        let t = task("MMLU").unwrap();
        let s7 = default_score(&by_name("LLaMA-2-7B").unwrap(), &t);
        let s70 = default_score(&by_name("LLaMA-2-70B").unwrap(), &t);
        let s1 = default_score(&by_name("LLaMA-2-1B").unwrap(), &t);
        assert!(s1 < s7 && s7 < s70);
        assert!(s70 < 100.0);
    }

    #[test]
    fn llama70b_mmlu_near_paper_anchor() {
        // Table 6: LLaMA-2-70B Default MMLU = 70.8
        let t = task("MMLU").unwrap();
        let s = default_score(&by_name("LLaMA-2-70B").unwrap(), &t);
        assert!((s - 70.8).abs() < 3.0, "got {s}");
    }

    #[test]
    fn mistral_beats_llama7b() {
        let t = task("MMLU").unwrap();
        assert!(default_score(&by_name("Mistral-7B").unwrap(), &t)
            > default_score(&by_name("LLaMA-2-7B").unwrap(), &t));
    }

    #[test]
    fn int4_hurts_gsm8k_more_than_hellaswag() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let mut c = base_cfg();
        c.inf.precision = Precision::Int4;
        let gsm = task("GSM8K").unwrap();
        let hs = task("HellaSwag").unwrap();
        let drop_gsm = quality_delta(&c, &m, &gsm);
        let drop_hs = quality_delta(&c, &m, &hs);
        assert!(drop_gsm < drop_hs && drop_hs < 0.0,
                "gsm={drop_gsm} hs={drop_hs}");
    }

    #[test]
    fn int8_graceful_int4_cliff() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = task("MMLU").unwrap();
        let mut c = base_cfg();
        c.inf.precision = Precision::Int8;
        let d8 = quality_delta(&c, &m, &t);
        c.inf.precision = Precision::Int4;
        let d4 = quality_delta(&c, &m, &t);
        assert!(d8 > 4.0 * d4, "d8={d8} d4={d4}"); // cliff, not linear
    }

    #[test]
    fn mistral_more_robust_under_int4() {
        let t = task("MMLU").unwrap();
        let mut c = base_cfg();
        c.inf.precision = Precision::Int4;
        let d_mistral = quality_delta(&c, &by_name("Mistral-7B").unwrap(), &t);
        let d_llama = quality_delta(&c, &by_name("LLaMA-2-7B").unwrap(), &t);
        assert!(d_mistral > d_llama);
    }

    #[test]
    fn awq_degrades_less_than_gptq() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = task("GSM8K").unwrap();
        let mut c = base_cfg();
        c.inf.precision = Precision::Int4;
        c.inf.quant_method = QuantMethod::Gptq;
        let gptq = quality_delta(&c, &m, &t);
        c.inf.quant_method = QuantMethod::Awq;
        assert!(quality_delta(&c, &m, &t) > gptq);
    }

    #[test]
    fn attention_quality_ordering() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = task("MMLU").unwrap();
        let mut scores = vec![];
        for a in [Attention::Mla, Attention::Mha, Attention::Gqa,
                  Attention::Mqa] {
            let mut c = base_cfg();
            c.arch.attention = a;
            scores.push(score(&c, &m, &t));
        }
        assert!(scores[0] > scores[1]);
        assert!(scores[1] > scores[2]);
        assert!(scores[2] > scores[3]);
    }

    #[test]
    fn moe_helps_code_more_than_understanding() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let mut c = base_cfg();
        c.arch.moe = MoE::Sparse { experts: 8, top_k: 2 };
        let code = quality_delta(&c, &m, &task("HumanEval").unwrap());
        let mmlu = quality_delta(&c, &m, &task("MMLU").unwrap());
        assert!(code > mmlu && code > 0.0);
    }

    #[test]
    fn moe_experts_diminishing_returns() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = task("HumanEval").unwrap();
        let mut deltas = vec![];
        for e in [2u8, 4, 8] {
            let mut c = base_cfg();
            c.arch.moe = MoE::Sparse { experts: e, top_k: 2 };
            deltas.push(quality_delta(&c, &m, &t));
        }
        assert!(deltas[1] - deltas[0] > deltas[2] - deltas[1]);
    }

    #[test]
    fn optimal_rank_scales_with_model_size() {
        let t = task("MMLU").unwrap();
        let best_rank = |name: &str| -> u16 {
            let m = by_name(name).unwrap();
            *crate::config::RANKS
                .iter()
                .max_by(|&&a, &&b| {
                    let mk = |r: u16| {
                        let mut c = base_cfg();
                        c.ft = FtConfig {
                            method: FtMethod::LoRA,
                            rank: r,
                            alpha_mult: 2,
                        };
                        quality_delta(&c, &m, &t)
                    };
                    mk(a).partial_cmp(&mk(b)).unwrap()
                })
                .unwrap()
        };
        assert_eq!(best_rank("LLaMA-2-1B"), 16);
        assert_eq!(best_rank("LLaMA-2-7B"), 32);
        assert!(best_rank("LLaMA-2-70B") >= 64);
    }

    #[test]
    fn rslora_wins_only_at_scale() {
        let t = task("MMLU").unwrap();
        let delta_for = |name: &str, method: FtMethod| {
            let m = by_name(name).unwrap();
            let mut c = base_cfg();
            c.ft = FtConfig { method, rank: 64, alpha_mult: 2 };
            quality_delta(&c, &m, &t)
        };
        assert!(delta_for("LLaMA-2-70B", FtMethod::RsLoRA)
            > delta_for("LLaMA-2-70B", FtMethod::LoRA));
        assert!(delta_for("LLaMA-2-7B", FtMethod::RsLoRA)
            <= delta_for("LLaMA-2-7B", FtMethod::LoRA));
    }

    #[test]
    fn kv_policy_hurts_long_context_most() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let mut c = base_cfg();
        c.inf.kv_cache = KvCache::MqaStyle;
        let long = quality_delta(&c, &m, &task("LongBench").unwrap());
        let short = quality_delta(&c, &m, &task("HellaSwag").unwrap());
        assert!(long < short);
    }

    #[test]
    fn scores_always_in_range() {
        let m = by_name("Qwen-72B").unwrap();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..300 {
            let c = crate::config::enumerate::sample(&mut rng);
            for t in suite() {
                let s = score(&c, &m, &t);
                assert!(s >= 0.0 && s <= ceiling(&t), "{s} for {}", t.name);
            }
        }
    }
}
