//! S5: the testbed oracle — the simulated measurement fleet standing in
//! for the paper's GPU testbeds (DESIGN.md §3 substitution table).
//!
//! `Testbed::measure` plays the role of "evaluate on actual hardware"
//! (Algorithm 1 line 5): it is treated as expensive by the coordinator,
//! returns *noisy* observations (§5.5 reports 5–10% hardware
//! variability), and hides ground truth the surrogates must learn.
//! Raw physics come from [`cost`] and [`accuracy`]; absolute scales are
//! calibrated so the Default configuration on each Table 2 model lands
//! on the paper's Default row.

pub mod accuracy;
pub mod cost;

use crate::config::Config;
use crate::evaluator::{EvalContext, Evaluator};
use crate::hardware::{self, Platform};
use crate::models::ModelSpec;
use crate::tasks::TaskSpec;
use crate::util::pool::{self, Parallelism};
use crate::util::Rng;

/// The four performance objectives of Definition 2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objectives {
    pub accuracy: f64,   // maximize (task units)
    pub latency_ms: f64, // minimize
    pub memory_gb: f64,  // minimize
    pub energy_j: f64,   // minimize
}

impl Objectives {
    /// True iff `self` Pareto-dominates `other` (>= everywhere with at
    /// least one strict improvement; accuracy maximized, rest minimized).
    pub fn dominates(&self, other: &Objectives) -> bool {
        let ge = self.accuracy >= other.accuracy
            && self.latency_ms <= other.latency_ms
            && self.memory_gb <= other.memory_gb
            && self.energy_j <= other.energy_j;
        let strict = self.accuracy > other.accuracy
            || self.latency_ms < other.latency_ms
            || self.memory_gb < other.memory_gb
            || self.energy_j < other.energy_j;
        ge && strict
    }

    /// Objective vector in minimization convention (for NSGA-II).
    pub fn as_min_vec(&self) -> [f64; 4] {
        [-self.accuracy, self.latency_ms, self.memory_gb, self.energy_j]
    }

    /// Serialize (the shared shape used by `RunReport`, the persistent
    /// front and the adaptation report).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("accuracy".into(), Json::Num(self.accuracy));
        m.insert("latency_ms".into(), Json::Num(self.latency_ms));
        m.insert("memory_gb".into(), Json::Num(self.memory_gb));
        m.insert("energy_j".into(), Json::Num(self.energy_j));
        Json::Obj(m)
    }

    /// Parse back from [`to_json`](Self::to_json)'s shape.
    pub fn from_json(j: &crate::util::json::Json)
                     -> Result<Objectives, String> {
        Ok(Objectives {
            accuracy: j.req_f64("accuracy")?,
            latency_ms: j.req_f64("latency_ms")?,
            memory_gb: j.req_f64("memory_gb")?,
            energy_j: j.req_f64("energy_j")?,
        })
    }
}

/// Table 2 "Default" anchor rows: (accuracy %, latency ms, memory GB,
/// energy J) per model, on the paper's per-scale hardware tier.
fn table2_anchor(name: &str) -> Option<[f64; 4]> {
    Some(match name {
        "LLaMA-2-1B" => [43.2, 12.5, 2.1, 0.08],
        "Phi-2" => [56.8, 18.3, 4.2, 0.15],
        "LLaMA-2-7B" => [68.5, 45.2, 13.5, 0.85],
        "Mistral-7B" => [71.2, 42.8, 14.1, 0.88],
        "LLaMA-3-8B" => [72.1, 48.5, 15.2, 0.95],
        "LLaMA-2-70B" => [82.5, 185.2, 138.5, 4.52],
        "Mixtral-8x7B" => [81.8, 165.8, 98.5, 3.85],
        "Qwen-72B" => [83.2, 192.5, 145.2, 4.82],
        // Table 4 VLM Default rows (accuracy is task-specific there; the
        // anchor carries the efficiency triple measured on LLaVA's tier).
        "LLaVA-1.5-7B" => [78.5, 85.2, 18.5, 1.25],
        "InternVL-Chat" => [81.2, 92.5, 22.5, 1.42],
        _ => return None,
    })
}

/// Power-law fallbacks for unanchored models, fit to the Table 2 rows
/// (see DESIGN.md §7): latency ≈ 11.7·P^0.65 ms, energy ≈ 0.075·P^0.97 J,
/// memory comes straight from the cost model.
fn fallback_anchor(m: &ModelSpec) -> [f64; 4] {
    let p = m.params_b;
    let acc = accuracy::default_score(
        m, &crate::tasks::blended_task());
    [
        acc,
        11.7 * p.powf(0.65),
        f64::NAN, // memory: use raw cost model (already calibrated)
        0.075 * p.powf(0.97),
    ]
}

/// The simulated measurement testbed for one hardware platform.
#[derive(Clone, Debug)]
pub struct Testbed {
    pub platform: Platform,
    /// Multiplicative measurement noise sigma for efficiency metrics
    /// (§5.5: 5–10% variability; default 4% sigma ~ 8% spread).
    pub noise_sigma: f64,
    /// Additive accuracy measurement noise (absolute points).
    pub acc_noise: f64,
    /// Configurations measured through the [`Evaluator`] trait on this
    /// instance (clones start from the cloned count).
    evals: usize,
}

impl Testbed {
    pub fn new(platform: Platform) -> Self {
        Testbed { platform, noise_sigma: 0.04, acc_noise: 0.15, evals: 0 }
    }

    /// Noise-free testbed (for reports and unit tests).
    pub fn noiseless(platform: Platform) -> Self {
        Testbed { platform, noise_sigma: 0.0, acc_noise: 0.0, evals: 0 }
    }

    /// The testbed the paper pairs with this model's scale bucket.
    pub fn for_model(m: &ModelSpec) -> Self {
        Testbed::new(hardware::tier_for_scale(m.scale))
    }

    /// Ground-truth objectives (deterministic; what reports use).
    pub fn true_objectives(&self, c: &Config, m: &ModelSpec,
                           t: &TaskSpec) -> Objectives {
        let default = Config::default_baseline();
        let anchor = table2_anchor(m.name).unwrap_or_else(|| fallback_anchor(m));

        // Raw physics, config vs default, on this platform.
        let raw_lat = cost::latency_ms(c, m, t, &self.platform);
        let raw_lat_def = cost::latency_ms(&default, m, t, &self.platform);
        let raw_mem = cost::memory_gb(c, m, t);
        let raw_mem_def = cost::memory_gb(&default, m, t);
        let raw_en = cost::energy_j(c, m, t, &self.platform);
        let raw_en_def = cost::energy_j(&default, m, t, &self.platform);

        // Anchor-calibrated absolute values.
        let latency_ms = anchor[1] * raw_lat / raw_lat_def;
        let memory_gb = if anchor[2].is_nan() {
            raw_mem
        } else {
            anchor[2] * raw_mem / raw_mem_def
        };
        let energy_j = anchor[3] * raw_en / raw_en_def;

        Objectives {
            accuracy: accuracy::score(c, m, t),
            latency_ms,
            memory_gb,
            energy_j,
        }
    }

    /// One noisy measurement — the expensive call of Algorithm 1 line 5.
    pub fn measure(&self, c: &Config, m: &ModelSpec, t: &TaskSpec,
                   rng: &mut Rng) -> Objectives {
        let o = self.true_objectives(c, m, t);
        let jitter = |rng: &mut Rng| {
            (1.0 + self.noise_sigma * rng.normal()).max(0.5)
        };
        Objectives {
            accuracy: (o.accuracy + self.acc_noise * rng.normal()).max(0.0),
            latency_ms: o.latency_ms * jitter(rng),
            memory_gb: o.memory_gb * (1.0 + 0.25 * self.noise_sigma
                * rng.normal()).max(0.5),
            energy_j: o.energy_j * jitter(rng),
        }
    }

    /// Ground-truth objectives for a whole batch, fanned across the
    /// thread pool; results are in submission order and identical to
    /// calling [`true_objectives`](Self::true_objectives) per config.
    pub fn true_objectives_batch(&self, cs: &[Config], m: &ModelSpec,
                                 t: &TaskSpec,
                                 par: Parallelism) -> Vec<Objectives> {
        pool::parallel_map(par, cs, |c| self.true_objectives(c, m, t))
    }

    /// Noisy measurements for a whole batch — the parallel form of the
    /// expensive Algorithm 1 line-5 call.
    ///
    /// Determinism contract: one child RNG is split off `rng`
    /// *sequentially per config* before the fan-out, so the same seed
    /// yields the same measurements at every parallelism level (the
    /// draws differ from what a single shared stream would produce, but
    /// they follow the same noise distribution).
    pub fn measure_batch(&self, cs: &[Config], m: &ModelSpec, t: &TaskSpec,
                         rng: &mut Rng,
                         par: Parallelism) -> Vec<Objectives> {
        let jobs: Vec<(Config, Rng)> =
            cs.iter().map(|c| (*c, rng.split())).collect();
        pool::parallel_map(par, &jobs, |(c, seed)| {
            let mut noise = seed.clone();
            self.measure(c, m, t, &mut noise)
        })
    }

    /// Sustained power draw (for the Definition 3 power constraint).
    pub fn power_w(&self, c: &Config, m: &ModelSpec, t: &TaskSpec) -> f64 {
        cost::power_w(c, m, t, &self.platform)
    }

    /// Definition 3 feasibility on this testbed's platform.
    pub fn feasible(&self, c: &Config, m: &ModelSpec, t: &TaskSpec) -> bool {
        let o = self.true_objectives(c, m, t);
        self.platform.feasible(o.memory_gb, self.power_w(c, m, t))
    }
}

/// The testbed as a first-class evaluation backend (DESIGN.md §9): the
/// trait call is a pure delegation to the inherent
/// [`measure_batch`](Testbed::measure_batch) — same RNG discipline,
/// same parallel fan-out — plus the trait's built-in eval counting.
impl Evaluator for Testbed {
    fn measure_batch(&mut self, cs: &[Config], ctx: &EvalContext,
                     rng: &mut Rng) -> Vec<Objectives> {
        self.evals += cs.len();
        Testbed::measure_batch(self, cs, ctx.model, ctx.task, rng,
                               ctx.parallelism)
    }

    fn evals(&self) -> usize {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{enumerate, Precision};
    use crate::models::by_name;
    use crate::tasks::blended_task;

    fn setup() -> (Testbed, ModelSpec, TaskSpec) {
        let m = by_name("LLaMA-2-7B").unwrap();
        (Testbed::noiseless(hardware::a100()), m, blended_task())
    }

    #[test]
    fn default_hits_table2_anchor_exactly() {
        let (tb, m, t) = setup();
        let o = tb.true_objectives(&Config::default_baseline(), &m, &t);
        assert!((o.latency_ms - 45.2).abs() < 1e-9);
        assert!((o.memory_gb - 13.5).abs() < 1e-9);
        assert!((o.energy_j - 0.85).abs() < 1e-9);
    }

    #[test]
    fn anchors_cover_all_table2_models() {
        for name in crate::models::table2_models() {
            assert!(table2_anchor(name).is_some(), "{name}");
        }
    }

    #[test]
    fn unanchored_model_uses_fallback() {
        let m = by_name("Qwen-14B").unwrap();
        let tb = Testbed::noiseless(hardware::a100());
        let o = tb.true_objectives(&Config::default_baseline(), &m,
                                   &blended_task());
        assert!(o.latency_ms > 45.0 && o.latency_ms < 120.0,
                "lat={}", o.latency_ms);
        assert!(o.memory_gb > 25.0, "mem={}", o.memory_gb);
    }

    #[test]
    fn int4_improves_all_efficiency_metrics() {
        let (tb, m, t) = setup();
        let def = tb.true_objectives(&Config::default_baseline(), &m, &t);
        let mut c = Config::default_baseline();
        c.inf.precision = Precision::Int4;
        let q = tb.true_objectives(&c, &m, &t);
        assert!(q.latency_ms < def.latency_ms);
        assert!(q.memory_gb < def.memory_gb);
        assert!(q.energy_j < def.energy_j);
        assert!(q.accuracy < def.accuracy); // pays in quality
    }

    #[test]
    fn measurement_noise_has_expected_spread() {
        let (mut tb, m, t) = setup();
        tb.noise_sigma = 0.04;
        tb.acc_noise = 0.15;
        let mut rng = Rng::new(7);
        let c = Config::default_baseline();
        let lats: Vec<f64> = (0..400)
            .map(|_| tb.measure(&c, &m, &t, &mut rng).latency_ms)
            .collect();
        let cv = crate::util::stats::cv(&lats);
        assert!((0.02..0.07).contains(&cv), "cv={cv}");
        // unbiased within tolerance
        let truth = tb.true_objectives(&c, &m, &t).latency_ms;
        assert!((crate::util::stats::mean(&lats) / truth - 1.0).abs() < 0.02);
    }

    #[test]
    fn noiseless_measure_equals_truth() {
        let (tb, m, t) = setup();
        let mut rng = Rng::new(1);
        let c = Config::default_baseline();
        assert_eq!(tb.measure(&c, &m, &t, &mut rng),
                   tb.true_objectives(&c, &m, &t));
    }

    #[test]
    fn batch_eval_matches_scalar_and_is_parallelism_invariant() {
        let (tb, m, t) = setup();
        let mut rng = Rng::new(21);
        let cs: Vec<Config> =
            (0..64).map(|_| enumerate::sample(&mut rng)).collect();
        let batch = tb.true_objectives_batch(
            &cs, &m, &t, crate::util::Parallelism::Threads(4));
        for (c, o) in cs.iter().zip(&batch) {
            assert_eq!(*o, tb.true_objectives(c, &m, &t));
        }
        // noisy batch: same seed + any parallelism -> same measurements
        let tb_noisy = Testbed::new(hardware::a100());
        let go = |par| {
            let mut r = Rng::new(5);
            tb_noisy.measure_batch(&cs, &m, &t, &mut r, par)
        };
        let a = go(crate::util::Parallelism::Sequential);
        let b = go(crate::util::Parallelism::Threads(4));
        let c = go(crate::util::Parallelism::Threads(8));
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    fn dominance_relation() {
        let a = Objectives { accuracy: 70.0, latency_ms: 10.0,
                             memory_gb: 5.0, energy_j: 0.5 };
        let mut b = a;
        assert!(!a.dominates(&b)); // equal: no strict improvement
        b.latency_ms = 12.0;
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        b.accuracy = 75.0; // trade-off: neither dominates
        assert!(!a.dominates(&b));
        assert!(!b.dominates(&a));
    }

    #[test]
    fn feasibility_catches_oversized_models() {
        let small_platform = Testbed::noiseless(hardware::rtx4090());
        let m70 = by_name("LLaMA-2-70B").unwrap();
        let t = blended_task();
        assert!(!small_platform.feasible(&Config::default_baseline(),
                                         &m70, &t));
        // INT4 70B ~ 35GB still too big for 24GB
        let mut c = Config::default_baseline();
        c.inf.precision = Precision::Int4;
        assert!(!small_platform.feasible(&c, &m70, &t));
        // but a 7B INT4 fits easily
        let m7 = by_name("LLaMA-2-7B").unwrap();
        assert!(small_platform.feasible(&c, &m7, &t));
    }

    #[test]
    fn random_configs_never_beat_ceiling_nor_go_negative() {
        let (tb, m, t) = setup();
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let c = enumerate::sample(&mut rng);
            let o = tb.true_objectives(&c, &m, &t);
            assert!(o.accuracy >= 0.0 && o.accuracy <= 100.0);
            assert!(o.latency_ms > 0.0);
            assert!(o.memory_gb > 0.0);
            assert!(o.energy_j > 0.0);
        }
    }

    #[test]
    fn some_config_achieves_big_efficiency_gain() {
        // The paper's headline: ~2-3x efficiency attainable. Verify the
        // oracle's landscape actually contains such configs.
        let (tb, m, t) = setup();
        let def = tb.true_objectives(&Config::default_baseline(), &m, &t);
        let mut rng = Rng::new(11);
        let mut best = 0.0f64;
        for _ in 0..500 {
            let c = enumerate::sample(&mut rng);
            let o = tb.true_objectives(&c, &m, &t);
            let gain = crate::util::stats::geometric_mean(&[
                def.latency_ms / o.latency_ms,
                def.memory_gb / o.memory_gb,
                def.energy_j / o.energy_j,
            ]);
            if o.accuracy > def.accuracy - 1.5 {
                best = best.max(gain);
            }
        }
        assert!(best > 1.8, "best accuracy-preserving gain {best}");
    }
}
