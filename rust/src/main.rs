//! AE-LLM command-line interface (Layer-3 leader entrypoint).
//!
//! ```text
//! ae-llm search  --model Mistral-7B [--task GSM8K] [--platform A100-80GB]
//!                [--prefs latency] [--strategy nsga2|random|racing|local]
//!                [--quick] [--seed N] [--json]
//! ae-llm table   --id 2|3|4|5|6|7|8|9|10 [--quick] [--seed N]
//!                # 7 = strategies, 8 = serving, 9 = adaptation,
//!                # 10 = cluster-scale serving
//! ae-llm figure  --id 1|2|3|4 [--quick] [--seed N] [--out reports/]
//! ae-llm e2e     [--repeats N] [--seed N]  # hardware-in-the-loop Algorithm 1
//! ae-llm serve   [--model M] [--scenario steady|diurnal|bursty|heavytail]
//!                [--strategy S] [--requests N] [--par N|auto|seq]
//!                [--quick] [--seed N]
//!                [--json OUT.json]        # simulated fleet, artifact-free
//! ae-llm serve   --variant V [--requests N] [--seed N]  # live PJRT path
//! ae-llm adapt   [--model M] [--scenario regime_shift|ramp|...]
//!                [--strategy S] [--epochs N] [--requests N/epoch]
//!                [--one-shot] [--quick] [--seed N] [--json OUT.json]
//!                # continual adaptation: drift-triggered re-search
//! ae-llm cluster [--model M] [--scenario S] [--strategy S]
//!                [--requests N] [--nodes N] [--capacity N] [--epochs N]
//!                [--par N|auto|seq] [--quick] [--seed N] [--json OUT.json]
//!                # cluster-scale serving on the sharded event core;
//!                # reports are byte-identical at every --par level
//! ae-llm store   ls|gc|verify [--store DIR]
//!                # content-addressed artifact store: list the catalog,
//!                # sweep unreferenced blobs, verify blob integrity
//!                # (DIR defaults to $AE_LLM_STORE; `search --store` /
//!                #  `adapt --store` write into it)
//! ae-llm check   # artifacts sanity: load + execute every variant
//! ae-llm space   # print the configuration-space inventory
//! ```
//!
//! (The argument parser is hand-rolled: `clap` is not in the offline
//! vendor set.  Unknown options are rejected per subcommand with a
//! nearest-match suggestion.)

use std::collections::BTreeMap;
use std::path::PathBuf;

use ae_llm::coordinator::{AeLlm, FnObserver, IterationEvent, Scenario};
use ae_llm::evaluator::CachingEvaluator;
use ae_llm::metrics::utility;
use ae_llm::report::{figures, tables, Budget};
use ae_llm::runtime;
use ae_llm::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

/// Parsed `--key value` / `--flag` options after the subcommand.
struct Opts {
    map: BTreeMap<String, String>,
}

impl Opts {
    /// Parse options, rejecting any key not in `valued`/`flags` for
    /// `cmd` (typo'd flags used to be silently ignored).  `valued`
    /// options require a following value; `flags` are boolean and
    /// never consume one (`--json report.json` is an error, not a
    /// silently ignored value).
    fn parse(cmd: &str, valued: &[&str], flags: &[&str], args: &[String])
             -> anyhow::Result<Opts> {
        let mut map = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            anyhow::ensure!(
                a.starts_with("--"),
                "unexpected argument {a:?} (options look like --key [value])"
            );
            let key = a.trim_start_matches("--").to_string();
            if flags.contains(&key.as_str()) {
                map.insert(key, "true".to_string());
                i += 1;
            } else if valued.contains(&key.as_str()) {
                anyhow::ensure!(
                    i + 1 < args.len() && !args[i + 1].starts_with("--"),
                    "--{key} expects a value"
                );
                map.insert(key, args[i + 1].clone());
                i += 2;
            } else {
                anyhow::bail!("{}",
                              unknown_option_msg(cmd, &key, valued, flags));
            }
        }
        Ok(Opts { map })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }

    fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number")),
        }
    }
}

fn unknown_option_msg(cmd: &str, key: &str, valued: &[&str],
                      flags: &[&str]) -> String {
    let allowed: Vec<&str> =
        valued.iter().chain(flags.iter()).copied().collect();
    let mut msg = format!("unknown option --{key} for `{cmd}`");
    if let Some(s) = closest(key, &allowed) {
        msg.push_str(&format!(" (did you mean --{s}?)"));
    }
    if allowed.is_empty() {
        msg.push_str("; this command takes no options");
    } else {
        let list: Vec<String> =
            allowed.iter().map(|k| format!("--{k}")).collect();
        msg.push_str(&format!("; allowed: {}", list.join(" ")));
    }
    msg
}

/// Nearest allowed key within edit distance 2, for typo suggestions.
fn closest<'a>(key: &str, allowed: &[&'a str]) -> Option<&'a str> {
    allowed
        .iter()
        .map(|a| (edit_distance(key, a), *a))
        .filter(|(d, _)| *d <= 2)
        .min_by_key(|(d, _)| *d)
        .map(|(_, a)| a)
}

/// Unknown *value* of a valued option (`--scenario bursy`): same
/// did-you-mean treatment the option keys get, plus the full list of
/// valid names.
fn unknown_value_msg(what: &str, got: &str, allowed: &[&str]) -> String {
    let mut msg = format!("unknown {what} {got:?}");
    if let Some(s) = closest(got, allowed) {
        msg.push_str(&format!(" (did you mean {s}?)"));
    }
    msg.push_str(&format!("; known: {}", allowed.join(", ")));
    msg
}

/// Resolve a `--scenario` value with a nearest-match suggestion.
fn parse_scenario(name: &str)
                  -> anyhow::Result<ae_llm::runtime::WorkloadKind> {
    ae_llm::runtime::WorkloadKind::by_name(name).ok_or_else(|| {
        let names: Vec<&str> = ae_llm::runtime::WorkloadKind::ALL
            .iter()
            .map(|k| k.name())
            .collect();
        anyhow::anyhow!("{}", unknown_value_msg("scenario", name, &names))
    })
}

/// Resolve a `--strategy` value with a nearest-match suggestion.
fn parse_strategy(name: &str)
                  -> anyhow::Result<ae_llm::search::StrategyKind> {
    ae_llm::search::StrategyKind::by_name(name).ok_or_else(|| {
        let names: Vec<&str> = ae_llm::search::StrategyKind::ALL
            .iter()
            .map(|k| k.name())
            .collect();
        anyhow::anyhow!("{}", unknown_value_msg("strategy", name, &names))
    })
}

/// Resolve a `--par` value: a positive thread count, `auto` (size the
/// pool to the machine), or `seq`/`sequential` (no pool).  The pool
/// contract (DESIGN.md §14) makes every level byte-identical, so this
/// only trades wall-clock time.  Shared by `serve` and `cluster`.
fn parse_parallelism(v: &str)
                     -> anyhow::Result<ae_llm::util::Parallelism> {
    use ae_llm::util::Parallelism;
    match v {
        "auto" => Ok(Parallelism::Auto),
        "seq" | "sequential" => Ok(Parallelism::Sequential),
        _ => match v.parse::<usize>() {
            Ok(0) => anyhow::bail!(
                "--par expects a positive thread count (or auto, seq)"
            ),
            Ok(n) => Ok(Parallelism::Threads(n)),
            Err(_) => anyhow::bail!(
                "{} (or a thread count, e.g. --par 4)",
                unknown_value_msg("parallelism", v, &["auto", "seq"])
            ),
        },
    }
}

/// Plain Levenshtein distance (small inputs; O(|a|·|b|)).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut row = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            row.push(sub.min(prev[j + 1] + 1).min(row[j] + 1));
        }
        prev = row;
    }
    prev[b.len()]
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let (valued, flags): (&[&str], &[&str]) = match cmd.as_str() {
        "search" => (&["model", "task", "platform", "prefs", "strategy",
                       "seed", "store"],
                     &["quick", "json"]),
        "table" => (&["id", "seed"], &["quick"]),
        "figure" => (&["id", "seed", "out"], &["quick"]),
        "e2e" => (&["repeats", "seed"], &[]),
        "serve" => (&["requests", "variant", "seed", "model", "scenario",
                      "strategy", "par", "json"],
                    &["quick"]),
        "adapt" => (&["requests", "epochs", "seed", "model", "scenario",
                      "strategy", "json", "store"],
                    &["quick", "one-shot"]),
        "cluster" => (&["requests", "nodes", "capacity", "epochs", "seed",
                        "model", "scenario", "strategy", "par", "json"],
                      &["quick"]),
        "check" | "space" => (&[], &[]),
        // `store` takes a positional action (`store ls`), which the
        // generic option parser would reject — it has its own parse.
        "store" => return cmd_store(&args[1..]),
        "help" | "--help" | "-h" => {
            print_help();
            return Ok(());
        }
        other => {
            // Same did-you-mean treatment the option keys get.
            const COMMANDS: &[&str] = &[
                "search", "table", "figure", "e2e", "serve", "adapt",
                "cluster", "store", "check", "space", "help",
            ];
            let hint = match closest(other, COMMANDS) {
                Some(s) => format!(" (did you mean `{s}`?)"),
                None => String::new(),
            };
            anyhow::bail!("unknown command {other:?}{hint}; try `help`")
        }
    };
    let opts = Opts::parse(cmd, valued, flags, &args[1..])?;
    let budget = Budget { quick: opts.flag("quick") };
    let seed = opts.u64_or("seed", 42)?;

    match cmd.as_str() {
        "search" => cmd_search(&opts, &budget, seed),
        "table" => cmd_table(&opts, &budget, seed),
        "figure" => cmd_figure(&opts, &budget, seed),
        "e2e" => cmd_e2e(&opts, seed),
        "serve" => cmd_serve(&opts, seed),
        "adapt" => cmd_adapt(&opts, seed),
        "cluster" => cmd_cluster(&opts, seed),
        "check" => cmd_check(),
        "space" => cmd_space(),
        _ => unreachable!("allowed-list match covers every command"),
    }
}

fn cmd_search(opts: &Opts, budget: &Budget, seed: u64) -> anyhow::Result<()> {
    let model = opts.get("model").unwrap_or("LLaMA-2-7B");
    let mut session = AeLlm::for_model(model)?;
    if let Some(task) = opts.get("task") {
        session = session.task(task)?;
    }
    if let Some(p) = opts.get("platform") {
        session = session.platform(p)?;
    }
    if let Some(w) = opts.get("prefs") {
        session = session.prefs_named(w)?;
    }
    session = session.params(budget.ae_params()).seed(seed);
    if let Some(s) = opts.get("strategy") {
        // After `params(...)` so the budget preset can't reset the
        // strategy choice back to the default.
        session = session.strategy(parse_strategy(s)?);
    }
    let session = session;

    if opts.flag("json") {
        // Machine-readable RunReport; nothing else on stdout (the
        // store notice goes to stderr).
        let report = session.run_testbed();
        persist_search(opts, &session, seed, &report)?;
        println!("{}", report.to_json().dump());
        return Ok(());
    }

    let scenario = session.scenario();
    println!(
        "AE-LLM search: model={} task={} platform={} strategy={} \
         (|C| grid = {})",
        scenario.model.name,
        scenario.task.name,
        scenario.testbed.platform.name,
        session.params_ref().strategy.name(),
        ae_llm::config::enumerate::grid_size(),
    );
    let report = session.run_testbed_observed(&mut FnObserver(
        |e: &IterationEvent| {
            println!(
                "  [refine {}/{}] front {} | hv {:.2} | {} testbed + {} \
                 surrogate evals",
                e.iteration, e.total_iterations, e.front_size,
                e.hypervolume, e.testbed_evals, e.surrogate_evals
            );
        },
    ));
    persist_search(opts, &session, seed, &report)?;
    let out = &report.outcome;
    println!(
        "search done in {:.2}s: {} testbed evals, {} surrogate evals\n",
        report.wall_ms / 1e3,
        out.testbed_evals,
        out.surrogate_evals
    );

    // Pareto front, sorted by latency.
    let mut entries: Vec<_> = out.pareto.entries().to_vec();
    entries.sort_by(|a, b| {
        a.objectives.latency_ms.partial_cmp(&b.objectives.latency_ms).unwrap()
    });
    let mut t = ae_llm::util::table::Table::new(&[
        "Configuration", "Acc", "Lat (ms)", "Mem (GB)", "En (J)", "Utility",
    ])
    .with_title("Pareto-optimal configurations P*");
    for e in &entries {
        t.row(&[
            e.config.signature(),
            format!("{:.1}", e.objectives.accuracy),
            format!("{:.1}", e.objectives.latency_ms),
            format!("{:.1}", e.objectives.memory_gb),
            format!("{:.2}", e.objectives.energy_j),
            format!("{:.3}",
                    utility(&e.objectives, &out.reference, &scenario.prefs)),
        ]);
    }
    println!("{}", t.render());
    println!("chosen c* = {}", out.chosen.signature());
    println!(
        "efficiency score {:.2} (accuracy {:.1} vs default {:.1})",
        out.chosen_efficiency_score,
        out.chosen_objectives.accuracy,
        out.reference.default.accuracy
    );
    Ok(())
}

fn cmd_table(opts: &Opts, budget: &Budget, seed: u64) -> anyhow::Result<()> {
    let id = opts.u64_or("id", 2)?;
    let t0 = std::time::Instant::now();
    let table = match id {
        2 => tables::table_2(budget, seed),
        3 => tables::table_3(budget, seed),
        4 => tables::table_4(budget, seed),
        5 => tables::table_5(),
        6 => tables::table_6(budget, seed),
        7 => tables::table_strategies(budget, seed),
        8 => tables::table_serving(budget, seed),
        9 => tables::table_adaptation(budget, seed),
        10 => tables::table_cluster(budget, seed),
        other => anyhow::bail!(
            "no table {other} (paper has 2-6; 7 = strategy comparison, \
             8 = adaptive vs static serving, 9 = continual adaptation \
             vs one-shot, 10 = cluster-scale serving)"
        ),
    };
    println!("{}", table.render());
    println!("(regenerated in {:.1}s)", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_figure(opts: &Opts, budget: &Budget, seed: u64) -> anyhow::Result<()> {
    let id = opts.u64_or("id", 1)?;
    let out_dir = PathBuf::from(opts.get("out").unwrap_or("reports"));
    let t0 = std::time::Instant::now();
    let fig = match id {
        1 => figures::figure_1(budget, seed),
        2 => figures::figure_2(budget, seed),
        3 => figures::figure_3(budget, seed),
        4 => figures::figure_4(budget, seed),
        other => anyhow::bail!("no figure {other} (paper has 1-4)"),
    };
    println!("{}", fig.summary);
    for path in fig.write_csvs(&out_dir)? {
        println!("wrote {path}");
    }
    println!("(regenerated in {:.1}s)", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Hardware-in-the-loop Algorithm 1: surrogates + NSGA-II as usual, but
/// line-5 measurements come from real PJRT executions of the AOT
/// artifacts (latency ratios + numeric fidelity), then the chosen
/// configuration is deployed on the batched server.
fn cmd_e2e(opts: &Opts, seed: u64) -> anyhow::Result<()> {
    let repeats = opts.u64_or("repeats", 5)? as usize;
    let dir = runtime::artifacts_dir();
    println!("== loading artifacts from {dir:?} ==");
    let mut engine = runtime::Engine::new(&dir)?;
    let names = engine.load_all()?;
    println!("compiled {} variants on {}", names.len(), engine.platform());

    println!("== measuring variants ({repeats} repeats) ==");
    let table = runtime::measure_all(&mut engine, 1, repeats)?;
    let mut mt = ae_llm::util::table::Table::new(&[
        "Variant", "Wall (ms)", "CV", "Fidelity err", "Weight bytes",
    ])
    .with_title("PJRT variant measurements");
    for row in table.rows.values() {
        mt.row(&[
            row.name.clone(),
            format!("{:.2}", row.wall_ms),
            format!("{:.3}", row.wall_cv),
            format!("{:.4}", row.fidelity_err),
            row.weight_bytes.to_string(),
        ]);
    }
    println!("{}", mt.render());

    let scenario = Scenario::for_model("LLaMA-2-7B").unwrap();
    // The measured evaluator is deterministic, so memoizing repeat
    // configurations (revisited candidates, the Default fallback) is
    // lossless and saves real hardware executions.
    let mut evaluator = CachingEvaluator::new(runtime::MeasuredEvaluator::new(
        table, scenario.testbed.clone()));
    println!("== Algorithm 1 with PJRT-measured evaluation ==");
    let mut params = ae_llm::coordinator::AeLlmParams::small();
    params.initial_sample = 160;
    let report = AeLlm::from_scenario(scenario.clone())
        .params(params)
        .seed(seed)
        .run_observed(
            &mut evaluator,
            &mut FnObserver(|e: &IterationEvent| {
                println!(
                    "  [refine {}/{}] front {} | hv {:.2} | {} measured \
                     evals",
                    e.iteration, e.total_iterations, e.front_size,
                    e.hypervolume, e.testbed_evals
                );
            }),
        );
    let out = &report.outcome;
    println!(
        "done in {:.2}s: {} evals ({} unique measurements, {} cache hits), \
         chosen {}",
        report.wall_ms / 1e3,
        out.testbed_evals,
        evaluator.misses(),
        evaluator.hits(),
        out.chosen.signature()
    );
    println!(
        "efficiency score {:.2}, accuracy {:.1} vs default {:.1}",
        out.chosen_efficiency_score,
        out.chosen_objectives.accuracy,
        out.reference.default.accuracy
    );

    // Deploy the chosen configuration's serve variant.
    let serve_variant = if matches!(out.chosen.inf.precision,
                                    ae_llm::config::Precision::Fp16
                                    | ae_llm::config::Precision::Fp8) {
        "serve_gqa_fp16"
    } else {
        "serve_gqa_int8"
    };
    cmd_serve_inner(&mut engine, serve_variant, 64, seed)
}

/// `serve` has two modes: with `--variant` it is the legacy live-PJRT
/// path (needs artifacts); otherwise it runs the artifact-free
/// simulated fleet — search, deploy from the Pareto front, and serve a
/// workload scenario on virtual time (deterministic per seed).
fn cmd_serve(opts: &Opts, seed: u64) -> anyhow::Result<()> {
    if let Some(variant) = opts.get("variant") {
        let n = opts.u64_or("requests", 64)? as usize;
        let variant = variant.to_string();
        let dir = runtime::artifacts_dir();
        let mut engine = runtime::Engine::new(&dir)?;
        return cmd_serve_inner(&mut engine, &variant, n, seed);
    }
    cmd_serve_simulated(opts, seed)
}

fn cmd_serve_simulated(opts: &Opts, seed: u64) -> anyhow::Result<()> {
    use ae_llm::runtime::workload::default_rate_rps;
    use ae_llm::runtime::Workload;

    let model = opts.get("model").unwrap_or("LLaMA-2-7B");
    let kind = parse_scenario(opts.get("scenario").unwrap_or("steady"))?;
    let n = opts.u64_or("requests", 800)? as usize;
    let par = parse_parallelism(opts.get("par").unwrap_or("auto"))?;

    let mut session = AeLlm::for_model(model)?
        .params(Budget { quick: opts.flag("quick") }.ae_params())
        .seed(seed);
    if let Some(s) = opts.get("strategy") {
        session = session.strategy(parse_strategy(s)?);
    }
    eprintln!(
        "== serve: searching ({}, strategy {}) then deploying ==",
        model, session.params_ref().strategy.name()
    );
    // Lean outcome-only run (no observer stream / per-iteration
    // hypervolume): serving only needs the front and the reference.
    let outcome = session.run_testbed_outcome();
    let deployment = session.deploy(&outcome)?;
    let rate = default_rate_rps(outcome.reference.default.latency_ms);
    let workload = Workload::new(kind, rate, n, seed);
    let requests = workload.generate();
    let deploy_report = deployment.serve(&requests, kind.name(), seed, par);

    if let Some(path) = opts.get("json") {
        std::fs::write(path, deploy_report.to_json().dump())?;
        println!("wrote {path}");
        return Ok(());
    }

    println!(
        "fleet of {} slots ({} distinct configs) serving {} `{}` \
         requests at {:.1} req/s",
        deployment.slots().len(),
        deployment.distinct_configs(),
        n,
        kind.name(),
        rate
    );
    let mut t = ae_llm::util::table::Table::new(&[
        "Slot", "Config", "Batch x Seq", "Deadline (ms)", "Done",
        "p95 (ms)", "Viol (%)",
    ])
    .with_title("Per-class serving slots");
    for (slot, (label, rep)) in
        deployment.slots().iter().zip(&deploy_report.per_slot)
    {
        t.row(&[
            label.clone(),
            slot.config.signature(),
            format!("{} x {}", slot.batch, slot.seq),
            format!("{:.0}", slot.deadline_ms),
            rep.completed.to_string(),
            format!("{:.1}", rep.p95_latency_ms),
            format!("{:.1}", rep.slo_violation_rate * 100.0),
        ]);
    }
    println!("{}", t.render());
    let o = &deploy_report.overall;
    println!(
        "overall: {} completed in {} batches | p50 {:.1} ms p95 {:.1} ms \
         | {:.1} req/s | {:.0} tok/s | SLO violations {:.1}% | energy \
         {:.1} J",
        o.completed, o.batches, o.p50_latency_ms, o.p95_latency_ms,
        o.throughput_rps, o.tokens_per_s, o.slo_violation_rate * 100.0,
        o.energy_j
    );
    Ok(())
}

/// Continual adaptation (DESIGN.md §12): search once, then serve a
/// (possibly drifting) workload in epochs — re-searching warm-started
/// from the persistent front and hot-swapping the fleet whenever the
/// drift detector fires.  `--one-shot` freezes the epoch-0 deployment
/// for comparison; `--json` dumps the deterministic `AdaptReport`.
fn cmd_adapt(opts: &Opts, seed: u64) -> anyhow::Result<()> {
    use ae_llm::coordinator::AdaptParams;

    let model = opts.get("model").unwrap_or("LLaMA-2-7B");
    let kind =
        parse_scenario(opts.get("scenario").unwrap_or("regime_shift"))?;
    let mut session = AeLlm::for_model(model)?
        .params(Budget { quick: opts.flag("quick") }.ae_params())
        .seed(seed);
    if let Some(s) = opts.get("strategy") {
        session = session.strategy(parse_strategy(s)?);
    }
    let defaults = AdaptParams::default();
    let params = AdaptParams {
        epochs: opts.u64_or("epochs", defaults.epochs as u64)? as usize,
        requests_per_epoch: opts
            .u64_or("requests", defaults.requests_per_epoch as u64)?
            as usize,
        adaptive: !opts.flag("one-shot"),
        ..defaults
    };

    eprintln!(
        "== adapt: {} serving `{}` for {} epochs x {} requests ({}) ==",
        model, kind.name(), params.epochs, params.requests_per_epoch,
        if params.adaptive { "continual" } else { "one-shot" }
    );
    let report = match resolve_store(opts) {
        Some(root) => {
            let mut store = ae_llm::store::Store::open(&root)?;
            eprintln!(
                "artifact store {} ({} catalog entries): warm-seeding \
                 the search and persisting each epoch's front",
                root.display(), store.ls().len()
            );
            session.adapt_stored(kind, &params, &mut store)?
        }
        None => session.adapt(kind, &params)?,
    };

    if let Some(path) = opts.get("json") {
        std::fs::write(path, report.to_json().dump())?;
        println!("wrote {path}");
        return Ok(());
    }

    let mut t = ae_llm::util::table::Table::new(&[
        "Epoch", "Req", "Long (%)", "Rate (req/s)", "p95 (ms)",
        "Viol (%)", "Drift", "Action",
    ])
    .with_title("Continual adaptation epochs");
    for e in &report.epochs {
        t.row(&[
            e.epoch.to_string(),
            e.telemetry.requests.to_string(),
            format!("{:.0}", e.telemetry.class_share[2] * 100.0),
            format!("{:.1}", e.telemetry.rate_rps),
            format!("{:.1}", e.report.p95_latency_ms),
            format!("{:.1}", e.report.slo_violation_rate * 100.0),
            format!("{:.2}{}", e.drift_score,
                    if e.drifted { " !" } else { "" }),
            if e.redeployed { "re-search + swap" } else { "-" }
                .to_string(),
        ]);
    }
    println!("{}", t.render());
    let o = &report.overall;
    println!(
        "{}: {} searches, {} redeployments | overall SLO violations \
         {:.1}% | p95 {:.1} ms | energy {:.1} J | front {}",
        report.mode, report.searches, report.redeployments,
        o.slo_violation_rate * 100.0, o.p95_latency_ms, o.energy_j,
        report.final_front.len()
    );
    Ok(())
}

/// Cluster-scale serving (DESIGN.md §13): search once, deploy the
/// front onto N fleet nodes behind the seeded least-loaded router, and
/// serve the workload on the event core.  `--json` dumps the
/// deterministic `ClusterReport` (schema `ae-llm.cluster-report/v1`).
fn cmd_cluster(opts: &Opts, seed: u64) -> anyhow::Result<()> {
    use ae_llm::runtime::workload::default_rate_rps;
    use ae_llm::runtime::{ClusterParams, Workload};

    let model = opts.get("model").unwrap_or("LLaMA-2-7B");
    let kind = parse_scenario(opts.get("scenario").unwrap_or("steady"))?;
    let n = opts.u64_or("requests", 4000)? as usize;
    let par = parse_parallelism(opts.get("par").unwrap_or("auto"))?;
    let defaults = ClusterParams::default();
    let params = ClusterParams {
        nodes: opts.u64_or("nodes", defaults.nodes as u64)? as usize,
        capacity: opts.u64_or("capacity", defaults.capacity as u64)?
            as usize,
        epochs: opts.u64_or("epochs", defaults.epochs as u64)? as usize,
        ..defaults
    };

    let mut session = AeLlm::for_model(model)?
        .params(Budget { quick: opts.flag("quick") }.ae_params())
        .seed(seed)
        .parallelism(par);
    if let Some(s) = opts.get("strategy") {
        session = session.strategy(parse_strategy(s)?);
    }
    eprintln!(
        "== cluster: searching ({}, strategy {}) then deploying {} \
         nodes ==",
        model, session.params_ref().strategy.name(), params.nodes
    );
    let outcome = session.run_testbed_outcome();
    // Offered load scales with the fleet: rate per node x nodes.
    let rate = params.nodes as f64
        * default_rate_rps(outcome.reference.default.latency_ms);
    let requests = Workload::new(kind, rate, n, seed).generate();
    let report =
        session.cluster(&outcome, params)?.serve(&requests, kind.name());

    if let Some(path) = opts.get("json") {
        std::fs::write(path, report.to_json().dump())?;
        println!("wrote {path}");
        return Ok(());
    }

    println!(
        "cluster of {} nodes (capacity {} pending each) serving {} `{}` \
         requests at {:.1} req/s over {} epochs",
        report.nodes, report.capacity, n, kind.name(), rate, report.epochs
    );
    let mut t = ae_llm::util::table::Table::new(&[
        "Node", "Routed", "Done", "p50 (ms)", "p95 (ms)", "Viol (%)",
        "Energy (J)",
    ])
    .with_title("Per-node serving");
    for (i, (rep, &routed)) in
        report.per_node.iter().zip(&report.routed).enumerate()
    {
        t.row(&[
            i.to_string(),
            routed.to_string(),
            rep.completed.to_string(),
            format!("{:.1}", rep.p50_latency_ms),
            format!("{:.1}", rep.p95_latency_ms),
            format!("{:.1}", rep.slo_violation_rate * 100.0),
            format!("{:.1}", rep.energy_j),
        ]);
    }
    println!("{}", t.render());
    let o = &report.overall;
    println!(
        "overall: {} completed in {} batches | p50 {:.1} ms p95 {:.1} ms \
         | {:.1} req/s | SLO violations {:.1}% | energy {:.1} J",
        o.completed, o.batches, o.p50_latency_ms, o.p95_latency_ms,
        o.throughput_rps, o.slo_violation_rate * 100.0, o.energy_j
    );
    Ok(())
}

fn cmd_serve_inner(engine: &mut runtime::Engine, variant: &str, n: usize,
                   seed: u64) -> anyhow::Result<()> {
    println!("== batched serving on {variant} ({n} requests) ==");
    engine.load(variant)?;
    let mut server = runtime::Server::new(engine, variant)?;
    let mut rng = Rng::new(seed);
    let seq = engine.manifest.get(variant).unwrap().seq as usize;
    for id in 0..n as u64 {
        let len = 8 + rng.below(seq - 8);
        let tokens: Vec<i32> =
            (0..len).map(|_| rng.below(256) as i32).collect();
        server.submit(runtime::Request::new(id, tokens));
    }
    server.drain()?;
    let r = server.report();
    println!(
        "completed {} requests in {} batches\n  p50 latency {:.1} ms | p95 \
         {:.1} ms | batch exec {:.1} ms\n  throughput {:.1} req/s | {:.0} \
         tok/s",
        r.completed, r.batches, r.p50_latency_ms, r.p95_latency_ms,
        r.mean_batch_exec_ms, r.throughput_rps, r.tokens_per_s
    );
    Ok(())
}

fn cmd_check() -> anyhow::Result<()> {
    let dir = runtime::artifacts_dir();
    let mut engine = runtime::Engine::new(&dir)?;
    let names = engine.load_all()?;
    println!("platform {}", engine.platform());
    for name in &names {
        let tokens = engine.make_tokens(name, 0)?;
        let f = engine.forward(name, &tokens)?;
        let finite = f.logits.iter().all(|x| x.is_finite());
        let nonzero = f.logits.iter().any(|x| *x != 0.0);
        anyhow::ensure!(finite && nonzero,
                        "{name}: degenerate logits (finite={finite})");
        println!("  {name:<22} ok  ({:.2} ms, {} logits)", f.wall_ms,
                 f.logits.len());
    }
    println!("all {} variants execute correctly", names.len());
    Ok(())
}

fn cmd_space() -> anyhow::Result<()> {
    use ae_llm::config::enumerate;
    println!("configuration-space inventory");
    println!("  grid size (unconstrained) : {}", enumerate::grid_size());
    println!("  valid configurations      : {}", enumerate::all_valid().len());
    println!("  models in zoo             : {}",
             ae_llm::models::zoo().len());
    println!("  VLMs                      : {}",
             ae_llm::models::vlm_zoo().len());
    println!("  tasks                     : {} + {} VLM",
             ae_llm::tasks::suite().len(),
             ae_llm::tasks::vlm_suite().len());
    println!("  platforms                 : {}",
             ae_llm::hardware::platforms().len());
    let d = ae_llm::config::Config::default_baseline();
    println!("  default baseline          : {}", d.signature());
    Ok(())
}

/// Resolve the artifact store root for a command: an explicit
/// `--store DIR` wins, falling back to the `AE_LLM_STORE` environment
/// variable.  `None` means persistence is off.
fn resolve_store(opts: &Opts) -> Option<std::path::PathBuf> {
    opts.get("store")
        .map(std::path::PathBuf::from)
        .or_else(|| std::env::var_os("AE_LLM_STORE")
            .map(std::path::PathBuf::from))
}

/// Persist a finished search into the artifact store, if one is
/// configured: the Pareto front (warm-start seed for later runs) and
/// the full run report.  Status goes to stderr so `--json` stdout
/// stays pure.
fn persist_search(opts: &Opts, session: &AeLlm, seed: u64,
                  report: &ae_llm::coordinator::RunReport)
                  -> anyhow::Result<()> {
    let Some(root) = resolve_store(opts) else { return Ok(()) };
    let mut store = ae_llm::store::Store::open(&root)?;
    let key = session.store_key("-");
    let front = store.put_front(&key, seed, &report.outcome.pareto)?;
    let run = store.put_run_report(&key, report)?;
    eprintln!("stored front {} + run report {} under {}",
              &front[..12], &run[..12], root.display());
    Ok(())
}

/// `store ls|gc|verify`: inspect and maintain the content-addressed
/// artifact store (DESIGN.md §14).  The action is positional, so this
/// parses its own tail instead of going through the generic table in
/// [`run`].
fn cmd_store(args: &[String]) -> anyhow::Result<()> {
    const ACTIONS: [&str; 3] = ["ls", "gc", "verify"];
    let Some(action) = args.first() else {
        anyhow::bail!(
            "`store` needs an action: ae-llm store ls|gc|verify \
             [--store DIR]"
        );
    };
    anyhow::ensure!(
        ACTIONS.contains(&action.as_str()),
        "{}",
        unknown_value_msg("store action", action, &ACTIONS)
    );
    let opts = Opts::parse("store", &["store"], &[], &args[1..])?;
    let Some(root) = resolve_store(&opts) else {
        anyhow::bail!(
            "no store configured: pass --store DIR or set AE_LLM_STORE"
        );
    };
    let mut store = ae_llm::store::Store::open(&root)?;
    match action.as_str() {
        "ls" => {
            let mut t = ae_llm::util::table::Table::new(&[
                "Seq", "Kind", "Model", "Task", "Platform", "Scenario",
                "Seed", "Front", "Hash",
            ])
            .with_title("Artifact store catalog");
            for e in store.ls() {
                t.row(&[
                    e.seq.to_string(),
                    e.kind.name().to_string(),
                    e.key.model.clone(),
                    e.key.task.clone(),
                    e.key.platform.clone(),
                    e.key.scenario.clone(),
                    e.seed.to_string(),
                    e.front_size.to_string(),
                    e.hash[..12].to_string(),
                ]);
            }
            println!("{}", t.render());
            println!("{} catalog entries, {} blobs on disk at {}",
                     store.ls().len(), store.blobs().list()?.len(),
                     root.display());
        }
        "gc" => {
            let report = store.gc()?;
            for h in &report.removed {
                println!("removed unreferenced blob {h}");
            }
            println!("gc done: kept {} referenced blob(s), removed {}",
                     report.kept, report.removed.len());
        }
        "verify" => {
            let report = store.verify()?;
            if report.ok() {
                println!("store ok: {} blob(s) verified at {}",
                         report.checked, root.display());
            } else {
                for p in &report.problems {
                    eprintln!("problem: {p}");
                }
                anyhow::bail!("store verify failed: {} problem(s) in {}",
                              report.problems.len(), root.display());
            }
        }
        _ => unreachable!("action validated above"),
    }
    Ok(())
}

fn print_help() {
    println!(
        "AE-LLM: Adaptive Efficiency Optimization for LLMs\n\n\
         USAGE: ae-llm <command> [options]\n\n\
         COMMANDS:\n  \
         search  --model M [--task T] [--platform P] [--prefs W]\n  \
         \x20       [--strategy S] [--quick] [--seed N] [--json]\n  \
         \x20       [--store DIR]\n  \
         \x20       (--json emits the RunReport; --store persists the\n  \
         \x20        front + report into the artifact store)\n  \
         table   --id 2|3|4|5|6|7|8|9|10 [--quick] [--seed N]\n  \
         \x20       (7 = strategies, 8 = adaptive vs static serving,\n  \
         \x20        9 = continual adaptation vs one-shot,\n  \
         \x20        10 = cluster-scale serving)\n  \
         figure  --id 1|2|3|4 [--quick] [--seed N] [--out DIR]\n  \
         e2e     [--repeats N] [--seed N]   hardware-in-the-loop + serving\n  \
         serve   [--model M] [--scenario S] [--strategy S] [--requests N]\n  \
         \x20       [--par N|auto|seq] [--quick] [--seed N] [--json OUT.json]\n  \
         \x20       (simulated fleet; --variant V switches to live PJRT)\n  \
         adapt   [--model M] [--scenario S] [--strategy S] [--epochs N]\n  \
         \x20       [--requests N/epoch] [--one-shot] [--quick] [--seed N]\n  \
         \x20       [--json OUT.json] [--store DIR]\n  \
         \x20       (continual adaptation: epoch serving, drift-triggered\n  \
         \x20        warm re-search, fleet hot-swap; --store warm-seeds\n  \
         \x20        from the catalog and persists each epoch's front)\n  \
         cluster [--model M] [--scenario S] [--strategy S] [--requests N]\n  \
         \x20       [--nodes N] [--capacity N] [--epochs N]\n  \
         \x20       [--par N|auto|seq] [--quick] [--seed N] [--json OUT.json]\n  \
         \x20       (N fleet nodes behind a seeded least-loaded router, on\n  \
         \x20        the sharded discrete-event core; --par only changes\n  \
         \x20        wall-clock time, never the report bytes)\n  \
         store   ls|gc|verify [--store DIR]\n  \
         \x20       (content-addressed artifact store: list the catalog,\n  \
         \x20        sweep unreferenced blobs, verify blob integrity;\n  \
         \x20        DIR defaults to $AE_LLM_STORE)\n  \
         check   load + execute every AOT artifact\n  \
         space   print the configuration-space inventory\n\n\
         prefs: balanced | latency | memory | accuracy | green\n\
         strategies: nsga2 | random | racing | local\n\
         scenarios: steady | diurnal | bursty | heavytail (stationary)\n\
         \x20          regime_shift | ramp (drifting, for `adapt`)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_key_values_and_flags() {
        let o = Opts::parse(
            "search",
            &["model", "seed"],
            &["quick"],
            &args(&["--model", "Phi-2", "--quick", "--seed", "7"]),
        )
        .unwrap();
        assert_eq!(o.get("model"), Some("Phi-2"));
        assert!(o.flag("quick"));
        assert_eq!(o.u64_or("seed", 42).unwrap(), 7);
        assert_eq!(o.u64_or("missing", 42).unwrap(), 42);
        assert!(!o.flag("json"));
    }

    #[test]
    fn unknown_key_rejected_with_suggestion() {
        let err = Opts::parse("search", &["model", "task"], &[],
                              &args(&["--modle", "X"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown option --modle"), "{err}");
        assert!(err.contains("did you mean --model?"), "{err}");
        assert!(err.contains("--task"), "{err}");
    }

    #[test]
    fn unknown_key_without_near_match_lists_allowed() {
        let err = Opts::parse("table", &["id", "seed"], &["quick"],
                              &args(&["--zzzzzz"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown option --zzzzzz for `table`"), "{err}");
        assert!(!err.contains("did you mean"), "{err}");
        assert!(err.contains("allowed: --id --seed --quick"), "{err}");
    }

    #[test]
    fn optionless_command_rejects_options() {
        let err = Opts::parse("space", &[], &[], &args(&["--verbose"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("takes no options"), "{err}");
    }

    #[test]
    fn positional_arguments_rejected() {
        let err = Opts::parse("search", &["model"], &[],
                              &args(&["model", "X"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unexpected argument"), "{err}");
    }

    #[test]
    fn flags_never_swallow_a_value() {
        // `--json report.json`: the stray token is an error, not a
        // silently ignored value that flips the flag off.
        let err = Opts::parse("search", &["model"], &["json"],
                              &args(&["--json", "report.json"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unexpected argument \"report.json\""), "{err}");
    }

    #[test]
    fn valued_options_require_a_value() {
        for tail in [vec!["--model"], vec!["--model", "--quick"]] {
            let err = Opts::parse("search", &["model"], &["quick"],
                                  &args(&tail))
                .unwrap_err()
                .to_string();
            assert!(err.contains("--model expects a value"), "{err}");
        }
    }

    #[test]
    fn numbers_validated() {
        let o = Opts::parse("table", &["id"], &[], &args(&["--id", "two"]))
            .unwrap();
        assert!(o.u64_or("id", 2).is_err());
    }

    #[test]
    fn edit_distance_sanity() {
        assert_eq!(edit_distance("model", "model"), 0);
        assert_eq!(edit_distance("modle", "model"), 2); // transposition
        assert_eq!(edit_distance("sed", "seed"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(closest("sed", &["seed", "model"]), Some("seed"));
        assert_eq!(closest("zzzzzz", &["seed", "model"]), None);
    }

    #[test]
    fn run_rejects_unknown_command_and_typod_flag() {
        assert!(run(&args(&["flyme"])).is_err());
        let err = run(&args(&["search", "--modle", "X"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--modle"), "{err}");
        // `--seed` is accepted by search (and listed in its help).
        let err = run(&args(&["search", "--seed", "abc"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--seed expects a number"), "{err}");
    }

    #[test]
    fn serve_rejects_unknown_scenario_before_searching() {
        let err = run(&args(&["serve", "--scenario", "nope"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("nope"), "{err}");
        assert!(err.contains("bursty"), "{err}");
        // drifting scenarios are listed as valid names too
        assert!(err.contains("regime_shift") && err.contains("ramp"),
                "{err}");
    }

    #[test]
    fn scenario_and_strategy_values_get_did_you_mean() {
        // typo'd scenario value: nearest-match suggestion + full list
        let err = run(&args(&["serve", "--scenario", "bursy"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean bursty?"), "{err}");
        let err = run(&args(&["adapt", "--scenario", "regime_shif"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean regime_shift?"), "{err}");
        assert!(err.contains("steady"), "{err}");
        // typo'd strategy value, on serve and adapt alike
        let err = run(&args(&["serve", "--strategy", "racng"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean racing?"), "{err}");
        let err = run(&args(&["adapt", "--strategy", "nsga3"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean nsga2?"), "{err}");
        assert!(err.contains("local"), "{err}");
    }

    #[test]
    fn adapt_parses_its_options_and_rejects_typos() {
        let err = run(&args(&["adapt", "--epoch", "3"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean --epochs?"), "{err}");
        let err = run(&args(&["adapt", "--epochs", "three"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--epochs expects a number"), "{err}");
        // `--one-shot` is a flag, never swallows a value
        let err = run(&args(&["adapt", "--one-shot", "yes"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("unexpected argument \"yes\""), "{err}");
    }

    #[test]
    fn cluster_parses_its_options_and_rejects_typos() {
        let err = run(&args(&["cluster", "--node", "4"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean --nodes?"), "{err}");
        let err = run(&args(&["cluster", "--nodes", "four"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--nodes expects a number"), "{err}");
        let err = run(&args(&["cluster", "--scenario", "bursy"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean bursty?"), "{err}");
        // `--par` is recognised (typo'd key gets the suggestion)
        let err = run(&args(&["cluster", "--pra", "4"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean --par?"), "{err}");
    }

    #[test]
    fn par_values_parse_and_reject_with_did_you_mean() {
        use ae_llm::util::Parallelism;
        assert_eq!(parse_parallelism("auto").unwrap(), Parallelism::Auto);
        assert_eq!(parse_parallelism("seq").unwrap(),
                   Parallelism::Sequential);
        assert_eq!(parse_parallelism("sequential").unwrap(),
                   Parallelism::Sequential);
        assert_eq!(parse_parallelism("4").unwrap(),
                   Parallelism::Threads(4));
        // zero threads is nonsense, not Sequential-by-accident
        let err = parse_parallelism("0").unwrap_err().to_string();
        assert!(err.contains("positive thread count"), "{err}");
        // typo'd keyword: nearest-match suggestion + thread-count hint
        let err = parse_parallelism("ato").unwrap_err().to_string();
        assert!(err.contains("did you mean auto?"), "{err}");
        assert!(err.contains("--par 4"), "{err}");
        // the shared helper is wired into both subcommands
        let err = run(&args(&["cluster", "--par", "sqe"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean seq?"), "{err}");
        let err = run(&args(&["serve", "--par", "0"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("positive thread count"), "{err}");
    }

    #[test]
    fn commands_get_did_you_mean() {
        let err = run(&args(&["stor"])).unwrap_err().to_string();
        assert!(err.contains("unknown command \"stor\""), "{err}");
        assert!(err.contains("did you mean `store`?"), "{err}");
        let err = run(&args(&["serch"])).unwrap_err().to_string();
        assert!(err.contains("did you mean `search`?"), "{err}");
        // no near match: no suggestion, still points at `help`
        let err = run(&args(&["flyme"])).unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
        assert!(err.contains("help"), "{err}");
    }

    #[test]
    fn store_actions_get_did_you_mean() {
        // missing action: usage line
        let err = run(&args(&["store"])).unwrap_err().to_string();
        assert!(err.contains("ls|gc|verify"), "{err}");
        // typo'd action: nearest-match suggestion + full list
        let err = run(&args(&["store", "lss"])).unwrap_err().to_string();
        assert!(err.contains("unknown store action \"lss\""), "{err}");
        assert!(err.contains("did you mean ls?"), "{err}");
        assert!(err.contains("verify"), "{err}");
        let err = run(&args(&["store", "verfy"])).unwrap_err().to_string();
        assert!(err.contains("did you mean verify?"), "{err}");
        // typo'd option key after a valid action
        let err = run(&args(&["store", "ls", "--stroe", "/tmp/x"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean --store?"), "{err}");
    }

    #[test]
    fn store_without_a_root_is_a_clear_error() {
        if std::env::var_os("AE_LLM_STORE").is_some() {
            return; // the environment provides a root; nothing to assert
        }
        let err = run(&args(&["store", "ls"])).unwrap_err().to_string();
        assert!(err.contains("AE_LLM_STORE"), "{err}");
    }

    #[test]
    fn store_maintenance_works_on_an_empty_store() {
        let dir = std::env::temp_dir().join(format!(
            "ae-llm-cli-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let root = dir.to_string_lossy().to_string();
        run(&args(&["store", "ls", "--store", root.as_str()])).unwrap();
        run(&args(&["store", "verify", "--store", root.as_str()]))
            .unwrap();
        run(&args(&["store", "gc", "--store", root.as_str()])).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_strategy_value_rejected_with_choices() {
        let err = run(&args(&["search", "--strategy", "nsga3"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("nsga3"), "{err}");
        assert!(err.contains("racing"), "{err}");
        // and the option key itself gets the did-you-mean machinery
        let err = run(&args(&["search", "--stratgy", "racing"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("did you mean --strategy?"), "{err}");
    }
}
