//! Deployment scenarios: the (model, task, hardware, preferences)
//! tuples Definition 4 optimizes over, plus the space-restriction mask
//! used by the Table 3 configuration-space ablations.

use crate::config::{Config, FtConfig, MoE, Precision};
use crate::hardware::Platform;
use crate::metrics::Preferences;
use crate::models::{self, ModelSpec};
use crate::oracle::Testbed;
use crate::tasks::{self, TaskSpec};

use super::session::AeLlmError;

/// One deployment scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub model: ModelSpec,
    pub task: TaskSpec,
    pub testbed: Testbed,
    pub prefs: Preferences,
}

impl Scenario {
    /// Paper-default scenario for a model: its scale-tier platform and
    /// the blended task mix (what Table 2 aggregates).
    pub fn for_model(name: &str) -> Result<Scenario, AeLlmError> {
        let model = models::by_name(name)
            .ok_or_else(|| AeLlmError::UnknownModel(name.to_string()))?;
        Ok(Scenario {
            testbed: Testbed::for_model(&model),
            model,
            task: tasks::blended_task(),
            prefs: Preferences::default(),
        })
    }

    pub fn with_task(mut self, task_name: &str)
                     -> Result<Scenario, AeLlmError> {
        self.task = tasks::by_name(task_name)
            .ok_or_else(|| AeLlmError::UnknownTask(task_name.to_string()))?;
        Ok(self)
    }

    pub fn with_platform(mut self, platform: Platform) -> Scenario {
        let noise = self.testbed.noise_sigma;
        self.testbed = Testbed::new(platform);
        self.testbed.noise_sigma = noise;
        self
    }

    pub fn with_prefs(mut self, prefs: Preferences) -> Scenario {
        self.prefs = prefs;
        self
    }

    pub fn noiseless(mut self) -> Scenario {
        self.testbed.noise_sigma = 0.0;
        self.testbed.acc_noise = 0.0;
        self
    }
}

/// Search-space restriction mask (Table 3 "Configuration Space
/// Components" ablations).  A disabled stage is clamped to the Default
/// configuration's value before evaluation, so the search effectively
/// runs in the restricted space.
#[derive(Clone, Copy, Debug)]
pub struct SpaceMask {
    pub arch: bool,
    pub ft: bool,
    pub inf: bool,
    /// finer-grained cuts inside the architecture / inference stages
    pub allow_moe: bool,
    pub allow_quant: bool,
}

impl Default for SpaceMask {
    fn default() -> Self {
        SpaceMask { arch: true, ft: true, inf: true, allow_moe: true,
                    allow_quant: true }
    }
}

impl SpaceMask {
    pub fn without_arch() -> Self {
        SpaceMask { arch: false, ..Default::default() }
    }

    pub fn without_ft() -> Self {
        SpaceMask { ft: false, ..Default::default() }
    }

    pub fn without_inf() -> Self {
        SpaceMask { inf: false, ..Default::default() }
    }

    pub fn without_moe() -> Self {
        SpaceMask { allow_moe: false, ..Default::default() }
    }

    pub fn without_quant() -> Self {
        SpaceMask { allow_quant: false, ..Default::default() }
    }

    /// Clamp a configuration into the masked space.
    pub fn clamp(&self, mut c: Config) -> Config {
        let d = Config::default_baseline();
        if !self.arch {
            c.arch = d.arch;
        }
        if !self.ft {
            c.ft = FtConfig::full();
        }
        if !self.inf {
            c.inf = d.inf;
            // Default inference = FP16 base, which invalidates QLoRA.
            if c.ft.method == crate::config::FtMethod::QLoRA {
                c.ft.method = crate::config::FtMethod::LoRA;
            }
        }
        if !self.allow_moe {
            c.arch.moe = MoE::Dense;
        }
        if !self.allow_quant {
            c.inf.precision = Precision::Fp16;
            // FP16 base invalidates QLoRA; fall back to LoRA.
            if c.ft.method == crate::config::FtMethod::QLoRA {
                c.ft.method = crate::config::FtMethod::LoRA;
            }
        }
        debug_assert!(crate::config::validity::is_valid(&c),
                      "mask produced invalid {c}");
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{enumerate, validity};
    use crate::util::Rng;

    #[test]
    fn scenario_builders() {
        let s = Scenario::for_model("Mistral-7B").unwrap();
        assert_eq!(s.model.name, "Mistral-7B");
        assert_eq!(s.testbed.platform.name, "A100-80GB");
        let s = s.with_task("GSM8K").unwrap();
        assert_eq!(s.task.name, "GSM8K");
        assert!(matches!(Scenario::for_model("GPT-5"),
                         Err(AeLlmError::UnknownModel(_))));
        assert!(matches!(
            Scenario::for_model("Phi-2").unwrap().with_task("nope"),
            Err(AeLlmError::UnknownTask(_))
        ));
    }

    #[test]
    fn noiseless_kills_noise() {
        let s = Scenario::for_model("Phi-2").unwrap().noiseless();
        assert_eq!(s.testbed.noise_sigma, 0.0);
        assert_eq!(s.testbed.acc_noise, 0.0);
    }

    #[test]
    fn default_mask_is_identity() {
        let mut rng = Rng::new(1);
        let mask = SpaceMask::default();
        for _ in 0..100 {
            let c = enumerate::sample(&mut rng);
            assert_eq!(mask.clamp(c), c);
        }
    }

    #[test]
    fn masks_clamp_their_stage_and_stay_valid() {
        let mut rng = Rng::new(2);
        let d = Config::default_baseline();
        for _ in 0..300 {
            let c = enumerate::sample(&mut rng);
            let a = SpaceMask::without_arch().clamp(c);
            assert_eq!(a.arch, d.arch);
            assert!(validity::is_valid(&a));
            let f = SpaceMask::without_ft().clamp(c);
            assert_eq!(f.ft, FtConfig::full());
            assert!(validity::is_valid(&f));
            let i = SpaceMask::without_inf().clamp(c);
            assert_eq!(i.inf, d.inf);
            assert!(validity::is_valid(&i));
            let m = SpaceMask::without_moe().clamp(c);
            assert_eq!(m.arch.moe, MoE::Dense);
            assert!(validity::is_valid(&m));
            let q = SpaceMask::without_quant().clamp(c);
            assert_eq!(q.inf.precision, Precision::Fp16);
            assert!(validity::is_valid(&q));
        }
    }
}
