//! Observer hooks for Algorithm 1 runs: per-refinement-iteration
//! progress events the CLI, benches and serving dashboards can stream
//! instead of waiting on a flat [`super::Outcome`].
//!
//! Observers are *passive*: they receive read-only snapshots after
//! each refinement iteration and must not (and cannot) perturb the
//! search — the events are computed from the measured archive without
//! touching the run's RNG, so an observed run is bit-identical to an
//! unobserved one.

/// Snapshot emitted after each refinement iteration (Algorithm 1
/// lines 3–6 completed once).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IterationEvent {
    /// 1-based refinement iteration index.
    pub iteration: usize,
    /// Total refinement iterations this run will perform.
    pub total_iterations: usize,
    /// Size of the measured Pareto archive after this iteration.
    pub front_size: usize,
    /// Normalized hypervolume of the measured front (each objective
    /// divided by the Default configuration's value; reference point
    /// [`super::algorithm1::HV_REF_FACTOR`]× the default in every
    /// minimized dimension).  Monitoring signal, not a paper metric.
    pub hypervolume: f64,
    /// Cumulative expensive (testbed / hardware) evaluations so far.
    pub testbed_evals: usize,
    /// Cumulative cheap surrogate predictions so far.
    pub surrogate_evals: usize,
}

/// Hook delivered per refinement iteration.  All methods have no-op
/// defaults, so implementors override only what they need.
pub trait RunObserver {
    fn on_iteration(&mut self, _event: &IterationEvent) {}

    /// Whether this observer consumes events at all.  When `false`
    /// (only [`NullObserver`] in-tree), the coordinator skips building
    /// the snapshot entirely — unobserved runs don't pay the exact 4-D
    /// hypervolume computation per iteration.
    fn enabled(&self) -> bool {
        true
    }
}

/// The do-nothing observer (the default for unobserved runs).
pub struct NullObserver;

impl RunObserver for NullObserver {
    fn enabled(&self) -> bool {
        false
    }
}

/// Collects every event; useful in tests and for post-run reporting.
#[derive(Debug, Default)]
pub struct CollectingObserver {
    pub events: Vec<IterationEvent>,
}

impl RunObserver for CollectingObserver {
    fn on_iteration(&mut self, event: &IterationEvent) {
        self.events.push(*event);
    }
}

/// Adapts a closure to [`RunObserver`] — the one-liner the CLI uses to
/// stream progress lines.
pub struct FnObserver<F: FnMut(&IterationEvent)>(pub F);

impl<F: FnMut(&IterationEvent)> RunObserver for FnObserver<F> {
    fn on_iteration(&mut self, event: &IterationEvent) {
        (self.0)(event)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(i: usize) -> IterationEvent {
        IterationEvent {
            iteration: i,
            total_iterations: 3,
            front_size: 4 + i,
            hypervolume: i as f64,
            testbed_evals: 100 * i,
            surrogate_evals: 1000 * i,
        }
    }

    #[test]
    fn collecting_observer_accumulates_in_order() {
        let mut obs = CollectingObserver::default();
        for i in 1..=3 {
            obs.on_iteration(&event(i));
        }
        assert_eq!(obs.events.len(), 3);
        assert_eq!(obs.events[0].iteration, 1);
        assert_eq!(obs.events[2].front_size, 7);
    }

    #[test]
    fn fn_observer_forwards() {
        let mut seen = Vec::new();
        {
            let mut obs = FnObserver(|e: &IterationEvent| {
                seen.push(e.iteration);
            });
            obs.on_iteration(&event(1));
            obs.on_iteration(&event(2));
        }
        assert_eq!(seen, vec![1, 2]);
    }

    #[test]
    fn null_observer_is_a_no_op() {
        NullObserver.on_iteration(&event(1));
    }
}
