//! Sensitivity analysis (§3.5 "we also provide sensitivity analysis",
//! Figure 4): one-dimensional sweeps of the key hyper-parameters with
//! per-task ranges.

use crate::config::{Config, FtConfig, FtMethod, MoE, Precision};
use crate::models::ModelSpec;
use crate::oracle::{accuracy, Testbed};
use crate::tasks::{suite, TaskSpec};

/// One sweep point: x value, accuracy stats across tasks, and the
/// efficiency metrics on the blended task.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub x: f64,
    pub label: String,
    /// accuracy delta (percentage points) vs default: mean/min/max
    /// across the task suite (the shaded region of Fig. 4)
    pub acc_mean: f64,
    pub acc_min: f64,
    pub acc_max: f64,
    pub latency_ms: f64,
    pub memory_gb: f64,
}

fn sweep_config(
    m: &ModelSpec,
    tb: &Testbed,
    blended: &TaskSpec,
    configs: Vec<(f64, String, Config)>,
) -> Vec<SweepPoint> {
    configs
        .into_iter()
        .map(|(x, label, c)| {
            let mut deltas: Vec<f64> = Vec::new();
            for t in suite() {
                let base = accuracy::default_score(m, &t);
                let s = accuracy::score(&c, m, &t);
                // normalize to percentage points of a 100-scale
                let scale = if t.unit == "/10" { 10.0 } else { 1.0 };
                deltas.push((s - base) * scale);
            }
            let o = tb.true_objectives(&c, m, blended);
            let (lo, hi) = crate::util::stats::min_max(&deltas);
            SweepPoint {
                x,
                label,
                acc_mean: crate::util::stats::mean(&deltas),
                acc_min: lo,
                acc_max: hi,
                latency_ms: o.latency_ms,
                memory_gb: o.memory_gb,
            }
        })
        .collect()
}

/// Fig. 4a: LoRA rank sweep (accuracy saturates, cost grows ~linearly).
pub fn lora_rank_sweep(m: &ModelSpec, tb: &Testbed,
                       blended: &TaskSpec) -> Vec<SweepPoint> {
    let configs = crate::config::RANKS
        .iter()
        .map(|&r| {
            let mut c = Config::default_baseline();
            c.ft = FtConfig { method: FtMethod::LoRA, rank: r, alpha_mult: 2 };
            (r as f64, format!("r={r}"), c)
        })
        .collect();
    sweep_config(m, tb, blended, configs)
}

/// Fig. 4b: quantization bit-width sweep (graceful to INT8, cliff at
/// INT4).
pub fn quant_bits_sweep(m: &ModelSpec, tb: &Testbed,
                        blended: &TaskSpec) -> Vec<SweepPoint> {
    let configs = [
        (16.0, Precision::Fp16),
        (8.0, Precision::Fp8),
        (8.0, Precision::Int8),
        (4.0, Precision::Int4),
    ]
    .into_iter()
    .map(|(bits, p)| {
        let mut c = Config::default_baseline();
        c.inf.precision = p;
        (bits, p.name().to_string(), c)
    })
    .collect();
    sweep_config(m, tb, blended, configs)
}

/// Fig. 4c: MoE expert-count sweep (diminishing accuracy returns,
/// linear memory overhead).
pub fn moe_experts_sweep(m: &ModelSpec, tb: &Testbed,
                         blended: &TaskSpec) -> Vec<SweepPoint> {
    let mut configs = vec![(1.0, "Dense".to_string(),
                            Config::default_baseline())];
    for e in [2u8, 4, 8] {
        let mut c = Config::default_baseline();
        c.arch.moe = MoE::Sparse { experts: e, top_k: 2 };
        configs.push((e as f64, format!("E={e}"), c));
    }
    sweep_config(m, tb, blended, configs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;
    use crate::tasks::blended_task;

    fn setup() -> (ModelSpec, Testbed, TaskSpec) {
        let m = by_name("LLaMA-2-7B").unwrap();
        let tb = Testbed::noiseless(crate::hardware::a100());
        (m, tb, blended_task())
    }

    #[test]
    fn rank_sweep_saturates() {
        let (m, tb, t) = setup();
        let pts = lora_rank_sweep(&m, &tb, &t);
        assert_eq!(pts.len(), 5);
        // gains from 8 -> 32 exceed gains from 32 -> 128 (saturation)
        let g_low = pts[2].acc_mean - pts[0].acc_mean;
        let g_high = pts[4].acc_mean - pts[2].acc_mean;
        assert!(g_low > g_high, "low={g_low} high={g_high}");
    }

    #[test]
    fn quant_sweep_shows_cliff() {
        let (m, tb, t) = setup();
        let pts = quant_bits_sweep(&m, &tb, &t);
        let fp16 = pts[0].acc_mean;
        let int8 = pts[2].acc_mean;
        let int4 = pts[3].acc_mean;
        assert!(fp16 - int8 < 0.6, "int8 drop {}", fp16 - int8);
        assert!(int8 - int4 > 2.0 * (fp16 - int8),
                "no cliff: int8={int8} int4={int4}");
        // spread across tasks grows at int4 (the shaded region widens)
        assert!(pts[3].acc_max - pts[3].acc_min
                > pts[2].acc_max - pts[2].acc_min);
    }

    #[test]
    fn experts_sweep_diminishing_returns_linear_memory() {
        let (m, tb, t) = setup();
        let pts = moe_experts_sweep(&m, &tb, &t);
        assert_eq!(pts.len(), 4);
        let g24 = pts[2].acc_mean - pts[1].acc_mean;
        let g48 = pts[3].acc_mean - pts[2].acc_mean;
        assert!(g48 < g24, "returns not diminishing");
        // memory strictly increasing with expert count
        assert!(pts[3].memory_gb > pts[2].memory_gb);
        assert!(pts[2].memory_gb > pts[1].memory_gb);
    }

    #[test]
    fn quant_sweep_latency_monotone() {
        let (m, tb, t) = setup();
        let pts = quant_bits_sweep(&m, &tb, &t);
        assert!(pts[3].latency_ms < pts[2].latency_ms);
        assert!(pts[2].latency_ms < pts[0].latency_ms);
    }
}
