//! S9: the AE-LLM coordinator — Algorithm 1 (surrogate-guided NSGA-II
//! with hardware-in-the-loop refinement), deployment scenarios, space
//! masks for ablations, and the Fig. 4 sensitivity sweeps.

pub mod algorithm1;
pub mod scenario;
pub mod sensitivity;

pub use algorithm1::{optimize, optimize_with, AeLlmParams, Outcome};
pub use scenario::{Scenario, SpaceMask};
