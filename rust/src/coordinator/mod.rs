//! S9: the AE-LLM coordinator — Algorithm 1 (surrogate-guided NSGA-II
//! with hardware-in-the-loop refinement) expressed against the
//! [`crate::evaluator::Evaluator`] backend trait, the builder-style
//! [`AeLlm`] session facade with typed errors and observer hooks,
//! deployment scenarios, space masks for ablations, and the Fig. 4
//! sensitivity sweeps.

pub mod algorithm1;
pub mod observer;
pub mod scenario;
pub mod sensitivity;
pub mod session;

#[allow(deprecated)]
pub use algorithm1::{optimize, optimize_with};
pub use algorithm1::{optimize_with_observer, pareto_hypervolume,
                     AeLlmParams, Outcome};
pub use observer::{CollectingObserver, FnObserver, IterationEvent,
                   NullObserver, RunObserver};
pub use scenario::{Scenario, SpaceMask};
pub use session::{AeLlm, AeLlmError, RunReport};
