//! S9: the AE-LLM coordinator — Algorithm 1 (surrogate warm-start +
//! pluggable search strategy + hardware-in-the-loop refinement)
//! expressed against the [`crate::evaluator::Evaluator`] backend trait
//! and the [`crate::search::strategy::SearchStrategy`] proposal trait,
//! the builder-style [`AeLlm`] session facade with typed errors and
//! observer hooks, deployment scenarios, space masks for ablations,
//! and the Fig. 4 sensitivity sweeps.
//!
//! The deprecated `optimize` / `optimize_with` shims are no longer
//! re-exported here: they stay reachable (and bit-identity-tested) at
//! their defining path, [`algorithm1::optimize`] /
//! [`algorithm1::optimize_with`], while the supported surface is the
//! trait/builder path ([`optimize_with_observer`],
//! [`optimize_with_strategy`], [`AeLlm`]).

pub mod algorithm1;
pub mod controller;
pub mod observer;
pub mod scenario;
pub mod sensitivity;
pub mod session;

pub use algorithm1::{optimize_with_observer, optimize_with_observer_warm,
                     optimize_with_strategy, optimize_with_strategy_warm,
                     pareto_hypervolume, pareto_hypervolume_with,
                     AeLlmParams, HvGate, Outcome};
pub use controller::{run_adapt, run_adapt_from, run_adapt_stored,
                     AdaptParams, AdaptReport, EpochRecord,
                     ADAPT_REPORT_SCHEMA};
pub use observer::{CollectingObserver, FnObserver, IterationEvent,
                   NullObserver, RunObserver};
pub use scenario::{Scenario, SpaceMask};
pub use session::{AeLlm, AeLlmError, RunReport};
