//! The builder-style session facade: name-addressed scenario
//! construction with typed errors, seeded runs over any [`Evaluator`],
//! and a serializable [`RunReport`].
//!
//! ```no_run
//! use ae_llm::coordinator::AeLlm;
//! use ae_llm::metrics::Preferences;
//!
//! # fn main() -> Result<(), ae_llm::coordinator::AeLlmError> {
//! let report = AeLlm::for_model("LLaMA-2-7B")?
//!     .task("GSM8K")?
//!     .platform("A100-80GB")?
//!     .prefs(Preferences::latency_critical())
//!     .seed(7)
//!     .run_testbed();
//! println!("chosen {}", report.outcome.chosen.signature());
//! # Ok(())
//! # }
//! ```

use std::fmt;

use crate::evaluator::Evaluator;
use crate::hardware;
use crate::metrics::Preferences;
use crate::models;
use crate::search::strategy::StrategyKind;
use crate::tasks;
use crate::util::json::Json;
use crate::util::{Parallelism, Rng};

use super::algorithm1::{optimize_with_observer, AeLlmParams, Outcome};
use super::observer::{IterationEvent, NullObserver, RunObserver};
use super::scenario::Scenario;

// ---------------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------------

/// Typed lookup errors for scenario construction — replaces the old
/// `Option` returns, so callers (and the CLI) can tell *which* name
/// failed and what the valid choices are.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AeLlmError {
    UnknownModel(String),
    UnknownTask(String),
    UnknownPlatform(String),
    UnknownPrefs(String),
    UnknownStrategy(String),
    /// Deployment asked of an empty Pareto front.
    EmptyFront,
    /// The front cannot serve `class` under the SLO policy: no entry
    /// clears the accuracy floor, or none can meet the class deadline
    /// at its serve shape.  `run_and_deploy` used to silently deploy
    /// anyway and let every request of the class violate at serve
    /// time; now the infeasibility is typed and surfaced up front.
    InfeasibleClass { class: String, reason: String },
    /// A persistent-store operation failed (rendered
    /// [`crate::store::StoreError`]; stringly so this enum stays
    /// `Eq`-comparable — `std::io::Error` is not).
    Store(String),
}

impl From<crate::store::StoreError> for AeLlmError {
    fn from(e: crate::store::StoreError) -> AeLlmError {
        AeLlmError::Store(e.to_string())
    }
}

fn join_names<I: IntoIterator<Item = &'static str>>(names: I) -> String {
    names.into_iter().collect::<Vec<_>>().join(", ")
}

impl fmt::Display for AeLlmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AeLlmError::UnknownModel(name) => write!(
                f,
                "unknown model {name:?} (known: {})",
                join_names(
                    models::zoo()
                        .iter()
                        .chain(models::vlm_zoo().iter())
                        .map(|m| m.name)
                        .collect::<Vec<_>>(),
                )
            ),
            AeLlmError::UnknownTask(name) => write!(
                f,
                "unknown task {name:?} (known: {})",
                join_names(
                    tasks::suite()
                        .iter()
                        .chain(tasks::vlm_suite().iter())
                        .map(|t| t.name)
                        .collect::<Vec<_>>(),
                )
            ),
            AeLlmError::UnknownPlatform(name) => write!(
                f,
                "unknown platform {name:?} (known: {})",
                join_names(
                    hardware::platforms().iter().map(|p| p.name)
                        .collect::<Vec<_>>(),
                )
            ),
            AeLlmError::UnknownPrefs(name) => write!(
                f,
                "unknown preferences {name:?} (known: balanced, latency, \
                 memory, accuracy, green)"
            ),
            AeLlmError::UnknownStrategy(name) => write!(
                f,
                "unknown strategy {name:?} (known: {})",
                join_names(StrategyKind::ALL.iter().map(|k| k.name())
                    .collect::<Vec<_>>()),
            ),
            AeLlmError::EmptyFront => {
                write!(f, "cannot deploy from an empty Pareto front")
            }
            AeLlmError::InfeasibleClass { class, reason } => write!(
                f,
                "SLO class {class:?} is infeasible under this policy: \
                 {reason}"
            ),
            AeLlmError::Store(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for AeLlmError {}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Builder-style session over one deployment scenario: configure by
/// name, then [`run`](AeLlm::run) against any [`Evaluator`] backend.
#[derive(Clone, Debug)]
pub struct AeLlm {
    scenario: Scenario,
    params: AeLlmParams,
    seed: u64,
    par: Parallelism,
}

impl AeLlm {
    /// Start a session for a model (its paper hardware tier and the
    /// blended task mix, as in [`Scenario::for_model`]).
    pub fn for_model(name: &str) -> Result<AeLlm, AeLlmError> {
        Ok(AeLlm::from_scenario(Scenario::for_model(name)?))
    }

    /// Start from an already-built scenario (platform objects,
    /// custom testbeds, `noiseless()`, ...).
    pub fn from_scenario(scenario: Scenario) -> AeLlm {
        AeLlm { scenario, params: AeLlmParams::default(), seed: 42,
                par: Parallelism::Auto }
    }

    pub fn task(mut self, name: &str) -> Result<AeLlm, AeLlmError> {
        self.scenario = self.scenario.with_task(name)?;
        Ok(self)
    }

    pub fn platform(mut self, name: &str) -> Result<AeLlm, AeLlmError> {
        let platform = hardware::by_name(name)
            .ok_or_else(|| AeLlmError::UnknownPlatform(name.to_string()))?;
        self.scenario = self.scenario.with_platform(platform);
        Ok(self)
    }

    pub fn prefs(mut self, prefs: Preferences) -> AeLlm {
        self.scenario = self.scenario.with_prefs(prefs);
        self
    }

    /// Preferences by CLI preset name (`balanced`, `latency`, `memory`,
    /// `accuracy`, `green`).
    pub fn prefs_named(self, name: &str) -> Result<AeLlm, AeLlmError> {
        let prefs = crate::report::prefs_by_name(name)
            .ok_or_else(|| AeLlmError::UnknownPrefs(name.to_string()))?;
        Ok(self.prefs(prefs))
    }

    pub fn params(mut self, params: AeLlmParams) -> AeLlm {
        self.params = params;
        self
    }

    /// Select the search procedure for Algorithm 1's proposal step
    /// (DESIGN.md §10).  NSGA-II is the default.
    pub fn strategy(mut self, kind: StrategyKind) -> AeLlm {
        self.params.strategy = kind;
        self
    }

    /// Strategy by CLI name (`nsga2`, `random`, `racing`, `local`).
    pub fn strategy_named(self, name: &str) -> Result<AeLlm, AeLlmError> {
        let kind = StrategyKind::by_name(name)
            .ok_or_else(|| AeLlmError::UnknownStrategy(name.to_string()))?;
        Ok(self.strategy(kind))
    }

    /// Shrink to the quick test/demo budget ([`AeLlmParams::small`]),
    /// preserving any mask/toggle customization is the caller's job —
    /// this replaces the whole parameter set.
    pub fn quick(self) -> AeLlm {
        self.params(AeLlmParams::small())
    }

    pub fn seed(mut self, seed: u64) -> AeLlm {
        self.seed = seed;
        self
    }

    /// Parallelism of everything this session fans out — today the
    /// cluster simulate phase ([`cluster`](Self::cluster), DESIGN.md
    /// §16).  A wall-clock knob only: every result is byte-identical
    /// at every level.  Defaults to [`Parallelism::Auto`].
    pub fn parallelism(mut self, par: Parallelism) -> AeLlm {
        self.par = par;
        self
    }

    pub fn par(&self) -> Parallelism {
        self.par
    }

    pub fn scenario(&self) -> &Scenario {
        &self.scenario
    }

    pub fn params_ref(&self) -> &AeLlmParams {
        &self.params
    }

    /// Run Algorithm 1 against `evaluator`, unobserved.
    pub fn run(&self, evaluator: &mut dyn Evaluator) -> RunReport {
        self.run_observed(evaluator, &mut NullObserver)
    }

    /// Run Algorithm 1 against `evaluator`, streaming iteration events
    /// to `observer` (the report also collects them).
    pub fn run_observed(&self, evaluator: &mut dyn Evaluator,
                        observer: &mut dyn RunObserver) -> RunReport {
        let mut tee = Tee { events: Vec::new(), forward: observer };
        let t0 = std::time::Instant::now();
        let mut rng = Rng::new(self.seed);
        // Delta, not the evaluator's lifetime total: a reused evaluator
        // must still report only what *this* run consumed.
        let evals_before = evaluator.evals();
        let outcome = optimize_with_observer(&self.scenario, &self.params,
                                             evaluator, &mut tee, &mut rng);
        RunReport {
            model: self.scenario.model.name.to_string(),
            task: self.scenario.task.name.to_string(),
            platform: self.scenario.testbed.platform.name.to_string(),
            prefs: self.scenario.prefs,
            seed: self.seed,
            strategy: outcome.strategy.to_string(),
            evaluator_evals: evaluator.evals() - evals_before,
            iterations: tee.events,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            outcome,
        }
    }

    /// Run against a fresh clone of the scenario's own testbed — the
    /// simulated-fleet default everyone starts with.
    pub fn run_testbed(&self) -> RunReport {
        let mut evaluator = self.scenario.testbed.clone();
        self.run(&mut evaluator)
    }

    /// Lean testbed run: just the [`Outcome`], no report assembly and
    /// no event collection — with a `NullObserver` the coordinator
    /// skips the per-iteration snapshot (and its exact 4-D
    /// hypervolume) entirely.  The one recipe report sweeps, tests and
    /// benches share; bit-identical to the legacy
    /// `optimize(scenario, params, &mut Rng::new(seed))` path
    /// (tests/integration_api.rs).
    pub fn run_testbed_outcome(&self) -> Outcome {
        let mut evaluator = self.scenario.testbed.clone();
        let mut rng = Rng::new(self.seed);
        optimize_with_observer(&self.scenario, &self.params, &mut evaluator,
                               &mut NullObserver, &mut rng)
    }

    /// [`run_testbed_outcome`](Self::run_testbed_outcome) warm-started
    /// from prior front entries (typically
    /// [`crate::store::Store::warm_entries`]).  With `warm` empty this
    /// is byte-for-byte the cold path — the
    /// `optimize_with_observer_warm` contract — so catalog misses need
    /// no special-casing.
    pub fn run_testbed_outcome_warm(
        &self, warm: &[crate::search::archive::Entry]) -> Outcome {
        let mut evaluator = self.scenario.testbed.clone();
        let mut rng = Rng::new(self.seed);
        super::algorithm1::optimize_with_observer_warm(
            &self.scenario, &self.params, warm, &mut evaluator,
            &mut NullObserver, &mut rng)
    }

    /// This session's catalog coordinates: (model, task, platform)
    /// from the scenario plus the caller's workload tag (`"-"` for
    /// plain searches, the [`crate::runtime::WorkloadKind`] name for
    /// adaptation runs).
    pub fn store_key(&self, scenario_tag: &str) -> crate::store::CatalogKey {
        crate::store::CatalogKey::new(
            self.scenario.model.name,
            self.scenario.task.name,
            self.scenario.testbed.platform.name,
            scenario_tag,
        )
    }

    /// [`run_testbed`](Self::run_testbed) with an observer.
    pub fn run_testbed_observed(&self, observer: &mut dyn RunObserver)
                                -> RunReport {
        let mut evaluator = self.scenario.testbed.clone();
        self.run_observed(&mut evaluator, observer)
    }

    // -- deployment (DESIGN.md §11) ------------------------------------

    /// SLO policy scaled to this scenario's Default-configuration
    /// latency (the Table 2 anchor), so deadlines are comparable
    /// across model scales.
    pub fn slo_policy(&self) -> crate::runtime::SloPolicy {
        let truth = crate::oracle::Testbed::noiseless(
            self.scenario.testbed.platform.clone());
        let o = truth.true_objectives(
            &crate::config::Config::default_baseline(),
            &self.scenario.model, &self.scenario.task);
        crate::runtime::SloPolicy::for_default_latency(o.latency_ms)
    }

    /// Build the adaptive serving fleet from a search outcome's Pareto
    /// front: one simulated slot per SLO class, routed per request
    /// (see [`crate::runtime::Deployment`]).
    ///
    /// ```
    /// use ae_llm::coordinator::AeLlm;
    ///
    /// # fn main() -> Result<(), ae_llm::coordinator::AeLlmError> {
    /// let session = AeLlm::for_model("Phi-2")?.quick().seed(7);
    /// let outcome = session.run_testbed_outcome();
    /// let deployment = session.deploy(&outcome)?;
    /// assert!(!deployment.slots().is_empty());
    /// # Ok(()) }
    /// ```
    pub fn deploy(&self, outcome: &Outcome)
                  -> Result<crate::runtime::Deployment, AeLlmError> {
        self.deploy_with(outcome, &self.slo_policy())
    }

    /// [`deploy`](Self::deploy) under an explicit SLO policy.  Checks
    /// serve-time feasibility first: a class no front entry can serve
    /// within the accuracy floor *and* the class deadline returns a
    /// typed [`AeLlmError::InfeasibleClass`] instead of a deployment
    /// that is guaranteed to violate.
    pub fn deploy_with(&self, outcome: &Outcome,
                       policy: &crate::runtime::SloPolicy)
                       -> Result<crate::runtime::Deployment, AeLlmError> {
        if outcome.pareto.is_empty() {
            return Err(AeLlmError::EmptyFront);
        }
        if let Some((class, reason)) =
            crate::runtime::fleet::infeasible_class(&outcome.pareto, policy)
        {
            return Err(AeLlmError::InfeasibleClass {
                class: class.name().to_string(),
                reason,
            });
        }
        Ok(crate::runtime::Deployment::from_front(
            &outcome.pareto, policy, &self.scenario.model,
            &self.scenario.task, &self.scenario.testbed.platform)
            .expect("feasibility pre-checked above"))
    }

    /// Search, then deploy: the full loop the paper promises — a
    /// scenario goes in, a served fleet comes out.
    ///
    /// ```
    /// use ae_llm::coordinator::AeLlm;
    /// use ae_llm::runtime::{Workload, WorkloadKind};
    /// use ae_llm::util::Parallelism;
    ///
    /// # fn main() -> Result<(), ae_llm::coordinator::AeLlmError> {
    /// let session = AeLlm::for_model("Phi-2")?.quick().seed(7);
    /// let (report, deployment) = session.run_and_deploy()?;
    /// let requests =
    ///     Workload::new(WorkloadKind::Steady, 40.0, 50, 7).generate();
    /// let served = deployment.serve(&requests, "steady", 7,
    ///                               Parallelism::Sequential);
    /// assert_eq!(served.overall.completed, 50);
    /// assert!(!report.outcome.pareto.is_empty());
    /// # Ok(()) }
    /// ```
    pub fn run_and_deploy(&self)
                          -> Result<(RunReport, crate::runtime::Deployment),
                                    AeLlmError> {
        let report = self.run_testbed();
        let deployment = self.deploy(&report.outcome)?;
        Ok((report, deployment))
    }

    /// Deploy a search outcome across an N-node simulated cluster
    /// (see [`crate::runtime::Cluster`], DESIGN.md §16): every node
    /// serves this session's deployment under its own derived seed,
    /// behind the seeded least-loaded router.  The session's
    /// [`parallelism`](Self::parallelism) and seed override the
    /// corresponding `params` fields, so one session configures its
    /// whole stack in one place.
    ///
    /// ```
    /// use ae_llm::coordinator::AeLlm;
    /// use ae_llm::runtime::{ClusterParams, Workload, WorkloadKind};
    /// use ae_llm::util::Parallelism;
    ///
    /// # fn main() -> Result<(), ae_llm::coordinator::AeLlmError> {
    /// let session = AeLlm::for_model("Phi-2")?
    ///     .quick()
    ///     .seed(7)
    ///     .parallelism(Parallelism::Threads(2));
    /// let outcome = session.run_testbed_outcome();
    /// let cluster = session.cluster(
    ///     &outcome, ClusterParams { nodes: 2, ..Default::default() })?;
    /// let requests =
    ///     Workload::new(WorkloadKind::Steady, 40.0, 60, 7).generate();
    /// let report = cluster.serve(&requests, "steady");
    /// assert_eq!(report.overall.completed, 60);
    /// # Ok(()) }
    /// ```
    pub fn cluster(&self, outcome: &Outcome,
                   params: crate::runtime::ClusterParams)
                   -> Result<crate::runtime::Cluster, AeLlmError> {
        let deployment = self.deploy(outcome)?;
        Ok(crate::runtime::Cluster::new(
            deployment,
            crate::runtime::ClusterParams { par: self.par, ..params },
            self.seed))
    }

    // -- continual adaptation (DESIGN.md §12) --------------------------

    /// Run the continual-adaptation loop on a workload scenario:
    /// search, deploy, then serve in epochs — re-searching (warm-
    /// started from the persistent front, re-scoped to the observed
    /// workload) and hot-swapping the fleet whenever the drift
    /// detector fires.  See [`super::controller::run_adapt`].
    ///
    /// ```no_run
    /// use ae_llm::coordinator::{AdaptParams, AeLlm};
    /// use ae_llm::runtime::WorkloadKind;
    ///
    /// # fn main() -> Result<(), ae_llm::coordinator::AeLlmError> {
    /// let report = AeLlm::for_model("Phi-2")?
    ///     .quick()
    ///     .seed(7)
    ///     .adapt(WorkloadKind::RegimeShift, &AdaptParams::default())?;
    /// println!("{} re-searches, {} redeployments",
    ///          report.searches, report.redeployments);
    /// # Ok(()) }
    /// ```
    pub fn adapt(&self, kind: crate::runtime::WorkloadKind,
                 params: &super::controller::AdaptParams)
                 -> Result<super::controller::AdaptReport, AeLlmError> {
        super::controller::run_adapt(self, self.seed, kind, params)
    }

    /// [`adapt`](Self::adapt) reusing a precomputed epoch-0 search
    /// outcome (it depends only on this session and its seed), so
    /// continual-vs-one-shot comparisons search once instead of once
    /// per mode.
    ///
    /// ```no_run
    /// use ae_llm::coordinator::{AdaptParams, AeLlm};
    /// use ae_llm::runtime::WorkloadKind;
    ///
    /// # fn main() -> Result<(), ae_llm::coordinator::AeLlmError> {
    /// let session = AeLlm::for_model("Phi-2")?.quick().seed(7);
    /// let outcome = session.run_testbed_outcome(); // search once ...
    /// let continual = session.adapt_from(
    ///     &outcome, WorkloadKind::Ramp, &AdaptParams::default())?;
    /// let frozen = session.adapt_from(
    ///     &outcome, WorkloadKind::Ramp, &AdaptParams::default().one_shot())?;
    /// // ... compare continual vs one-shot on the same epoch-0 front.
    /// assert!(continual.searches >= frozen.searches);
    /// # Ok(()) }
    /// ```
    pub fn adapt_from(&self, outcome: &Outcome,
                      kind: crate::runtime::WorkloadKind,
                      params: &super::controller::AdaptParams)
                      -> Result<super::controller::AdaptReport, AeLlmError> {
        super::controller::run_adapt_from(self, self.seed, kind, params,
                                          outcome)
    }

    /// [`adapt`](Self::adapt) against a persistent
    /// [`crate::store::Store`]: the epoch-0 search warm-starts from
    /// the catalog's best similar front, and every searched front is
    /// persisted and indexed as it is produced — so the next process
    /// (or node) inherits this run's knowledge.  See
    /// [`super::controller::run_adapt_stored`].
    pub fn adapt_stored(&self, kind: crate::runtime::WorkloadKind,
                        params: &super::controller::AdaptParams,
                        store: &mut crate::store::Store)
                        -> Result<super::controller::AdaptReport,
                                  AeLlmError> {
        super::controller::run_adapt_stored(self, self.seed, kind,
                                            params, store)
    }
}

/// Collects events for the report while forwarding to the caller's
/// observer.
struct Tee<'a> {
    events: Vec<IterationEvent>,
    forward: &'a mut dyn RunObserver,
}

impl RunObserver for Tee<'_> {
    fn on_iteration(&mut self, event: &IterationEvent) {
        self.events.push(*event);
        self.forward.on_iteration(event);
    }
}

// ---------------------------------------------------------------------------
// RunReport
// ---------------------------------------------------------------------------

/// Everything one run produced: the scenario coordinates, the
/// [`Outcome`], the observer's iteration stream, and wall-clock — in a
/// shape that serializes to JSON (`ae-llm search --json`).
#[derive(Clone)]
pub struct RunReport {
    pub model: String,
    pub task: String,
    pub platform: String,
    pub prefs: Preferences,
    pub seed: u64,
    /// Name of the search strategy that ran (`outcome.strategy`).
    pub strategy: String,
    /// The evaluator's own request counter (differs from
    /// `outcome.testbed_evals` only for decorators, e.g. a caching
    /// wrapper whose inner backend measured less).
    pub evaluator_evals: usize,
    pub iterations: Vec<IterationEvent>,
    pub wall_ms: f64,
    pub outcome: Outcome,
}

fn objectives_json(o: &crate::oracle::Objectives) -> Json {
    // Shared shape with the persistent front and the adapt report.
    o.to_json()
}

impl RunReport {
    /// Serialize the full report (schema `ae-llm.run-report/v2`; v2
    /// adds the `strategy` name and the `strategy_evals` counter —
    /// the strategy's own mid-round measurements, split out of
    /// `testbed_evals`).  Field reference in docs/SCHEMAS.md.
    pub fn to_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        root.insert("schema".into(),
                    Json::Str("ae-llm.run-report/v2".into()));
        root.insert("model".into(), Json::Str(self.model.clone()));
        root.insert("task".into(), Json::Str(self.task.clone()));
        root.insert("platform".into(), Json::Str(self.platform.clone()));
        root.insert("strategy".into(), Json::Str(self.strategy.clone()));
        // String, not Num: Json numbers are f64 and would corrupt
        // seeds above 2^53, breaking replay-from-report.
        root.insert("seed".into(), Json::Str(self.seed.to_string()));
        root.insert("wall_ms".into(), Json::Num(self.wall_ms));

        let mut prefs = std::collections::BTreeMap::new();
        prefs.insert("w_acc".into(), Json::Num(self.prefs.w_acc));
        prefs.insert("w_lat".into(), Json::Num(self.prefs.w_lat));
        prefs.insert("w_mem".into(), Json::Num(self.prefs.w_mem));
        prefs.insert("w_energy".into(), Json::Num(self.prefs.w_energy));
        root.insert("prefs".into(), Json::Obj(prefs));

        let out = &self.outcome;
        let mut chosen = std::collections::BTreeMap::new();
        chosen.insert("signature".into(),
                      Json::Str(out.chosen.signature()));
        chosen.insert("objectives".into(),
                      objectives_json(&out.chosen_objectives));
        chosen.insert("utility".into(), Json::Num(out.chosen_utility));
        chosen.insert("efficiency_score".into(),
                      Json::Num(out.chosen_efficiency_score));
        root.insert("chosen".into(), Json::Obj(chosen));

        root.insert("reference_default".into(),
                    objectives_json(&out.reference.default));
        root.insert("testbed_evals".into(),
                    Json::Num(out.testbed_evals as f64));
        root.insert("surrogate_evals".into(),
                    Json::Num(out.surrogate_evals as f64));
        root.insert("strategy_evals".into(),
                    Json::Num(out.strategy_evals as f64));
        root.insert("evaluator_evals".into(),
                    Json::Num(self.evaluator_evals as f64));

        let pareto: Vec<Json> = out
            .pareto
            .entries()
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("signature".into(), Json::Str(e.config.signature()));
                m.insert("objectives".into(),
                         objectives_json(&e.objectives));
                Json::Obj(m)
            })
            .collect();
        root.insert("pareto".into(), Json::Arr(pareto));

        let iterations: Vec<Json> = self
            .iterations
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("iteration".into(), Json::Num(e.iteration as f64));
                m.insert("front_size".into(),
                         Json::Num(e.front_size as f64));
                m.insert("hypervolume".into(), Json::Num(e.hypervolume));
                m.insert("testbed_evals".into(),
                         Json::Num(e.testbed_evals as f64));
                m.insert("surrogate_evals".into(),
                         Json::Num(e.surrogate_evals as f64));
                Json::Obj(m)
            })
            .collect();
        root.insert("iterations".into(), Json::Arr(iterations));

        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_reports_typed_errors() {
        match AeLlm::for_model("GPT-5") {
            Err(AeLlmError::UnknownModel(n)) => assert_eq!(n, "GPT-5"),
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        let b = AeLlm::for_model("Phi-2").unwrap();
        assert!(matches!(b.clone().task("nope"),
                         Err(AeLlmError::UnknownTask(_))));
        assert!(matches!(b.clone().platform("TPU-9000"),
                         Err(AeLlmError::UnknownPlatform(_))));
        assert!(matches!(b.clone().strategy_named("nsga3"),
                         Err(AeLlmError::UnknownStrategy(_))));
        assert!(matches!(b.prefs_named("speedy"),
                         Err(AeLlmError::UnknownPrefs(_))));
    }

    #[test]
    fn error_messages_name_the_culprit_and_choices() {
        let e = AeLlmError::UnknownModel("GPT-5".into()).to_string();
        assert!(e.contains("GPT-5") && e.contains("LLaMA-2-7B"), "{e}");
        let e = AeLlmError::UnknownPrefs("speedy".into()).to_string();
        assert!(e.contains("speedy") && e.contains("green"), "{e}");
        let e = AeLlmError::UnknownStrategy("nsga3".into()).to_string();
        assert!(e.contains("nsga3") && e.contains("racing"), "{e}");
    }

    #[test]
    fn builder_configures_the_scenario() {
        let b = AeLlm::for_model("Mistral-7B")
            .unwrap()
            .task("GSM8K")
            .unwrap()
            .platform("RTX-4090")
            .unwrap()
            .prefs(Preferences::memory_constrained())
            .strategy_named("racing")
            .unwrap()
            .seed(9);
        assert_eq!(b.scenario().model.name, "Mistral-7B");
        assert_eq!(b.scenario().task.name, "GSM8K");
        assert_eq!(b.scenario().testbed.platform.name, "RTX-4090");
        assert_eq!(b.params_ref().strategy, StrategyKind::Racing);
        assert_eq!(b.seed, 9);
    }

    #[test]
    fn run_and_deploy_builds_a_fleet_from_the_front() {
        let (report, deployment) = AeLlm::for_model("Phi-2")
            .unwrap()
            .quick()
            .seed(4)
            .run_and_deploy()
            .unwrap();
        assert!(!report.outcome.pareto.is_empty());
        assert_eq!(deployment.slots().len(), 3);
        assert!(deployment.distinct_configs() >= 1);
        assert_eq!(deployment.routing(), "adaptive");
        // deadlines scale with the scenario's default latency (Phi-2
        // anchors at 18.3 ms)
        let policy = AeLlm::for_model("Phi-2").unwrap().slo_policy();
        assert!((policy.interactive_deadline_ms - 2.0 * 18.3).abs()
                    < 1e-9);
    }

    #[test]
    fn deploy_rejects_infeasible_class_with_typed_error() {
        // Regression for the silent fallback: a policy no front entry
        // can satisfy must be a typed error, not a deployment that is
        // guaranteed to violate at serve time.
        let session = AeLlm::for_model("Phi-2").unwrap().quick().seed(4);
        let outcome = session.run_testbed_outcome();
        // feasible under the scenario policy
        assert!(session.deploy(&outcome).is_ok());
        // an impossible interactive deadline: typed, names the class
        let tight = crate::runtime::SloPolicy {
            interactive_deadline_ms: 0.01,
            ..session.slo_policy()
        };
        match session.deploy_with(&outcome, &tight) {
            Err(AeLlmError::InfeasibleClass { class, reason }) => {
                assert_eq!(class, "interactive");
                assert!(reason.contains("deadline"), "{reason}");
            }
            other => panic!("expected InfeasibleClass, got {other:?}"),
        }
        // an accuracy floor above 1.0 excludes every entry
        let absurd = crate::runtime::SloPolicy {
            accuracy_floor: 1.5,
            ..session.slo_policy()
        };
        assert!(matches!(session.deploy_with(&outcome, &absurd),
                         Err(AeLlmError::InfeasibleClass { .. })));
        // the error message renders the class and reason
        let e = AeLlmError::InfeasibleClass {
            class: "interactive".into(),
            reason: "over the deadline".into(),
        };
        let s = e.to_string();
        assert!(s.contains("interactive") && s.contains("deadline"), "{s}");
    }

    #[test]
    fn run_report_serializes_and_parses_back() {
        let report = AeLlm::for_model("Phi-2")
            .unwrap()
            .quick()
            .seed(3)
            .run_testbed();
        assert_eq!(report.iterations.len(),
                   report.iterations.last().unwrap().total_iterations);
        assert_eq!(report.strategy, "nsga2");
        let text = report.to_json().dump();
        let parsed = Json::parse(&text).expect("valid JSON");
        assert_eq!(parsed.get("schema").and_then(|s| s.as_str()),
                   Some("ae-llm.run-report/v2"));
        assert_eq!(parsed.get("model").and_then(|s| s.as_str()),
                   Some("Phi-2"));
        assert_eq!(parsed.get("strategy").and_then(|s| s.as_str()),
                   Some("nsga2"));
        assert!(parsed.get("strategy_evals").is_some());
        assert_eq!(parsed.get("seed").and_then(|s| s.as_str()), Some("3"));
        let chosen_sig = parsed
            .get("chosen")
            .and_then(|c| c.get("signature"))
            .and_then(|s| s.as_str())
            .unwrap();
        assert_eq!(chosen_sig, report.outcome.chosen.signature());
        assert!(parsed.get("iterations").and_then(|a| a.as_arr()).unwrap()
            .len() >= 1);
    }
}
