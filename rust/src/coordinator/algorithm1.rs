//! Algorithm 1: Adaptive Efficiency Optimization — the AE-LLM
//! coordinator tying together surrogates, the pluggable search
//! strategy and the testbed.
//!
//! ```text
//! Require: model M, task T, hardware H, preferences w
//! Require: initial sample n0, refinement iterations R, evals/iter k
//!  1: train surrogate models on initial sample C0
//!  2: for r = 1 to R do
//!  3:   run NSGA-II with current surrogates -> Pareto set P_r
//!  4:   select top-k *uncertain* configurations from P_r
//!  5:   evaluate selected configurations on actual hardware
//!  6:   update surrogate models with new evaluations
//!  7: end for
//!  8: return Pareto-optimal configurations P*
//! ```
//!
//! Since PR 3 the coordinator is pure orchestration: lines 3–4 (search
//! the space, pick the candidates worth measuring) belong to a
//! [`SearchStrategy`] (DESIGN.md §10) — NSGA-II is merely the default
//! ([`crate::search::strategy::Nsga2Strategy`], selected by
//! [`AeLlmParams::strategy`]) — while the coordinator keeps the
//! surrogate warm-start, the line-5 measurement batches, the measured
//! Pareto archive, surrogate updates and observer events.  "Actual
//! hardware" is any [`Evaluator`] backend (DESIGN.md §9):
//! [`crate::oracle::Testbed`] (simulated fleet) by default, the
//! PJRT-measured [`crate::runtime::MeasuredEvaluator`] for the
//! end-to-end path, or a decorated stack of either.  The primary entry
//! point is [`optimize_with_observer`]; the [`super::AeLlm`] builder
//! wraps it with a friendlier surface, and the legacy [`optimize`] /
//! [`optimize_with`] closures remain as deprecated shims.

use std::collections::BTreeSet;

use crate::config::{encode, Config};
use crate::evaluator::{EvalContext, Evaluator, FnEvaluator};
use crate::metrics::{efficiency_score, utility, Reference};
use crate::oracle::Objectives;
use crate::search::archive::{Entry, ParetoArchive};
use crate::search::dominance::MinVec;
use crate::search::hypervolume::{self, HvScratch};
use crate::search::nsga2::{Nsga2Params, Toggles};
use crate::search::strategy::{SearchStrategy, StrategyCx, StrategyKind};
use crate::surrogate::{GbtParams, Sample, SurrogateSet};
use crate::util::pool::Parallelism;
use crate::util::Rng;

use super::observer::{IterationEvent, NullObserver, RunObserver};
use super::scenario::{Scenario, SpaceMask};

/// AE-LLM hyper-parameters (defaults mirror §3.5 / Table 5, scaled to
/// the simulated testbed's cost).
#[derive(Clone, Copy, Debug)]
pub struct AeLlmParams {
    /// |C0|: initial random sample measured on the testbed (paper: 500).
    pub initial_sample: usize,
    /// R: refinement iterations (paper default: 3).
    pub refine_iters: usize,
    /// k: hardware evaluations per refinement iteration.
    pub evals_per_iter: usize,
    pub nsga: Nsga2Params,
    pub gbt: GbtParams,
    pub toggles: Toggles,
    /// Ablation "- Predictive Models": skip surrogates, run NSGA-II
    /// against random-forest—free direct measurement of a small random
    /// subset (the paper's "random search" variant).
    pub use_surrogates: bool,
    /// Restriction of the configuration space (Table 3 ablations).
    pub mask: SpaceMask,
    /// Which search procedure proposes the candidates of lines 3–4
    /// (DESIGN.md §10).  NSGA-II is the paper default; `random`,
    /// `racing` and `local` trade surrogate guidance against
    /// measurement cost differently.
    pub strategy: StrategyKind,
    /// Worker count for every fan-out the coordinator drives: the
    /// initial-sample measurement batch, surrogate (re)fits, NSGA-II
    /// population evaluation, candidate-uncertainty scoring, and the
    /// per-iteration measurement batches.  Overrides the `parallelism`
    /// fields of `nsga`/`gbt`, and reaches the evaluator through
    /// [`EvalContext::parallelism`].  Defaults to all available cores;
    /// results are identical at every level (see `util::pool`).
    pub parallelism: Parallelism,
}

impl Default for AeLlmParams {
    fn default() -> Self {
        AeLlmParams {
            initial_sample: 300,
            refine_iters: 3,
            evals_per_iter: 12,
            nsga: Nsga2Params::default(),
            gbt: GbtParams::fast(),
            toggles: Toggles::default(),
            use_surrogates: true,
            mask: SpaceMask::default(),
            strategy: StrategyKind::Nsga2,
            parallelism: Parallelism::Auto,
        }
    }
}

impl AeLlmParams {
    /// Reduced setting for tests and quick demos.
    pub fn small() -> Self {
        AeLlmParams {
            initial_sample: 120,
            refine_iters: 2,
            evals_per_iter: 8,
            nsga: Nsga2Params::small(),
            ..Default::default()
        }
    }
}

/// Result of one AE-LLM optimization run.
#[derive(Clone)]
pub struct Outcome {
    /// P*: Pareto front with *measured* objectives.
    pub pareto: ParetoArchive,
    /// argmax-utility member of P* (Definition 4's c*).
    pub chosen: Config,
    pub chosen_objectives: Objectives,
    /// Eq. 4 utility and the composite efficiency score of `chosen`.
    pub chosen_utility: f64,
    pub chosen_efficiency_score: f64,
    /// Default-config reference used for normalization.
    pub reference: Reference,
    /// Total testbed measurements consumed (the paper's "search cost"):
    /// warm-start + strategy mid-round evals + per-round measurement
    /// batches + the Default fallback.
    pub testbed_evals: usize,
    /// Surrogate-prediction calls during the strategy's search phase
    /// (cheap evaluations).
    pub surrogate_evals: usize,
    /// Name of the [`SearchStrategy`] that proposed the candidates.
    pub strategy: &'static str,
    /// Expensive evaluations the strategy performed itself mid-round
    /// (racing rungs, direct-measurement NSGA-II); a subset of
    /// `testbed_evals`.
    pub strategy_evals: usize,
    /// Observer hypervolume queries this run answered (one per observed
    /// refinement iteration; 0 under a disabled observer, which skips
    /// the snapshot entirely).
    pub hv_queries: usize,
    /// How many of those queries actually recomputed the hypervolume:
    /// iterations whose measurement batch left the measured archive
    /// untouched reuse the previous value, change-gated on
    /// [`ParetoArchive::version`] (see [`HvGate`]).
    pub hv_recomputes: usize,
}

/// Reference-point factor for the observer's normalized hypervolume:
/// each minimized objective's reference coordinate is this multiple of
/// the Default configuration's value.
pub const HV_REF_FACTOR: f64 = 4.0;

/// Normalized hypervolume of a measured archive: objectives are
/// divided by the Default reference, accuracy enters negated (min
/// convention, reference coordinate 0), and the minimized dimensions
/// use a [`HV_REF_FACTOR`]× default reference point.  Entries worse
/// than the reference box contribute nothing.
pub fn pareto_hypervolume(archive: &ParetoArchive,
                          reference: &Reference) -> f64 {
    pareto_hypervolume_with(&mut HvScratch::default(), archive, reference)
}

/// [`pareto_hypervolume`] through a caller-owned arena — the
/// zero-allocation form for repeated queries (the observer loop).
pub fn pareto_hypervolume_with(scratch: &mut HvScratch,
                               archive: &ParetoArchive,
                               reference: &Reference) -> f64 {
    let d = reference.default;
    let denom = |v: f64| if v.abs() < 1e-12 { 1.0 } else { v };
    let pts: Vec<MinVec> = archive
        .entries()
        .iter()
        .map(|e| {
            let o = e.objectives;
            [
                -o.accuracy / denom(d.accuracy),
                o.latency_ms / denom(d.latency_ms),
                o.memory_gb / denom(d.memory_gb),
                o.energy_j / denom(d.energy_j),
            ]
        })
        .collect();
    let r: MinVec = [0.0, HV_REF_FACTOR, HV_REF_FACTOR, HV_REF_FACTOR];
    hypervolume::hypervolume_with(scratch, &pts, &r)
}

/// Change-gated per-iteration hypervolume for the observer loop.
///
/// [`pareto_hypervolume`] is a pure function of the archive's entry
/// list and the reference, and [`ParetoArchive::version`] changes
/// whenever that list does — so a query at an unchanged version can
/// return the previously computed value, which is trivially
/// bitwise-equal to what a recomputation would produce.  Iterations
/// whose measurement batch was entirely rejected (every candidate
/// dominated or infeasible) therefore skip the exact 4-D hypervolume
/// sweep.
///
/// One gate serves one (archive instance, reference) pair: versions
/// are per-instance counters, so reusing a gate across archives could
/// alias them.
pub struct HvGate {
    scratch: HvScratch,
    last: Option<(u64, f64)>,
    queries: usize,
    recomputes: usize,
}

impl HvGate {
    pub fn new() -> Self {
        HvGate {
            scratch: HvScratch::default(),
            last: None,
            queries: 0,
            recomputes: 0,
        }
    }

    /// The hypervolume of `archive` — recomputed only when its version
    /// moved since the last query.
    pub fn value(&mut self, archive: &ParetoArchive,
                 reference: &Reference) -> f64 {
        self.queries += 1;
        let version = archive.version();
        if let Some((seen, hv)) = self.last {
            if seen == version {
                return hv;
            }
        }
        self.recomputes += 1;
        let hv = pareto_hypervolume_with(&mut self.scratch, archive,
                                         reference);
        self.last = Some((version, hv));
        hv
    }

    /// Queries answered (reused + recomputed).
    pub fn queries(&self) -> usize {
        self.queries
    }

    /// Queries that ran the full hypervolume sweep.
    pub fn recomputes(&self) -> usize {
        self.recomputes
    }
}

impl Default for HvGate {
    fn default() -> Self {
        HvGate::new()
    }
}

/// Run Algorithm 1 on a scenario against its testbed oracle.  Testbed
/// measurement batches fan out over `params.parallelism` workers.
#[deprecated(
    note = "use an `Evaluator` with `optimize_with_observer`, or the \
            `AeLlm` builder; this shim clones the scenario's testbed"
)]
pub fn optimize(scenario: &Scenario, params: &AeLlmParams,
                rng: &mut Rng) -> Outcome {
    let mut evaluator = scenario.testbed.clone();
    let out = optimize_with_observer(scenario, params, &mut evaluator,
                                     &mut NullObserver, rng);
    debug_assert_eq!(out.testbed_evals, Evaluator::evals(&evaluator));
    out
}

/// Run Algorithm 1 with an arbitrary "actual hardware" closure — the
/// pre-`Evaluator` calling convention, kept for compatibility.
///
/// `measure` receives a whole batch of configurations (Algorithm 1
/// line 5 is a fan-out point) and must return exactly one `Objectives`
/// per input, in input order.
#[deprecated(
    note = "implement `Evaluator` (or wrap the closure in \
            `FnEvaluator`) and call `optimize_with_observer`"
)]
pub fn optimize_with<F>(
    scenario: &Scenario,
    params: &AeLlmParams,
    measure: &mut F,
    rng: &mut Rng,
) -> Outcome
where
    F: FnMut(&[Config], &mut Rng) -> Vec<Objectives>,
{
    let mut evaluator =
        FnEvaluator::new(|cs: &[Config], rng: &mut Rng| measure(cs, rng));
    optimize_with_observer(scenario, params, &mut evaluator,
                           &mut NullObserver, rng)
}

/// Run Algorithm 1 against any [`Evaluator`] backend, streaming one
/// [`IterationEvent`] per refinement iteration to `observer`.  The
/// search procedure is the one `params.strategy` names; use
/// [`optimize_with_strategy`] to inject a custom [`SearchStrategy`].
///
/// This is the primary entry point; [`super::AeLlm`] wraps it with a
/// builder-style surface and a serializable report.  Observer calls
/// are computed without touching `rng`, so an observed run is
/// bit-identical to an unobserved one, and the evaluator's RNG
/// discipline (see `crate::evaluator`) keeps the whole run identical
/// at every `params.parallelism` level.
pub fn optimize_with_observer(
    scenario: &Scenario,
    params: &AeLlmParams,
    evaluator: &mut dyn Evaluator,
    observer: &mut dyn RunObserver,
    rng: &mut Rng,
) -> Outcome {
    let mut strategy = params.strategy.build();
    optimize_with_strategy(scenario, params, strategy.as_mut(), evaluator,
                           observer, rng)
}

/// [`optimize_with_observer`] warm-started from a prior Pareto front
/// (DESIGN.md §12): the continual-adaptation re-search entry point.
/// `warm` entries are re-measured on `evaluator` under *this*
/// scenario — their archived objectives belong to the regime that
/// produced them — and seed the measured archive, the seen-set and
/// (when surrogates run) the training sample, whose random part
/// shrinks by `warm.len()` so the re-search costs no more than a cold
/// run.  An empty `warm` is byte-for-byte a cold run.
pub fn optimize_with_observer_warm(
    scenario: &Scenario,
    params: &AeLlmParams,
    warm: &[Entry],
    evaluator: &mut dyn Evaluator,
    observer: &mut dyn RunObserver,
    rng: &mut Rng,
) -> Outcome {
    let mut strategy = params.strategy.build();
    optimize_with_strategy_warm(scenario, params, strategy.as_mut(), warm,
                                evaluator, observer, rng)
}

/// Run Algorithm 1 with an explicit [`SearchStrategy`] instance (the
/// generalized form of [`optimize_with_observer`], for strategies not
/// reachable through [`StrategyKind`], e.g. baseline selectors or
/// user-defined procedures).
///
/// The coordinator owns the orchestration — surrogate warm-start
/// (line 1, skipped unless both `params.use_surrogates` and
/// [`SearchStrategy::uses_surrogates`] agree), the per-round
/// full-fidelity measurement batch (line 5), the measured Pareto
/// archive, surrogate updates (line 6) and observer events — while
/// `strategy.propose` covers lines 3–4.
pub fn optimize_with_strategy(
    scenario: &Scenario,
    params: &AeLlmParams,
    strategy: &mut dyn SearchStrategy,
    evaluator: &mut dyn Evaluator,
    observer: &mut dyn RunObserver,
    rng: &mut Rng,
) -> Outcome {
    optimize_with_strategy_warm(scenario, params, strategy, &[], evaluator,
                                observer, rng)
}

/// [`optimize_with_strategy`] with a warm-start front (DESIGN.md §12).
/// With `warm` empty this *is* the cold run — same RNG stream, same
/// evaluator calls — which is what keeps every pre-existing
/// bit-identity contract intact.  With entries present:
///
/// 1. `strategy.warm_start(warm)` fires (before any RNG use);
/// 2. the prior configurations are re-measured in one batch under this
///    scenario's context and seeded into the measured archive (the
///    front is *persistent*, but its objective values are not portable
///    across regimes — re-measurement re-anchors them);
/// 3. when the strategy warm-starts surrogates, the random initial
///    sample shrinks by the warm count, so the warm re-search fits the
///    cold budget ceiling; strategies without a surrogate warm-start
///    (racing, random) pay `warm.len()` extra measurements — the price
///    of re-anchoring the front — on top of their exact cold budgets.
pub fn optimize_with_strategy_warm(
    scenario: &Scenario,
    params: &AeLlmParams,
    strategy: &mut dyn SearchStrategy,
    warm: &[Entry],
    evaluator: &mut dyn Evaluator,
    observer: &mut dyn RunObserver,
    rng: &mut Rng,
) -> Outcome {
    let m = &scenario.model;
    let t = &scenario.task;
    let tb = &scenario.testbed;
    let mask = params.mask;
    let mut testbed_evals = 0usize;
    let mut surrogate_evals = 0usize;
    let mut strategy_evals = 0usize;

    // Reference for Eq. 4 normalization: the Default configuration.
    let default_cfg = Config::default_baseline();
    let reference = Reference {
        default: tb.true_objectives(&default_cfg, m, t),
    };

    // The coordinator-level knob governs every nested fan-out,
    // including the evaluator's own batch fan-out (via the context).
    let par = params.parallelism;
    let ctx = EvalContext::new(m, t, par);
    let gbt_params = GbtParams { parallelism: par, ..params.gbt };

    // Measured results accumulate here; P* is built from measurements,
    // never from raw surrogate (or cheap-fidelity) guesses.
    let mut measured = ParetoArchive::new(params.nsga.archive_capacity);
    let mut measured_configs: BTreeSet<Config> = Default::default();

    // ---- warm start from a prior front ----------------------------------
    let mut warm_samples: Vec<Sample> = Vec::new();
    if !warm.is_empty() {
        strategy.warm_start(warm);
        let mut warm_cfgs: Vec<Config> = Vec::with_capacity(warm.len());
        for e in warm {
            let c = mask.clamp(e.config);
            if !warm_cfgs.contains(&c) {
                warm_cfgs.push(c);
            }
        }
        testbed_evals += warm_cfgs.len();
        let objectives = evaluator.measure_batch(&warm_cfgs, &ctx, rng);
        assert_eq!(objectives.len(), warm_cfgs.len(),
                   "evaluator must return one Objectives per config");
        for (c, o) in warm_cfgs.iter().zip(objectives) {
            measured_configs.insert(*c);
            if tb.platform.feasible(o.memory_gb, tb.power_w(c, m, t)) {
                measured.insert(*c, o);
            }
            warm_samples.push(Sample {
                features: encode::encode(c, m, t),
                objectives: o,
            });
        }
    }

    // ---- line 1: initial sample + surrogate training --------------------
    let warm_start = params.use_surrogates && strategy.uses_surrogates();
    let mut surrogates: Option<SurrogateSet> = if warm_start {
        let fresh_n = params.initial_sample.saturating_sub(warm.len());
        let configs: Vec<Config> =
            crate::config::enumerate::sample_distinct(rng, fresh_n)
                .into_iter()
                .map(|c| mask.clamp(c))
                .collect();
        testbed_evals += configs.len();
        let objectives = evaluator.measure_batch(&configs, &ctx, rng);
        assert_eq!(objectives.len(), configs.len(),
                   "evaluator must return one Objectives per config");
        let mut samples: Vec<Sample> = configs
            .iter()
            .zip(objectives)
            .map(|(c, o)| Sample {
                features: encode::encode(c, m, t),
                objectives: o,
            })
            .collect();
        samples.append(&mut warm_samples);
        Some(SurrogateSet::fit(samples, gbt_params, rng))
    } else {
        None
    };

    let iters = strategy.rounds(params).max(1);

    // Change-gated observer hypervolume: iterations that leave the
    // measured archive untouched reuse the previous value.
    let mut hv_gate = HvGate::new();

    for iteration in 0..iters {
        // ---- lines 3+4: the strategy proposes this round's candidates ---
        let round = {
            let cx = StrategyCx {
                scenario,
                params,
                reference: &reference,
                surrogates: surrogates.as_ref(),
                measured: &measured,
                seen: &measured_configs,
                iteration,
                rounds: iters,
            };
            strategy.propose(&cx, evaluator, rng)
        };
        surrogate_evals += round.surrogate_evals;
        strategy_evals += round.strategy_evals;
        testbed_evals += round.strategy_evals;
        let candidates = round.proposals;

        // ---- lines 5+6: measure on hardware, update surrogates ----------
        testbed_evals += candidates.len();
        let objectives = evaluator.measure_batch(&candidates, &ctx, rng);
        assert_eq!(objectives.len(), candidates.len(),
                   "evaluator must return one Objectives per config");
        let mut fresh: Vec<Sample> = Vec::new();
        for (c, o) in candidates.into_iter().zip(objectives) {
            measured_configs.insert(c);
            if tb.platform.feasible(o.memory_gb, tb.power_w(&c, m, t)) {
                measured.insert(c, o);
            }
            fresh.push(Sample {
                features: encode::encode(&c, m, t),
                objectives: o,
            });
        }
        if let Some(sur) = &mut surrogates {
            if !fresh.is_empty() {
                sur.update(fresh, rng);
            }
        }

        // ---- observer hook: pure snapshot, no RNG consumption -----------
        // Gated so unobserved runs skip the hypervolume computation.
        if observer.enabled() {
            observer.on_iteration(&IterationEvent {
                iteration: iteration + 1,
                total_iterations: iters,
                front_size: measured.len(),
                hypervolume: hv_gate.value(&measured, &reference),
                testbed_evals,
                surrogate_evals,
            });
        }
    }

    // Always include the default as a fallback so `chosen` exists.
    {
        testbed_evals += 1;
        let o = evaluator.measure_batch(&[mask.clamp(default_cfg)], &ctx,
                                        rng)[0];
        measured.insert(mask.clamp(default_cfg), o);
    }

    // ---- line 8: select c* from the measured Pareto set -----------------
    let best = measured
        .best_by(|e| utility(&e.objectives, &reference, &scenario.prefs))
        .expect("archive non-empty");
    let chosen = best.config;
    let chosen_objectives = best.objectives;
    let chosen_utility = utility(&chosen_objectives, &reference,
                                 &scenario.prefs);
    let chosen_efficiency_score =
        efficiency_score(&chosen_objectives, &reference);

    Outcome {
        pareto: measured,
        chosen,
        chosen_objectives,
        chosen_utility,
        chosen_efficiency_score,
        reference,
        testbed_evals,
        surrogate_evals,
        strategy: strategy.name(),
        strategy_evals,
        hv_queries: hv_gate.queries(),
        hv_recomputes: hv_gate.recomputes(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::observer::CollectingObserver;
    use super::*;
    use crate::config::Precision;

    fn scenario() -> Scenario {
        Scenario::for_model("LLaMA-2-7B").unwrap()
    }

    /// Trait-path run against the scenario's testbed (what the
    /// deprecated `optimize` shim wraps).
    fn opt(s: &Scenario, params: &AeLlmParams, rng: &mut Rng) -> Outcome {
        let mut evaluator = s.testbed.clone();
        optimize_with_observer(s, params, &mut evaluator, &mut NullObserver,
                               rng)
    }

    #[test]
    fn optimizer_beats_default_utility() {
        let s = scenario();
        let mut rng = Rng::new(1);
        let out = opt(&s, &AeLlmParams::small(), &mut rng);
        let u_def = utility(&out.reference.default, &out.reference, &s.prefs);
        assert!(out.chosen_utility > u_def,
                "chosen={} default={u_def}", out.chosen_utility);
        assert!(out.chosen_efficiency_score > 1.3,
                "es={}", out.chosen_efficiency_score);
        assert_eq!(out.strategy, "nsga2");
        assert_eq!(out.strategy_evals, 0,
                   "surrogate-mode NSGA-II measures only through the \
                    coordinator");
    }

    #[test]
    fn accuracy_stays_within_paper_band() {
        // §4.2: "within 1.2% of the default configuration"
        let s = scenario();
        let mut rng = Rng::new(2);
        let out = opt(&s, &AeLlmParams::small(), &mut rng);
        let drop = out.reference.default.accuracy
            - out.chosen_objectives.accuracy;
        assert!(drop < 2.0, "accuracy drop {drop}");
    }

    #[test]
    fn surrogate_mode_uses_fewer_testbed_evals_than_direct() {
        let s = scenario();
        let mut rng = Rng::new(3);
        let with = opt(&s, &AeLlmParams::small(), &mut rng);
        let mut p_direct = AeLlmParams::small();
        p_direct.use_surrogates = false;
        let mut rng2 = Rng::new(3);
        let without = opt(&s, &p_direct, &mut rng2);
        // surrogate path: bounded by n0 + R*k + 1; direct path: a full
        // (small) NSGA-II of testbed calls
        assert!(with.surrogate_evals > 0);
        assert!(without.surrogate_evals == 0);
        assert!(with.testbed_evals
                <= 120 + 2 * 8 + 1 + 1,
                "testbed evals {}", with.testbed_evals);
        assert!(without.testbed_evals > 24 * 8,
                "direct evals {}", without.testbed_evals);
        // direct mode's NSGA-II measurements are strategy-internal:
        // total = strategy evals + (<= k proposals) + default fallback
        assert!(without.strategy_evals > 24 * 8,
                "strategy evals {}", without.strategy_evals);
        let extra = without.testbed_evals - without.strategy_evals;
        assert!((1..=8 + 1).contains(&extra),
                "direct evals {} vs strategy evals {}",
                without.testbed_evals, without.strategy_evals);
    }

    #[test]
    fn refinement_iterations_help() {
        let s = scenario().noiseless();
        let score_with_iters = |r: usize, seed: u64| {
            let mut p = AeLlmParams::small();
            p.refine_iters = r.max(1);
            p.evals_per_iter = if r == 0 { 1 } else { 10 };
            let mut rng = Rng::new(seed);
            opt(&s, &p, &mut rng).chosen_efficiency_score
        };
        // average over seeds to damp search stochasticity
        let mean = |r: usize| -> f64 {
            (0..4).map(|seed| score_with_iters(r, seed)).sum::<f64>() / 4.0
        };
        // Search stochasticity is real; require only that more
        // refinement is not systematically *worse* (Table 3's +8% trend
        // is verified at full budget by the table3 bench).
        let lo = mean(1);
        let hi = mean(3);
        assert!(hi >= lo - 0.30, "1 iter {lo} vs 3 iters {hi}");
    }

    #[test]
    fn mask_restricts_chosen_config() {
        let s = scenario();
        let mut p = AeLlmParams::small();
        p.mask = SpaceMask::without_quant();
        let mut rng = Rng::new(5);
        let out = opt(&s, &p, &mut rng);
        assert_eq!(out.chosen.inf.precision, Precision::Fp16);
        for e in out.pareto.entries() {
            assert_eq!(e.config.inf.precision, Precision::Fp16);
        }
    }

    #[test]
    fn mask_restricts_every_strategy() {
        let s = scenario();
        for kind in StrategyKind::ALL {
            let mut p = AeLlmParams::small();
            p.mask = SpaceMask::without_quant();
            p.strategy = kind;
            let mut rng = Rng::new(5);
            let out = opt(&s, &p, &mut rng);
            for e in out.pareto.entries() {
                assert_eq!(e.config.inf.precision, Precision::Fp16,
                           "{} leaked quantized config {}", kind.name(),
                           e.config);
            }
        }
    }

    #[test]
    fn chosen_is_feasible_on_platform() {
        let s = scenario();
        let mut rng = Rng::new(6);
        let out = opt(&s, &AeLlmParams::small(), &mut rng);
        assert!(out.chosen_objectives.memory_gb
                <= s.testbed.platform.mem_capacity_gb);
    }

    #[test]
    fn deterministic_given_seed() {
        let s = scenario();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let o1 = opt(&s, &AeLlmParams::small(), &mut r1);
        let o2 = opt(&s, &AeLlmParams::small(), &mut r2);
        assert_eq!(o1.chosen, o2.chosen);
        assert_eq!(o1.testbed_evals, o2.testbed_evals);
    }

    #[test]
    fn outcome_invariant_under_parallelism() {
        let s = scenario();
        let go = |par: Parallelism| {
            let p = AeLlmParams { parallelism: par, ..AeLlmParams::small() };
            let mut rng = Rng::new(13);
            let out = opt(&s, &p, &mut rng);
            let mut front: Vec<_> = out
                .pareto
                .entries()
                .iter()
                .map(|e| (e.config, format!("{:?}", e.objectives)))
                .collect();
            front.sort();
            (out.chosen, out.testbed_evals, out.surrogate_evals, front)
        };
        let seq = go(Parallelism::Sequential);
        let par4 = go(Parallelism::Threads(4));
        assert_eq!(seq, par4, "coordinator must be parallelism-invariant");
    }

    #[test]
    fn observer_streams_one_event_per_refinement_iteration() {
        let s = scenario();
        let params = AeLlmParams::small();
        let mut evaluator = s.testbed.clone();
        let mut obs = CollectingObserver::default();
        let mut rng = Rng::new(17);
        let out = optimize_with_observer(&s, &params, &mut evaluator,
                                         &mut obs, &mut rng);
        assert_eq!(obs.events.len(), params.refine_iters);
        for (i, e) in obs.events.iter().enumerate() {
            assert_eq!(e.iteration, i + 1);
            assert_eq!(e.total_iterations, params.refine_iters);
            assert!(e.front_size >= 1);
            assert!(e.hypervolume.is_finite() && e.hypervolume >= 0.0,
                    "hv={}", e.hypervolume);
        }
        // Cumulative counters are monotone and bounded by the outcome
        // (the final Default measurement lands after the last event).
        for w in obs.events.windows(2) {
            assert!(w[1].testbed_evals >= w[0].testbed_evals);
            assert!(w[1].surrogate_evals >= w[0].surrogate_evals);
        }
        let last = obs.events.last().unwrap();
        assert_eq!(last.testbed_evals + 1, out.testbed_evals);
        assert_eq!(last.surrogate_evals, out.surrogate_evals);
    }

    #[test]
    fn observed_run_is_bit_identical_to_unobserved() {
        let s = scenario();
        let params = AeLlmParams::small();
        let run = |observe: bool| {
            let mut evaluator = s.testbed.clone();
            let mut rng = Rng::new(23);
            let out = if observe {
                let mut obs = CollectingObserver::default();
                optimize_with_observer(&s, &params, &mut evaluator,
                                       &mut obs, &mut rng)
            } else {
                optimize_with_observer(&s, &params, &mut evaluator,
                                       &mut NullObserver, &mut rng)
            };
            (out.chosen, format!("{:?}", out.chosen_objectives),
             out.testbed_evals, out.surrogate_evals)
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn hv_gate_reuses_value_only_while_archive_is_unchanged() {
        let s = scenario().noiseless();
        let reference = Reference {
            default: s.testbed.true_objectives(
                &Config::default_baseline(), &s.model, &s.task),
        };
        let mut archive = ParetoArchive::new(16);
        archive.insert(Config::default_baseline(), reference.default);
        let mut gate = HvGate::new();

        // First query computes; an unchanged archive reuses the value
        // bitwise.
        let hv0 = gate.value(&archive, &reference);
        let hv1 = gate.value(&archive, &reference);
        assert_eq!(hv0.to_bits(), hv1.to_bits());
        assert_eq!((gate.queries(), gate.recomputes()), (2, 1));
        assert_eq!(hv0.to_bits(),
                   pareto_hypervolume(&archive, &reference).to_bits());

        // A rejected (dominated) candidate leaves the version alone.
        let worse = Objectives {
            accuracy: reference.default.accuracy - 1.0,
            latency_ms: reference.default.latency_ms * 2.0,
            memory_gb: reference.default.memory_gb * 2.0,
            energy_j: reference.default.energy_j * 2.0,
        };
        let mut c = Config::default_baseline();
        c.inf.precision = Precision::Int4;
        let v = archive.version();
        assert!(!archive.insert(c, worse));
        assert_eq!(archive.version(), v);
        gate.value(&archive, &reference);
        assert_eq!((gate.queries(), gate.recomputes()), (3, 1));

        // An accepted candidate bumps the version and forces a
        // recompute that matches the ungated function bitwise.
        let better = Objectives {
            accuracy: reference.default.accuracy + 1.0,
            latency_ms: reference.default.latency_ms * 0.5,
            memory_gb: reference.default.memory_gb * 0.5,
            energy_j: reference.default.energy_j * 0.5,
        };
        let mut c2 = Config::default_baseline();
        c2.inf.precision = Precision::Int8;
        assert!(archive.insert(c2, better));
        assert!(archive.version() > v);
        let hv2 = gate.value(&archive, &reference);
        assert_eq!((gate.queries(), gate.recomputes()), (4, 2));
        assert_eq!(hv2.to_bits(),
                   pareto_hypervolume(&archive, &reference).to_bits());
        assert!(hv2 > hv0);
    }

    #[test]
    fn observed_run_counts_gate_activity() {
        let s = scenario();
        let params = AeLlmParams::small();
        let mut evaluator = s.testbed.clone();
        let mut obs = CollectingObserver::default();
        let mut rng = Rng::new(29);
        let out = optimize_with_observer(&s, &params, &mut evaluator,
                                         &mut obs, &mut rng);
        // One query per observed refinement iteration, never more
        // recomputes than queries.
        assert_eq!(out.hv_queries, params.refine_iters);
        assert!(out.hv_recomputes >= 1);
        assert!(out.hv_recomputes <= out.hv_queries);
        // A disabled observer skips the snapshot (and the gate) fully.
        let mut rng2 = Rng::new(29);
        let mut ev2 = s.testbed.clone();
        let silent = optimize_with_observer(&s, &params, &mut ev2,
                                            &mut NullObserver, &mut rng2);
        assert_eq!(silent.hv_queries, 0);
        assert_eq!(silent.hv_recomputes, 0);
        // The gate is invisible to everything else.
        assert_eq!(out.chosen, silent.chosen);
        assert_eq!(out.testbed_evals, silent.testbed_evals);
    }

    #[test]
    fn run_report_json_is_byte_identical_across_parallelism() {
        // The full-pipeline contract behind `search --json`: the
        // serialized report (wall-clock zeroed — the one field that
        // legitimately differs) is byte-identical at Parallelism 1
        // and 4, observer events and their gated hypervolumes
        // included.
        use super::super::AeLlm;
        let dump = |par: Parallelism| -> String {
            let p = AeLlmParams { parallelism: par, ..AeLlmParams::small() };
            let mut report = AeLlm::for_model("LLaMA-2-7B")
                .unwrap()
                .params(p)
                .seed(41)
                .run_testbed();
            report.wall_ms = 0.0;
            report.to_json().dump()
        };
        assert_eq!(dump(Parallelism::Sequential),
                   dump(Parallelism::Threads(4)));
    }

    #[test]
    fn hypervolume_grows_with_a_dominating_entry() {
        let s = scenario().noiseless();
        let reference = Reference {
            default: s.testbed.true_objectives(
                &Config::default_baseline(), &s.model, &s.task),
        };
        let mut archive = ParetoArchive::new(16);
        archive.insert(Config::default_baseline(), reference.default);
        let hv0 = pareto_hypervolume(&archive, &reference);
        // A strictly better point must enlarge the dominated volume.
        let better = Objectives {
            accuracy: reference.default.accuracy + 1.0,
            latency_ms: reference.default.latency_ms * 0.5,
            memory_gb: reference.default.memory_gb * 0.5,
            energy_j: reference.default.energy_j * 0.5,
        };
        let mut c = Config::default_baseline();
        c.inf.precision = Precision::Int8;
        archive.insert(c, better);
        let hv1 = pareto_hypervolume(&archive, &reference);
        assert!(hv1 > hv0, "hv {hv0} -> {hv1}");
    }
}
