//! The continual-adaptation controller (DESIGN.md §12): the loop that
//! closes search → deploy → serve back onto search.
//!
//! One-shot `run_and_deploy` treats the Pareto front as a terminal
//! artifact; this module makes it a *living* one.  Serving runs in
//! epochs on the virtual clock ([`crate::runtime::EpochFleet`]); each
//! epoch emits an [`EpochTelemetry`]; an EWMA drift detector
//! ([`crate::runtime::DriftDetector`]) watches the workload shape; and
//! when the shape departs from baseline, the controller
//!
//! 1. re-scopes the scenario's task descriptor to the *observed*
//!    workload (prompt lengths, class mix) so the oracle prices
//!    configurations for the traffic that actually arrived,
//! 2. re-searches warm-started from the persistent front
//!    ([`optimize_with_observer_warm`]), and
//! 3. hot-swaps the deployment
//!    ([`crate::runtime::Deployment::refresh_from_front`] + a lane
//!    re-plan) without dropping queued requests.
//!
//! Everything is a deterministic function of (scenario, workload kind,
//! seed, params): the [`AdaptReport`] serializes byte-identically for
//! the same seed at every parallelism level (no wall-clock fields —
//! that is deliberate).
//!
//! Since the event-core refactor (DESIGN.md §13) the epoch loop runs
//! on the deterministic event heap: every request is an `Arrival`
//! event, every epoch end an `EpochBoundary` event at the epoch's last
//! arrival timestamp — pushed *between* that epoch's arrivals and the
//! next epoch's, so the `(time, seq)` tie-break reproduces the old
//! index-sliced loop exactly.  [`run_adapt_from_polled`] keeps the
//! pre-refactor loop as the reference the golden-report test compares
//! byte-for-byte against.

use crate::runtime::drift::{DriftDetector, EpochTelemetry, DRIFT_ALPHA,
                            DRIFT_THRESHOLD};
use crate::runtime::events::{Event, EventQueue};
use crate::runtime::fleet::{infeasible_class_at, lane_plan, EpochFleet,
                            EpochOutcome, RedeployPlan};
use crate::runtime::serve::DrainDriver;
use crate::runtime::workload::default_rate_rps;
use crate::runtime::{ServeReport, Workload, WorkloadKind};
use crate::search::archive::ParetoArchive;
use crate::store::{CatalogKey, Store, StoreError};
use crate::tasks::{Category, TaskSpec};
use crate::util::json::Json;
use crate::util::Rng;

use super::algorithm1::{optimize_with_observer_warm, Outcome};
use super::observer::NullObserver;
use super::scenario::Scenario;
use super::session::{AeLlm, AeLlmError};

/// Controller knobs.  Defaults give six epochs of 400 requests — long
/// enough for the drifting scenarios to move regimes mid-run with
/// whole epochs on each side of the transition.
#[derive(Clone, Copy, Debug)]
pub struct AdaptParams {
    /// Serving epochs (drift decisions happen at epoch boundaries).
    pub epochs: usize,
    /// Requests generated per epoch.
    pub requests_per_epoch: usize,
    /// EWMA smoothing of the drift baseline.
    pub ewma_alpha: f64,
    /// Drift threshold (see [`DriftDetector`] scoring).
    pub drift_threshold: f64,
    /// Total serving lanes split across the fleet's slots.
    pub lane_budget: usize,
    /// `false` = the one-shot baseline: same initial search, same
    /// epoch-0 deployment and lane plan, but drift never triggers
    /// re-search or re-deployment.  The comparison `table --id 9`
    /// reports.
    pub adaptive: bool,
}

impl Default for AdaptParams {
    fn default() -> AdaptParams {
        AdaptParams {
            epochs: 6,
            requests_per_epoch: 400,
            ewma_alpha: DRIFT_ALPHA,
            drift_threshold: DRIFT_THRESHOLD,
            lane_budget: 6,
            adaptive: true,
        }
    }
}

impl AdaptParams {
    /// One-shot baseline variant of these parameters.
    pub fn one_shot(self) -> AdaptParams {
        AdaptParams { adaptive: false, ..self }
    }
}

/// One epoch's row in the [`AdaptReport`].
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub telemetry: EpochTelemetry,
    /// Serve statistics over exactly this epoch's completions.
    pub report: ServeReport,
    pub drift_score: f64,
    pub drifted: bool,
    /// A re-search ran and the fleet was hot-swapped after this epoch.
    pub redeployed: bool,
    /// Size of the (persistent) front after this epoch's decision.
    pub front_size: usize,
    /// Per-slot lane provisioning in force after this epoch's decision.
    pub lanes: Vec<usize>,
}

pub const ADAPT_REPORT_SCHEMA: &str = "ae-llm.adapt-report/v1";

/// Everything one adaptation run produced (schema
/// `ae-llm.adapt-report/v1`; `ae-llm adapt --json`).  Deliberately
/// wall-clock-free: same seed → byte-identical JSON.
#[derive(Clone, Debug)]
pub struct AdaptReport {
    pub model: String,
    /// Workload scenario name.
    pub scenario: String,
    /// `continual` or `one-shot`.
    pub mode: String,
    pub seed: u64,
    pub epochs: Vec<EpochRecord>,
    /// Total searches (the initial one plus every drift-triggered
    /// re-search).
    pub searches: usize,
    pub redeployments: usize,
    /// Whole-run serve statistics across every epoch.
    pub overall: ServeReport,
    /// The persistent front as of the end of the run
    /// (schema `ae-llm.front/v1` when serialized).
    pub final_front: ParetoArchive,
}

impl AdaptReport {
    /// Serialize (schema `ae-llm.adapt-report/v1`; field reference in
    /// docs/SCHEMAS.md).  Same-seed runs dump byte-identical JSON.
    pub fn to_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        root.insert("schema".into(), Json::Str(ADAPT_REPORT_SCHEMA.into()));
        root.insert("model".into(), Json::Str(self.model.clone()));
        root.insert("scenario".into(), Json::Str(self.scenario.clone()));
        root.insert("mode".into(), Json::Str(self.mode.clone()));
        // String, not Num: Json numbers are f64 and would corrupt
        // seeds above 2^53 (same convention as RunReport).
        root.insert("seed".into(), Json::Str(self.seed.to_string()));
        let epochs: Vec<Json> = self
            .epochs
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("epoch".into(), Json::Num(e.epoch as f64));
                m.insert("telemetry".into(), e.telemetry.to_json());
                m.insert("report".into(), e.report.to_json());
                m.insert("drift_score".into(), Json::Num(e.drift_score));
                m.insert("drifted".into(), Json::Bool(e.drifted));
                m.insert("redeployed".into(), Json::Bool(e.redeployed));
                m.insert("front_size".into(),
                         Json::Num(e.front_size as f64));
                m.insert(
                    "lanes".into(),
                    Json::Arr(e.lanes.iter()
                        .map(|&l| Json::Num(l as f64)).collect()),
                );
                Json::Obj(m)
            })
            .collect();
        root.insert("epochs".into(), Json::Arr(epochs));
        root.insert("searches".into(), Json::Num(self.searches as f64));
        root.insert("redeployments".into(),
                    Json::Num(self.redeployments as f64));
        root.insert("overall".into(), self.overall.to_json());
        root.insert("front".into(), self.final_front.to_json());
        Json::Obj(root)
    }
}

/// Re-scope a task descriptor to the observed workload shape: the
/// oracle's `EvalContext` carries (model, task), and the task's
/// sequence length / category are what make its cost landscape — so a
/// re-search under the re-scoped task selects configurations for the
/// traffic that actually arrived, not for the static scenario the run
/// was launched with.
pub fn rescope_task(base: &TaskSpec, telemetry: &EpochTelemetry)
                    -> TaskSpec {
    // prompt + completion allowance; the clamp keeps the descriptor in
    // the band the cost model is calibrated for
    let seq_len = (2.0 * telemetry.mean_seq).clamp(256.0, 16384.0) as u32;
    let category = if telemetry.class_share[2] > 0.30 {
        Category::LongContext
    } else {
        base.category
    };
    TaskSpec {
        name: "Observed",
        category,
        seq_len,
        ..base.clone()
    }
}

/// Run the adaptation loop.  `seed` drives everything: the initial
/// search (through `session`), the workload, the epoch fleet and every
/// re-search (each gets a distinct derived stream).
pub fn run_adapt(session: &AeLlm, seed: u64, kind: WorkloadKind,
                 params: &AdaptParams) -> Result<AdaptReport, AeLlmError> {
    let outcome = session.run_testbed_outcome();
    run_adapt_from(session, seed, kind, params, &outcome)
}

/// [`run_adapt`] starting from a precomputed epoch-0 search outcome.
/// The outcome depends only on (session, seed) — not on workload kind
/// or adaptivity — so comparisons like `table --id 9` (2 scenarios ×
/// 2 modes) search once and reuse it, which is also what makes the
/// one-shot baseline *provably* share the continual run's epoch-0
/// front.  Runs on the event core ([`EventQueue`]); the pre-refactor
/// loop survives as [`run_adapt_from_polled`].
pub fn run_adapt_from(session: &AeLlm, seed: u64, kind: WorkloadKind,
                      params: &AdaptParams, outcome: &Outcome)
                      -> Result<AdaptReport, AeLlmError> {
    run_adapt_impl(session, seed, kind, params, outcome,
                   DrainDriver::Event)
}

/// [`run_adapt`] against a persistent [`Store`] (the fleet-wide warm
/// re-search loop): the epoch-0 search warm-starts from the catalog's
/// best front for a similar scenario — byte-for-byte the cold path
/// when the catalog has no hit — and every searched front (epoch 0
/// plus each drift-triggered re-search) is persisted and indexed as
/// it is produced, so the catalog's final entry is always the run's
/// final front.
///
/// Store writes happen strictly *after* each search has consumed its
/// RNG stream, so given the same warm entries the report is
/// byte-identical to the purely in-memory path
/// ([`AeLlm::run_testbed_outcome_warm`] + [`run_adapt_from`]) at every
/// parallelism level — the contract tests/integration_store.rs proves.
/// Mid-run store failures are captured, the serve loop finishes, and
/// the first failure surfaces as [`AeLlmError::Store`].
pub fn run_adapt_stored(session: &AeLlm, seed: u64, kind: WorkloadKind,
                        params: &AdaptParams, store: &mut Store)
                        -> Result<AdaptReport, AeLlmError> {
    let key = session.store_key(kind.name());
    let warm = store.warm_entries(&key, seed)?;
    let outcome = session.run_testbed_outcome_warm(&warm);
    store.put_front(&key, seed, &outcome.pareto)?;
    let mut persist = Persist { store, key, seed, error: None };
    let report = run_adapt_impl_persist(session, seed, kind, params,
                                        &outcome, DrainDriver::Event,
                                        Some(&mut persist))?;
    match persist.error {
        Some(e) => Err(e.into()),
        None => Ok(report),
    }
}

/// The PR 5 reference implementation: index-sliced epoch loop on the
/// pooled drain path.  Kept so the golden-report test can prove the
/// event core's [`AdaptReport`] is byte-identical to pre-refactor
/// output; not a serving path anything else should use.
#[doc(hidden)]
pub fn run_adapt_from_polled(session: &AeLlm, seed: u64,
                             kind: WorkloadKind, params: &AdaptParams,
                             outcome: &Outcome)
                             -> Result<AdaptReport, AeLlmError> {
    run_adapt_impl(session, seed, kind, params, outcome,
                   DrainDriver::Polled)
}

/// Mutable controller state threaded through the epoch boundaries.
struct LoopState {
    fleet: EpochFleet,
    detector: DriftDetector,
    front: ParetoArchive,
    searches: usize,
    retry_swap: bool,
    records: Vec<EpochRecord>,
}

/// Store-persistence context for [`run_adapt_stored`]: where
/// re-searched fronts are filed.  The first write error is captured
/// here instead of aborting the serve loop mid-epoch (the run's
/// *results* are sound either way — persistence is a side effect).
struct Persist<'a> {
    store: &'a mut Store,
    key: CatalogKey,
    seed: u64,
    error: Option<StoreError>,
}

impl Persist<'_> {
    fn put_front(&mut self, front: &ParetoArchive) {
        if self.error.is_none() {
            if let Err(e) =
                self.store.put_front(&self.key, self.seed, front)
            {
                self.error = Some(e);
            }
        }
    }
}

fn run_adapt_impl(session: &AeLlm, seed: u64, kind: WorkloadKind,
                  params: &AdaptParams, outcome: &Outcome,
                  driver: DrainDriver) -> Result<AdaptReport, AeLlmError> {
    run_adapt_impl_persist(session, seed, kind, params, outcome, driver,
                           None)
}

fn run_adapt_impl_persist(session: &AeLlm, seed: u64, kind: WorkloadKind,
                          params: &AdaptParams, outcome: &Outcome,
                          driver: DrainDriver,
                          mut persist: Option<&mut Persist>)
                          -> Result<AdaptReport, AeLlmError> {
    let scenario = session.scenario();
    let par = session.params_ref().parallelism;

    // ---- epoch 0 state: deploy the precomputed search ------------------
    let policy = session.slo_policy();
    let deployment = session.deploy_with(outcome, &policy)?;
    // Provision lanes for the scenario's *starting* regime — the best
    // static choice, so the one-shot baseline is not a strawman.
    let plan = lane_plan(&kind.mix_at(0.0), deployment.slots(),
                         params.lane_budget);
    let deployment = deployment.with_lane_plan(&plan);

    let rate = default_rate_rps(outcome.reference.default.latency_ms);
    let n_epochs = params.epochs.max(1);
    let per_epoch = params.requests_per_epoch.max(1);
    let requests =
        Workload::new(kind, rate, n_epochs * per_epoch, seed).generate();

    let mut state = LoopState {
        fleet: EpochFleet::new(deployment, seed, par).with_driver(driver),
        detector: DriftDetector::new(params.ewma_alpha,
                                     params.drift_threshold),
        front: outcome.pareto.clone(),
        searches: 1,
        // A drift whose swap was refused (infeasible front) retries
        // next epoch even if the detector's EWMA has since absorbed
        // the shift.
        retry_swap: false,
        records: Vec::with_capacity(n_epochs),
    };

    match driver {
        DrainDriver::Event => {
            // ---- the loop as events: every request an Arrival, every
            // epoch end an EpochBoundary at the epoch's last arrival
            // timestamp.  Boundaries are pushed between their epoch's
            // arrivals and the next epoch's, so ties resolve exactly
            // like the index-sliced loop: a next-epoch request sharing
            // the boundary's timestamp still arrives *after* the drain.
            let mut queue: EventQueue<Event> = EventQueue::new();
            let mut boundary = 0.0f64;
            for epoch in 0..n_epochs {
                let lo = epoch * per_epoch;
                for (k, r) in requests[lo..lo + per_epoch].iter()
                    .enumerate()
                {
                    queue.push(r.arrival_ms,
                               Event::Arrival { index: lo + k });
                }
                boundary = requests[lo + per_epoch - 1]
                    .arrival_ms
                    .max(boundary);
                queue.push(boundary, Event::EpochBoundary { epoch });
            }
            while let Some((_t, _seq, ev)) = queue.pop() {
                match ev {
                    Event::Arrival { index } => {
                        state.fleet.submit(requests[index].clone());
                    }
                    Event::EpochBoundary { epoch } => {
                        let out = state.fleet.close_epoch(epoch);
                        epoch_boundary(session, seed, params, n_epochs,
                                       epoch, out, &mut state,
                                       persist.as_deref_mut());
                    }
                    Event::BatchClose { .. }
                    | Event::BatchComplete { .. } => {
                        unreachable!("batch events live inside drains")
                    }
                }
            }
        }
        DrainDriver::Polled => {
            // ---- the PR 5 loop: serve, sense, re-search, swap ----------
            for epoch in 0..n_epochs {
                let slice =
                    &requests[epoch * per_epoch..(epoch + 1) * per_epoch];
                let out = state.fleet.serve_epoch(epoch, slice);
                epoch_boundary(session, seed, params, n_epochs, epoch,
                               out, &mut state, persist.as_deref_mut());
            }
        }
    }

    Ok(AdaptReport {
        model: scenario.model.name.to_string(),
        scenario: kind.name().to_string(),
        mode: if params.adaptive { "continual" } else { "one-shot" }
            .to_string(),
        seed,
        epochs: state.records,
        searches: state.searches,
        redeployments: state.fleet.redeployments(),
        overall: state.fleet.overall_report(),
        final_front: state.front,
    })
}

/// The decision block at every epoch boundary: observe drift,
/// re-search + hot-swap when warranted, record the epoch.  When a
/// persistence context is present, each re-searched front is filed in
/// the store — strictly after the re-search consumed its RNG, so
/// persistence never perturbs the deterministic streams.
fn epoch_boundary(session: &AeLlm, seed: u64, params: &AdaptParams,
                  n_epochs: usize, epoch: usize, out: EpochOutcome,
                  state: &mut LoopState, persist: Option<&mut Persist>) {
    let scenario = session.scenario();
    let decision = state.detector.observe(&out.telemetry);

    let mut redeployed = false;
    // Re-searching after the final epoch would adapt to traffic
    // that will never arrive.
    if params.adaptive
        && (decision.drifted || state.retry_swap)
        && epoch + 1 < n_epochs
    {
        let observed = Scenario {
            model: scenario.model.clone(),
            task: rescope_task(&scenario.task, &out.telemetry),
            testbed: scenario.testbed.clone(),
            prefs: scenario.prefs,
        };
        let warm: Vec<_> = state.front.entries().to_vec();
        let mut evaluator = observed.testbed.clone();
        let mut rng = Rng::new(seed ^ (epoch as u64 + 1)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let re = optimize_with_observer_warm(
            &observed, session.params_ref(), &warm, &mut evaluator,
            &mut NullObserver, &mut rng);
        state.searches += 1;
        state.front = re.pareto;
        if let Some(p) = persist {
            p.put_front(&state.front);
        }
        let plan = RedeployPlan::from_telemetry(
            &out.telemetry, state.fleet.deployment().slots(),
            params.lane_budget);
        // Same gate deploy_with applies on the epoch-0 path —
        // priced at the shape the swap would actually deploy
        // (plan.long_seq, not the class default).  A front that
        // cannot serve a class must not be hot-swapped in: keep
        // the current deployment and retry with a fresh re-search
        // next epoch (the retry flag carries the intent — the
        // detector's EWMA baseline absorbs a persisting shift
        // within a couple of epochs, so it cannot).
        let feasible = infeasible_class_at(
            &state.front, state.fleet.deployment().policy(),
            plan.long_seq)
            .is_none();
        let mut refreshed = state.fleet.deployment().clone();
        if feasible
            && refreshed.refresh_from_front(&state.front,
                                            Some(&plan)).is_ok()
        {
            state.fleet.redeploy(refreshed);
            state.detector.rebase(&out.telemetry);
            redeployed = true;
            state.retry_swap = false;
        } else {
            state.retry_swap = true;
        }
    }

    state.records.push(EpochRecord {
        epoch,
        telemetry: out.telemetry,
        report: out.report,
        drift_score: decision.score,
        drifted: decision.drifted,
        redeployed,
        front_size: state.front.len(),
        lanes: state.fleet.deployment().slots().iter().map(|s| s.lanes)
            .collect(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::drift::SEQ_BUCKETS;
    use crate::tasks::blended_task;

    fn telemetry(share: [f64; 3], mean_seq: f64) -> EpochTelemetry {
        EpochTelemetry {
            epoch: 0,
            requests: 100,
            class_counts: [0; 3],
            class_share: share,
            rate_rps: 20.0,
            mean_seq,
            max_seq: mean_seq as usize,
            seq_hist: [0; SEQ_BUCKETS],
            completed: 100,
            violations: 0,
            violation_rate: 0.0,
            truncated: 0,
            p95_latency_ms: 10.0,
            energy_j: 1.0,
            span_ms: 100.0,
        }
    }

    #[test]
    fn rescope_tracks_observed_shape() {
        let base = blended_task();
        // chat-era traffic: short prompts, category preserved
        let chat = rescope_task(&base, &telemetry([0.8, 0.17, 0.03], 150.0));
        assert_eq!(chat.name, "Observed");
        assert_eq!(chat.seq_len, 300);
        assert_eq!(chat.category, base.category);
        assert_eq!(chat.quant_sensitivity, base.quant_sensitivity);
        // long-heavy traffic: the descriptor goes long-context
        let long = rescope_task(&base,
                                &telemetry([0.25, 0.15, 0.60], 1100.0));
        assert_eq!(long.seq_len, 2200);
        assert_eq!(long.category, Category::LongContext);
        // clamps hold at the extremes
        assert_eq!(rescope_task(&base, &telemetry([1.0, 0.0, 0.0], 10.0))
                       .seq_len, 256);
        assert_eq!(rescope_task(&base, &telemetry([0.0, 0.0, 1.0], 99999.0))
                       .seq_len, 16384);
    }

    #[test]
    fn adapt_params_one_shot_flips_only_adaptivity() {
        let p = AdaptParams::default();
        let o = p.one_shot();
        assert!(p.adaptive && !o.adaptive);
        assert_eq!(p.epochs, o.epochs);
        assert_eq!(p.lane_budget, o.lane_budget);
    }
}
