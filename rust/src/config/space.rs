//! The AE-LLM configuration space (paper §3.2, Table 1).
//!
//! A configuration `c = (c_arch, c_ft, c_inf)` combines choices across
//! the three lifecycle stages.  The enums below mirror Table 1 exactly:
//!
//! | stage        | axis          | options                                   |
//! |--------------|---------------|-------------------------------------------|
//! | architecture | attention     | MHA, MQA, GQA, MLA                        |
//! | architecture | MoE           | dense, sparse-MoE {2,4,8} × top-{1,2}     |
//! | fine-tuning  | method        | Full, LoRA, QLoRA, DoRA, RSLoRA           |
//! | fine-tuning  | rank / alpha  | r ∈ {8..128}, α ∈ {r, 2r, 4r}             |
//! | inference    | quantization  | {FP16, FP8, INT8, INT4} × {GPTQ,AWQ,SQ}   |
//! | inference    | KV cache      | Full, MQA-style, GQA-style                |

use std::fmt;

/// Attention mechanism (architecture stage).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Attention {
    Mha,
    Mqa,
    Gqa,
    Mla,
}

impl Attention {
    pub const ALL: [Attention; 4] =
        [Attention::Mha, Attention::Mqa, Attention::Gqa, Attention::Mla];

    /// Fraction of full KV heads this variant keeps (drives KV-cache
    /// memory and bandwidth in the cost model).  GQA assumes the common
    /// groups-of-4 setting; MLA's latent cache is ~1/8 of full KV.
    pub fn kv_fraction(self) -> f64 {
        match self {
            Attention::Mha => 1.0,
            Attention::Gqa => 0.25,
            Attention::Mqa => 0.125, // one head of a typical 8-head group
            Attention::Mla => 0.125,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Attention::Mha => "MHA",
            Attention::Mqa => "MQA",
            Attention::Gqa => "GQA",
            Attention::Mla => "MLA",
        }
    }
}

/// Mixture-of-experts setting (architecture stage).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MoE {
    Dense,
    /// `experts` total, `top_k` active per token.
    Sparse { experts: u8, top_k: u8 },
}

impl MoE {
    pub const ALL: [MoE; 7] = [
        MoE::Dense,
        MoE::Sparse { experts: 2, top_k: 1 },
        MoE::Sparse { experts: 2, top_k: 2 },
        MoE::Sparse { experts: 4, top_k: 1 },
        MoE::Sparse { experts: 4, top_k: 2 },
        MoE::Sparse { experts: 8, top_k: 1 },
        MoE::Sparse { experts: 8, top_k: 2 },
    ];

    pub fn experts(self) -> u8 {
        match self {
            MoE::Dense => 1,
            MoE::Sparse { experts, .. } => experts,
        }
    }

    pub fn active(self) -> u8 {
        match self {
            MoE::Dense => 1,
            MoE::Sparse { top_k, .. } => top_k,
        }
    }

    pub fn is_sparse(self) -> bool {
        !matches!(self, MoE::Dense)
    }

    pub fn name(self) -> String {
        match self {
            MoE::Dense => "Dense".into(),
            MoE::Sparse { experts, top_k } => {
                format!("MoE{experts}t{top_k}")
            }
        }
    }
}

/// Fine-tuning method (fine-tuning stage).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FtMethod {
    Full,
    LoRA,
    QLoRA,
    DoRA,
    RsLoRA,
}

impl FtMethod {
    pub const ALL: [FtMethod; 5] = [
        FtMethod::Full,
        FtMethod::LoRA,
        FtMethod::QLoRA,
        FtMethod::DoRA,
        FtMethod::RsLoRA,
    ];

    pub fn is_peft(self) -> bool {
        !matches!(self, FtMethod::Full)
    }

    pub fn name(self) -> &'static str {
        match self {
            FtMethod::Full => "Full",
            FtMethod::LoRA => "LoRA",
            FtMethod::QLoRA => "QLoRA",
            FtMethod::DoRA => "DoRA",
            FtMethod::RsLoRA => "RSLoRA",
        }
    }
}

/// LoRA rank options.
pub const RANKS: [u16; 5] = [8, 16, 32, 64, 128];
/// Alpha multiplier options (alpha = mult * rank).
pub const ALPHA_MULTS: [u8; 3] = [1, 2, 4];

/// Weight precision (inference stage).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    Fp16,
    Fp8,
    Int8,
    Int4,
}

impl Precision {
    pub const ALL: [Precision; 4] =
        [Precision::Fp16, Precision::Fp8, Precision::Int8, Precision::Int4];

    pub fn bytes_per_weight(self) -> f64 {
        match self {
            Precision::Fp16 => 2.0,
            Precision::Fp8 => 1.0,
            Precision::Int8 => 1.0,
            Precision::Int4 => 0.5,
        }
    }

    pub fn bits(self) -> u8 {
        match self {
            Precision::Fp16 => 16,
            Precision::Fp8 => 8,
            Precision::Int8 => 8,
            Precision::Int4 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp16 => "FP16",
            Precision::Fp8 => "FP8",
            Precision::Int8 => "INT8",
            Precision::Int4 => "INT4",
        }
    }
}

/// Post-training quantization algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum QuantMethod {
    Gptq,
    Awq,
    SmoothQuant,
}

impl QuantMethod {
    pub const ALL: [QuantMethod; 3] =
        [QuantMethod::Gptq, QuantMethod::Awq, QuantMethod::SmoothQuant];

    pub fn name(self) -> &'static str {
        match self {
            QuantMethod::Gptq => "GPTQ",
            QuantMethod::Awq => "AWQ",
            QuantMethod::SmoothQuant => "SmoothQuant",
        }
    }
}

/// KV-cache layout policy (inference stage; independent of the trained
/// attention architecture — e.g. post-hoc GQA-style cache sharing).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KvCache {
    Full,
    GqaStyle,
    MqaStyle,
}

impl KvCache {
    pub const ALL: [KvCache; 3] =
        [KvCache::Full, KvCache::GqaStyle, KvCache::MqaStyle];

    pub fn fraction(self) -> f64 {
        match self {
            KvCache::Full => 1.0,
            KvCache::GqaStyle => 0.25,
            KvCache::MqaStyle => 0.125,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvCache::Full => "Full",
            KvCache::GqaStyle => "GQA-style",
            KvCache::MqaStyle => "MQA-style",
        }
    }
}

/// Architecture-stage configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArchConfig {
    pub attention: Attention,
    pub moe: MoE,
}

/// Fine-tuning-stage configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FtConfig {
    pub method: FtMethod,
    /// rank is meaningful only for PEFT methods (0 for Full).
    pub rank: u16,
    /// alpha = alpha_mult * rank.
    pub alpha_mult: u8,
}

impl FtConfig {
    pub fn full() -> Self {
        FtConfig { method: FtMethod::Full, rank: 0, alpha_mult: 1 }
    }

    pub fn alpha(&self) -> f64 {
        self.alpha_mult as f64 * self.rank as f64
    }
}

/// Inference-stage configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InfConfig {
    pub precision: Precision,
    pub quant_method: QuantMethod,
    pub kv_cache: KvCache,
}

/// A complete efficiency configuration (paper Definition 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Config {
    pub arch: ArchConfig,
    pub ft: FtConfig,
    pub inf: InfConfig,
}

impl Config {
    /// The paper's "Default" baseline: vanilla dense MHA, full
    /// fine-tuning, FP16, full KV cache.
    pub fn default_baseline() -> Self {
        Config {
            arch: ArchConfig { attention: Attention::Mha, moe: MoE::Dense },
            ft: FtConfig::full(),
            inf: InfConfig {
                precision: Precision::Fp16,
                quant_method: QuantMethod::Gptq,
                kv_cache: KvCache::Full,
            },
        }
    }

    /// Parse a configuration back from its [`signature`](Self::signature)
    /// — the inverse used by the persistent-front serialization
    /// (`ae-llm.front/v1`), so archived fronts survive process restarts
    /// without a second encoding of the configuration space.
    pub fn from_signature(s: &str) -> Result<Config, String> {
        let parts: Vec<&str> = s.split('/').collect();
        if parts.len() != 5 {
            return Err(format!("signature {s:?}: expected 5 '/'-separated \
                                stages, got {}", parts.len()));
        }
        let attention = Attention::ALL
            .into_iter()
            .find(|a| a.name() == parts[0])
            .ok_or_else(|| format!("unknown attention {:?}", parts[0]))?;
        let moe = MoE::ALL
            .into_iter()
            .find(|m| m.name() == parts[1])
            .ok_or_else(|| format!("unknown MoE setting {:?}", parts[1]))?;
        let ft = if parts[2] == "Full" {
            FtConfig::full()
        } else {
            // `{method}-r{rank}a{alpha_mult}`
            let (method_name, tail) = parts[2]
                .split_once("-r")
                .ok_or_else(|| format!("bad ft stage {:?}", parts[2]))?;
            let method = FtMethod::ALL
                .into_iter()
                .find(|m| m.name() == method_name)
                .ok_or_else(|| format!("unknown ft method {method_name:?}"))?;
            let (rank, alpha_mult) = tail
                .split_once('a')
                .ok_or_else(|| format!("bad ft stage {:?}", parts[2]))?;
            FtConfig {
                method,
                rank: rank.parse()
                    .map_err(|_| format!("bad rank {rank:?}"))?,
                alpha_mult: alpha_mult.parse()
                    .map_err(|_| format!("bad alpha mult {alpha_mult:?}"))?,
            }
        };
        let (prec_name, quant_name) = parts[3]
            .split_once('-')
            .ok_or_else(|| format!("bad inference stage {:?}", parts[3]))?;
        let precision = Precision::ALL
            .into_iter()
            .find(|p| p.name() == prec_name)
            .ok_or_else(|| format!("unknown precision {prec_name:?}"))?;
        let quant_method = QuantMethod::ALL
            .into_iter()
            .find(|q| q.name() == quant_name)
            .ok_or_else(|| format!("unknown quant method {quant_name:?}"))?;
        let kv_name = parts[4]
            .strip_prefix("KV-")
            .ok_or_else(|| format!("bad KV stage {:?}", parts[4]))?;
        let kv_cache = KvCache::ALL
            .into_iter()
            .find(|k| k.name() == kv_name)
            .ok_or_else(|| format!("unknown KV cache {kv_name:?}"))?;
        Ok(Config {
            arch: ArchConfig { attention, moe },
            ft,
            inf: InfConfig { precision, quant_method, kv_cache },
        })
    }

    /// Short human-readable signature, e.g.
    /// `GQA/MoE4t2/LoRA-r32a2/INT8-AWQ/KV-GQA`.
    pub fn signature(&self) -> String {
        let ft = if self.ft.method.is_peft() {
            format!("{}-r{}a{}", self.ft.method.name(), self.ft.rank,
                    self.ft.alpha_mult)
        } else {
            "Full".to_string()
        };
        format!(
            "{}/{}/{}/{}-{}/KV-{}",
            self.arch.attention.name(),
            self.arch.moe.name(),
            ft,
            self.inf.precision.name(),
            self.inf.quant_method.name(),
            self.inf.kv_cache.name(),
        )
    }
}

impl fmt::Display for Config {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.signature())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_baseline_is_vanilla() {
        let c = Config::default_baseline();
        assert_eq!(c.arch.attention, Attention::Mha);
        assert_eq!(c.arch.moe, MoE::Dense);
        assert_eq!(c.ft.method, FtMethod::Full);
        assert_eq!(c.inf.precision, Precision::Fp16);
        assert_eq!(c.inf.kv_cache, KvCache::Full);
    }

    #[test]
    fn kv_fractions_ordered() {
        assert!(Attention::Mha.kv_fraction() > Attention::Gqa.kv_fraction());
        assert!(Attention::Gqa.kv_fraction() > Attention::Mqa.kv_fraction());
        assert_eq!(Attention::Mla.kv_fraction(), Attention::Mqa.kv_fraction());
    }

    #[test]
    fn precision_bytes_ordered() {
        let mut prev = f64::INFINITY;
        for p in Precision::ALL {
            assert!(p.bytes_per_weight() <= prev);
            prev = p.bytes_per_weight();
        }
        assert_eq!(Precision::Int4.bytes_per_weight(), 0.5);
    }

    #[test]
    fn moe_active_le_experts() {
        for m in MoE::ALL {
            assert!(m.active() <= m.experts());
        }
    }

    #[test]
    fn signature_contains_all_stages() {
        let c = Config {
            arch: ArchConfig {
                attention: Attention::Gqa,
                moe: MoE::Sparse { experts: 4, top_k: 2 },
            },
            ft: FtConfig { method: FtMethod::LoRA, rank: 32, alpha_mult: 2 },
            inf: InfConfig {
                precision: Precision::Int8,
                quant_method: QuantMethod::Awq,
                kv_cache: KvCache::GqaStyle,
            },
        };
        let s = c.signature();
        for part in ["GQA", "MoE4t2", "LoRA-r32a2", "INT8-AWQ", "KV-GQA"] {
            assert!(s.contains(part), "{s} missing {part}");
        }
    }

    #[test]
    fn full_ft_signature_has_no_rank() {
        let s = Config::default_baseline().signature();
        assert!(s.contains("Full"));
        assert!(!s.contains("r0"));
    }

    #[test]
    fn alpha_computation() {
        let ft = FtConfig { method: FtMethod::RsLoRA, rank: 64, alpha_mult: 4 };
        assert_eq!(ft.alpha(), 256.0);
    }

    #[test]
    fn signature_roundtrips_through_from_signature() {
        // Every valid configuration survives the textual round trip —
        // the invariant the persistent-front schema relies on.
        let mut rng = crate::util::Rng::new(17);
        for _ in 0..300 {
            let c = crate::config::enumerate::sample(&mut rng);
            let back = Config::from_signature(&c.signature()).unwrap();
            assert_eq!(back, c, "signature {}", c.signature());
        }
        let d = Config::default_baseline();
        assert_eq!(Config::from_signature(&d.signature()).unwrap(), d);
    }

    #[test]
    fn from_signature_rejects_malformed_text() {
        for bad in ["", "MHA", "MHA/Dense/Full/FP16-GPTQ",
                    "XXX/Dense/Full/FP16-GPTQ/KV-Full",
                    "MHA/Dense/LoRA-r32/FP16-GPTQ/KV-Full",
                    "MHA/Dense/Full/FP16/KV-Full",
                    "MHA/Dense/Full/FP16-GPTQ/Full"] {
            assert!(Config::from_signature(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn config_is_hashable_and_ord() {
        use std::collections::BTreeSet;
        let mut set = BTreeSet::new();
        set.insert(Config::default_baseline());
        set.insert(Config::default_baseline());
        assert_eq!(set.len(), 1);
    }
}
