//! Feature encoding of (configuration, model, task) triples for the
//! surrogate models (paper Eq. 5: `o_hat = f_o(c, phi(M), psi(T))`).
//!
//! Gradient-boosted trees split on raw ordinal/one-hot features, so the
//! encoding is deliberately simple and stable: a fixed-length `Vec<f64>`
//! whose layout is documented by [`feature_names`].  Categorical axes
//! are one-hot; magnitudes (rank, experts, params) are log-scaled so
//! splits distribute sensibly across model scales.

use super::space::*;
use crate::models::ModelSpec;
use crate::tasks::TaskSpec;

/// Number of configuration features.
pub const CONFIG_DIM: usize = 24;
/// Number of model features (phi).
pub const MODEL_DIM: usize = 6;
/// Number of task features (psi).
pub const TASK_DIM: usize = 6;
/// Total feature-vector length.
pub const TOTAL_DIM: usize = CONFIG_DIM + MODEL_DIM + TASK_DIM;

/// Encode just the configuration (first CONFIG_DIM slots).
pub fn encode_config(c: &Config) -> Vec<f64> {
    let mut f = Vec::with_capacity(CONFIG_DIM);
    // attention one-hot (4)
    for a in Attention::ALL {
        f.push(if c.arch.attention == a { 1.0 } else { 0.0 });
    }
    // kv fraction of the *architecture* (1)
    f.push(c.arch.attention.kv_fraction());
    // moe: sparse flag, log2(experts), active fraction (3)
    f.push(if c.arch.moe.is_sparse() { 1.0 } else { 0.0 });
    f.push((c.arch.moe.experts() as f64).log2());
    f.push(c.arch.moe.active() as f64 / c.arch.moe.experts() as f64);
    // ft method one-hot (5)
    for m in FtMethod::ALL {
        f.push(if c.ft.method == m { 1.0 } else { 0.0 });
    }
    // rank (log2, 0 for Full), alpha mult (2)
    f.push(if c.ft.rank > 0 { (c.ft.rank as f64).log2() } else { 0.0 });
    f.push(c.ft.alpha_mult as f64);
    // precision one-hot (4) + bits (1)
    for p in Precision::ALL {
        f.push(if c.inf.precision == p { 1.0 } else { 0.0 });
    }
    f.push(c.inf.precision.bits() as f64);
    // quant method one-hot (3)
    for q in QuantMethod::ALL {
        f.push(if c.inf.quant_method == q { 1.0 } else { 0.0 });
    }
    // kv cache fraction (1)
    f.push(c.inf.kv_cache.fraction());
    debug_assert_eq!(f.len(), CONFIG_DIM);
    f
}

/// Encode model characteristics phi(M).
pub fn encode_model(m: &ModelSpec) -> Vec<f64> {
    vec![
        (m.params_b * 1e9).log10(),
        m.n_layers as f64,
        (m.d_model as f64).log2(),
        m.n_heads as f64,
        if m.native_moe { 1.0 } else { 0.0 },
        if m.is_vlm { 1.0 } else { 0.0 },
    ]
}

/// Encode task properties psi(T).
pub fn encode_task(t: &TaskSpec) -> Vec<f64> {
    vec![
        t.category as u8 as f64,
        (t.seq_len as f64).log2(),
        t.quant_sensitivity,
        t.moe_affinity,
        t.reasoning_weight,
        if t.multimodal { 1.0 } else { 0.0 },
    ]
}

/// Full feature vector for the surrogate models.
pub fn encode(c: &Config, m: &ModelSpec, t: &TaskSpec) -> Vec<f64> {
    let mut f = encode_config(c);
    f.extend(encode_model(m));
    f.extend(encode_task(t));
    debug_assert_eq!(f.len(), TOTAL_DIM);
    f
}

/// Human-readable names for every feature slot (reports / debugging).
pub fn feature_names() -> Vec<&'static str> {
    vec![
        "attn=MHA", "attn=MQA", "attn=GQA", "attn=MLA", "arch_kv_frac",
        "moe_sparse", "moe_log2_experts", "moe_active_frac",
        "ft=Full", "ft=LoRA", "ft=QLoRA", "ft=DoRA", "ft=RSLoRA",
        "ft_log2_rank", "ft_alpha_mult",
        "prec=FP16", "prec=FP8", "prec=INT8", "prec=INT4", "prec_bits",
        "qm=GPTQ", "qm=AWQ", "qm=SmoothQuant", "kv_policy_frac",
        "m_log10_params", "m_layers", "m_log2_dmodel", "m_heads",
        "m_native_moe", "m_is_vlm",
        "t_category", "t_log2_seq", "t_quant_sens", "t_moe_affinity",
        "t_reasoning", "t_multimodal",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::tasks::suite;

    #[test]
    fn dims_consistent() {
        assert_eq!(feature_names().len(), TOTAL_DIM);
        let c = Config::default_baseline();
        let m = &zoo()[0];
        let t = &suite()[0];
        assert_eq!(encode(&c, m, t).len(), TOTAL_DIM);
        assert_eq!(encode_config(&c).len(), CONFIG_DIM);
        assert_eq!(encode_model(m).len(), MODEL_DIM);
        assert_eq!(encode_task(t).len(), TASK_DIM);
    }

    #[test]
    fn one_hots_are_exclusive() {
        let c = Config::default_baseline();
        let f = encode_config(&c);
        assert_eq!(f[0..4].iter().sum::<f64>(), 1.0); // attention
        assert_eq!(f[8..13].iter().sum::<f64>(), 1.0); // ft method
        assert_eq!(f[15..19].iter().sum::<f64>(), 1.0); // precision
        assert_eq!(f[20..23].iter().sum::<f64>(), 1.0); // quant method
    }

    #[test]
    fn distinct_configs_encode_differently() {
        let a = Config::default_baseline();
        let mut b = a;
        b.inf.precision = Precision::Int4;
        assert_ne!(encode_config(&a), encode_config(&b));
    }

    #[test]
    fn encoding_is_deterministic() {
        let c = Config::default_baseline();
        assert_eq!(encode_config(&c), encode_config(&c));
    }

    #[test]
    fn all_features_finite_for_entire_zoo_and_suite() {
        let c = Config::default_baseline();
        for m in zoo() {
            for t in suite() {
                assert!(encode(&c, &m, &t).iter().all(|x| x.is_finite()));
            }
        }
    }
}
