//! S1: the AE-LLM configuration space (paper §3.2, Table 1).
//!
//! * [`space`] — the typed configuration grid `(arch, ft, inf)`;
//! * [`validity`] — structural consistency rules (§5.5 conflicts);
//! * [`enumerate`] — exhaustive iteration + seeded random sampling;
//! * [`encode`] — feature vectors for the surrogate models (Eq. 5).

pub mod encode;
pub mod enumerate;
pub mod space;
pub mod validity;

pub use space::{
    ArchConfig, Attention, Config, FtConfig, FtMethod, InfConfig, KvCache,
    MoE, Precision, QuantMethod, ALPHA_MULTS, RANKS,
};
