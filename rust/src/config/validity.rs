//! Structural validity rules for configurations.
//!
//! These encode *semantic* consistency (not hardware feasibility — that
//! is Definition 3 and lives in `hardware`): combinations that make no
//! sense or that the paper's §5.5 identifies as unstable are rejected at
//! the space level so the search never wastes evaluations on them.

use super::space::*;

/// Reasons a configuration can be structurally invalid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// PEFT methods need a rank; Full must not carry one.
    RankInconsistent,
    /// QLoRA definitionally fine-tunes on a quantized base model; running
    /// it with FP16 inference weights contradicts the method.
    QloraNeedsQuantBase,
    /// §5.5 "Cross-Stage Conflicts": INT4 on top-1-routed sparse MoE
    /// causes routing instability; the space excludes it outright.
    Int4MoeTop1Unstable,
    /// A KV-cache policy *more aggressive than the attention architecture
    /// already provides* is meaningless (e.g. MQA attention + "GQA-style"
    /// cache reduction — there is nothing left to share).
    KvCacheRedundant,
}

/// Check all rules; returns every violation (empty = valid).
pub fn violations(c: &Config) -> Vec<Violation> {
    let mut out = Vec::new();

    let peft = c.ft.method.is_peft();
    if peft != (c.ft.rank > 0) {
        out.push(Violation::RankInconsistent);
    }

    if c.ft.method == FtMethod::QLoRA
        && matches!(c.inf.precision, Precision::Fp16)
    {
        out.push(Violation::QloraNeedsQuantBase);
    }

    if c.inf.precision == Precision::Int4 {
        if let MoE::Sparse { top_k: 1, .. } = c.arch.moe {
            out.push(Violation::Int4MoeTop1Unstable);
        }
    }

    // A cache-reduction policy is only meaningful if the attention
    // architecture keeps more KV than the policy's target fraction.
    let arch_kv = c.arch.attention.kv_fraction();
    let policy_kv = c.inf.kv_cache.fraction();
    if policy_kv < 1.0 && arch_kv <= policy_kv {
        out.push(Violation::KvCacheRedundant);
    }

    out
}

/// True when the configuration is structurally valid.
pub fn is_valid(c: &Config) -> bool {
    violations(c).is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Config {
        Config::default_baseline()
    }

    #[test]
    fn default_is_valid() {
        assert!(is_valid(&base()));
    }

    #[test]
    fn peft_without_rank_invalid() {
        let mut c = base();
        c.ft.method = FtMethod::LoRA;
        c.ft.rank = 0;
        assert!(violations(&c).contains(&Violation::RankInconsistent));
    }

    #[test]
    fn full_with_rank_invalid() {
        let mut c = base();
        c.ft.rank = 16;
        assert!(violations(&c).contains(&Violation::RankInconsistent));
    }

    #[test]
    fn qlora_fp16_invalid() {
        let mut c = base();
        c.ft.method = FtMethod::QLoRA;
        c.ft.rank = 16;
        assert!(violations(&c).contains(&Violation::QloraNeedsQuantBase));
        c.inf.precision = Precision::Int8;
        assert!(is_valid(&c));
    }

    #[test]
    fn int4_top1_moe_invalid() {
        let mut c = base();
        c.arch.moe = MoE::Sparse { experts: 4, top_k: 1 };
        c.inf.precision = Precision::Int4;
        assert!(violations(&c).contains(&Violation::Int4MoeTop1Unstable));
        c.arch.moe = MoE::Sparse { experts: 4, top_k: 2 };
        assert!(is_valid(&c));
    }

    #[test]
    fn kv_policy_on_mqa_arch_redundant() {
        let mut c = base();
        c.arch.attention = Attention::Mqa;
        c.inf.kv_cache = KvCache::GqaStyle;
        assert!(violations(&c).contains(&Violation::KvCacheRedundant));
        c.inf.kv_cache = KvCache::Full;
        assert!(is_valid(&c));
    }

    #[test]
    fn kv_gqa_policy_on_mha_arch_fine() {
        let mut c = base();
        c.inf.kv_cache = KvCache::GqaStyle;
        assert!(is_valid(&c));
    }

    #[test]
    fn mqa_policy_on_gqa_arch_fine() {
        // GQA arch keeps 0.25, MQA-style policy targets 0.125 < 0.25 -> OK
        let mut c = base();
        c.arch.attention = Attention::Gqa;
        c.inf.kv_cache = KvCache::MqaStyle;
        assert!(is_valid(&c));
    }
}
