//! Enumeration and random sampling of the configuration space.
//!
//! The full space (paper §3.3.3: |C| ~ O(10^6) including continuous
//! relaxations; our discrete grid is ~10^5) is never materialized during
//! search — NSGA-II samples and mutates — but exhaustive enumeration is
//! needed by the "- Constraint-Aware Pruning" ablation and by tests.

use super::space::*;
use super::validity;
use crate::util::Rng;

/// Iterate every *valid* configuration in the discrete grid.
pub fn all_valid() -> Vec<Config> {
    let mut out = Vec::new();
    for &attention in &Attention::ALL {
        for &moe in &MoE::ALL {
            for &method in &FtMethod::ALL {
                let ft_variants: Vec<FtConfig> = if method.is_peft() {
                    RANKS
                        .iter()
                        .flat_map(|&rank| {
                            ALPHA_MULTS.iter().map(move |&alpha_mult| FtConfig {
                                method,
                                rank,
                                alpha_mult,
                            })
                        })
                        .collect()
                } else {
                    vec![FtConfig::full()]
                };
                for ft in ft_variants {
                    for &precision in &Precision::ALL {
                        for &quant_method in &QuantMethod::ALL {
                            for &kv_cache in &KvCache::ALL {
                                let c = Config {
                                    arch: ArchConfig { attention, moe },
                                    ft,
                                    inf: InfConfig {
                                        precision,
                                        quant_method,
                                        kv_cache,
                                    },
                                };
                                if validity::is_valid(&c) {
                                    out.push(c);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Size of the unconstrained grid (before validity filtering); used in
/// reports to echo the paper's search-space-size claim.
pub fn grid_size() -> usize {
    let ft = 1 + (FtMethod::ALL.len() - 1) * RANKS.len() * ALPHA_MULTS.len();
    Attention::ALL.len()
        * MoE::ALL.len()
        * ft
        * Precision::ALL.len()
        * QuantMethod::ALL.len()
        * KvCache::ALL.len()
}

/// Draw one uniformly random configuration (resampling until valid;
/// validity rejects only a small fraction so this terminates fast).
pub fn sample(rng: &mut Rng) -> Config {
    loop {
        let method = *rng.pick(&FtMethod::ALL);
        let ft = if method.is_peft() {
            FtConfig {
                method,
                rank: *rng.pick(&RANKS),
                alpha_mult: *rng.pick(&ALPHA_MULTS),
            }
        } else {
            FtConfig::full()
        };
        let c = Config {
            arch: ArchConfig {
                attention: *rng.pick(&Attention::ALL),
                moe: *rng.pick(&MoE::ALL),
            },
            ft,
            inf: InfConfig {
                precision: *rng.pick(&Precision::ALL),
                quant_method: *rng.pick(&QuantMethod::ALL),
                kv_cache: *rng.pick(&KvCache::ALL),
            },
        };
        if validity::is_valid(&c) {
            return c;
        }
    }
}

/// Sample `n` distinct configurations.
pub fn sample_distinct(rng: &mut Rng, n: usize) -> Vec<Config> {
    let mut seen = std::collections::BTreeSet::new();
    let mut out = Vec::with_capacity(n);
    let mut guard = 0;
    while out.len() < n && guard < n * 200 {
        let c = sample(rng);
        if seen.insert(c) {
            out.push(c);
        }
        guard += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumeration_nonempty_and_all_valid() {
        let all = all_valid();
        assert!(all.len() > 10_000, "got {}", all.len());
        assert!(all.iter().all(validity::is_valid));
    }

    #[test]
    fn enumeration_has_no_duplicates() {
        let all = all_valid();
        let set: std::collections::BTreeSet<_> = all.iter().collect();
        assert_eq!(set.len(), all.len());
    }

    #[test]
    fn grid_size_upper_bounds_valid_count() {
        assert!(all_valid().len() <= grid_size());
        // sanity: 4 attn * 7 moe * (1 + 4*5*3) ft * 4 prec * 3 qm * 3 kv
        assert_eq!(grid_size(), 4 * 7 * 61 * 4 * 3 * 3);
    }

    #[test]
    fn default_baseline_is_in_grid() {
        assert!(all_valid().contains(&Config::default_baseline()));
    }

    #[test]
    fn samples_are_valid_and_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for _ in 0..200 {
            let a = sample(&mut r1);
            assert!(validity::is_valid(&a));
            assert_eq!(a, sample(&mut r2));
        }
    }

    #[test]
    fn sample_distinct_returns_unique() {
        let mut rng = Rng::new(6);
        let v = sample_distinct(&mut rng, 100);
        assert_eq!(v.len(), 100);
        let set: std::collections::BTreeSet<_> = v.iter().collect();
        assert_eq!(set.len(), 100);
    }

    #[test]
    fn sampling_covers_every_attention_kind() {
        let mut rng = Rng::new(7);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..500 {
            seen.insert(sample(&mut rng).arch.attention);
        }
        assert_eq!(seen.len(), Attention::ALL.len());
    }
}
