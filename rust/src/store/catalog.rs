//! The indexed catalog over the blob store: a manifest
//! (`ae-llm.manifest/v1`) mapping (model, task, platform, scenario)
//! keys to blob addresses, plus the *seeded similarity ranking* that
//! lets `adapt` warm-start from the best prior front for a *similar*
//! scenario — the paper's scenario-dependence claim turned into a
//! lookup rule.
//!
//! Similarity is hierarchical: matching the model matters more than
//! the task, the task more than the platform, the platform more than
//! the workload scenario (weights 8/4/2/1).  Exact-score ties are
//! broken by a seeded stream consumed *only* on a tie — the same
//! idiom as the cluster router — so same-seed lookups are
//! byte-reproducible without making the ranking secretly
//! insertion-ordered.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::Rng;

/// Schema tag of the serialized manifest (docs/SCHEMAS.md).
pub const MANIFEST_SCHEMA: &str = "ae-llm.manifest/v1";

/// Salt for the catalog tie-break stream, decorrelating it from the
/// search and serve streams at the same seed.
const CATALOG_SALT: u64 = 0xCA7A_1060_5EED_BA5E;

/// What kind of document a catalog entry points at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlobKind {
    /// An `ae-llm.front/v1` Pareto front.
    Front,
    /// An `ae-llm.run-report/v2` run report.
    RunReport,
}

impl BlobKind {
    pub fn name(self) -> &'static str {
        match self {
            BlobKind::Front => "front",
            BlobKind::RunReport => "run-report",
        }
    }

    pub fn by_name(name: &str) -> Option<BlobKind> {
        match name {
            "front" => Some(BlobKind::Front),
            "run-report" => Some(BlobKind::RunReport),
            _ => None,
        }
    }
}

/// The scenario coordinates an artifact was produced under.  `scenario`
/// is the workload kind for `adapt`/`serve` artifacts and `"-"` for
/// plain searches (which have no workload dimension).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CatalogKey {
    pub model: String,
    pub task: String,
    pub platform: String,
    pub scenario: String,
}

impl CatalogKey {
    pub fn new(model: &str, task: &str, platform: &str, scenario: &str)
               -> CatalogKey {
        CatalogKey {
            model: model.to_string(),
            task: task.to_string(),
            platform: platform.to_string(),
            scenario: scenario.to_string(),
        }
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("task".into(), Json::Str(self.task.clone()));
        m.insert("platform".into(), Json::Str(self.platform.clone()));
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<CatalogKey, String> {
        Ok(CatalogKey {
            model: j.req_str("model")?,
            task: j.req_str("task")?,
            platform: j.req_str("platform")?,
            scenario: j.req_str("scenario")?,
        })
    }
}

/// Hierarchical scenario similarity in `[0, 15]`: model 8, task 4,
/// platform 2, scenario 1.  The weights are powers of two, so any
/// model match outranks every model mismatch no matter how the minor
/// dimensions fall — warm-starting a different model's front is never
/// preferred over the same model's (transfer across models goes
/// through `transfer_fit`, not through warm entries).
pub fn similarity(query: &CatalogKey, candidate: &CatalogKey) -> u32 {
    let mut score = 0;
    if query.model == candidate.model {
        score += 8;
    }
    if query.task == candidate.task {
        score += 4;
    }
    if query.platform == candidate.platform {
        score += 2;
    }
    if query.scenario == candidate.scenario {
        score += 1;
    }
    score
}

/// One manifest row: a blob address plus the coordinates and seed it
/// was produced under.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CatalogEntry {
    /// Monotonic insertion number (unique within a manifest).
    pub seq: u64,
    pub kind: BlobKind,
    pub key: CatalogKey,
    /// Seed of the run that produced the artifact.
    pub seed: u64,
    /// Content address of the blob.
    pub hash: String,
    /// Entry count of the stored front (0 for run reports) — shown by
    /// `store ls` so a fleet operator can see catalog health at a
    /// glance.
    pub front_size: usize,
}

impl CatalogEntry {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        // seq/seed as strings: Json numbers are f64 and would corrupt
        // values above 2^53 (same rule as run-report seeds).
        m.insert("seq".into(), Json::Str(self.seq.to_string()));
        m.insert("kind".into(), Json::Str(self.kind.name().into()));
        m.insert("key".into(), self.key.to_json());
        m.insert("seed".into(), Json::Str(self.seed.to_string()));
        m.insert("hash".into(), Json::Str(self.hash.clone()));
        m.insert("front_size".into(),
                 Json::Num(self.front_size as f64));
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<CatalogEntry, String> {
        let seq = j
            .req_str("seq")?
            .parse::<u64>()
            .map_err(|e| format!("bad seq: {e}"))?;
        let kind_name = j.req_str("kind")?;
        let kind = BlobKind::by_name(&kind_name)
            .ok_or_else(|| format!("unknown blob kind {kind_name:?}"))?;
        let key = CatalogKey::from_json(
            j.get("key").ok_or("entry missing key")?)?;
        let seed = j
            .req_str("seed")?
            .parse::<u64>()
            .map_err(|e| format!("bad seed: {e}"))?;
        let hash = j.req_str("hash")?;
        let front_size = j.req_u64("front_size")? as usize;
        Ok(CatalogEntry { seq, kind, key, seed, hash, front_size })
    }
}

/// The manifest: every catalog entry in insertion order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Manifest {
    next_seq: u64,
    entries: Vec<CatalogEntry>,
}

impl Manifest {
    pub fn new() -> Manifest {
        Manifest::default()
    }

    pub fn entries(&self) -> &[CatalogEntry] {
        &self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Append an entry; returns its assigned `seq`.
    pub fn record(&mut self, kind: BlobKind, key: CatalogKey, seed: u64,
                  hash: String, front_size: usize) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.entries.push(CatalogEntry {
            seq,
            kind,
            key,
            seed,
            hash,
            front_size,
        });
        seq
    }

    /// Every blob address the manifest references (the `gc` root set).
    pub fn referenced_hashes(&self)
                             -> std::collections::BTreeSet<String> {
        self.entries.iter().map(|e| e.hash.clone()).collect()
    }

    /// Entries of `kind` ranked for `query`: similarity descending,
    /// newest (`seq`) first within a score.  Zero-score entries — no
    /// dimension in common — are excluded; an unrelated front is worse
    /// than a cold start because the warm re-measure budget is finite.
    pub fn ranked(&self, query: &CatalogKey, kind: BlobKind)
                  -> Vec<&CatalogEntry> {
        let mut hits: Vec<&CatalogEntry> = self
            .entries
            .iter()
            .filter(|e| e.kind == kind && similarity(query, &e.key) > 0)
            .collect();
        hits.sort_by(|a, b| {
            similarity(query, &b.key)
                .cmp(&similarity(query, &a.key))
                .then(b.seq.cmp(&a.seq))
        });
        hits
    }

    /// The best entry of `kind` for `query`, with exact top-score ties
    /// broken by a seeded stream.  The stream is consumed *only* on an
    /// actual tie (cluster-router idiom), so a manifest with a unique
    /// best hit resolves identically at every seed.
    pub fn best_match(&self, query: &CatalogKey, kind: BlobKind,
                      seed: u64) -> Option<&CatalogEntry> {
        let ranked = self.ranked(query, kind);
        let top = similarity(query, &ranked.first()?.key);
        let ties: Vec<&&CatalogEntry> = ranked
            .iter()
            .take_while(|e| similarity(query, &e.key) == top)
            .collect();
        if ties.len() == 1 {
            Some(*ties[0])
        } else {
            let mut rng = Rng::new(seed ^ CATALOG_SALT);
            Some(*ties[rng.below(ties.len())])
        }
    }

    /// Serialize (schema [`MANIFEST_SCHEMA`]).  Like every schema in
    /// docs/SCHEMAS.md, the shape is frozen and the bytes are
    /// canonical: sorted keys, one number form — so two writers
    /// recording the same entries produce identical files.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("schema".into(), Json::Str(MANIFEST_SCHEMA.into()));
        root.insert("next_seq".into(),
                    Json::Str(self.next_seq.to_string()));
        root.insert(
            "entries".into(),
            Json::Arr(self.entries.iter().map(CatalogEntry::to_json)
                          .collect()),
        );
        Json::Obj(root)
    }

    /// Parse back from [`to_json`](Self::to_json)'s form
    /// (schema-checked); entries are restored verbatim, in order.
    pub fn from_json(j: &Json) -> Result<Manifest, String> {
        let schema = j.req_str("schema")?;
        if schema != MANIFEST_SCHEMA {
            return Err(format!("unexpected schema {schema:?}"));
        }
        let next_seq = j
            .req_str("next_seq")?
            .parse::<u64>()
            .map_err(|e| format!("bad next_seq: {e}"))?;
        let raw = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("missing/invalid entries array")?;
        let mut entries = Vec::with_capacity(raw.len());
        for e in raw {
            entries.push(CatalogEntry::from_json(e)?);
        }
        Ok(Manifest { next_seq, entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(model: &str, task: &str, platform: &str, scenario: &str)
           -> CatalogKey {
        CatalogKey::new(model, task, platform, scenario)
    }

    fn fake_hash(tag: u8) -> String {
        super::super::sha256::sha256_hex(&[tag])
    }

    #[test]
    fn similarity_is_hierarchical() {
        let q = key("Phi-2", "GSM8K", "A100-80GB", "bursty");
        assert_eq!(similarity(&q, &q), 15);
        // model match alone beats everything-but-model
        let model_only = key("Phi-2", "x", "y", "z");
        let all_but_model = key("other", "GSM8K", "A100-80GB", "bursty");
        assert_eq!(similarity(&q, &model_only), 8);
        assert_eq!(similarity(&q, &all_but_model), 7);
        assert!(similarity(&q, &model_only)
                > similarity(&q, &all_but_model));
        let unrelated = key("a", "b", "c", "d");
        assert_eq!(similarity(&q, &unrelated), 0);
    }

    #[test]
    fn ranked_orders_by_score_then_recency_and_drops_unrelated() {
        let mut m = Manifest::new();
        let q = key("Phi-2", "GSM8K", "A100-80GB", "bursty");
        m.record(BlobKind::Front, key("a", "b", "c", "d"), 1,
                 fake_hash(0), 3);
        m.record(BlobKind::Front, key("Phi-2", "x", "y", "z"), 1,
                 fake_hash(1), 3);
        m.record(BlobKind::Front, q.clone(), 1, fake_hash(2), 3);
        m.record(BlobKind::Front, q.clone(), 1, fake_hash(3), 3);
        // a run report under the exact key must not rank as a front
        m.record(BlobKind::RunReport, q.clone(), 1, fake_hash(4), 0);
        let ranked = m.ranked(&q, BlobKind::Front);
        assert_eq!(
            ranked.iter().map(|e| e.hash.clone()).collect::<Vec<_>>(),
            // exact matches first (newest of them first), model-only
            // match after; the unrelated entry is gone
            vec![fake_hash(3), fake_hash(2), fake_hash(1)],
        );
    }

    #[test]
    fn best_match_is_deterministic_without_ties() {
        let mut m = Manifest::new();
        let q = key("Phi-2", "GSM8K", "A100-80GB", "bursty");
        m.record(BlobKind::Front, key("Phi-2", "x", "y", "z"), 1,
                 fake_hash(1), 3);
        m.record(BlobKind::Front, q.clone(), 1, fake_hash(2), 3);
        for seed in 0..32 {
            assert_eq!(m.best_match(&q, BlobKind::Front, seed)
                           .unwrap().hash,
                       fake_hash(2));
        }
        assert!(m.best_match(&key("a", "b", "c", "d"), BlobKind::Front, 0)
                    .is_none());
        assert!(Manifest::new().best_match(&q, BlobKind::Front, 0)
                    .is_none());
    }

    #[test]
    fn best_match_tie_break_is_seeded_and_stable() {
        let mut m = Manifest::new();
        let q = key("Phi-2", "GSM8K", "A100-80GB", "bursty");
        // two entries with the same (exact) score
        m.record(BlobKind::Front, q.clone(), 1, fake_hash(1), 3);
        m.record(BlobKind::Front, q.clone(), 2, fake_hash(2), 3);
        // same seed → same pick; the pick is one of the tied entries
        for seed in 0..64u64 {
            let a = m.best_match(&q, BlobKind::Front, seed).unwrap().hash
                .clone();
            let b = m.best_match(&q, BlobKind::Front, seed).unwrap().hash
                .clone();
            assert_eq!(a, b);
            assert!(a == fake_hash(1) || a == fake_hash(2));
        }
        // the tie-break actually uses the seed: across many seeds both
        // entries get picked at least once
        let picks: std::collections::BTreeSet<String> = (0..64u64)
            .map(|s| m.best_match(&q, BlobKind::Front, s).unwrap().hash
                .clone())
            .collect();
        assert_eq!(picks.len(), 2, "tie-break never varied: {picks:?}");
    }

    #[test]
    fn manifest_json_roundtrip_is_exact_and_canonical() {
        let mut m = Manifest::new();
        m.record(BlobKind::Front,
                 key("Phi-2", "GSM8K", "A100-80GB", "bursty"),
                 u64::MAX, fake_hash(1), 12);
        m.record(BlobKind::RunReport,
                 key("LLaMA-2-7B", "MMLU", "RTX-4090", "-"),
                 7, fake_hash(2), 0);
        let text = m.to_json().dump();
        let back =
            Manifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
        // canonical: re-dumping the parsed form is byte-identical
        assert_eq!(back.to_json().dump(), text);
        // u64::MAX survived the string-typed seed field
        assert_eq!(back.entries()[0].seed, u64::MAX);
    }

    #[test]
    fn from_json_rejects_wrong_schema_and_bad_entries() {
        assert!(Manifest::from_json(
            &Json::parse(r#"{"schema":"nope"}"#).unwrap()).is_err());
        let bad_kind = r#"{"schema":"ae-llm.manifest/v1","next_seq":"1",
            "entries":[{"seq":"0","kind":"blob","seed":"1",
            "hash":"x","front_size":0,
            "key":{"model":"m","task":"t","platform":"p",
                   "scenario":"s"}}]}"#;
        assert!(Manifest::from_json(&Json::parse(bad_kind).unwrap())
                    .is_err());
    }

    #[test]
    fn record_assigns_monotonic_seqs() {
        let mut m = Manifest::new();
        let k = key("m", "t", "p", "s");
        assert_eq!(m.record(BlobKind::Front, k.clone(), 1, fake_hash(1),
                            1), 0);
        assert_eq!(m.record(BlobKind::Front, k.clone(), 1, fake_hash(2),
                            1), 1);
        // seq survives a round trip and keeps counting from next_seq
        let mut back =
            Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.record(BlobKind::Front, k, 1, fake_hash(3), 1),
                   2);
    }
}
