//! Content-addressed blob storage: canonical JSON bytes filed under
//! their own SHA-256 (`objects/<first2>/<remaining 62 hex>`), re-hashed
//! on every load so a flipped bit is a typed [`StoreError::Corrupt`] —
//! never a silently wrong Pareto front.
//!
//! Content addressing works here *because* the repo's serialization is
//! canonical: `util::json` emits sorted keys, one number form, one
//! escape form (docs/SCHEMAS.md).  Equal documents are equal bytes, so
//! equal bytes are one blob — dedup falls out for free.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::search::archive::{ParetoArchive, FRONT_SCHEMA};
use crate::util::json::Json;

use super::sha256::{is_valid_hex_digest, sha256_hex};
use super::StoreError;

/// Schema tag of serialized run reports
/// ([`crate::coordinator::RunReport::to_json`]).
pub const RUN_REPORT_SCHEMA: &str = "ae-llm.run-report/v2";

/// The object store: a directory of immutable, hash-named blobs.
#[derive(Debug)]
pub struct BlobStore {
    objects_dir: PathBuf,
}

impl BlobStore {
    /// Open (creating if needed) the object store under `root`.
    /// Blobs live in `root/objects/`.
    pub fn open(root: &Path) -> Result<BlobStore, StoreError> {
        let objects_dir = root.join("objects");
        fs::create_dir_all(&objects_dir)?;
        Ok(BlobStore { objects_dir })
    }

    /// On-disk path of (a hypothetical) blob `hash`.
    fn path_of(&self, hash: &str) -> PathBuf {
        self.objects_dir.join(&hash[..2]).join(&hash[2..])
    }

    /// Store `bytes`; returns their content address.  A blob that
    /// already exists is left untouched (same hash ⇒ same bytes), so
    /// `put` is idempotent and duplicate fronts cost one copy.  New
    /// blobs are written to a temp file and renamed into place, so a
    /// crash mid-write never leaves a half-blob at a valid address.
    pub fn put(&self, bytes: &[u8]) -> Result<String, StoreError> {
        let hash = sha256_hex(bytes);
        let path = self.path_of(&hash);
        if path.exists() {
            return Ok(hash);
        }
        let dir = path.parent().expect("objects/<xx>/ has a parent");
        fs::create_dir_all(dir)?;
        let tmp = dir.join(format!("{}.tmp", &hash[2..]));
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &path)?;
        Ok(hash)
    }

    /// Load the blob at `hash`, verifying its content address: the
    /// bytes are re-hashed and any mismatch is [`StoreError::Corrupt`].
    pub fn get(&self, hash: &str) -> Result<Vec<u8>, StoreError> {
        if !is_valid_hex_digest(hash) {
            return Err(StoreError::Malformed(format!(
                "not a sha-256 address: {hash:?}"
            )));
        }
        let path = self.path_of(hash);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(StoreError::Missing(hash.to_string()));
            }
            Err(e) => return Err(StoreError::Io(e)),
        };
        let actual = sha256_hex(&bytes);
        if actual != hash {
            return Err(StoreError::Corrupt {
                hash: hash.to_string(),
                actual,
            });
        }
        Ok(bytes)
    }

    /// Whether a blob with this address exists (no integrity check —
    /// that happens on `get`).
    pub fn contains(&self, hash: &str) -> bool {
        is_valid_hex_digest(hash) && self.path_of(hash).exists()
    }

    /// Every blob address present on disk, sorted (deterministic for
    /// `verify`/`gc` reports).  Files that are not shaped like
    /// `<2 hex>/<62 hex>` are ignored — they are not reachable
    /// addresses (leftover temp files, stray notes).
    pub fn list(&self) -> Result<Vec<String>, StoreError> {
        let mut out = Vec::new();
        for prefix in fs::read_dir(&self.objects_dir)? {
            let prefix = prefix?;
            if !prefix.file_type()?.is_dir() {
                continue;
            }
            let Some(p) = prefix.file_name().to_str().map(String::from)
            else {
                continue;
            };
            for entry in fs::read_dir(prefix.path())? {
                let entry = entry?;
                let Some(rest) = entry.file_name().to_str().map(String::from)
                else {
                    continue;
                };
                let hash = format!("{p}{rest}");
                if is_valid_hex_digest(&hash) {
                    out.push(hash);
                }
            }
        }
        out.sort();
        Ok(out)
    }

    /// Delete the blob at `hash` (used by `gc`; missing is fine —
    /// the goal state "not present" already holds).
    pub fn remove(&self, hash: &str) -> Result<(), StoreError> {
        if !is_valid_hex_digest(hash) {
            return Err(StoreError::Malformed(format!(
                "not a sha-256 address: {hash:?}"
            )));
        }
        match fs::remove_file(self.path_of(hash)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(StoreError::Io(e)),
        }
    }

    // -- typed helpers over the two stored document kinds ---------------

    /// Store a Pareto front as its canonical `ae-llm.front/v1` bytes.
    pub fn put_front(&self, front: &ParetoArchive)
                     -> Result<String, StoreError> {
        self.put(front.to_json().dump().as_bytes())
    }

    /// Load + schema-check + parse a stored front.
    pub fn get_front(&self, hash: &str)
                     -> Result<ParetoArchive, StoreError> {
        let j = self.get_json(hash, FRONT_SCHEMA)?;
        ParetoArchive::from_json(&j)
            .map_err(|e| StoreError::Malformed(format!("blob {hash}: {e}")))
    }

    /// Load a blob as JSON and require its `schema` tag.  The
    /// integrity check already proved the bytes are exactly what was
    /// stored; this guards against *addressing* the wrong kind of
    /// document (a run report where a front was expected).
    pub fn get_json(&self, hash: &str, schema: &str)
                    -> Result<Json, StoreError> {
        let bytes = self.get(hash)?;
        let text = std::str::from_utf8(&bytes).map_err(|e| {
            StoreError::Malformed(format!("blob {hash}: not UTF-8: {e}"))
        })?;
        let j = Json::parse(text).map_err(|e| {
            StoreError::Malformed(format!("blob {hash}: {e}"))
        })?;
        let found = j.req_str("schema").map_err(|e| {
            StoreError::Malformed(format!("blob {hash}: {e}"))
        })?;
        if found != schema {
            return Err(StoreError::Schema {
                expected: schema.to_string(),
                found,
            });
        }
        Ok(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::oracle::Objectives;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("ae-llm-blob-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_front(seed: u64, n: u64) -> ParetoArchive {
        let mut a = ParetoArchive::new(32);
        let mut rng = crate::util::Rng::new(seed);
        for _ in 0..n {
            let c: Config = crate::config::enumerate::sample(&mut rng);
            a.insert(c, Objectives {
                accuracy: 50.0 + 40.0 * rng.f64(),
                latency_ms: 5.0 + 50.0 * rng.f64(),
                memory_gb: 1.0 + 10.0 * rng.f64(),
                energy_j: 0.1 + rng.f64(),
            });
        }
        a
    }

    #[test]
    fn put_get_roundtrip_is_byte_identical() {
        let dir = tmp_dir("roundtrip");
        let store = BlobStore::open(&dir).unwrap();
        let payload = b"{\"schema\":\"x\"}".to_vec();
        let hash = store.put(&payload).unwrap();
        assert_eq!(store.get(&hash).unwrap(), payload);
        // idempotent: same bytes, same address, still one blob
        assert_eq!(store.put(&payload).unwrap(), hash);
        assert_eq!(store.list().unwrap(), vec![hash.clone()]);
        assert!(store.contains(&hash));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn front_roundtrip_preserves_bytes_and_entries() {
        let dir = tmp_dir("front");
        let store = BlobStore::open(&dir).unwrap();
        let front = sample_front(7, 60);
        let hash = store.put_front(&front).unwrap();
        let back = store.get_front(&hash).unwrap();
        // byte-identity through the store: re-serializing the loaded
        // front reproduces the stored bytes exactly
        assert_eq!(back.to_json().dump(), front.to_json().dump());
        assert_eq!(back.to_json().dump().as_bytes(),
                   store.get(&hash).unwrap().as_slice());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bit_flip_anywhere_is_detected_as_corrupt() {
        let dir = tmp_dir("corrupt");
        let store = BlobStore::open(&dir).unwrap();
        let front = sample_front(3, 10);
        let hash = store.put_front(&front).unwrap();
        let clean = store.get(&hash).unwrap();
        let path = store.path_of(&hash);
        // flip one bit at several positions across the blob
        for pos in [0, clean.len() / 2, clean.len() - 1] {
            let mut bad = clean.clone();
            bad[pos] ^= 0x01;
            fs::write(&path, &bad).unwrap();
            match store.get(&hash) {
                Err(StoreError::Corrupt { hash: h, actual }) => {
                    assert_eq!(h, hash);
                    assert_ne!(actual, hash);
                }
                other => panic!("expected Corrupt at byte {pos}, \
                                 got {other:?}"),
            }
            assert!(store.get_front(&hash).is_err());
        }
        // restore and it loads again
        fs::write(&path, &clean).unwrap();
        assert!(store.get_front(&hash).is_ok());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_and_malformed_addresses_are_typed() {
        let dir = tmp_dir("missing");
        let store = BlobStore::open(&dir).unwrap();
        let absent = super::super::sha256::sha256_hex(b"never stored");
        assert!(matches!(store.get(&absent),
                         Err(StoreError::Missing(_))));
        assert!(matches!(store.get("zz"),
                         Err(StoreError::Malformed(_))));
        assert!(!store.contains("zz"));
        // removing a missing blob is a no-op, not an error
        store.remove(&absent).unwrap();
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn schema_mismatch_is_typed() {
        let dir = tmp_dir("schema");
        let store = BlobStore::open(&dir).unwrap();
        let front = sample_front(5, 4);
        let hash = store.put_front(&front).unwrap();
        match store.get_json(&hash, RUN_REPORT_SCHEMA) {
            Err(StoreError::Schema { expected, found }) => {
                assert_eq!(expected, RUN_REPORT_SCHEMA);
                assert_eq!(found, FRONT_SCHEMA);
            }
            other => panic!("expected Schema error, got {other:?}"),
        }
        fs::remove_dir_all(&dir).unwrap();
    }
}
