//! S14: the persistent artifact store (DESIGN.md §14) — a
//! content-addressed blob store ([`BlobStore`]) plus an indexed
//! catalog ([`Manifest`]) that turns per-process warm re-search into
//! fleet-wide transfer: every searched Pareto front is filed under its
//! SHA-256 and indexed by (model, task, platform, scenario), so any
//! later `adapt` on any node can warm-start from the best prior front
//! for a *similar* scenario, and a different model's front can seed
//! [`crate::surrogate::transfer::transfer_fit`] as a source corpus.
//!
//! Layout under the store root (CLI: `--store DIR` / `AE_LLM_STORE`):
//!
//! ```text
//! <root>/manifest.json          ae-llm.manifest/v1 (the catalog)
//! <root>/objects/<2 hex>/<62 hex>   immutable blobs, hash-named
//! ```
//!
//! Every load re-hashes the bytes; corruption is a typed
//! [`StoreError::Corrupt`], never a silently wrong front.  The store
//! only exists because the repo's serialization is canonical
//! (docs/SCHEMAS.md): deterministic bytes make content addressing
//! well-defined and deduplicating.

pub mod blob;
pub mod catalog;
pub mod sha256;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::models;
use crate::search::archive::{Entry, ParetoArchive, FRONT_SCHEMA};
use crate::surrogate::transfer::SourceCorpus;
use crate::tasks;

pub use blob::{BlobStore, RUN_REPORT_SCHEMA};
pub use catalog::{similarity, BlobKind, CatalogEntry, CatalogKey,
                  Manifest, MANIFEST_SCHEMA};

// ---------------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------------

/// Typed store failures.  `Corrupt` is the load-bearing one: a blob
/// whose bytes no longer hash to their address must fail loudly — a
/// silently wrong Pareto front would poison every warm-start
/// downstream of it.
#[derive(Debug)]
pub enum StoreError {
    Io(std::io::Error),
    /// The blob at `hash` re-hashed to `actual` — on-disk corruption.
    Corrupt { hash: String, actual: String },
    /// No blob at this address.
    Missing(String),
    /// Unparseable address, non-UTF-8/non-JSON blob, or a bad
    /// manifest.
    Malformed(String),
    /// The blob parsed but carries the wrong `schema` tag.
    Schema { expected: String, found: String },
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "store I/O error: {e}"),
            StoreError::Corrupt { hash, actual } => write!(
                f,
                "corrupt blob {hash}: content hashes to {actual}"
            ),
            StoreError::Missing(hash) => {
                write!(f, "no blob at {hash}")
            }
            StoreError::Malformed(msg) => {
                write!(f, "malformed store data: {msg}")
            }
            StoreError::Schema { expected, found } => write!(
                f,
                "schema mismatch: expected {expected:?}, found {found:?}"
            ),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> StoreError {
        StoreError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Reports
// ---------------------------------------------------------------------------

/// What `store verify` found.
#[derive(Debug, Default)]
pub struct VerifyReport {
    /// Distinct blobs checked (manifest-referenced plus on-disk).
    pub checked: usize,
    /// Human-readable descriptions of every problem found.
    pub problems: Vec<String>,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.problems.is_empty()
    }
}

/// What `store gc` did.
#[derive(Debug, Default)]
pub struct GcReport {
    /// Blobs still referenced by the manifest (kept).
    pub kept: usize,
    /// Addresses of the unreferenced blobs that were removed.
    pub removed: Vec<String>,
}

// ---------------------------------------------------------------------------
// The facade
// ---------------------------------------------------------------------------

/// Blob store + catalog under one root directory.
#[derive(Debug)]
pub struct Store {
    root: PathBuf,
    blobs: BlobStore,
    manifest: Manifest,
}

impl Store {
    /// Open (creating if needed) the store at `root`, loading the
    /// manifest if one exists.
    pub fn open(root: &Path) -> Result<Store, StoreError> {
        fs::create_dir_all(root)?;
        let blobs = BlobStore::open(root)?;
        let manifest_path = root.join("manifest.json");
        let manifest = if manifest_path.exists() {
            let text = fs::read_to_string(&manifest_path)?;
            let j = crate::util::json::Json::parse(&text).map_err(|e| {
                StoreError::Malformed(format!("manifest.json: {e}"))
            })?;
            Manifest::from_json(&j).map_err(|e| {
                StoreError::Malformed(format!("manifest.json: {e}"))
            })?
        } else {
            Manifest::new()
        };
        Ok(Store { root: root.to_path_buf(), blobs, manifest })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn blobs(&self) -> &BlobStore {
        &self.blobs
    }

    /// Atomically rewrite `manifest.json` (temp + rename, like blob
    /// writes: a crash never leaves a truncated manifest).
    fn save_manifest(&self) -> Result<(), StoreError> {
        let path = self.root.join("manifest.json");
        let tmp = self.root.join("manifest.json.tmp");
        fs::write(&tmp, self.manifest.to_json().dump())?;
        fs::rename(&tmp, &path)?;
        Ok(())
    }

    // -- writing --------------------------------------------------------

    /// Store a front under `key` and index it; returns the blob
    /// address.
    pub fn put_front(&mut self, key: &CatalogKey, seed: u64,
                     front: &ParetoArchive) -> Result<String, StoreError> {
        let hash = self.blobs.put_front(front)?;
        self.manifest.record(BlobKind::Front, key.clone(), seed,
                             hash.clone(), front.len());
        self.save_manifest()?;
        Ok(hash)
    }

    /// Store a run report under `key` and index it; returns the blob
    /// address.
    pub fn put_run_report(&mut self, key: &CatalogKey,
                          report: &crate::coordinator::RunReport)
                          -> Result<String, StoreError> {
        let hash =
            self.blobs.put(report.to_json().dump().as_bytes())?;
        self.manifest.record(BlobKind::RunReport, key.clone(),
                             report.seed, hash.clone(), 0);
        self.save_manifest()?;
        Ok(hash)
    }

    // -- reading --------------------------------------------------------

    /// Load + verify + parse a stored front by address.
    pub fn load_front(&self, hash: &str)
                      -> Result<ParetoArchive, StoreError> {
        self.blobs.get_front(hash)
    }

    /// The best stored front for a scenario similar to `key`
    /// ([`Manifest::best_match`] semantics), loaded and verified.
    /// `None` when nothing in the catalog shares any dimension.
    pub fn best_front(&self, key: &CatalogKey, seed: u64)
                      -> Result<Option<(CatalogEntry, ParetoArchive)>,
                                StoreError> {
        match self.manifest.best_match(key, BlobKind::Front, seed) {
            None => Ok(None),
            Some(entry) => {
                let front = self.load_front(&entry.hash)?;
                Ok(Some((entry.clone(), front)))
            }
        }
    }

    /// Warm-start entries for `key`: the best similar front's entries,
    /// or empty when the catalog has no relevant front.  Feeding the
    /// empty case to `optimize_with_observer_warm` is byte-for-byte
    /// the cold path, so callers need no branch.
    pub fn warm_entries(&self, key: &CatalogKey, seed: u64)
                        -> Result<Vec<Entry>, StoreError> {
        Ok(self
            .best_front(key, seed)?
            .map(|(_, front)| front.entries().to_vec())
            .unwrap_or_default())
    }

    /// A transfer corpus from the best *other-model* front for `key`:
    /// cross-model catalog hits cannot seed warm entries (the configs
    /// were priced on a different model) but they can seed
    /// [`crate::surrogate::transfer::transfer_fit`].  Candidates are
    /// ranked by
    /// the minor dimensions (task 4 / platform 2 / scenario 1), newest
    /// first; entries whose model name the zoo no longer knows are
    /// skipped.
    pub fn source_corpus(&self, key: &CatalogKey)
                         -> Result<Option<SourceCorpus>, StoreError> {
        let mut candidates: Vec<&CatalogEntry> = self
            .manifest
            .entries()
            .iter()
            .filter(|e| {
                e.kind == BlobKind::Front
                    && e.key.model != key.model
                    && e.front_size > 0
                    && models::by_name(&e.key.model).is_some()
            })
            .collect();
        let minor = |k: &CatalogKey| -> u32 {
            let mut s = 0;
            if k.task == key.task {
                s += 4;
            }
            if k.platform == key.platform {
                s += 2;
            }
            if k.scenario == key.scenario {
                s += 1;
            }
            s
        };
        candidates.sort_by(|a, b| {
            minor(&b.key).cmp(&minor(&a.key)).then(b.seq.cmp(&a.seq))
        });
        let Some(entry) = candidates.first() else {
            return Ok(None);
        };
        let front = self.load_front(&entry.hash)?;
        let model = models::by_name(&entry.key.model)
            .expect("filtered to known models above");
        let task = tasks::by_name(&entry.key.task)
            .unwrap_or_else(tasks::blended_task);
        Ok(Some(SourceCorpus::from_entries(model, task,
                                           front.entries())))
    }

    /// Catalog listing, in insertion order (what `store ls` prints).
    pub fn ls(&self) -> &[CatalogEntry] {
        self.manifest.entries()
    }

    // -- maintenance ----------------------------------------------------

    /// Check every blob: each manifest-referenced blob must exist,
    /// hash to its address, and parse under its recorded kind's
    /// schema; every on-disk blob (referenced or not) must hash to its
    /// filename.  Read-only — reports, never repairs.
    pub fn verify(&self) -> Result<VerifyReport, StoreError> {
        let mut report = VerifyReport::default();
        let mut seen = std::collections::BTreeSet::new();
        for entry in self.manifest.entries() {
            if seen.insert(entry.hash.clone()) {
                report.checked += 1;
            }
            let result = match entry.kind {
                BlobKind::Front => self
                    .blobs
                    .get_json(&entry.hash, FRONT_SCHEMA)
                    .and_then(|j| {
                        ParetoArchive::from_json(&j).map(|_| ()).map_err(
                            |e| StoreError::Malformed(format!(
                                "blob {}: {e}", entry.hash)))
                    }),
                BlobKind::RunReport => self
                    .blobs
                    .get_json(&entry.hash, RUN_REPORT_SCHEMA)
                    .map(|_| ()),
            };
            if let Err(e) = result {
                report.problems.push(format!(
                    "entry {} ({} for {}): {e}",
                    entry.seq,
                    entry.kind.name(),
                    entry.key.model
                ));
            }
        }
        // Unreferenced blobs still live at content addresses; a
        // corrupted one is a real problem `gc` would otherwise sweep
        // under the rug.
        for hash in self.blobs.list()? {
            if seen.contains(&hash) {
                continue;
            }
            report.checked += 1;
            if let Err(e) = self.blobs.get(&hash) {
                report.problems.push(format!("unreferenced blob: {e}"));
            }
        }
        Ok(report)
    }

    /// Remove every blob the manifest does not reference.  The
    /// manifest is the root set, so a referenced blob is *never*
    /// collected.
    pub fn gc(&mut self) -> Result<GcReport, StoreError> {
        let referenced = self.manifest.referenced_hashes();
        let mut report = GcReport::default();
        for hash in self.blobs.list()? {
            if referenced.contains(&hash) {
                report.kept += 1;
            } else {
                self.blobs.remove(&hash)?;
                report.removed.push(hash);
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::oracle::Objectives;
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("ae-llm-store-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sample_front(seed: u64, n: u64) -> ParetoArchive {
        let mut a = ParetoArchive::new(32);
        let mut rng = Rng::new(seed);
        for _ in 0..n {
            let c: Config = crate::config::enumerate::sample(&mut rng);
            a.insert(c, Objectives {
                accuracy: 50.0 + 40.0 * rng.f64(),
                latency_ms: 5.0 + 50.0 * rng.f64(),
                memory_gb: 1.0 + 10.0 * rng.f64(),
                energy_j: 0.1 + rng.f64(),
            });
        }
        a
    }

    fn key(model: &str, scenario: &str) -> CatalogKey {
        CatalogKey::new(model, "GSM8K", "A100-80GB", scenario)
    }

    #[test]
    fn store_reopens_with_its_catalog() {
        let dir = tmp_dir("reopen");
        let front = sample_front(1, 20);
        let hash = {
            let mut store = Store::open(&dir).unwrap();
            store.put_front(&key("Phi-2", "bursty"), 7, &front).unwrap()
        };
        // a second process (fresh handle) sees the same catalog and
        // loads the identical front
        let store = Store::open(&dir).unwrap();
        assert_eq!(store.ls().len(), 1);
        assert_eq!(store.ls()[0].hash, hash);
        assert_eq!(store.ls()[0].seed, 7);
        assert_eq!(store.ls()[0].front_size, front.len());
        let back = store.load_front(&hash).unwrap();
        assert_eq!(back.to_json().dump(), front.to_json().dump());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn best_front_prefers_similar_scenarios() {
        let dir = tmp_dir("best");
        let mut store = Store::open(&dir).unwrap();
        let other = sample_front(2, 8);
        let exact = sample_front(3, 8);
        store.put_front(&key("LLaMA-2-7B", "bursty"), 1, &other)
            .unwrap();
        let exact_hash = store
            .put_front(&key("Phi-2", "bursty"), 1, &exact)
            .unwrap();
        let (entry, front) =
            store.best_front(&key("Phi-2", "bursty"), 9).unwrap()
                .unwrap();
        assert_eq!(entry.hash, exact_hash);
        assert_eq!(front.to_json().dump(), exact.to_json().dump());
        // warm_entries mirrors best_front; unrelated keys come up empty
        assert_eq!(store.warm_entries(&key("Phi-2", "bursty"), 9)
                       .unwrap().len(),
                   exact.len());
        let nothing = CatalogKey::new("x", "y", "z", "w");
        assert!(store.best_front(&nothing, 9).unwrap().is_none());
        assert!(store.warm_entries(&nothing, 9).unwrap().is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn source_corpus_comes_from_another_model() {
        let dir = tmp_dir("corpus");
        let mut store = Store::open(&dir).unwrap();
        // same-model front must NOT be a transfer source
        store.put_front(&key("Phi-2", "bursty"), 1,
                        &sample_front(4, 10)).unwrap();
        assert!(store.source_corpus(&key("Phi-2", "bursty")).unwrap()
                    .is_none());
        // a different model's front is
        let src = sample_front(5, 10);
        store.put_front(&key("LLaMA-2-7B", "bursty"), 1, &src).unwrap();
        let corpus =
            store.source_corpus(&key("Phi-2", "bursty")).unwrap()
                .unwrap();
        assert_eq!(corpus.model.name, "LLaMA-2-7B");
        assert_eq!(corpus.evaluations.len(), src.len());
        // a model name the zoo doesn't know is skipped, not an error
        store.put_front(&key("SomeForeignModel", "bursty"), 1,
                        &sample_front(6, 10)).unwrap();
        let corpus =
            store.source_corpus(&key("Phi-2", "bursty")).unwrap()
                .unwrap();
        assert_eq!(corpus.model.name, "LLaMA-2-7B");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_never_collects_referenced_blobs() {
        let dir = tmp_dir("gc");
        let mut store = Store::open(&dir).unwrap();
        let kept = sample_front(7, 12);
        let kept_hash =
            store.put_front(&key("Phi-2", "bursty"), 1, &kept).unwrap();
        // an orphan blob: stored directly, never indexed
        let orphan_hash =
            store.blobs.put(b"{\"schema\":\"orphan\"}").unwrap();
        let report = store.gc().unwrap();
        assert_eq!(report.kept, 1);
        assert_eq!(report.removed, vec![orphan_hash.clone()]);
        assert!(store.blobs.contains(&kept_hash));
        assert!(!store.blobs.contains(&orphan_hash));
        // idempotent: a second sweep removes nothing
        let report = store.gc().unwrap();
        assert_eq!(report.kept, 1);
        assert!(report.removed.is_empty());
        assert_eq!(store.load_front(&kept_hash).unwrap().to_json()
                       .dump(),
                   kept.to_json().dump());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn verify_catches_corruption_and_passes_clean_stores() {
        let dir = tmp_dir("verify");
        let mut store = Store::open(&dir).unwrap();
        let front = sample_front(8, 12);
        let hash =
            store.put_front(&key("Phi-2", "bursty"), 1, &front).unwrap();
        let report = store.verify().unwrap();
        assert!(report.ok(), "{:?}", report.problems);
        assert_eq!(report.checked, 1);
        // flip a byte in the object file
        let path = dir.join("objects").join(&hash[..2]).join(&hash[2..]);
        let mut bytes = fs::read(&path).unwrap();
        bytes[10] ^= 0x01;
        fs::write(&path, &bytes).unwrap();
        let report = store.verify().unwrap();
        assert!(!report.ok());
        assert!(report.problems[0].contains("corrupt"),
                "{:?}", report.problems);
        // a deleted blob is also caught
        fs::remove_file(&path).unwrap();
        let report = store.verify().unwrap();
        assert!(!report.ok());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_rejects_a_garbage_manifest() {
        let dir = tmp_dir("badmanifest");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("manifest.json"), "not json").unwrap();
        assert!(matches!(Store::open(&dir),
                         Err(StoreError::Malformed(_))));
        fs::remove_dir_all(&dir).unwrap();
    }
}
