//! Serving telemetry and drift detection — the sensors of the
//! continual-adaptation loop (DESIGN.md §12).
//!
//! Each serving epoch distills into one [`EpochTelemetry`]: the
//! arrival-side workload shape (per-SLO-class rates and shares, the
//! prompt seq-length histogram) plus the serve-side outcome stats
//! (violations, truncations, latency, energy).  The
//! [`DriftDetector`] maintains an EWMA baseline over the workload-shape
//! features ([`crate::util::stats::ewma_step`]) and signals drift when
//! the current epoch departs from that baseline by more than a
//! threshold — at which point the controller re-scopes the search to
//! the observed shape, warm-starts from the persistent front and
//! hot-swaps the deployment.
//!
//! Everything here is a pure function of its inputs: no clocks, no
//! RNG.  Same epochs in → same decisions out, at every parallelism
//! level — which is what keeps the whole `AdaptReport` byte-identical
//! per seed.

use crate::util::json::Json;
use crate::util::stats;

use super::fleet::SloClass;
use super::serve::{Arrival, Completion};

/// Upper edges of the prompt seq-length histogram buckets (the last
/// bucket is open-ended).
pub const SEQ_BUCKET_EDGES: [usize; 7] = [64, 128, 256, 512, 1024, 1536, 2048];

/// Number of histogram buckets (`edges + 1` for the open tail).
pub const SEQ_BUCKETS: usize = SEQ_BUCKET_EDGES.len() + 1;

/// One serving epoch's telemetry (DESIGN.md §12): what arrived, and
/// how serving it went.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochTelemetry {
    pub epoch: usize,
    /// Requests that arrived this epoch.
    pub requests: usize,
    /// Arrivals per [`SloClass`] (interactive, batch, long-context).
    pub class_counts: [usize; 3],
    /// Class shares; sums to 1 for a non-empty epoch.
    pub class_share: [f64; 3],
    /// Mean arrival rate over the epoch's arrival span, requests/s.
    pub rate_rps: f64,
    /// Mean raw prompt length, tokens.
    pub mean_seq: f64,
    /// Longest raw prompt observed, tokens (what shape re-provisioning
    /// keys on: a serve shape below this truncates).
    pub max_seq: usize,
    /// Prompt-length histogram over [`SEQ_BUCKET_EDGES`].
    pub seq_hist: [usize; SEQ_BUCKETS],
    /// Completions accounted this epoch.
    pub completed: usize,
    pub violations: usize,
    pub violation_rate: f64,
    pub truncated: usize,
    pub p95_latency_ms: f64,
    /// Energy the backends drew this epoch, J.
    pub energy_j: f64,
    /// First arrival to last completion of the epoch, ms.
    pub span_ms: f64,
}

impl EpochTelemetry {
    /// Distill one epoch from the serving hooks: the arrival log slice
    /// and the completion records the fleet accounted this epoch.
    pub fn from_epoch(epoch: usize, arrivals: &[Arrival],
                      completions: &[Completion], energy_j: f64)
                      -> EpochTelemetry {
        let n = arrivals.len();
        let mut class_counts = [0usize; 3];
        let mut seq_hist = [0usize; SEQ_BUCKETS];
        let mut seq_sum = 0usize;
        let mut max_seq = 0usize;
        let mut first_arrival = f64::INFINITY;
        let mut last_arrival = f64::NEG_INFINITY;
        for a in arrivals {
            max_seq = max_seq.max(a.len);
            let i = SloClass::ALL
                .iter()
                .position(|&c| c == a.slo)
                .expect("every class is in ALL");
            class_counts[i] += 1;
            let bucket = SEQ_BUCKET_EDGES
                .iter()
                .position(|&edge| a.len <= edge)
                .unwrap_or(SEQ_BUCKETS - 1);
            seq_hist[bucket] += 1;
            seq_sum += a.len;
            first_arrival = first_arrival.min(a.arrival_ms);
            last_arrival = last_arrival.max(a.arrival_ms);
        }
        let mut class_share = [0.0; 3];
        if n > 0 {
            for i in 0..3 {
                class_share[i] = class_counts[i] as f64 / n as f64;
            }
        }
        let arrival_span_ms = (last_arrival - first_arrival).max(0.0);
        let rate_rps = if n > 1 && arrival_span_ms > 0.0 {
            (n as f64 - 1.0) / (arrival_span_ms / 1e3)
        } else {
            0.0
        };

        let violations = completions.iter().filter(|c| c.violated).count();
        let truncated = completions.iter().filter(|c| c.truncated).count();
        let lats: Vec<f64> =
            completions.iter().map(|c| c.latency_ms).collect();
        let last_done = completions
            .iter()
            .map(|c| c.done_ms)
            .fold(f64::NEG_INFINITY, f64::max);
        let span_ms = if n > 0 && !completions.is_empty() {
            (last_done - first_arrival).max(0.0)
        } else {
            0.0
        };
        EpochTelemetry {
            epoch,
            requests: n,
            class_counts,
            class_share,
            rate_rps,
            mean_seq: if n > 0 { seq_sum as f64 / n as f64 } else { 0.0 },
            max_seq,
            seq_hist,
            completed: completions.len(),
            violations,
            violation_rate: if completions.is_empty() {
                0.0
            } else {
                violations as f64 / completions.len() as f64
            },
            truncated,
            p95_latency_ms: stats::quantile(&lats, 0.95),
            energy_j,
            span_ms,
        }
    }

    /// The workload-shape feature vector the drift detector baselines:
    /// the three class shares, the arrival rate and the mean prompt
    /// length.
    pub fn shape_features(&self) -> [f64; 5] {
        [
            self.class_share[0],
            self.class_share[1],
            self.class_share[2],
            self.rate_rps,
            self.mean_seq,
        ]
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("epoch".into(), Json::Num(self.epoch as f64));
        m.insert("requests".into(), Json::Num(self.requests as f64));
        m.insert(
            "class_counts".into(),
            Json::Arr(self.class_counts.iter()
                .map(|&c| Json::Num(c as f64)).collect()),
        );
        m.insert(
            "class_share".into(),
            Json::Arr(self.class_share.iter()
                .map(|&s| Json::Num(s)).collect()),
        );
        m.insert("rate_rps".into(), Json::Num(self.rate_rps));
        m.insert("mean_seq".into(), Json::Num(self.mean_seq));
        m.insert("max_seq".into(), Json::Num(self.max_seq as f64));
        m.insert(
            "seq_hist".into(),
            Json::Arr(self.seq_hist.iter()
                .map(|&c| Json::Num(c as f64)).collect()),
        );
        m.insert("completed".into(), Json::Num(self.completed as f64));
        m.insert("violations".into(), Json::Num(self.violations as f64));
        m.insert("violation_rate".into(), Json::Num(self.violation_rate));
        m.insert("truncated".into(), Json::Num(self.truncated as f64));
        m.insert("p95_latency_ms".into(), Json::Num(self.p95_latency_ms));
        m.insert("energy_j".into(), Json::Num(self.energy_j));
        m.insert("span_ms".into(), Json::Num(self.span_ms));
        Json::Obj(m)
    }
}

/// What one [`DriftDetector::observe`] call decided.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftDecision {
    /// Distance of the epoch's workload shape from the EWMA baseline.
    pub score: f64,
    pub drifted: bool,
}

/// EWMA drift detector over the workload-shape features.
///
/// Score = Σ|Δ class share| + min(1, |ln(rate / baseline rate)|)
///       + min(1, |Δ mean seq| / baseline mean seq); the log/relative
/// terms make the score scale-free, the caps keep one runaway feature
/// from swamping the budget.  To resist single-epoch sampling noise,
/// drift fires only when the score exceeds the threshold in two
/// consecutive epochs, or exceeds 2× the threshold outright (an abrupt
/// regime shift).  Pure and seedless: decisions are a deterministic
/// function of the telemetry stream.
#[derive(Clone, Debug)]
pub struct DriftDetector {
    alpha: f64,
    threshold: f64,
    baseline: Option<[f64; 5]>,
    /// Previous epoch exceeded the threshold (confirmation state).
    armed: bool,
}

/// Default EWMA smoothing for the baseline.
pub const DRIFT_ALPHA: f64 = 0.35;
/// Default drift threshold (see [`DriftDetector`] scoring).
pub const DRIFT_THRESHOLD: f64 = 0.45;

impl DriftDetector {
    pub fn new(alpha: f64, threshold: f64) -> DriftDetector {
        DriftDetector { alpha, threshold, baseline: None, armed: false }
    }

    /// Observe one epoch: score it against the baseline, fold it into
    /// the EWMA, and decide.  The first epoch seeds the baseline and
    /// never signals drift.
    pub fn observe(&mut self, t: &EpochTelemetry) -> DriftDecision {
        let x = t.shape_features();
        let Some(b) = self.baseline else {
            self.baseline = Some(x);
            return DriftDecision { score: 0.0, drifted: false };
        };
        let mut score = 0.0;
        for i in 0..3 {
            score += (x[i] - b[i]).abs();
        }
        if x[3] > 0.0 && b[3] > 0.0 {
            score += (x[3] / b[3]).ln().abs().min(1.0);
        }
        if b[4] > 0.0 {
            score += ((x[4] - b[4]) / b[4]).abs().min(1.0);
        }
        let exceeded = score > self.threshold;
        let drifted = (exceeded && self.armed)
            || score > 2.0 * self.threshold;
        self.armed = exceeded && !drifted;
        let mut next = b;
        for i in 0..5 {
            next[i] = stats::ewma_step(b[i], x[i], self.alpha);
        }
        self.baseline = Some(next);
        DriftDecision { score, drifted }
    }

    /// Re-anchor the baseline on the current regime — called after a
    /// re-deployment so the freshly-adapted fleet is not immediately
    /// re-flagged against the pre-drift baseline.
    pub fn rebase(&mut self, t: &EpochTelemetry) {
        self.baseline = Some(t.shape_features());
        self.armed = false;
    }
}

impl Default for DriftDetector {
    fn default() -> DriftDetector {
        DriftDetector::new(DRIFT_ALPHA, DRIFT_THRESHOLD)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn telemetry(epoch: usize, share: [f64; 3], rate: f64, seq: f64)
                 -> EpochTelemetry {
        EpochTelemetry {
            epoch,
            requests: 400,
            class_counts: [
                (share[0] * 400.0) as usize,
                (share[1] * 400.0) as usize,
                (share[2] * 400.0) as usize,
            ],
            class_share: share,
            rate_rps: rate,
            mean_seq: seq,
            max_seq: seq as usize,
            seq_hist: [0; SEQ_BUCKETS],
            completed: 400,
            violations: 0,
            violation_rate: 0.0,
            truncated: 0,
            p95_latency_ms: 10.0,
            energy_j: 1.0,
            span_ms: 1000.0,
        }
    }

    #[test]
    fn from_epoch_aggregates_arrivals_and_completions() {
        let arrivals = vec![
            Arrival { slo: SloClass::Interactive, len: 50,
                      arrival_ms: 0.0 },
            Arrival { slo: SloClass::Interactive, len: 100,
                      arrival_ms: 500.0 },
            Arrival { slo: SloClass::LongContext, len: 1500,
                      arrival_ms: 1000.0 },
        ];
        let completions = vec![Completion {
            id: 0,
            next_token: 1,
            latency_ms: 20.0,
            batch_index: 0,
            slo: SloClass::Interactive,
            violated: true,
            truncated: false,
            done_ms: 20.0,
        }];
        let t = EpochTelemetry::from_epoch(3, &arrivals, &completions, 2.5);
        assert_eq!(t.epoch, 3);
        assert_eq!(t.requests, 3);
        assert_eq!(t.class_counts, [2, 0, 1]);
        assert!((t.class_share[0] - 2.0 / 3.0).abs() < 1e-12);
        // 2 gaps over 1s of arrivals -> 2 rps
        assert!((t.rate_rps - 2.0).abs() < 1e-9, "rate {}", t.rate_rps);
        assert!((t.mean_seq - 550.0).abs() < 1e-9);
        assert_eq!(t.max_seq, 1500);
        // 50 -> bucket 0 (<=64), 100 -> bucket 1 (<=128),
        // 1500 -> bucket 5 (<=1536)
        assert_eq!(t.seq_hist[0], 1);
        assert_eq!(t.seq_hist[1], 1);
        assert_eq!(t.seq_hist[5], 1);
        assert_eq!(t.violations, 1);
        assert_eq!(t.energy_j, 2.5);
        assert_eq!(t.span_ms, 20.0);
    }

    #[test]
    fn empty_epoch_stays_defined() {
        let t = EpochTelemetry::from_epoch(0, &[], &[], 0.0);
        assert_eq!(t.requests, 0);
        assert_eq!(t.rate_rps, 0.0);
        assert_eq!(t.violation_rate, 0.0);
        assert_eq!(t.mean_seq, 0.0);
    }

    #[test]
    fn telemetry_json_is_complete() {
        let t = telemetry(2, [0.7, 0.25, 0.05], 30.0, 200.0);
        let j = t.to_json();
        for key in ["epoch", "requests", "class_share", "rate_rps",
                    "mean_seq", "seq_hist", "violation_rate", "energy_j"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn stable_stream_never_drifts() {
        let mut d = DriftDetector::default();
        for e in 0..20 {
            // small sampling jitter around a fixed regime
            let w = 0.01 * ((e % 3) as f64 - 1.0);
            let dec = d.observe(&telemetry(
                e, [0.70 + w, 0.25 - w, 0.05], 30.0 + w * 10.0,
                200.0 + w * 40.0));
            assert!(!dec.drifted, "epoch {e} score {}", dec.score);
            assert!(dec.score < DRIFT_THRESHOLD, "score {}", dec.score);
        }
    }

    #[test]
    fn abrupt_shift_drifts_immediately() {
        let mut d = DriftDetector::default();
        for e in 0..3 {
            assert!(!d.observe(&telemetry(e, [0.8, 0.17, 0.03], 30.0,
                                          150.0)).drifted);
        }
        // the regime flips: shares, rate and lengths all move
        let dec = d.observe(&telemetry(3, [0.25, 0.15, 0.60], 60.0,
                                       1000.0));
        assert!(dec.drifted, "score {}", dec.score);
        assert!(dec.score > 2.0 * DRIFT_THRESHOLD);
    }

    #[test]
    fn gradual_drift_needs_confirmation_then_fires() {
        let mut d = DriftDetector::new(DRIFT_ALPHA, 0.2);
        assert!(!d.observe(&telemetry(0, [0.8, 0.17, 0.03], 30.0,
                                      150.0)).drifted);
        // two consecutive moderately-drifted epochs: the first exceeds
        // the threshold but stays under 2x (arms, does not fire), the
        // second fires
        let first = d.observe(&telemetry(1, [0.74, 0.18, 0.08], 32.0,
                                         175.0));
        assert!(!first.drifted && first.score > 0.2 && first.score < 0.4,
                "score {}", first.score);
        let second = d.observe(&telemetry(2, [0.70, 0.18, 0.12], 34.0,
                                          210.0));
        assert!(second.drifted, "score {}", second.score);
    }

    #[test]
    fn rebase_accepts_the_new_regime() {
        let mut d = DriftDetector::default();
        d.observe(&telemetry(0, [0.8, 0.17, 0.03], 30.0, 150.0));
        let t_new = telemetry(1, [0.25, 0.15, 0.60], 60.0, 1000.0);
        assert!(d.observe(&t_new).drifted);
        d.rebase(&t_new);
        // the same hot regime is now the baseline: no re-flagging
        let dec = d.observe(&telemetry(2, [0.26, 0.15, 0.59], 59.0,
                                       990.0));
        assert!(!dec.drifted, "score {}", dec.score);
        assert!(dec.score < 0.1);
    }
}
