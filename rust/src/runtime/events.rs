//! Deterministic discrete-event core (DESIGN.md §13).
//!
//! The serving stack used to advance by *polling*: walk requests, walk
//! servers, step virtual time, repeat — wall-clock cost proportional to
//! the amount of virtual time swept.  This module replaces that with a
//! binary event heap: producers push timestamped events, the consumer
//! pops them in timeline order, and wall-clock cost is proportional to
//! the number of *events processed*, which is what makes cluster-scale
//! simulation (hundreds of nodes, millions of requests) tractable.
//!
//! Event taxonomy (see [`Event`]):
//!
//! * `Arrival` — a request reaches a server's queue;
//! * `BatchClose` — a formed batch becomes dispatchable (size- or
//!   deadline-triggered, per [`super::batcher::Batcher`]);
//! * `BatchComplete` — an executing batch finishes on its lane;
//! * `EpochBoundary` — the adaptation controller's epoch ends (drain,
//!   telemetry, drift decision).
//!
//! Ordering and determinism contract: every event is keyed by
//! `(time_ms, seq)` where `seq` is a monotonically increasing counter
//! assigned at push.  The heap pops strictly in that key order, so
//!
//! 1. events at distinct times pop in timeline order, and
//! 2. events at the *same* time pop in **submission order** — the tie-
//!    break is stable, never a hash or pointer comparison.
//!
//! That second property is what keeps same-seed runs byte-identical
//! across machines and parallelism levels: whenever two things happen
//! "at the same instant" (a batch closing exactly when the next request
//! arrives, an epoch boundary sharing a timestamp with the first
//! arrival of the next epoch), the winner is decided by push order,
//! which every deterministic driver reproduces exactly.  Times must be
//! non-NaN (`push` asserts); infinities are allowed and sort last.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// The event taxonomy of the serving simulation.  Payloads are indices
/// into the driver's own side tables (request lists, formed-batch
/// tables), keeping the heap small and `Copy`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Request `index` (into the driver's submission-ordered request
    /// list) arrives at its server's queue.
    Arrival { index: usize },
    /// Formed batch `batch` (into the driver's side table of closed
    /// batches) becomes dispatchable.
    BatchClose { batch: usize },
    /// Executing batch `batch` completes on its serving lane.
    BatchComplete { batch: usize },
    /// Serving epoch `epoch` ends: drain, extract telemetry, decide.
    EpochBoundary { epoch: usize },
}

/// Heap entry: the `(time_ms, seq)` ordering key plus the payload.
/// `Ord` looks only at the key, so the payload type needs no bounds.
struct Keyed<E> {
    time_ms: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Keyed<E> {
    fn eq(&self, other: &Self) -> bool {
        // seq is unique per queue, so this is really seq equality; the
        // time check keeps eq consistent with cmp by construction.
        self.seq == other.seq && self.time_ms == other.time_ms
    }
}

impl<E> Eq for Keyed<E> {}

impl<E> PartialOrd for Keyed<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Keyed<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Times are asserted non-NaN at push, so partial_cmp is total
        // here; seq breaks ties stably (push order).
        self.time_ms
            .partial_cmp(&other.time_ms)
            .expect("event times are never NaN")
            .then(self.seq.cmp(&other.seq))
    }
}

/// A deterministic min-heap of timestamped events.
///
/// Pops in `(time_ms, seq)` order: timeline order first, push order
/// among ties.  Generic over the payload so drivers can carry their
/// own event types ([`Event`] is the shared taxonomy).
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Keyed<E>>>,
    next_seq: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// A queue whose backing heap is pre-sized for `cap` concurrently
    /// scheduled events (capacity hint only; the queue still grows
    /// past it if needed).
    pub fn with_capacity(cap: usize) -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::with_capacity(cap), next_seq: 0 }
    }

    /// Schedule `event` at `time_ms`; returns the sequence number that
    /// breaks ties against other events at the same time (monotonically
    /// increasing, so later pushes lose ties to earlier ones).
    pub fn push(&mut self, time_ms: f64, event: E) -> u64 {
        assert!(!time_ms.is_nan(), "event time must not be NaN");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Keyed { time_ms, seq, event }));
        seq
    }

    /// Pop the earliest event: smallest `(time_ms, seq)` key.
    pub fn pop(&mut self) -> Option<(f64, u64, E)> {
        self.heap
            .pop()
            .map(|Reverse(k)| (k.time_ms, k.seq, k.event))
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time_ms(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(k)| k.time_ms)
    }

    /// Drop every queued event and reset the tie-break counter, keeping
    /// the backing heap's allocation — a queue reused across epochs (or
    /// across [`drain`](super::serve) calls) allocates once at its
    /// high-water mark instead of rebuilding per cycle (the zero-churn
    /// pass, DESIGN.md §15).  Resetting `next_seq` is behavior-neutral:
    /// only the *relative* order of sequence numbers within one fill
    /// ever matters.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.next_seq = 0;
    }

    /// Events the backing heap can hold without reallocating.
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Reserve room for at least `additional` more events.
    pub fn reserve(&mut self, additional: usize) {
        self.heap.reserve(additional);
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30.0, "c");
        q.push(10.0, "a");
        q.push(20.0, "b");
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek_time_ms(), Some(10.0));
        let order: Vec<&str> =
            std::iter::from_fn(|| q.pop()).map(|(_, _, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert!(q.is_empty());
    }

    #[test]
    fn same_time_events_pop_in_submission_order() {
        let mut q = EventQueue::new();
        for i in 0..100usize {
            q.push(5.0, i);
        }
        let order: Vec<usize> =
            std::iter::from_fn(|| q.pop()).map(|(_, _, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_pushes_and_pops_keep_key_order() {
        let mut q = EventQueue::new();
        q.push(10.0, 0usize);
        q.push(10.0, 1);
        assert_eq!(q.pop().map(|(_, _, e)| e), Some(0));
        // A later push at the same instant still loses the tie to the
        // event pushed before it.
        q.push(10.0, 2);
        assert_eq!(q.pop().map(|(_, _, e)| e), Some(1));
        assert_eq!(q.pop().map(|(_, _, e)| e), Some(2));
        assert_eq!(q.pop().map(|(_, _, e)| e), None);
    }

    #[test]
    fn property_random_tied_times_preserve_submission_order() {
        // Many events drawn from a tiny set of timestamps (maximal
        // tying): the pop sequence must be sorted by time, and within
        // every timestamp must preserve push order exactly.
        let mut rng = Rng::new(42);
        let mut q = EventQueue::new();
        let mut pushed: Vec<(f64, usize)> = Vec::new();
        for i in 0..500usize {
            let t = [0.0, 1.0, 1.0, 2.5, 7.0][rng.below(5) as usize];
            q.push(t, i);
            pushed.push((t, i));
        }
        let popped: Vec<(f64, usize)> =
            std::iter::from_fn(|| q.pop()).map(|(t, _, e)| (t, e)).collect();
        // stable sort of the push log by time == heap pop order
        let mut expect = pushed.clone();
        expect.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert_eq!(popped, expect);
    }

    #[test]
    fn infinity_sorts_last_and_seq_is_returned() {
        let mut q = EventQueue::new();
        let s0 = q.push(f64::INFINITY, "flush");
        let s1 = q.push(3.0, "work");
        assert!(s1 > s0);
        assert_eq!(q.pop().map(|(t, s, e)| (t, s, e)),
                   Some((3.0, s1, "work")));
        assert_eq!(q.pop().map(|(_, _, e)| e), Some("flush"));
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn nan_times_are_rejected() {
        EventQueue::new().push(f64::NAN, 0usize);
    }

    #[test]
    fn clear_retains_capacity_and_resets_the_tie_break() {
        let mut q = EventQueue::new();
        for i in 0..256usize {
            q.push(i as f64, i);
        }
        let cap = q.capacity();
        assert!(cap >= 256);
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.capacity(), cap, "clear must keep the allocation");
        // The tie-break counter restarts, and a refill of the same size
        // never grows the heap.
        assert_eq!(q.push(1.0, 0usize), 0);
        for i in 1..256usize {
            q.push(1.0, i);
        }
        assert_eq!(q.capacity(), cap, "refill within capacity reallocated");
        let order: Vec<usize> =
            std::iter::from_fn(|| q.pop()).map(|(_, _, e)| e).collect();
        assert_eq!(order, (0..256).collect::<Vec<_>>(),
                   "submission order must survive a clear");
    }

    #[test]
    fn reserve_grows_capacity_up_front() {
        let mut q: EventQueue<usize> = EventQueue::new();
        q.reserve(100);
        assert!(q.capacity() >= 100);
    }

    #[test]
    fn taxonomy_is_copy_and_comparable() {
        let e = Event::Arrival { index: 3 };
        let f = e; // Copy
        assert_eq!(e, f);
        assert_ne!(Event::BatchClose { batch: 0 },
                   Event::BatchComplete { batch: 0 });
        let _ = Event::EpochBoundary { epoch: 1 };
    }
}
