//! Cluster-scale serving (DESIGN.md §13): N fleet nodes behind a
//! deterministic cross-node router, driven by the discrete-event core.
//!
//! One [`super::fleet::EpochFleet`] models a single machine's slot
//! servers.  A [`Cluster`] stacks N of them — each node gets its own
//! derived seed and per-slot lane budget via its [`Deployment`] — and
//! routes every arriving request to the least-loaded node (pending
//! in-flight count), with a per-node soft capacity cap and a *seeded*
//! tie-break so same-seed runs route byte-identically:
//!
//! ```text
//!                  ┌────────────────────────────────────┐
//!   requests ──▶   │ Cluster: least-loaded router (Rng) │
//!                  └──┬──────────────┬──────────────┬───┘
//!                     ▼              ▼              ▼
//!                EpochFleet     EpochFleet     EpochFleet   (node 0..N)
//!                     │              │              │
//!                     ▼              ▼              ▼
//!                  Server×slots   Server×slots  Server×slots
//!                     │              │              │
//!                     ▼              ▼              ▼
//!                  Backend        Backend        Backend
//! ```
//!
//! Two drivers serve the same workload:
//!
//! * [`Cluster::serve`] — the event core: arrivals and epoch
//!   boundaries are heap events on one [`EventQueue`], so wall-clock
//!   cost is proportional to events processed.  This is what makes
//!   64-node / 100k-request simulation tractable.
//! * [`Cluster::serve_polled`] — the pre-event-core reference: a
//!   fixed-step tick loop that polls every node at every tick
//!   (`benches/perf_cluster.rs` measures the before/after).
//!
//! Both submit arrivals in the same order and harvest completions only
//! at epoch boundaries, so `pending()` — and therefore every routing
//! decision — is identical between them; the drivers differ only in
//! *when* ripe batches execute mid-epoch (the tick loop dispatches
//! deadline-triggered tails at deadline ticks, the event driver flushes
//! them at the boundary drain), which is why the cluster tests assert
//! identical routing and completion counts rather than byte-equal
//! reports across drivers.  Within one driver, same-seed runs are
//! byte-identical at every parallelism level.

use crate::util::json::Json;
use crate::util::pool::Parallelism;
use crate::util::rng::Rng;

use super::events::{Event, EventQueue};
use super::fleet::{Deployment, EpochFleet};
use super::serve::{Completion, Request, ServeReport};

/// Golden-ratio stride used everywhere the repo derives child seeds.
const SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;
/// Salt for the router's tie-break stream, so routing noise is
/// decorrelated from the nodes' backend noise at the same seed.
const ROUTE_SALT: u64 = 0x5EED_0F0A_7E55_C1A5;

/// Sizing of a simulated cluster.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterParams {
    /// Number of fleet nodes.
    pub nodes: usize,
    /// Soft cap on per-node pending requests: nodes at or over it are
    /// skipped by the router while any node is under it (when every
    /// node is saturated the router falls back to least-loaded over
    /// all of them, so requests are never dropped).
    pub capacity: usize,
    /// Serving epochs the workload is split into; completions are
    /// harvested (and per-node queues drained) at each boundary.
    pub epochs: usize,
    /// Virtual-time step of the tick-polled reference driver, ms.
    pub tick_ms: f64,
}

impl Default for ClusterParams {
    fn default() -> ClusterParams {
        ClusterParams { nodes: 4, capacity: 64, epochs: 4, tick_ms: 1.0 }
    }
}

/// N deployment nodes behind a seeded least-loaded router.
///
/// Construction is cheap; the fleets are instantiated per serve call
/// so one `Cluster` value can drive both the event and the polled
/// driver from the same (deployment, seed) without shared state.
pub struct Cluster {
    deployment: Deployment,
    params: ClusterParams,
    seed: u64,
    par: Parallelism,
}

impl Cluster {
    pub fn new(deployment: Deployment, params: ClusterParams, seed: u64,
               par: Parallelism) -> Cluster {
        Cluster {
            deployment,
            params: ClusterParams {
                nodes: params.nodes.max(1),
                capacity: params.capacity.max(1),
                epochs: params.epochs.max(1),
                tick_ms: if params.tick_ms > 0.0 {
                    params.tick_ms
                } else {
                    1.0
                },
            },
            seed,
            par,
        }
    }

    pub fn params(&self) -> &ClusterParams {
        &self.params
    }

    /// Serve a timestamped workload across the cluster on the event
    /// core and aggregate per-node + merged statistics (schema
    /// `ae-llm.cluster-report/v1`).  Deterministic per seed at every
    /// parallelism level.
    ///
    /// ```
    /// use ae_llm::config::enumerate::sample;
    /// use ae_llm::oracle::Objectives;
    /// use ae_llm::runtime::fleet::{Deployment, SloPolicy};
    /// use ae_llm::runtime::{Cluster, ClusterParams, Request, SloClass};
    /// use ae_llm::search::archive::ParetoArchive;
    /// use ae_llm::util::{Parallelism, Rng};
    ///
    /// let mut front = ParetoArchive::new(8);
    /// front.insert(sample(&mut Rng::new(1)),
    ///              Objectives { accuracy: 68.0, latency_ms: 12.0,
    ///                           memory_gb: 10.0, energy_j: 0.6 });
    /// let model = ae_llm::models::by_name("LLaMA-2-7B").unwrap();
    /// let deployment = Deployment::from_front(
    ///     &front, &SloPolicy::default(), &model,
    ///     &ae_llm::tasks::blended_task(), &ae_llm::hardware::a100())
    ///     .unwrap();
    /// let requests: Vec<Request> = (0..40)
    ///     .map(|i| Request::new(i, vec![1; 64])
    ///         .at(i as f64 * 8.0)
    ///         .class(SloClass::Interactive))
    ///     .collect();
    /// let cluster = Cluster::new(deployment,
    ///                            ClusterParams { nodes: 2,
    ///                                            ..Default::default() },
    ///                            7, Parallelism::Sequential);
    /// let report = cluster.serve(&requests, "steady");
    /// assert_eq!(report.overall.completed, 40);
    /// assert_eq!(report.routed.iter().sum::<usize>(), 40);
    /// ```
    pub fn serve(&self, requests: &[Request], scenario: &str)
                 -> ClusterReport {
        let mut nodes = self.make_nodes(super::serve::DrainDriver::Event);
        let mut rng = Rng::new(self.seed ^ ROUTE_SALT);
        let mut routed = vec![0usize; nodes.len()];

        let per = chunk_len(requests.len(), self.params.epochs);
        let mut queue: EventQueue<Event> = EventQueue::new();
        let mut boundary = 0.0f64;
        for (epoch, chunk) in requests.chunks(per).enumerate() {
            let base = epoch * per;
            for (k, r) in chunk.iter().enumerate() {
                queue.push(r.arrival_ms, Event::Arrival { index: base + k });
            }
            // The boundary shares the last arrival's timestamp but is
            // pushed *after* it, so it loses ties to its own epoch's
            // arrivals and wins them against the next epoch's.
            boundary = chunk
                .last()
                .map(|r| r.arrival_ms)
                .unwrap_or(boundary)
                .max(boundary);
            queue.push(boundary, Event::EpochBoundary { epoch });
        }

        while let Some((_, _, ev)) = queue.pop() {
            match ev {
                Event::Arrival { index } => {
                    let n = route(&nodes, self.params.capacity, &mut rng);
                    routed[n] += 1;
                    nodes[n].submit(requests[index].clone());
                }
                Event::EpochBoundary { epoch } => {
                    for node in &mut nodes {
                        node.close_epoch(epoch);
                    }
                }
                Event::BatchClose { .. } | Event::BatchComplete { .. } => {
                    unreachable!("batch events live inside server drains")
                }
            }
        }
        self.build_report(scenario, nodes, routed)
    }

    /// [`serve`](Self::serve) through the pre-event-core tick loop:
    /// virtual time advances in fixed `tick_ms` steps and every node is
    /// polled at every tick — wall-clock cost proportional to virtual
    /// time swept times nodes, the cost profile the event core removes.
    /// Kept as the before-side of `benches/perf_cluster.rs` and as a
    /// routing cross-check (both drivers make identical routing
    /// decisions; see the module docs for why reports may differ in
    /// mid-epoch dispatch timing).
    pub fn serve_polled(&self, requests: &[Request], scenario: &str)
                        -> ClusterReport {
        let mut nodes = self.make_nodes(super::serve::DrainDriver::Polled);
        let mut rng = Rng::new(self.seed ^ ROUTE_SALT);
        let mut routed = vec![0usize; nodes.len()];

        let per = chunk_len(requests.len(), self.params.epochs);
        let mut t = 0.0f64;
        let mut boundary = 0.0f64;
        for (epoch, chunk) in requests.chunks(per).enumerate() {
            boundary = chunk
                .last()
                .map(|r| r.arrival_ms)
                .unwrap_or(boundary)
                .max(boundary);
            let mut next = 0usize;
            while t < boundary {
                while next < chunk.len()
                    && chunk[next].arrival_ms <= t
                {
                    let n = route(&nodes, self.params.capacity, &mut rng);
                    routed[n] += 1;
                    nodes[n].submit(chunk[next].clone());
                    next += 1;
                }
                for node in &mut nodes {
                    node.poll(t);
                }
                t += self.params.tick_ms;
            }
            for r in &chunk[next..] {
                let n = route(&nodes, self.params.capacity, &mut rng);
                routed[n] += 1;
                nodes[n].submit(r.clone());
            }
            for node in &mut nodes {
                node.close_epoch(epoch);
            }
        }
        self.build_report(scenario, nodes, routed)
    }

    fn make_nodes(&self, driver: super::serve::DrainDriver)
                  -> Vec<EpochFleet> {
        (0..self.params.nodes)
            .map(|i| {
                let seed = self.seed
                    ^ ((i as u64) + 1).wrapping_mul(SEED_STRIDE);
                EpochFleet::new(self.deployment.clone(), seed, self.par)
                    .with_driver(driver)
            })
            .collect()
    }

    fn build_report(&self, scenario: &str, nodes: Vec<EpochFleet>,
                    routed: Vec<usize>) -> ClusterReport {
        let per_node: Vec<ServeReport> =
            nodes.iter().map(|n| n.overall_report()).collect();
        let all: Vec<Completion> = nodes
            .iter()
            .flat_map(|n| n.completions().iter().cloned())
            .collect();
        let exec: Vec<f64> = nodes
            .iter()
            .flat_map(|n| n.batch_exec_ms().iter().copied())
            .collect();
        let energy: f64 = nodes.iter().map(|n| n.total_energy_j()).sum();
        let tokens: usize = nodes.iter().map(|n| n.total_tokens()).sum();
        let span = nodes.iter().filter_map(|n| n.span()).fold(
            None,
            |acc: Option<(f64, f64)>, (f, l)| Some(match acc {
                None => (f, l),
                Some((af, al)) => (af.min(f), al.max(l)),
            }),
        );
        let overall = ServeReport::from_completions(
            &all, exec.len(), &exec, energy, span, tokens);
        ClusterReport {
            scenario: scenario.to_string(),
            seed: self.seed,
            nodes: self.params.nodes,
            capacity: self.params.capacity,
            epochs: self.params.epochs,
            routed,
            per_node,
            overall,
        }
    }
}

/// Epoch chunk length: ceil(len / epochs), at least 1.
fn chunk_len(len: usize, epochs: usize) -> usize {
    (len.div_ceil(epochs.max(1))).max(1)
}

/// Least-loaded routing with a soft capacity cap: candidates are the
/// nodes under `capacity` pending (all nodes when saturated); among
/// candidates, minimum `pending()` wins, and exact ties are broken by
/// the seeded stream — `rng` is consumed *only* on a tie, so the
/// stream stays aligned across runs that make the same decisions.
fn route(nodes: &[EpochFleet], capacity: usize, rng: &mut Rng) -> usize {
    let pending: Vec<usize> = nodes.iter().map(|n| n.pending()).collect();
    let candidates: Vec<usize> = {
        let under: Vec<usize> = (0..nodes.len())
            .filter(|&i| pending[i] < capacity)
            .collect();
        if under.is_empty() {
            (0..nodes.len()).collect()
        } else {
            under
        }
    };
    let min = candidates
        .iter()
        .map(|&i| pending[i])
        .min()
        .expect("cluster has at least one node");
    let ties: Vec<usize> = candidates
        .into_iter()
        .filter(|&i| pending[i] == min)
        .collect();
    if ties.len() == 1 {
        ties[0]
    } else {
        ties[rng.below(ties.len())]
    }
}

// ---------------------------------------------------------------------------
// ClusterReport
// ---------------------------------------------------------------------------

pub const CLUSTER_REPORT_SCHEMA: &str = "ae-llm.cluster-report/v1";

/// Everything one cluster serving run produced (schema
/// `ae-llm.cluster-report/v1`; `ae-llm cluster --json`).
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub scenario: String,
    pub seed: u64,
    pub nodes: usize,
    pub capacity: usize,
    pub epochs: usize,
    /// Requests routed to each node, aligned with `per_node`.
    pub routed: Vec<usize>,
    /// Whole-run serve statistics per node.
    pub per_node: Vec<ServeReport>,
    /// Merged statistics across every node.
    pub overall: ServeReport,
}

impl ClusterReport {
    /// Serialize (schema `ae-llm.cluster-report/v1`; field reference in
    /// docs/SCHEMAS.md).  Same-seed runs dump byte-identical JSON.
    pub fn to_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        root.insert("schema".into(),
                    Json::Str(CLUSTER_REPORT_SCHEMA.into()));
        root.insert("scenario".into(), Json::Str(self.scenario.clone()));
        // String, not Num: Json numbers are f64 and would corrupt
        // seeds above 2^53 (same convention as RunReport).
        root.insert("seed".into(), Json::Str(self.seed.to_string()));
        root.insert("nodes".into(), Json::Num(self.nodes as f64));
        root.insert("capacity".into(), Json::Num(self.capacity as f64));
        root.insert("epochs".into(), Json::Num(self.epochs as f64));
        root.insert("routed".into(), Json::Arr(
            self.routed.iter().map(|&n| Json::Num(n as f64)).collect()));
        root.insert("per_node".into(), Json::Arr(
            self.per_node.iter().map(ServeReport::to_json).collect()));
        root.insert("overall".into(), self.overall.to_json());
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::super::fleet::{SloClass, SloPolicy};
    use super::super::workload::{Workload, WorkloadKind};
    use super::*;
    use crate::config::Config;
    use crate::oracle::Objectives;
    use crate::search::archive::ParetoArchive;
    use crate::util::Rng;

    fn cfg(seed: u64) -> Config {
        crate::config::enumerate::sample(&mut Rng::new(seed))
    }

    fn front() -> ParetoArchive {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), Objectives { accuracy: 68.0, latency_ms: 12.0,
                                      memory_gb: 10.0, energy_j: 0.60 });
        a.insert(cfg(2), Objectives { accuracy: 68.5, latency_ms: 30.0,
                                      memory_gb: 9.0, energy_j: 0.20 });
        a.insert(cfg(3), Objectives { accuracy: 68.2, latency_ms: 28.0,
                                      memory_gb: 4.0, energy_j: 0.55 });
        a
    }

    fn deployment() -> Deployment {
        let m = crate::models::by_name("LLaMA-2-7B").unwrap();
        Deployment::from_front(&front(), &SloPolicy::default(), &m,
                               &crate::tasks::blended_task(),
                               &crate::hardware::a100())
            .unwrap()
    }

    #[test]
    fn same_seed_cluster_serve_is_byte_identical() {
        let reqs = Workload::new(WorkloadKind::Bursty, 60.0, 300, 9)
            .generate();
        let go = |par| {
            Cluster::new(deployment(),
                         ClusterParams { nodes: 3, ..Default::default() },
                         11, par)
                .serve(&reqs, "bursty")
                .to_json()
                .dump()
        };
        let a = go(Parallelism::Sequential);
        let b = go(Parallelism::Threads(4));
        let c = go(Parallelism::Sequential);
        assert_eq!(a, b, "parallelism changed the cluster report");
        assert_eq!(a, c, "same seed produced different cluster reports");
        assert!(a.contains("\"schema\":\"ae-llm.cluster-report/v1\""),
                "{a}");
    }

    #[test]
    fn event_and_polled_drivers_route_identically_and_complete_all() {
        let reqs = Workload::new(WorkloadKind::Steady, 50.0, 240, 5)
            .generate();
        let params = ClusterParams { nodes: 4, capacity: 32, epochs: 3,
                                     tick_ms: 2.0 };
        let cluster =
            Cluster::new(deployment(), params, 7, Parallelism::Sequential);
        let event = cluster.serve(&reqs, "steady");
        let polled = cluster.serve_polled(&reqs, "steady");
        // pending() moves only at epoch boundaries on both drivers, so
        // every routing decision is shared.
        assert_eq!(event.routed, polled.routed);
        assert_eq!(event.routed.iter().sum::<usize>(), reqs.len());
        assert_eq!(event.overall.completed, reqs.len());
        assert_eq!(polled.overall.completed, reqs.len());
        // per-node completions line up with routing on both drivers
        for (rep, &n) in event.per_node.iter().zip(&event.routed) {
            assert_eq!(rep.completed, n);
        }
        for (rep, &n) in polled.per_node.iter().zip(&polled.routed) {
            assert_eq!(rep.completed, n);
        }
    }

    #[test]
    fn routing_spreads_load_across_nodes() {
        let reqs = Workload::new(WorkloadKind::Steady, 80.0, 400, 3)
            .generate();
        let report = Cluster::new(
            deployment(),
            ClusterParams { nodes: 4, capacity: 16, ..Default::default() },
            13, Parallelism::Sequential)
            .serve(&reqs, "steady");
        assert_eq!(report.routed.len(), 4);
        assert!(report.routed.iter().all(|&n| n > 0),
                "a node was starved: {:?}", report.routed);
        let max = *report.routed.iter().max().unwrap();
        assert!(max < reqs.len(),
                "one node swallowed the whole workload: {:?}",
                report.routed);
        // least-loaded routing keeps the split roughly even
        let min = *report.routed.iter().min().unwrap();
        assert!(max <= 2 * min + 16,
                "routing badly skewed: {:?}", report.routed);
    }

    #[test]
    fn single_node_cluster_matches_its_own_fleet() {
        // With one node there is nothing to route; the cluster view is
        // exactly that node's whole-run report.
        let reqs = Workload::new(WorkloadKind::Diurnal, 40.0, 200, 9)
            .generate();
        let report = Cluster::new(
            deployment(),
            ClusterParams { nodes: 1, epochs: 2, ..Default::default() },
            21, Parallelism::Sequential)
            .serve(&reqs, "diurnal");
        assert_eq!(report.routed, vec![reqs.len()]);
        assert_eq!(report.per_node.len(), 1);
        assert_eq!(report.per_node[0].to_json().dump(),
                   report.overall.to_json().dump());
    }

    #[test]
    fn report_json_carries_per_node_and_routing() {
        let reqs: Vec<_> = (0..30u64)
            .map(|i| super::super::serve::Request::new(i, vec![1; 64])
                .at(i as f64 * 12.0)
                .class(SloClass::ALL[(i % 3) as usize]))
            .collect();
        let j = Cluster::new(
            deployment(),
            ClusterParams { nodes: 2, ..Default::default() },
            5, Parallelism::Sequential)
            .serve(&reqs, "steady")
            .to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str),
                   Some(CLUSTER_REPORT_SCHEMA));
        assert_eq!(j.get("nodes").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("seed").and_then(Json::as_str), Some("5"));
        let per = match j.get("per_node") {
            Some(Json::Arr(a)) => a.len(),
            _ => panic!("per_node missing"),
        };
        assert_eq!(per, 2);
        match j.get("routed") {
            Some(Json::Arr(a)) => assert_eq!(a.len(), 2),
            _ => panic!("routed missing"),
        }
    }
}
