//! Cluster-scale serving (DESIGN.md §13, §16): N fleet nodes behind a
//! deterministic cross-node router, driven by the discrete-event core
//! and simulated **in parallel across nodes** between epoch boundaries.
//!
//! One [`super::fleet::EpochFleet`] models a single machine's slot
//! servers.  A [`Cluster`] stacks N of them — each node gets its own
//! derived seed and per-slot lane budget via its [`Deployment`] — and
//! routes every arriving request to the least-loaded node (pending
//! in-flight count), with a per-node soft capacity cap and a *seeded*
//! tie-break so same-seed runs route byte-identically:
//!
//! ```text
//!                  ┌────────────────────────────────────┐
//!   requests ──▶   │ Cluster: least-loaded router (Rng) │
//!                  └──┬──────────────┬──────────────┬───┘
//!                     ▼              ▼              ▼
//!                EpochFleet     EpochFleet     EpochFleet   (node 0..N)
//!                     │              │              │
//!                     ▼              ▼              ▼
//!                  Server×slots   Server×slots  Server×slots
//!                     │              │              │
//!                     ▼              ▼              ▼
//!                  Backend        Backend        Backend
//! ```
//!
//! **Sharded simulation.** Both drivers run each epoch in two phases
//! (DESIGN.md §16).  A cheap sequential *route phase* assigns every
//! arrival in the epoch to a node, consuming the router RNG exactly as
//! the original interleaved loop did: a node's `pending()` moves only
//! at `submit` and at `close_epoch`, so mid-epoch every routing input
//! is reproducible from the epoch-start snapshot plus this epoch's own
//! assignments — a plain counter mirror, no node state touched.  A
//! *simulate phase* then drains each node's epoch in parallel
//! ([`crate::util::pool::parallel_for_each_mut`]; each node is an
//! independent `&mut` shard) and refreshes the mirror from the real
//! `pending()` counts at the boundary.  Reports merge in node order,
//! so output is byte-identical to the sequential loop at every
//! [`Parallelism`] level — the golden tests sweep
//! Sequential/Threads(4)/Threads(8) over all six workload scenarios,
//! and the retained pre-shard loops ([`Cluster::serve_interleaved`],
//! [`Cluster::serve_polled_interleaved`]) back a randomized
//! differential test of per-request assignments.
//!
//! Two drivers serve the same workload:
//!
//! * [`Cluster::serve`] — the event core: arrivals and epoch
//!   boundaries are heap events on one [`EventQueue`], so wall-clock
//!   cost is proportional to events processed.  This is what makes
//!   64-node / 100k-request simulation tractable.
//! * [`Cluster::serve_polled`] — the pre-event-core reference: a
//!   fixed-step tick loop that polls every node at every tick
//!   (`benches/perf_cluster.rs` measures the before/after).
//!
//! Both submit arrivals in the same order and harvest completions only
//! at epoch boundaries, so `pending()` — and therefore every routing
//! decision — is identical between them; the drivers differ only in
//! *when* ripe batches execute mid-epoch (the tick loop dispatches
//! deadline-triggered tails at deadline ticks, the event driver flushes
//! them at the boundary drain), which is why the cluster tests assert
//! identical routing and completion counts rather than byte-equal
//! reports across drivers.  Within one driver, same-seed runs are
//! byte-identical at every parallelism level.

use crate::util::json::Json;
use crate::util::pool::{self, Parallelism};
use crate::util::rng::Rng;

use super::events::{Event, EventQueue};
use super::fleet::{Deployment, EpochFleet};
use super::serve::{Completion, Request, ServeReport};

/// Golden-ratio stride used everywhere the repo derives child seeds.
const SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;
/// Salt for the router's tie-break stream, so routing noise is
/// decorrelated from the nodes' backend noise at the same seed.
const ROUTE_SALT: u64 = 0x5EED_0F0A_7E55_C1A5;

/// Sizing of a simulated cluster.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterParams {
    /// Number of fleet nodes.
    pub nodes: usize,
    /// Soft cap on per-node pending requests: nodes at or over it are
    /// skipped by the router while any node is under it (when every
    /// node is saturated the router falls back to least-loaded over
    /// all of them, so requests are never dropped).
    pub capacity: usize,
    /// Serving epochs the workload is split into; completions are
    /// harvested (and per-node queues drained) at each boundary.
    pub epochs: usize,
    /// Virtual-time step of the tick-polled reference driver, ms.
    pub tick_ms: f64,
    /// Parallelism of the simulate phase: how many nodes drain their
    /// epoch concurrently.  Purely a wall-clock knob — reports are
    /// byte-identical at every level (DESIGN.md §16).
    pub par: Parallelism,
}

impl Default for ClusterParams {
    fn default() -> ClusterParams {
        ClusterParams { nodes: 4, capacity: 64, epochs: 4, tick_ms: 1.0,
                        par: Parallelism::Auto }
    }
}

/// N deployment nodes behind a seeded least-loaded router.
///
/// Construction is cheap; the fleets are instantiated per serve call
/// so one `Cluster` value can drive both the event and the polled
/// driver from the same (deployment, seed) without shared state.
pub struct Cluster {
    deployment: Deployment,
    params: ClusterParams,
    seed: u64,
}

impl Cluster {
    pub fn new(deployment: Deployment, params: ClusterParams, seed: u64)
               -> Cluster {
        Cluster {
            deployment,
            params: ClusterParams {
                nodes: params.nodes.max(1),
                capacity: params.capacity.max(1),
                epochs: params.epochs.max(1),
                tick_ms: if params.tick_ms > 0.0 {
                    params.tick_ms
                } else {
                    1.0
                },
                par: params.par,
            },
            seed,
        }
    }

    pub fn params(&self) -> &ClusterParams {
        &self.params
    }

    /// Serve a timestamped workload across the cluster on the event
    /// core and aggregate per-node + merged statistics (schema
    /// `ae-llm.cluster-report/v1`).  Routing runs sequentially, node
    /// epochs simulate in parallel per `params.par` (DESIGN.md §16);
    /// deterministic per seed at every parallelism level.
    ///
    /// ```
    /// use ae_llm::config::enumerate::sample;
    /// use ae_llm::oracle::Objectives;
    /// use ae_llm::runtime::fleet::{Deployment, SloPolicy};
    /// use ae_llm::runtime::{Cluster, ClusterParams, Request, SloClass};
    /// use ae_llm::search::archive::ParetoArchive;
    /// use ae_llm::util::{Parallelism, Rng};
    ///
    /// let mut front = ParetoArchive::new(8);
    /// front.insert(sample(&mut Rng::new(1)),
    ///              Objectives { accuracy: 68.0, latency_ms: 12.0,
    ///                           memory_gb: 10.0, energy_j: 0.6 });
    /// let model = ae_llm::models::by_name("LLaMA-2-7B").unwrap();
    /// let deployment = Deployment::from_front(
    ///     &front, &SloPolicy::default(), &model,
    ///     &ae_llm::tasks::blended_task(), &ae_llm::hardware::a100())
    ///     .unwrap();
    /// let requests: Vec<Request> = (0..40)
    ///     .map(|i| Request::new(i, vec![1; 64])
    ///         .at(i as f64 * 8.0)
    ///         .class(SloClass::Interactive))
    ///     .collect();
    /// let cluster = Cluster::new(
    ///     deployment,
    ///     ClusterParams { nodes: 2,
    ///                     par: Parallelism::Threads(2),
    ///                     ..Default::default() },
    ///     7);
    /// let report = cluster.serve(&requests, "steady");
    /// assert_eq!(report.overall.completed, 40);
    /// assert_eq!(report.routed.iter().sum::<usize>(), 40);
    /// ```
    pub fn serve(&self, requests: &[Request], scenario: &str)
                 -> ClusterReport {
        self.serve_assignments(requests, scenario).0
    }

    /// [`serve`](Self::serve) plus the route phase's decisions:
    /// `assignments[i]` is the node request `i` was routed to.  The
    /// differential tests hold it against the retained
    /// [`serve_interleaved`](Self::serve_interleaved) loop.
    pub fn serve_assignments(&self, requests: &[Request], scenario: &str)
                             -> (ClusterReport, Vec<usize>) {
        let mut nodes = self.make_nodes(super::serve::DrainDriver::Event);
        let mut rng = Rng::new(self.seed ^ ROUTE_SALT);
        let mut routed = vec![0usize; nodes.len()];
        let mut assignments = vec![usize::MAX; requests.len()];

        let per = chunk_len(requests.len(), self.params.epochs);
        let mut queue: EventQueue<Event> =
            EventQueue::with_capacity(requests.len() + self.params.epochs);
        let mut boundary = 0.0f64;
        for (epoch, chunk) in requests.chunks(per).enumerate() {
            let base = epoch * per;
            for (k, r) in chunk.iter().enumerate() {
                queue.push(r.arrival_ms, Event::Arrival { index: base + k });
            }
            // The boundary shares the last arrival's timestamp but is
            // pushed *after* it, so it loses ties to its own epoch's
            // arrivals and wins them against the next epoch's.
            boundary = chunk
                .last()
                .map(|r| r.arrival_ms)
                .unwrap_or(boundary)
                .max(boundary);
            queue.push(boundary, Event::EpochBoundary { epoch });
        }

        // Route-phase mirror of each node's `pending()`: epoch-start
        // snapshot plus this epoch's own assignments.  Exact because
        // `pending()` moves only at submit (+1, mirrored here) and at
        // `close_epoch` (refreshed below) — never mid-epoch.
        let mut pending: Vec<usize> =
            nodes.iter().map(|n| n.pending()).collect();
        // Per-node arrival indices awaiting the simulate phase, in heap
        // pop order — exactly the order the interleaved loop submitted.
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];

        while let Some((_, _, ev)) = queue.pop() {
            match ev {
                Event::Arrival { index } => {
                    let n = route(&pending, self.params.capacity, &mut rng);
                    pending[n] += 1;
                    routed[n] += 1;
                    assignments[index] = n;
                    buckets[n].push(index);
                }
                Event::EpochBoundary { epoch } => {
                    // Simulate phase: each node is an independent
                    // `&mut` shard — submit its epoch's arrivals in
                    // route order, then drain at the boundary.
                    pool::parallel_for_each_mut(
                        self.params.par, &mut nodes, |i, node| {
                            for &idx in &buckets[i] {
                                node.submit(requests[idx].clone());
                            }
                            node.close_epoch(epoch);
                        });
                    for b in &mut buckets {
                        b.clear();
                    }
                    for (p, node) in pending.iter_mut().zip(&nodes) {
                        *p = node.pending();
                    }
                }
                Event::BatchClose { .. } | Event::BatchComplete { .. } => {
                    unreachable!("batch events live inside server drains")
                }
            }
        }
        (self.build_report(scenario, nodes, routed), assignments)
    }

    /// [`serve`](Self::serve) through the pre-event-core tick loop:
    /// virtual time advances in fixed `tick_ms` steps and every node is
    /// polled at every tick — wall-clock cost proportional to virtual
    /// time swept times nodes, the cost profile the event core removes.
    /// Kept as the before-side of `benches/perf_cluster.rs` and as a
    /// routing cross-check (both drivers make identical routing
    /// decisions; see the module docs for why reports may differ in
    /// mid-epoch dispatch timing).  Sharded like [`serve`](Self::serve):
    /// each node replays its own tick sweep in parallel.
    pub fn serve_polled(&self, requests: &[Request], scenario: &str)
                        -> ClusterReport {
        self.serve_polled_assignments(requests, scenario).0
    }

    /// [`serve_polled`](Self::serve_polled) plus the per-request node
    /// assignments (see [`serve_assignments`](Self::serve_assignments)).
    pub fn serve_polled_assignments(&self, requests: &[Request],
                                    scenario: &str)
                                    -> (ClusterReport, Vec<usize>) {
        let mut nodes = self.make_nodes(super::serve::DrainDriver::Polled);
        let mut rng = Rng::new(self.seed ^ ROUTE_SALT);
        let mut routed = vec![0usize; nodes.len()];
        let mut assignments = vec![usize::MAX; requests.len()];

        let per = chunk_len(requests.len(), self.params.epochs);
        let tick = self.params.tick_ms;
        let mut t = 0.0f64;
        let mut boundary = 0.0f64;
        let mut pending: Vec<usize> =
            nodes.iter().map(|n| n.pending()).collect();
        // Per-node (submit-gate time, arrival index) pairs for one
        // epoch, in chunk order.
        let mut buckets: Vec<Vec<(f64, usize)>> =
            vec![Vec::new(); nodes.len()];
        for (epoch, chunk) in requests.chunks(per).enumerate() {
            let base = epoch * per;
            boundary = chunk
                .last()
                .map(|r| r.arrival_ms)
                .unwrap_or(boundary)
                .max(boundary);
            // Route phase, in chunk order.  The interleaved loop blocks
            // on the first not-yet-due request, so a request's submit
            // tick is gated by the *prefix max* of arrival times (equal
            // to its own arrival for the monotone generated workloads).
            let mut gate = f64::NEG_INFINITY;
            for (k, r) in chunk.iter().enumerate() {
                gate = gate.max(r.arrival_ms);
                let n = route(&pending, self.params.capacity, &mut rng);
                pending[n] += 1;
                routed[n] += 1;
                assignments[base + k] = n;
                buckets[n].push((gate, base + k));
            }
            // Simulate phase: each node replays the tick sweep over its
            // own shard — submit what comes due, poll, step — exactly
            // the per-node projection of the interleaved loop (other
            // nodes' submissions and polls never touch this node).
            let t0 = t;
            let bdry = boundary;
            pool::parallel_for_each_mut(
                self.params.par, &mut nodes, |i, node| {
                    let mine = &buckets[i];
                    let mut tn = t0;
                    let mut next = 0usize;
                    while tn < bdry {
                        while next < mine.len() && mine[next].0 <= tn {
                            node.submit(requests[mine[next].1].clone());
                            next += 1;
                        }
                        node.poll(tn);
                        tn += tick;
                    }
                    for &(_, idx) in &mine[next..] {
                        node.submit(requests[idx].clone());
                    }
                    node.close_epoch(epoch);
                });
            // Advance the shared clock with the same float operations
            // every node performed, so all timelines agree exactly.
            while t < boundary {
                t += tick;
            }
            for b in &mut buckets {
                b.clear();
            }
            for (p, node) in pending.iter_mut().zip(&nodes) {
                *p = node.pending();
            }
        }
        (self.build_report(scenario, nodes, routed), assignments)
    }

    /// The pre-shard event loop, retained as the reference
    /// implementation (route and simulate interleaved on one thread,
    /// routing off the nodes' live `pending()`): the differential tests
    /// hold [`serve`](Self::serve) against it — per-request
    /// assignments, routed counts and report bytes must all match.
    /// Returns the report plus the per-request node assignments.
    pub fn serve_interleaved(&self, requests: &[Request], scenario: &str)
                             -> (ClusterReport, Vec<usize>) {
        let mut nodes = self.make_nodes(super::serve::DrainDriver::Event);
        let mut rng = Rng::new(self.seed ^ ROUTE_SALT);
        let mut routed = vec![0usize; nodes.len()];
        let mut assignments = vec![usize::MAX; requests.len()];

        let per = chunk_len(requests.len(), self.params.epochs);
        let mut queue: EventQueue<Event> = EventQueue::new();
        let mut boundary = 0.0f64;
        for (epoch, chunk) in requests.chunks(per).enumerate() {
            let base = epoch * per;
            for (k, r) in chunk.iter().enumerate() {
                queue.push(r.arrival_ms, Event::Arrival { index: base + k });
            }
            boundary = chunk
                .last()
                .map(|r| r.arrival_ms)
                .unwrap_or(boundary)
                .max(boundary);
            queue.push(boundary, Event::EpochBoundary { epoch });
        }

        while let Some((_, _, ev)) = queue.pop() {
            match ev {
                Event::Arrival { index } => {
                    let n = route_live(&nodes, self.params.capacity,
                                       &mut rng);
                    routed[n] += 1;
                    assignments[index] = n;
                    nodes[n].submit(requests[index].clone());
                }
                Event::EpochBoundary { epoch } => {
                    for node in &mut nodes {
                        node.close_epoch(epoch);
                    }
                }
                Event::BatchClose { .. } | Event::BatchComplete { .. } => {
                    unreachable!("batch events live inside server drains")
                }
            }
        }
        (self.build_report(scenario, nodes, routed), assignments)
    }

    /// The pre-shard polled loop, retained as the reference for
    /// [`serve_polled`](Self::serve_polled) (see
    /// [`serve_interleaved`](Self::serve_interleaved)).
    pub fn serve_polled_interleaved(&self, requests: &[Request],
                                    scenario: &str)
                                    -> (ClusterReport, Vec<usize>) {
        let mut nodes = self.make_nodes(super::serve::DrainDriver::Polled);
        let mut rng = Rng::new(self.seed ^ ROUTE_SALT);
        let mut routed = vec![0usize; nodes.len()];
        let mut assignments = vec![usize::MAX; requests.len()];

        let per = chunk_len(requests.len(), self.params.epochs);
        let mut t = 0.0f64;
        let mut boundary = 0.0f64;
        for (epoch, chunk) in requests.chunks(per).enumerate() {
            let base = epoch * per;
            boundary = chunk
                .last()
                .map(|r| r.arrival_ms)
                .unwrap_or(boundary)
                .max(boundary);
            let mut next = 0usize;
            while t < boundary {
                while next < chunk.len()
                    && chunk[next].arrival_ms <= t
                {
                    let n = route_live(&nodes, self.params.capacity,
                                       &mut rng);
                    routed[n] += 1;
                    assignments[base + next] = n;
                    nodes[n].submit(chunk[next].clone());
                    next += 1;
                }
                for node in &mut nodes {
                    node.poll(t);
                }
                t += self.params.tick_ms;
            }
            for (off, r) in chunk[next..].iter().enumerate() {
                let n = route_live(&nodes, self.params.capacity, &mut rng);
                routed[n] += 1;
                assignments[base + next + off] = n;
                nodes[n].submit(r.clone());
            }
            for node in &mut nodes {
                node.close_epoch(epoch);
            }
        }
        (self.build_report(scenario, nodes, routed), assignments)
    }

    fn make_nodes(&self, driver: super::serve::DrainDriver)
                  -> Vec<EpochFleet> {
        // The shard axis is the node: giving every node the whole pool
        // for intra-node batch execution too would oversubscribe the
        // cores, so multi-node clusters keep their nodes' execution
        // sequential.  Bit-identical either way — the server pool's
        // ordered reduce guarantees it (util/pool.rs contract).
        let node_par = if self.params.nodes > 1 {
            Parallelism::Sequential
        } else {
            self.params.par
        };
        (0..self.params.nodes)
            .map(|i| {
                let seed = self.seed
                    ^ ((i as u64) + 1).wrapping_mul(SEED_STRIDE);
                EpochFleet::new(self.deployment.clone(), seed, node_par)
                    .with_driver(driver)
            })
            .collect()
    }

    fn build_report(&self, scenario: &str, nodes: Vec<EpochFleet>,
                    routed: Vec<usize>) -> ClusterReport {
        let per_node: Vec<ServeReport> =
            nodes.iter().map(|n| n.overall_report()).collect();
        let all: Vec<Completion> = nodes
            .iter()
            .flat_map(|n| n.completions().iter().cloned())
            .collect();
        let exec: Vec<f64> = nodes
            .iter()
            .flat_map(|n| n.batch_exec_ms().iter().copied())
            .collect();
        let energy: f64 = nodes.iter().map(|n| n.total_energy_j()).sum();
        let tokens: usize = nodes.iter().map(|n| n.total_tokens()).sum();
        let span = nodes.iter().filter_map(|n| n.span()).fold(
            None,
            |acc: Option<(f64, f64)>, (f, l)| Some(match acc {
                None => (f, l),
                Some((af, al)) => (af.min(f), al.max(l)),
            }),
        );
        let overall = ServeReport::from_completions(
            &all, exec.len(), &exec, energy, span, tokens);
        ClusterReport {
            scenario: scenario.to_string(),
            seed: self.seed,
            nodes: self.params.nodes,
            capacity: self.params.capacity,
            epochs: self.params.epochs,
            routed,
            per_node,
            overall,
        }
    }
}

/// Epoch chunk length: ceil(len / epochs), at least 1.
fn chunk_len(len: usize, epochs: usize) -> usize {
    (len.div_ceil(epochs.max(1))).max(1)
}

/// Least-loaded routing with a soft capacity cap, over a slice of
/// per-node pending counts (the route phase's mirror, or a live
/// snapshot via [`route_live`]): candidates are the nodes under
/// `capacity` pending (all nodes when saturated); among candidates,
/// minimum pending wins, and exact ties are broken by the seeded
/// stream — `rng` is consumed *only* on a tie, so the stream stays
/// aligned across runs that make the same decisions.
fn route(pending: &[usize], capacity: usize, rng: &mut Rng) -> usize {
    let candidates: Vec<usize> = {
        let under: Vec<usize> = (0..pending.len())
            .filter(|&i| pending[i] < capacity)
            .collect();
        if under.is_empty() {
            (0..pending.len()).collect()
        } else {
            under
        }
    };
    let min = candidates
        .iter()
        .map(|&i| pending[i])
        .min()
        .expect("cluster has at least one node");
    let ties: Vec<usize> = candidates
        .into_iter()
        .filter(|&i| pending[i] == min)
        .collect();
    if ties.len() == 1 {
        ties[0]
    } else {
        ties[rng.below(ties.len())]
    }
}

/// [`route`] over the nodes' live `pending()` counts — the interleaved
/// reference loops' router.
fn route_live(nodes: &[EpochFleet], capacity: usize, rng: &mut Rng)
              -> usize {
    let pending: Vec<usize> = nodes.iter().map(|n| n.pending()).collect();
    route(&pending, capacity, rng)
}

// ---------------------------------------------------------------------------
// ClusterReport
// ---------------------------------------------------------------------------

pub const CLUSTER_REPORT_SCHEMA: &str = "ae-llm.cluster-report/v1";

/// Everything one cluster serving run produced (schema
/// `ae-llm.cluster-report/v1`; `ae-llm cluster --json`).
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub scenario: String,
    pub seed: u64,
    pub nodes: usize,
    pub capacity: usize,
    pub epochs: usize,
    /// Requests routed to each node, aligned with `per_node`.
    pub routed: Vec<usize>,
    /// Whole-run serve statistics per node.
    pub per_node: Vec<ServeReport>,
    /// Merged statistics across every node.
    pub overall: ServeReport,
}

impl ClusterReport {
    /// Serialize (schema `ae-llm.cluster-report/v1`; field reference in
    /// docs/SCHEMAS.md).  Same-seed runs dump byte-identical JSON at
    /// every parallelism level.
    pub fn to_json(&self) -> Json {
        let mut root = std::collections::BTreeMap::new();
        root.insert("schema".into(),
                    Json::Str(CLUSTER_REPORT_SCHEMA.into()));
        root.insert("scenario".into(), Json::Str(self.scenario.clone()));
        // String, not Num: Json numbers are f64 and would corrupt
        // seeds above 2^53 (same convention as RunReport).
        root.insert("seed".into(), Json::Str(self.seed.to_string()));
        root.insert("nodes".into(), Json::Num(self.nodes as f64));
        root.insert("capacity".into(), Json::Num(self.capacity as f64));
        root.insert("epochs".into(), Json::Num(self.epochs as f64));
        root.insert("routed".into(), Json::Arr(
            self.routed.iter().map(|&n| Json::Num(n as f64)).collect()));
        root.insert("per_node".into(), Json::Arr(
            self.per_node.iter().map(ServeReport::to_json).collect()));
        root.insert("overall".into(), self.overall.to_json());
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::super::fleet::{SloClass, SloPolicy};
    use super::super::workload::{Workload, WorkloadKind};
    use super::*;
    use crate::config::Config;
    use crate::oracle::Objectives;
    use crate::search::archive::ParetoArchive;
    use crate::util::Rng;

    fn cfg(seed: u64) -> Config {
        crate::config::enumerate::sample(&mut Rng::new(seed))
    }

    fn front() -> ParetoArchive {
        let mut a = ParetoArchive::new(10);
        a.insert(cfg(1), Objectives { accuracy: 68.0, latency_ms: 12.0,
                                      memory_gb: 10.0, energy_j: 0.60 });
        a.insert(cfg(2), Objectives { accuracy: 68.5, latency_ms: 30.0,
                                      memory_gb: 9.0, energy_j: 0.20 });
        a.insert(cfg(3), Objectives { accuracy: 68.2, latency_ms: 28.0,
                                      memory_gb: 4.0, energy_j: 0.55 });
        a
    }

    fn deployment() -> Deployment {
        let m = crate::models::by_name("LLaMA-2-7B").unwrap();
        Deployment::from_front(&front(), &SloPolicy::default(), &m,
                               &crate::tasks::blended_task(),
                               &crate::hardware::a100())
            .unwrap()
    }

    fn params(nodes: usize, par: Parallelism) -> ClusterParams {
        ClusterParams { nodes, par, ..Default::default() }
    }

    #[test]
    fn same_seed_cluster_serve_is_byte_identical() {
        let reqs = Workload::new(WorkloadKind::Bursty, 60.0, 300, 9)
            .generate();
        let go = |par| {
            Cluster::new(deployment(), params(3, par), 11)
                .serve(&reqs, "bursty")
                .to_json()
                .dump()
        };
        let a = go(Parallelism::Sequential);
        let b = go(Parallelism::Threads(4));
        let c = go(Parallelism::Sequential);
        assert_eq!(a, b, "parallelism changed the cluster report");
        assert_eq!(a, c, "same seed produced different cluster reports");
        assert!(a.contains("\"schema\":\"ae-llm.cluster-report/v1\""),
                "{a}");
    }

    #[test]
    fn golden_sharded_serve_matches_sequential_on_all_scenarios() {
        // The determinism contract of the shard (DESIGN.md §16):
        // byte-identical reports at Sequential / Threads(4) /
        // Threads(8), on every workload scenario, and equal to the
        // retained pre-shard interleaved loop.
        let d = deployment();
        for kind in WorkloadKind::ALL {
            let reqs =
                Workload::new(kind, 50.0, 240, 17).generate();
            let go = |par: Parallelism| {
                Cluster::new(d.clone(),
                             ClusterParams { nodes: 4, capacity: 16,
                                             par,
                                             ..Default::default() },
                             13)
                    .serve(&reqs, kind.name())
                    .to_json()
                    .dump()
            };
            let seq = go(Parallelism::Sequential);
            assert_eq!(seq, go(Parallelism::Threads(4)),
                       "Threads(4) diverged on {}", kind.name());
            assert_eq!(seq, go(Parallelism::Threads(8)),
                       "Threads(8) diverged on {}", kind.name());
            let (reference, _) =
                Cluster::new(d.clone(),
                             ClusterParams { nodes: 4, capacity: 16,
                                             par: Parallelism::Threads(4),
                                             ..Default::default() },
                             13)
                    .serve_interleaved(&reqs, kind.name());
            assert_eq!(seq, reference.to_json().dump(),
                       "shard diverged from the interleaved reference \
                        on {}", kind.name());
        }
    }

    #[test]
    fn sharded_polled_driver_matches_its_interleaved_reference() {
        let d = deployment();
        for kind in [WorkloadKind::Bursty, WorkloadKind::Ramp] {
            let reqs = Workload::new(kind, 60.0, 200, 7).generate();
            let p = ClusterParams { nodes: 3, capacity: 16, tick_ms: 2.0,
                                    par: Parallelism::Threads(4),
                                    ..Default::default() };
            let cluster = Cluster::new(d.clone(), p, 19);
            let (sharded, asg) =
                cluster.serve_polled_assignments(&reqs, kind.name());
            let (reference, asg_ref) =
                cluster.serve_polled_interleaved(&reqs, kind.name());
            assert_eq!(asg, asg_ref,
                       "polled shard re-routed on {}", kind.name());
            assert_eq!(sharded.to_json().dump(),
                       reference.to_json().dump(),
                       "polled shard diverged on {}", kind.name());
        }
    }

    #[test]
    fn property_sharded_routing_matches_interleaved_across_seeds() {
        // Randomized differential: across seeds × node counts ×
        // parallelism 1/4/8, the route-then-simulate split reproduces
        // the retained interleaved loop exactly — per-request
        // assignment order, per-node routed counts, and report bytes.
        // A small capacity forces the saturated fallback and pending
        // ties, so the RNG tie-break stream is genuinely exercised.
        let d = deployment();
        let mut meta = Rng::new(0xC1A5);
        for trial in 0..4usize {
            let seed = meta.below(1 << 20) as u64;
            let kind = WorkloadKind::ALL[meta.below(WorkloadKind::ALL.len())];
            let nodes = [2, 3, 5][trial % 3];
            let reqs =
                Workload::new(kind, 70.0, 180, seed ^ 0xA5).generate();
            let base = ClusterParams { nodes, capacity: 8, epochs: 3,
                                       par: Parallelism::Sequential,
                                       ..Default::default() };
            let (reference, asg_ref) =
                Cluster::new(d.clone(), base, seed)
                    .serve_interleaved(&reqs, kind.name());
            let ref_dump = reference.to_json().dump();
            for par in [Parallelism::Threads(1), Parallelism::Threads(4),
                        Parallelism::Threads(8)] {
                let (rep, asg) = Cluster::new(
                    d.clone(), ClusterParams { par, ..base }, seed)
                    .serve_assignments(&reqs, kind.name());
                assert_eq!(asg, asg_ref,
                           "assignments diverged: trial {trial} {par:?}");
                assert_eq!(rep.routed, reference.routed,
                           "routed counts diverged: trial {trial} {par:?}");
                assert_eq!(rep.to_json().dump(), ref_dump,
                           "report diverged: trial {trial} {par:?}");
            }
        }
    }

    #[test]
    fn event_and_polled_drivers_route_identically_and_complete_all() {
        let reqs = Workload::new(WorkloadKind::Steady, 50.0, 240, 5)
            .generate();
        let params = ClusterParams { nodes: 4, capacity: 32, epochs: 3,
                                     tick_ms: 2.0,
                                     par: Parallelism::Sequential };
        let cluster = Cluster::new(deployment(), params, 7);
        let event = cluster.serve(&reqs, "steady");
        let polled = cluster.serve_polled(&reqs, "steady");
        // pending() moves only at epoch boundaries on both drivers, so
        // every routing decision is shared.
        assert_eq!(event.routed, polled.routed);
        assert_eq!(event.routed.iter().sum::<usize>(), reqs.len());
        assert_eq!(event.overall.completed, reqs.len());
        assert_eq!(polled.overall.completed, reqs.len());
        // per-node completions line up with routing on both drivers
        for (rep, &n) in event.per_node.iter().zip(&event.routed) {
            assert_eq!(rep.completed, n);
        }
        for (rep, &n) in polled.per_node.iter().zip(&polled.routed) {
            assert_eq!(rep.completed, n);
        }
    }

    #[test]
    fn routing_spreads_load_across_nodes() {
        let reqs = Workload::new(WorkloadKind::Steady, 80.0, 400, 3)
            .generate();
        let report = Cluster::new(
            deployment(),
            ClusterParams { nodes: 4, capacity: 16,
                            par: Parallelism::Sequential,
                            ..Default::default() },
            13)
            .serve(&reqs, "steady");
        assert_eq!(report.routed.len(), 4);
        assert!(report.routed.iter().all(|&n| n > 0),
                "a node was starved: {:?}", report.routed);
        let max = *report.routed.iter().max().unwrap();
        assert!(max < reqs.len(),
                "one node swallowed the whole workload: {:?}",
                report.routed);
        // least-loaded routing keeps the split roughly even
        let min = *report.routed.iter().min().unwrap();
        assert!(max <= 2 * min + 16,
                "routing badly skewed: {:?}", report.routed);
    }

    #[test]
    fn single_node_cluster_matches_its_own_fleet() {
        // With one node there is nothing to route; the cluster view is
        // exactly that node's whole-run report.
        let reqs = Workload::new(WorkloadKind::Diurnal, 40.0, 200, 9)
            .generate();
        let report = Cluster::new(
            deployment(),
            ClusterParams { nodes: 1, epochs: 2,
                            par: Parallelism::Sequential,
                            ..Default::default() },
            21)
            .serve(&reqs, "diurnal");
        assert_eq!(report.routed, vec![reqs.len()]);
        assert_eq!(report.per_node.len(), 1);
        assert_eq!(report.per_node[0].to_json().dump(),
                   report.overall.to_json().dump());
    }

    #[test]
    fn report_json_carries_per_node_and_routing() {
        let reqs: Vec<_> = (0..30u64)
            .map(|i| super::super::serve::Request::new(i, vec![1; 64])
                .at(i as f64 * 12.0)
                .class(SloClass::ALL[(i % 3) as usize]))
            .collect();
        let j = Cluster::new(
            deployment(),
            ClusterParams { nodes: 2, par: Parallelism::Sequential,
                            ..Default::default() },
            5)
            .serve(&reqs, "steady")
            .to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str),
                   Some(CLUSTER_REPORT_SCHEMA));
        assert_eq!(j.get("nodes").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("seed").and_then(Json::as_str), Some("5"));
        let per = match j.get("per_node") {
            Some(Json::Arr(a)) => a.len(),
            _ => panic!("per_node missing"),
        };
        assert_eq!(per, 2);
        match j.get("routed") {
            Some(Json::Arr(a)) => assert_eq!(a.len(), 2),
            _ => panic!("routed missing"),
        }
    }
}
