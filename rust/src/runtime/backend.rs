//! Execution backends for the serving subsystem (DESIGN.md §11).
//!
//! [`ExecBackend`] is the seam between the batching/scheduling logic
//! (`serve.rs`) and whatever actually runs a forward pass:
//!
//! * [`PjrtBackend`] — wraps the PJRT [`Engine`]: real compiled
//!   artifacts, real wall-clock `exec_ms` (requires `make artifacts`);
//! * [`SimulatedBackend`] — the `oracle::cost` latency/energy model
//!   with seedable multiplicative noise: zero artifacts, deterministic,
//!   the backend every CI test and the fleet simulation run on.
//!
//! Determinism contract: `execute_batch` must be a *pure function* of
//! (variant, token buffer, occupied rows).  The simulated backend draws
//! its noise from an RNG seeded by a hash of exactly those inputs — not
//! from shared mutable state — so batches may be executed concurrently
//! in any order and still produce identical results at every
//! [`crate::util::Parallelism`] level.

use std::collections::BTreeMap;

use crate::config::Config;
use crate::hardware::Platform;
use crate::models::ModelSpec;
use crate::oracle::{cost, Testbed};
use crate::tasks::TaskSpec;
use crate::util::Rng;

use super::engine::Engine;

/// Static shape of one serve variant's batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchShape {
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
}

/// What one batch execution produced.
#[derive(Clone, Debug)]
pub struct BatchResult {
    /// Argmax next-token per *occupied* row (padding rows excluded).
    pub next_tokens: Vec<i32>,
    /// Tokens processed (occupied rows × sequence length).
    pub tokens: usize,
    /// Execution time of the batch, ms (wall for PJRT, modeled for the
    /// simulated backend).
    pub exec_ms: f64,
    /// Energy drawn by the batch, J (0.0 where unmeasurable, e.g. PJRT
    /// on a host without power counters).
    pub energy_j: f64,
}

/// An execution backend the generic [`super::serve::Server`] drives.
///
/// `Sync` because independent batches fan out across the thread pool;
/// implementations must be safe to call concurrently and — see the
/// module docs — deterministic per input.
///
/// ```
/// use ae_llm::config::Config;
/// use ae_llm::runtime::{ExecBackend, SimulatedBackend};
///
/// let model = ae_llm::models::by_name("LLaMA-2-7B").unwrap();
/// let task = ae_llm::tasks::blended_task();
/// let backend = SimulatedBackend::for_config(
///     "sim", &Config::default_baseline(), &model, &task,
///     &ae_llm::hardware::a100(), 8, 512, 7);
///
/// let shape = backend.shape("sim").unwrap();
/// let flat = vec![3i32; shape.batch * shape.seq]; // padded token buffer
/// let out = backend.execute_batch("sim", &flat, 5).unwrap();
/// assert_eq!(out.next_tokens.len(), 5);           // occupied rows only
/// assert_eq!(out.tokens, 5 * shape.seq);
///
/// // Pure function of (variant, buffer, rows): re-running is identical.
/// let again = backend.execute_batch("sim", &flat, 5).unwrap();
/// assert_eq!(out.exec_ms, again.exec_ms);
/// ```
pub trait ExecBackend: Sync {
    /// Batch/seq/vocab shape of a variant (error if unknown).
    fn shape(&self, variant: &str) -> anyhow::Result<BatchShape>;

    /// Execute one padded batch. `flat` is row-major `batch × seq`
    /// token ids; `rows` is the number of occupied (non-padding) rows.
    fn execute_batch(&self, variant: &str, flat: &[i32], rows: usize)
                     -> anyhow::Result<BatchResult>;
}

// ---------------------------------------------------------------------------
// PJRT
// ---------------------------------------------------------------------------

/// Real artifact execution through the PJRT [`Engine`].
pub struct PjrtBackend<'a> {
    pub engine: &'a Engine,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(engine: &'a Engine) -> PjrtBackend<'a> {
        PjrtBackend { engine }
    }
}

impl ExecBackend for PjrtBackend<'_> {
    fn shape(&self, variant: &str) -> anyhow::Result<BatchShape> {
        let v = self
            .engine
            .manifest
            .get(variant)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {variant:?}"))?;
        Ok(BatchShape {
            batch: v.batch as usize,
            seq: v.seq as usize,
            vocab: v.config.vocab as usize,
        })
    }

    fn execute_batch(&self, variant: &str, flat: &[i32], rows: usize)
                     -> anyhow::Result<BatchResult> {
        let shape = self.shape(variant)?;
        let fwd = self.engine.forward(variant, flat)?;
        // argmax over the last position's logits, occupied rows only
        let next_tokens = (0..rows.min(shape.batch))
            .map(|row| {
                let base = (row * shape.seq + (shape.seq - 1)) * shape.vocab;
                let slice = &fwd.logits[base..base + shape.vocab];
                slice
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect();
        Ok(BatchResult {
            next_tokens,
            tokens: rows * shape.seq,
            exec_ms: fwd.wall_ms,
            energy_j: 0.0,
        })
    }
}

// ---------------------------------------------------------------------------
// Simulated
// ---------------------------------------------------------------------------

/// Cost-model parameters of one simulated variant.
#[derive(Clone, Debug)]
pub struct SimVariant {
    pub shape: BatchShape,
    /// Modeled execution time of a *full* batch at this shape, ms.
    pub base_ms: f64,
    /// Modeled energy per occupied row at full occupancy, J.
    pub energy_per_row_j: f64,
}

/// Deterministic, artifact-free execution model over `oracle::cost`.
///
/// Noise is derived per call from `seed ⊕ fnv1a(variant, flat, rows)`,
/// so two backends with the same seed are interchangeable and a batch's
/// result does not depend on when (or on which worker) it executed.
pub struct SimulatedBackend {
    variants: BTreeMap<String, SimVariant>,
    noise_sigma: f64,
    seed: u64,
}

/// Batching amortizes per-request work: a full batch costs 1.25× the
/// base latency while a single occupied row costs ~0.53× — matching the
/// sub-linear batch scaling real serving stacks exhibit.  Public so the
/// fleet's deadline-feasibility check prices a full batch the same way
/// the backend will.
pub const EXEC_FLOOR: f64 = 0.45;
pub const EXEC_SLOPE: f64 = 0.80;

/// Sub-linear sequence-length scaling exponent: a serve shape's cost
/// rescales from the measurement reference ([`cost::INPUT_TOKENS`]) as
/// `(seq / 512)^0.85`.  Shared by [`sim_variant`], the fleet's lane
/// provisioning and the deadline-feasibility check.
pub const SEQ_SCALE_EXP: f64 = 0.85;

impl SimulatedBackend {
    pub fn new(seed: u64) -> SimulatedBackend {
        SimulatedBackend {
            variants: BTreeMap::new(),
            noise_sigma: 0.03,
            seed,
        }
    }

    /// Override the multiplicative exec-time noise sigma (0.0 for
    /// noise-free unit tests).
    pub fn with_noise(mut self, sigma: f64) -> SimulatedBackend {
        self.noise_sigma = sigma;
        self
    }

    /// Register a variant with explicit cost parameters.
    pub fn with_variant(mut self, name: &str, v: SimVariant)
                        -> SimulatedBackend {
        self.variants.insert(name.to_string(), v);
        self
    }

    /// Register a variant whose costs come from the calibrated testbed
    /// truth for `config` on (model, task, platform), rescaled from the
    /// cost model's reference sequence length to `seq`.
    pub fn with_config_variant(self, name: &str, config: &Config,
                               model: &ModelSpec, task: &TaskSpec,
                               platform: &Platform, batch: usize, seq: usize)
                               -> SimulatedBackend {
        self.with_variant(name, sim_variant(config, model, task, platform,
                                            batch, seq))
    }

    /// One-variant convenience constructor.
    pub fn for_config(name: &str, config: &Config, model: &ModelSpec,
                      task: &TaskSpec, platform: &Platform, batch: usize,
                      seq: usize, seed: u64) -> SimulatedBackend {
        SimulatedBackend::new(seed).with_config_variant(
            name, config, model, task, platform, batch, seq)
    }
}

/// Calibrated cost parameters for one (config, shape) pair.
pub fn sim_variant(config: &Config, model: &ModelSpec, task: &TaskSpec,
                   platform: &Platform, batch: usize, seq: usize)
                   -> SimVariant {
    let truth = Testbed::noiseless(platform.clone())
        .true_objectives(config, model, task);
    // Longer serve shapes read more KV and decode more positions; scale
    // sub-linearly from the measurement reference (cost::INPUT_TOKENS).
    let seq_scale = (seq as f64 / cost::INPUT_TOKENS).powf(SEQ_SCALE_EXP);
    SimVariant {
        shape: BatchShape { batch, seq, vocab: 256 },
        base_ms: truth.latency_ms * seq_scale,
        energy_per_row_j: truth.energy_j * seq_scale,
    }
}

/// FNV-1a over the execution inputs: the per-call noise seed.
fn fnv1a(seed: u64, variant: &str, flat: &[i32], rows: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ seed;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    };
    for b in variant.bytes() {
        eat(b);
    }
    for t in flat {
        for b in t.to_le_bytes() {
            eat(b);
        }
    }
    for b in (rows as u64).to_le_bytes() {
        eat(b);
    }
    h
}

impl ExecBackend for SimulatedBackend {
    fn shape(&self, variant: &str) -> anyhow::Result<BatchShape> {
        self.variants
            .get(variant)
            .map(|v| v.shape)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {variant:?}"))
    }

    fn execute_batch(&self, variant: &str, flat: &[i32], rows: usize)
                     -> anyhow::Result<BatchResult> {
        let v = self
            .variants
            .get(variant)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {variant:?}"))?;
        let BatchShape { batch, seq, vocab } = v.shape;
        anyhow::ensure!(flat.len() == batch * seq,
                        "token buffer {} != batch*seq {}", flat.len(),
                        batch * seq);
        anyhow::ensure!(rows >= 1 && rows <= batch,
                        "occupied rows {rows} out of 1..={batch}");
        let occ = rows as f64 / batch as f64;
        let mut rng = Rng::new(fnv1a(self.seed, variant, flat, rows));
        let jitter = (1.0 + self.noise_sigma * rng.normal()).max(0.5);
        let exec_ms = v.base_ms * (EXEC_FLOOR + EXEC_SLOPE * occ) * jitter;
        // Partially occupied batches still pay static power for the
        // padding rows (the 0.55·batch term), so *per-row* energy
        // degrades at low occupancy; a full batch anchors at
        // energy_per_row_j per row.
        let energy_j = v.energy_per_row_j
            * (0.55 * batch as f64 + 0.45 * rows as f64);
        // Deterministic pseudo-decode: next token is a pure function of
        // the row's prompt.
        let next_tokens = (0..rows)
            .map(|row| {
                let slice = &flat[row * seq..(row + 1) * seq];
                (fnv1a(self.seed, variant, slice, 1) % vocab as u64) as i32
            })
            .collect();
        Ok(BatchResult {
            next_tokens,
            tokens: rows * seq,
            exec_ms,
            energy_j,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware;
    use crate::models::by_name;
    use crate::tasks::blended_task;

    fn backend(sigma: f64) -> SimulatedBackend {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = blended_task();
        SimulatedBackend::for_config(
            "sim", &Config::default_baseline(), &m, &t, &hardware::a100(),
            8, 512, 7)
            .with_noise(sigma)
    }

    #[test]
    fn execute_is_deterministic_per_input() {
        let b = backend(0.05);
        let flat = vec![3i32; 8 * 512];
        let a = b.execute_batch("sim", &flat, 5).unwrap();
        let c = b.execute_batch("sim", &flat, 5).unwrap();
        assert_eq!(a.exec_ms, c.exec_ms);
        assert_eq!(a.next_tokens, c.next_tokens);
        assert_eq!(a.tokens, 5 * 512);
        // different rows -> different noise stream
        let d = b.execute_batch("sim", &flat, 6).unwrap();
        assert_ne!(a.exec_ms, d.exec_ms);
    }

    #[test]
    fn full_batch_costs_more_than_single_row_but_sublinearly() {
        let b = backend(0.0);
        let flat = vec![3i32; 8 * 512];
        let one = b.execute_batch("sim", &flat, 1).unwrap();
        let full = b.execute_batch("sim", &flat, 8).unwrap();
        assert!(full.exec_ms > one.exec_ms);
        assert!(full.exec_ms < one.exec_ms * 8.0 * 0.5,
                "batching should amortize: {} vs {}", full.exec_ms,
                one.exec_ms);
        assert!(full.energy_j > one.energy_j);
        // ...but static power makes *per-row* energy worse at low
        // occupancy (padding rows aren't free)
        assert!(one.energy_j / 1.0 > full.energy_j / 8.0,
                "per-row energy should degrade at low occupancy: {} vs {}",
                one.energy_j, full.energy_j / 8.0);
    }

    #[test]
    fn noiseless_base_matches_calibrated_latency_scale() {
        // default 7B on A100 anchors at 45.2 ms; at the reference seq
        // a full batch should land at 1.25x that.
        let b = backend(0.0);
        let flat = vec![0i32; 8 * 512];
        let full = b.execute_batch("sim", &flat, 8).unwrap();
        assert!((full.exec_ms - 45.2 * 1.25).abs() < 1e-6,
                "exec {}", full.exec_ms);
    }

    #[test]
    fn longer_seq_variant_is_slower() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = blended_task();
        let c = Config::default_baseline();
        let short = sim_variant(&c, &m, &t, &hardware::a100(), 8, 256);
        let long = sim_variant(&c, &m, &t, &hardware::a100(), 8, 2048);
        assert!(long.base_ms > short.base_ms * 4.0);
    }

    #[test]
    fn rejects_bad_shapes_and_unknown_variants() {
        let b = backend(0.0);
        assert!(b.shape("nope").is_err());
        assert!(b.execute_batch("sim", &[0; 7], 1).is_err());
        let flat = vec![0i32; 8 * 512];
        assert!(b.execute_batch("sim", &flat, 0).is_err());
        assert!(b.execute_batch("sim", &flat, 9).is_err());
    }
}
