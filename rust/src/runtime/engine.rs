//! PJRT execution engine: loads AOT-lowered HLO text artifacts, compiles
//! them on the CPU PJRT client and executes them from the rust hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute`.  Python is never involved at this point.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use super::manifest::{Manifest, Variant};

/// A compiled, executable variant.
pub struct Loaded {
    pub variant: Variant,
    exe: xla::PjRtLoadedExecutable,
    /// wall-clock spent compiling (reported in perf logs)
    pub compile_ms: f64,
}

/// The engine owns the PJRT client and all compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    loaded: BTreeMap<String, Loaded>,
    pub manifest: Manifest,
}

/// Result of one forward execution.
pub struct Forward {
    /// logits, flattened (batch * seq * vocab)
    pub logits: Vec<f32>,
    pub wall_ms: f64,
}

impl Engine {
    /// Create the client and parse the manifest (no compilation yet).
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, loaded: BTreeMap::new(), manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one variant (idempotent).
    pub fn load(&mut self, name: &str) -> anyhow::Result<&Loaded> {
        if !self.loaded.contains_key(name) {
            let variant = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown variant {name:?}"))?
                .clone();
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                variant.path.to_str().unwrap(),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
            self.loaded.insert(
                name.to_string(),
                Loaded { variant, exe, compile_ms },
            );
        }
        Ok(&self.loaded[name])
    }

    /// Compile every measurement variant in the manifest.
    pub fn load_all(&mut self) -> anyhow::Result<Vec<String>> {
        let names: Vec<String> =
            self.manifest.variants.keys().cloned().collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names)
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        self.loaded.keys().map(|s| s.as_str()).collect()
    }

    /// Run one forward pass: token ids (batch*seq, row-major) → logits.
    pub fn forward(&self, name: &str, tokens: &[i32]) -> anyhow::Result<Forward> {
        let loaded = self
            .loaded
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("variant {name:?} not loaded"))?;
        let (b, s) = (loaded.variant.batch as usize,
                      loaded.variant.seq as usize);
        anyhow::ensure!(
            tokens.len() == b * s,
            "token buffer {} != batch*seq {}",
            tokens.len(),
            b * s
        );
        let vocab = loaded.variant.config.vocab as i32;
        anyhow::ensure!(
            tokens.iter().all(|&t| t >= 0 && t < vocab),
            "token id out of range [0,{vocab})"
        );
        let input = xla::Literal::vec1(tokens)
            .reshape(&[b as i64, s as i64])?;
        let t0 = Instant::now();
        let result = loaded.exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        // aot.py lowers with return_tuple=True -> 1-tuple of logits.
        let logits = result.to_tuple1()?.to_vec::<f32>()?;
        anyhow::ensure!(
            logits.len() == b * s * loaded.variant.config.vocab as usize,
            "unexpected logits size {}",
            logits.len()
        );
        Ok(Forward { logits, wall_ms })
    }

    /// Deterministic token batch for a variant (measurement workload).
    pub fn make_tokens(&self, name: &str, seed: u64) -> anyhow::Result<Vec<i32>> {
        let v = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {name:?}"))?;
        let mut rng = crate::util::Rng::new(seed);
        let n = (v.batch * v.seq) as usize;
        Ok((0..n)
            .map(|_| rng.below(v.config.vocab as usize) as i32)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::manifest::artifacts_dir;
    use super::*;

    fn engine_or_skip() -> Option<Engine> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::new(&dir).unwrap())
    }

    #[test]
    fn loads_and_runs_gqa_fp16() {
        let Some(mut e) = engine_or_skip() else { return };
        e.load("gqa_fp16").unwrap();
        let tokens = e.make_tokens("gqa_fp16", 0).unwrap();
        let out = e.forward("gqa_fp16", &tokens).unwrap();
        assert_eq!(out.logits.len(), 4 * 64 * 256);
        assert!(out.logits.iter().all(|x| x.is_finite()));
        assert!(out.wall_ms > 0.0);
    }

    #[test]
    fn forward_is_deterministic() {
        let Some(mut e) = engine_or_skip() else { return };
        e.load("mqa_int8").unwrap();
        let tokens = e.make_tokens("mqa_int8", 1).unwrap();
        let a = e.forward("mqa_int8", &tokens).unwrap().logits;
        let b = e.forward("mqa_int8", &tokens).unwrap().logits;
        assert_eq!(a, b);
    }

    #[test]
    fn quantized_variant_close_to_baseline() {
        let Some(mut e) = engine_or_skip() else { return };
        e.load("gqa_fp16").unwrap();
        e.load("gqa_int8").unwrap();
        let tokens = e.make_tokens("gqa_fp16", 2).unwrap();
        let base = e.forward("gqa_fp16", &tokens).unwrap().logits;
        let q = e.forward("gqa_int8", &tokens).unwrap().logits;
        let mae: f32 = base
            .iter()
            .zip(&q)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / base.len() as f32;
        let scale: f32 =
            base.iter().map(|x| x.abs()).sum::<f32>() / base.len() as f32;
        // quantized but same weights: close, not identical
        assert!(mae > 0.0);
        assert!(mae / scale < 0.2, "relative MAE {}", mae / scale);
    }

    #[test]
    fn rejects_bad_inputs() {
        let Some(mut e) = engine_or_skip() else { return };
        e.load("gqa_fp16").unwrap();
        assert!(e.forward("gqa_fp16", &[0i32; 3]).is_err()); // wrong size
        let mut tokens = e.make_tokens("gqa_fp16", 3).unwrap();
        tokens[0] = 9999; // out of vocab
        assert!(e.forward("gqa_fp16", &tokens).is_err());
        assert!(e.forward("not_a_variant", &[]).is_err());
    }
}
