//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.  `make artifacts` writes `artifacts/manifest.json`
//! describing every AOT-lowered transformer variant; this module parses
//! it (with the in-house JSON parser — serde is unavailable offline).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Mirror of `python/compile/model.py::ModelConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantConfig {
    pub attention: String,
    pub quant: String,
    pub d_model: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub vocab: u64,
    pub moe_experts: u64,
    pub moe_top_k: u64,
    pub lora_rank: u64,
    pub mla_latent: u64,
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub path: PathBuf,
    /// fp16 sibling used as the numeric-fidelity reference.
    pub fidelity_baseline: String,
    pub batch: u64,
    pub seq: u64,
    pub config: VariantConfig,
    pub param_count: u64,
    pub weight_bytes: u64,
    pub flops_per_token: u64,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub weight_seed: u64,
    pub variants: BTreeMap<String, Variant>,
}

impl Manifest {
    /// Load from `<artifacts_dir>/manifest.json`.
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {path:?}: {e} (run `make artifacts` first)"
            )
        })?;
        Self::parse(&text, artifacts_dir)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str, artifacts_dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("bad manifest JSON: {e}"))?;
        let weight_seed = j
            .req_u64("weight_seed")
            .map_err(|e| anyhow::anyhow!(e))?;
        let mut variants = BTreeMap::new();
        for v in j
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing variants[]"))?
        {
            let e = |m: String| anyhow::anyhow!(m);
            let cfg = v
                .get("config")
                .ok_or_else(|| anyhow::anyhow!("variant missing config"))?;
            let variant = Variant {
                name: v.req_str("name").map_err(e)?,
                path: artifacts_dir.join(v.req_str("file").map_err(e)?),
                fidelity_baseline: v.req_str("fidelity_baseline").map_err(e)?,
                batch: v.req_u64("batch").map_err(e)?,
                seq: v.req_u64("seq").map_err(e)?,
                config: VariantConfig {
                    attention: cfg.req_str("attention").map_err(e)?,
                    quant: cfg.req_str("quant").map_err(e)?,
                    d_model: cfg.req_u64("d_model").map_err(e)?,
                    n_layers: cfg.req_u64("n_layers").map_err(e)?,
                    n_heads: cfg.req_u64("n_heads").map_err(e)?,
                    vocab: cfg.req_u64("vocab").map_err(e)?,
                    moe_experts: cfg.req_u64("moe_experts").map_err(e)?,
                    moe_top_k: cfg.req_u64("moe_top_k").map_err(e)?,
                    lora_rank: cfg.req_u64("lora_rank").map_err(e)?,
                    mla_latent: cfg.req_u64("mla_latent").map_err(e)?,
                },
                param_count: v.req_u64("param_count").map_err(e)?,
                weight_bytes: v.req_u64("weight_bytes").map_err(e)?,
                flops_per_token: v.req_u64("flops_per_token").map_err(e)?,
            };
            variants.insert(variant.name.clone(), variant);
        }
        if variants.is_empty() {
            anyhow::bail!("manifest has no variants");
        }
        Ok(Manifest { weight_seed, variants })
    }

    pub fn get(&self, name: &str) -> Option<&Variant> {
        self.variants.get(name)
    }

    /// Names of the non-"serve" measurement variants.
    pub fn measurement_variants(&self) -> Vec<&Variant> {
        self.variants
            .values()
            .filter(|v| !v.name.starts_with("serve_"))
            .collect()
    }
}

/// Default artifacts directory: `$AE_LLM_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("AE_LLM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "weight_seed": 1234,
        "variants": [
            {"name": "gqa_fp16", "file": "gqa_fp16.hlo.txt",
             "fidelity_baseline": "gqa_fp16", "batch": 4, "seq": 64,
             "config": {"vocab": 256, "d_model": 128, "n_layers": 2,
                        "n_heads": 8, "attention": "gqa", "gqa_groups": 4,
                        "mla_latent": 32, "ffn_mult": 4, "moe_experts": 0,
                        "moe_top_k": 2, "quant": "fp16", "lora_rank": 0,
                        "lora_alpha": 32.0, "use_pallas": true},
             "param_count": 1000, "weight_bytes": 2000,
             "flops_per_token": 4000},
            {"name": "serve_gqa_int8", "file": "serve_gqa_int8.hlo.txt",
             "fidelity_baseline": "serve_gqa_fp16", "batch": 8, "seq": 128,
             "config": {"vocab": 256, "d_model": 128, "n_layers": 2,
                        "n_heads": 8, "attention": "gqa", "gqa_groups": 4,
                        "mla_latent": 32, "ffn_mult": 4, "moe_experts": 0,
                        "moe_top_k": 2, "quant": "int8", "lora_rank": 0,
                        "lora_alpha": 32.0, "use_pallas": true},
             "param_count": 1000, "weight_bytes": 1000,
             "flops_per_token": 4000}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.weight_seed, 1234);
        assert_eq!(m.variants.len(), 2);
        let v = m.get("gqa_fp16").unwrap();
        assert_eq!(v.config.attention, "gqa");
        assert_eq!(v.config.quant, "fp16");
        assert_eq!(v.path, Path::new("/tmp/a/gqa_fp16.hlo.txt"));
    }

    #[test]
    fn measurement_variants_exclude_serve() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let names: Vec<_> = m
            .measurement_variants()
            .iter()
            .map(|v| v.name.as_str())
            .collect();
        assert_eq!(names, vec!["gqa_fp16"]);
    }

    #[test]
    fn rejects_empty_and_garbage() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse("not json", Path::new(".")).is_err());
        assert!(Manifest::parse(
            r#"{"weight_seed": 1, "variants": []}"#, Path::new(".")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.variants.len() >= 12);
        for v in m.variants.values() {
            assert!(v.path.exists(), "{:?} missing", v.path);
            assert!(m.get(&v.fidelity_baseline).is_some());
        }
    }
}
