//! Seeded workload generators: the paper's "deployment scenarios" as
//! traffic, not just preference weights (DESIGN.md §11).
//!
//! Four scenario shapes, each emitting timestamped, SLO-tagged
//! [`Request`]s from a single seed:
//!
//! * **steady** — homogeneous Poisson arrivals, chat-heavy mix;
//! * **diurnal** — sinusoidally modulated rate (the day/night wave);
//! * **bursty** — Poisson base load with multiplicative arrival spikes;
//! * **heavytail** — long-context-heavy mix with Pareto-distributed
//!   prompt lengths (the document-analytics workload).
//!
//! Every scenario mixes all three [`SloClass`]es (in different
//! proportions) because that is what makes routing interesting:
//! technique rankings flip with workload shape (EfficientLLM), and a
//! single static configuration cannot be right for all of the mix.
//! Arrival times are non-decreasing, so generated traffic can be
//! submitted in order to any server.

use crate::util::Rng;

use super::fleet::SloClass;
use super::serve::Request;

/// Workload scenario shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    Steady,
    Diurnal,
    Bursty,
    HeavyTail,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 4] = [
        WorkloadKind::Steady,
        WorkloadKind::Diurnal,
        WorkloadKind::Bursty,
        WorkloadKind::HeavyTail,
    ];

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Steady => "steady",
            WorkloadKind::Diurnal => "diurnal",
            WorkloadKind::Bursty => "bursty",
            WorkloadKind::HeavyTail => "heavytail",
        }
    }

    pub fn by_name(name: &str) -> Option<WorkloadKind> {
        Some(match name {
            "steady" => WorkloadKind::Steady,
            "diurnal" => WorkloadKind::Diurnal,
            "bursty" => WorkloadKind::Bursty,
            "heavytail" | "heavy-tail" => WorkloadKind::HeavyTail,
            _ => return None,
        })
    }

    /// SLO-class mix (interactive, batch, long-context); sums to 1.
    fn mix(self) -> [f64; 3] {
        match self {
            WorkloadKind::Steady => [0.70, 0.25, 0.05],
            WorkloadKind::Diurnal => [0.60, 0.30, 0.10],
            WorkloadKind::Bursty => [0.75, 0.18, 0.07],
            WorkloadKind::HeavyTail => [0.45, 0.25, 0.30],
        }
    }
}

/// A sized, seeded traffic description.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub kind: WorkloadKind,
    /// Mean arrival rate, requests/second.
    pub rate_rps: f64,
    /// Number of requests to emit.
    pub requests: usize,
    pub seed: u64,
}

/// A serving rate that moderately loads a deployment of the given
/// scale: ~1.2k requests per "default latency" second of compute.
pub fn default_rate_rps(default_latency_ms: f64) -> f64 {
    1200.0 / default_latency_ms.max(1e-9)
}

/// Burst parameters: a burst multiplies the arrival rate by
/// `BURST_FACTOR` for `BURST_LEN` consecutive requests.
const BURST_START_P: f64 = 0.04;
const BURST_FACTOR: f64 = 10.0;
const BURST_LEN: usize = 24;

impl Workload {
    pub fn new(kind: WorkloadKind, rate_rps: f64, requests: usize,
               seed: u64) -> Workload {
        Workload { kind, rate_rps, requests, seed }
    }

    /// Generate the request stream.  Pure function of the fields: the
    /// same workload always produces byte-identical traffic.
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed ^ 0x5e41_11e5_4ea7_71c0);
        let mix = self.kind.mix();
        let rate_per_ms = self.rate_rps.max(1e-9) / 1e3;
        // Diurnal wave period: ~3 waves across the expected horizon.
        let horizon_ms = self.requests as f64 / rate_per_ms;
        let period_ms = (horizon_ms / 3.0).max(2000.0);

        let mut out = Vec::with_capacity(self.requests);
        let mut t_ms = 0.0f64;
        let mut burst_left = 0usize;
        for id in 0..self.requests as u64 {
            let mut rate = rate_per_ms;
            match self.kind {
                WorkloadKind::Diurnal => {
                    let phase = std::f64::consts::TAU * t_ms / period_ms;
                    rate *= 0.3 + 0.7 * 0.5 * (1.0 + phase.sin());
                }
                WorkloadKind::Bursty => {
                    if burst_left == 0 && rng.chance(BURST_START_P) {
                        burst_left = BURST_LEN;
                    }
                    if burst_left > 0 {
                        burst_left -= 1;
                        rate *= BURST_FACTOR;
                    }
                }
                WorkloadKind::Steady | WorkloadKind::HeavyTail => {}
            }
            // Exponential inter-arrival gap at the momentary rate.
            let u = rng.f64().max(1e-12);
            t_ms += -u.ln() / rate;

            let class = {
                let x = rng.f64();
                if x < mix[0] {
                    SloClass::Interactive
                } else if x < mix[0] + mix[1] {
                    SloClass::Batch
                } else {
                    SloClass::LongContext
                }
            };
            let len = self.prompt_len(class, &mut rng);
            let tokens: Vec<i32> =
                (0..len).map(|_| rng.below(256) as i32).collect();
            out.push(Request::new(id, tokens).at(t_ms).class(class));
        }
        out
    }

    /// Prompt length per class; the heavy-tail scenario draws
    /// long-context lengths from a (truncated) Pareto instead of a
    /// uniform band.
    fn prompt_len(&self, class: SloClass, rng: &mut Rng) -> usize {
        match class {
            SloClass::Interactive => 8 + rng.below(152),
            SloClass::Batch => 160 + rng.below(320),
            SloClass::LongContext => {
                if self.kind == WorkloadKind::HeavyTail {
                    let u = rng.f64().max(1e-9);
                    let l = 700.0 * u.powf(-0.35);
                    (l as usize).min(1900)
                } else {
                    700 + rng.below(1200)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(kind: WorkloadKind) -> Vec<Request> {
        Workload::new(kind, 50.0, 1000, 7).generate()
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        for kind in WorkloadKind::ALL {
            let a = gen(kind);
            let b = gen(kind);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.arrival_ms, y.arrival_ms);
                assert_eq!(x.tokens, y.tokens);
                assert_eq!(x.slo, y.slo);
            }
            let c = Workload::new(kind, 50.0, 1000, 8).generate();
            assert!(a.iter().zip(&c).any(|(x, y)|
                x.arrival_ms != y.arrival_ms));
        }
    }

    #[test]
    fn arrivals_are_monotone_and_rate_is_respected() {
        for kind in WorkloadKind::ALL {
            let reqs = gen(kind);
            for w in reqs.windows(2) {
                assert!(w[1].arrival_ms >= w[0].arrival_ms, "{kind:?}");
            }
            // 1000 requests at 50 rps ≈ 20s horizon, loosely
            let horizon_s = reqs.last().unwrap().arrival_ms / 1e3;
            assert!((8.0..60.0).contains(&horizon_s),
                    "{kind:?} horizon {horizon_s}");
        }
    }

    #[test]
    fn every_scenario_mixes_all_classes() {
        for kind in WorkloadKind::ALL {
            let reqs = gen(kind);
            for class in SloClass::ALL {
                let share = reqs.iter().filter(|r| r.slo == class).count()
                    as f64 / reqs.len() as f64;
                assert!(share > 0.02, "{kind:?} lacks {}", class.name());
            }
        }
    }

    #[test]
    fn long_context_prompts_exceed_the_static_shape() {
        for kind in WorkloadKind::ALL {
            let reqs = gen(kind);
            assert!(reqs.iter()
                        .filter(|r| r.slo == SloClass::LongContext)
                        .all(|r| r.tokens.len() > 512 &&
                                 r.tokens.len() <= 2048),
                    "{kind:?}");
        }
    }

    #[test]
    fn heavy_tail_skews_long_and_bursty_clusters() {
        let heavy = gen(WorkloadKind::HeavyTail);
        let steady = gen(WorkloadKind::Steady);
        let long_share = |rs: &[Request]| {
            rs.iter().filter(|r| r.slo == SloClass::LongContext).count()
                as f64 / rs.len() as f64
        };
        assert!(long_share(&heavy) > 2.0 * long_share(&steady));

        // bursty: the minimum inter-arrival gap cluster is much denser
        // than steady's mean gap
        let gaps = |rs: &[Request]| -> Vec<f64> {
            rs.windows(2).map(|w| w[1].arrival_ms - w[0].arrival_ms)
                .collect()
        };
        let bursty = gen(WorkloadKind::Bursty);
        let mean_steady =
            crate::util::stats::mean(&gaps(&steady));
        let p10_bursty =
            crate::util::stats::quantile(&gaps(&bursty), 0.10);
        assert!(p10_bursty < mean_steady * 0.5,
                "bursts not visible: p10 {p10_bursty} vs steady mean \
                 {mean_steady}");
    }

    #[test]
    fn names_roundtrip() {
        for kind in WorkloadKind::ALL {
            assert_eq!(WorkloadKind::by_name(kind.name()), Some(kind));
        }
        assert_eq!(WorkloadKind::by_name("heavy-tail"),
                   Some(WorkloadKind::HeavyTail));
        assert!(WorkloadKind::by_name("nope").is_none());
    }
}
