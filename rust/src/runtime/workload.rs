//! Seeded workload generators: the paper's "deployment scenarios" as
//! traffic, not just preference weights (DESIGN.md §11, §12).
//!
//! Six scenario shapes, each emitting timestamped, SLO-tagged
//! [`Request`]s from a single seed.  Four are *stationary* (their
//! statistics do not change over the run):
//!
//! * **steady** — homogeneous Poisson arrivals, chat-heavy mix;
//! * **diurnal** — sinusoidally modulated rate (the day/night wave);
//! * **bursty** — Poisson base load with multiplicative arrival spikes;
//! * **heavytail** — long-context-heavy mix with Pareto-distributed
//!   prompt lengths (the document-analytics workload).
//!
//! Two are *drifting* — class mix, arrival rate and prompt lengths
//! change mid-run, which is what gives the adaptation controller
//! (DESIGN.md §12) something real to win on:
//!
//! * **regime_shift** — an abrupt change at the half-way point, from a
//!   chat-heavy regime to a 3× hotter, long-context-heavy one whose
//!   documents outgrow the default 2048 serve shape (the "product
//!   launch" scenario);
//! * **ramp** — the same transition as a continuous drift (the
//!   "gradual adoption" scenario).
//!
//! Every scenario mixes all three [`SloClass`]es (in different
//! proportions) because that is what makes routing interesting:
//! technique rankings flip with workload shape (EfficientLLM), and a
//! single static configuration cannot be right for all of the mix.
//! Arrival times are non-decreasing, so generated traffic can be
//! submitted in order to any server.

use crate::util::Rng;

use super::fleet::SloClass;
use super::serve::Request;

/// Workload scenario shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    Steady,
    Diurnal,
    Bursty,
    HeavyTail,
    /// Abrupt mid-run regime change: chat-heavy → hot, long-heavy.
    RegimeShift,
    /// The same transition as a continuous ramp.
    Ramp,
}

/// Drifting-mix endpoints: the chat-heavy starting regime and the hot,
/// long-context-heavy regime the drifting scenarios move toward.
const DRIFT_MIX_FROM: [f64; 3] = [0.80, 0.17, 0.03];
const DRIFT_MIX_TO: [f64; 3] = [0.25, 0.15, 0.60];
/// Arrival-rate multiplier of the hot regime.  3× is what makes the
/// drift *structural*: the long-context compute load of the hot regime
/// exceeds the lane capacity any chat-era provisioning assigns to the
/// long slot, so a deployment that never re-provisions must saturate.
const DRIFT_RATE_TO: f64 = 3.0;

/// The ramp reaches the hot regime at 70% of the stream and plateaus,
/// so the fully-hot phase lasts whole epochs rather than one instant.
fn ramp_ease(progress: f64) -> f64 {
    (progress / 0.7).clamp(0.0, 1.0)
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 6] = [
        WorkloadKind::Steady,
        WorkloadKind::Diurnal,
        WorkloadKind::Bursty,
        WorkloadKind::HeavyTail,
        WorkloadKind::RegimeShift,
        WorkloadKind::Ramp,
    ];

    /// The stationary scenarios (the adaptive-vs-static serving table
    /// and its acceptance tests sweep exactly these four).
    pub const STATIONARY: [WorkloadKind; 4] = [
        WorkloadKind::Steady,
        WorkloadKind::Diurnal,
        WorkloadKind::Bursty,
        WorkloadKind::HeavyTail,
    ];

    /// The drifting scenarios (what the adaptation controller is
    /// measured on).
    pub const DRIFTING: [WorkloadKind; 2] =
        [WorkloadKind::RegimeShift, WorkloadKind::Ramp];

    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Steady => "steady",
            WorkloadKind::Diurnal => "diurnal",
            WorkloadKind::Bursty => "bursty",
            WorkloadKind::HeavyTail => "heavytail",
            WorkloadKind::RegimeShift => "regime_shift",
            WorkloadKind::Ramp => "ramp",
        }
    }

    pub fn by_name(name: &str) -> Option<WorkloadKind> {
        Some(match name {
            "steady" => WorkloadKind::Steady,
            "diurnal" => WorkloadKind::Diurnal,
            "bursty" => WorkloadKind::Bursty,
            "heavytail" | "heavy-tail" => WorkloadKind::HeavyTail,
            "regime_shift" | "regime-shift" => WorkloadKind::RegimeShift,
            "ramp" => WorkloadKind::Ramp,
            _ => return None,
        })
    }

    pub fn is_drifting(self) -> bool {
        matches!(self, WorkloadKind::RegimeShift | WorkloadKind::Ramp)
    }

    /// SLO-class mix (interactive, batch, long-context) at `progress`
    /// ∈ [0, 1] through the request stream; sums to 1.  Stationary
    /// scenarios ignore `progress`.
    pub fn mix_at(self, progress: f64) -> [f64; 3] {
        match self {
            WorkloadKind::Steady => [0.70, 0.25, 0.05],
            WorkloadKind::Diurnal => [0.60, 0.30, 0.10],
            WorkloadKind::Bursty => [0.75, 0.18, 0.07],
            WorkloadKind::HeavyTail => [0.45, 0.25, 0.30],
            WorkloadKind::RegimeShift => {
                if progress < 0.5 { DRIFT_MIX_FROM } else { DRIFT_MIX_TO }
            }
            WorkloadKind::Ramp => {
                let q = ramp_ease(progress);
                let mut m = [0.0; 3];
                for i in 0..3 {
                    m[i] = DRIFT_MIX_FROM[i]
                        + q * (DRIFT_MIX_TO[i] - DRIFT_MIX_FROM[i]);
                }
                m
            }
        }
    }

    /// Arrival-rate multiplier at `progress` (1.0 for every stationary
    /// scenario; their modulation — diurnal wave, bursts — stays inside
    /// [`Workload::generate`] because it is stochastic, not a drift).
    fn rate_mult_at(self, progress: f64) -> f64 {
        match self {
            WorkloadKind::RegimeShift => {
                if progress < 0.5 { 1.0 } else { DRIFT_RATE_TO }
            }
            WorkloadKind::Ramp => {
                1.0 + ramp_ease(progress) * (DRIFT_RATE_TO - 1.0)
            }
            _ => 1.0,
        }
    }
}

/// A sized, seeded traffic description.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub kind: WorkloadKind,
    /// Mean arrival rate, requests/second.
    pub rate_rps: f64,
    /// Number of requests to emit.
    pub requests: usize,
    pub seed: u64,
}

/// A serving rate that moderately loads a deployment of the given
/// scale: ~1.2k requests per "default latency" second of compute.
pub fn default_rate_rps(default_latency_ms: f64) -> f64 {
    1200.0 / default_latency_ms.max(1e-9)
}

/// Burst parameters: a burst multiplies the arrival rate by
/// `BURST_FACTOR` for `BURST_LEN` consecutive requests.
const BURST_START_P: f64 = 0.04;
const BURST_FACTOR: f64 = 10.0;
const BURST_LEN: usize = 24;

impl Workload {
    pub fn new(kind: WorkloadKind, rate_rps: f64, requests: usize,
               seed: u64) -> Workload {
        Workload { kind, rate_rps, requests, seed }
    }

    /// Generate the request stream.  Pure function of the fields: the
    /// same workload always produces byte-identical traffic.  For the
    /// drifting scenarios the class mix, arrival rate and long-context
    /// prompt lengths are functions of the request's *progress* through
    /// the stream (id / requests), so slicing the stream into epochs
    /// hands the adaptation controller a genuinely moving target.
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed ^ 0x5e41_11e5_4ea7_71c0);
        let rate_per_ms = self.rate_rps.max(1e-9) / 1e3;
        // Diurnal wave period: ~3 waves across the expected horizon.
        let horizon_ms = self.requests as f64 / rate_per_ms;
        let period_ms = (horizon_ms / 3.0).max(2000.0);

        let mut out = Vec::with_capacity(self.requests);
        let mut t_ms = 0.0f64;
        let mut burst_left = 0usize;
        for id in 0..self.requests as u64 {
            let progress = id as f64 / self.requests.max(1) as f64;
            let mix = self.kind.mix_at(progress);
            let mut rate = rate_per_ms * self.kind.rate_mult_at(progress);
            match self.kind {
                WorkloadKind::Diurnal => {
                    let phase = std::f64::consts::TAU * t_ms / period_ms;
                    rate *= 0.3 + 0.7 * 0.5 * (1.0 + phase.sin());
                }
                WorkloadKind::Bursty => {
                    if burst_left == 0 && rng.chance(BURST_START_P) {
                        burst_left = BURST_LEN;
                    }
                    if burst_left > 0 {
                        burst_left -= 1;
                        rate *= BURST_FACTOR;
                    }
                }
                WorkloadKind::Steady
                | WorkloadKind::HeavyTail
                | WorkloadKind::RegimeShift
                | WorkloadKind::Ramp => {}
            }
            // Exponential inter-arrival gap at the momentary rate.
            let u = rng.f64().max(1e-12);
            t_ms += -u.ln() / rate;

            let class = {
                let x = rng.f64();
                if x < mix[0] {
                    SloClass::Interactive
                } else if x < mix[0] + mix[1] {
                    SloClass::Batch
                } else {
                    SloClass::LongContext
                }
            };
            let len = self.prompt_len(class, progress, &mut rng);
            let tokens: Vec<i32> =
                (0..len).map(|_| rng.below(256) as i32).collect();
            out.push(Request::new(id, tokens).at(t_ms).class(class));
        }
        out
    }

    /// Prompt length per class; the heavy-tail scenario draws
    /// long-context lengths from a (truncated) Pareto instead of a
    /// uniform band.  Stationary long-context lengths stay within
    /// (512, 2048] — over the static 512 shape, under the long-context
    /// one — while the drifting scenarios push the hot regime's
    /// documents past 2048 (but under 4096, the first re-provision
    /// step): documents get longer, not just more frequent.
    fn prompt_len(&self, class: SloClass, progress: f64, rng: &mut Rng)
                  -> usize {
        match class {
            SloClass::Interactive => 8 + rng.below(152),
            SloClass::Batch => 160 + rng.below(320),
            SloClass::LongContext => match self.kind {
                WorkloadKind::HeavyTail => {
                    let u = rng.f64().max(1e-9);
                    let l = 700.0 * u.powf(-0.35);
                    (l as usize).min(1900)
                }
                // Drifting scenarios: the hot regime's documents grow
                // *past* the 2048-token long-context serve shape — the
                // structural reason a deployment that never
                // re-provisions must truncate (= violate) them, while
                // the adaptation controller re-scopes the slot's
                // sequence length from observed telemetry.
                WorkloadKind::RegimeShift => {
                    if progress < 0.5 {
                        700 + rng.below(400)
                    } else {
                        2200 + rng.below(700)
                    }
                }
                WorkloadKind::Ramp => {
                    let base =
                        700 + (ramp_ease(progress) * 1700.0) as usize;
                    base + rng.below(500)
                }
                _ => 700 + rng.below(1200),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(kind: WorkloadKind) -> Vec<Request> {
        Workload::new(kind, 50.0, 1000, 7).generate()
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        for kind in WorkloadKind::ALL {
            let a = gen(kind);
            let b = gen(kind);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.arrival_ms, y.arrival_ms);
                assert_eq!(x.tokens, y.tokens);
                assert_eq!(x.slo, y.slo);
            }
            let c = Workload::new(kind, 50.0, 1000, 8).generate();
            assert!(a.iter().zip(&c).any(|(x, y)|
                x.arrival_ms != y.arrival_ms));
        }
    }

    #[test]
    fn arrivals_are_monotone_and_rate_is_respected() {
        for kind in WorkloadKind::ALL {
            let reqs = gen(kind);
            for w in reqs.windows(2) {
                assert!(w[1].arrival_ms >= w[0].arrival_ms, "{kind:?}");
            }
            // 1000 requests at 50 rps ≈ 20s horizon, loosely
            let horizon_s = reqs.last().unwrap().arrival_ms / 1e3;
            assert!((8.0..60.0).contains(&horizon_s),
                    "{kind:?} horizon {horizon_s}");
        }
    }

    #[test]
    fn every_scenario_mixes_all_classes() {
        for kind in WorkloadKind::ALL {
            let reqs = gen(kind);
            for class in SloClass::ALL {
                let share = reqs.iter().filter(|r| r.slo == class).count()
                    as f64 / reqs.len() as f64;
                assert!(share > 0.02, "{kind:?} lacks {}", class.name());
            }
        }
    }

    #[test]
    fn long_context_prompts_exceed_the_static_shape() {
        // Stationary scenarios stay within the 2048 long-context serve
        // shape (their structural margin is against the *static 512*
        // shape only).
        for kind in WorkloadKind::STATIONARY {
            let reqs = gen(kind);
            assert!(reqs.iter()
                        .filter(|r| r.slo == SloClass::LongContext)
                        .all(|r| r.tokens.len() > 512 &&
                                 r.tokens.len() <= 2048),
                    "{kind:?}");
        }
        // Drifting scenarios additionally overflow the 2048 shape in
        // the hot regime — the truncation margin the adaptation
        // controller wins by — but never the 4096 re-provision.
        for kind in WorkloadKind::DRIFTING {
            let reqs = gen(kind);
            let longs: Vec<usize> = reqs
                .iter()
                .filter(|r| r.slo == SloClass::LongContext)
                .map(|r| r.tokens.len())
                .collect();
            assert!(longs.iter().all(|&l| l > 512 && l < 4096),
                    "{kind:?}");
            assert!(longs.iter().any(|&l| l > 2048),
                    "{kind:?}: hot regime never overflows the 2048 \
                     shape");
            // the cold half still fits the default provisioning
            let cold: Vec<usize> = reqs[..reqs.len() / 2]
                .iter()
                .filter(|r| r.slo == SloClass::LongContext)
                .map(|r| r.tokens.len())
                .collect();
            assert!(cold.iter().take(5).all(|&l| l <= 2048),
                    "{kind:?}: cold regime already overflows");
        }
    }

    #[test]
    fn heavy_tail_skews_long_and_bursty_clusters() {
        let heavy = gen(WorkloadKind::HeavyTail);
        let steady = gen(WorkloadKind::Steady);
        let long_share = |rs: &[Request]| {
            rs.iter().filter(|r| r.slo == SloClass::LongContext).count()
                as f64 / rs.len() as f64
        };
        assert!(long_share(&heavy) > 2.0 * long_share(&steady));

        // bursty: the minimum inter-arrival gap cluster is much denser
        // than steady's mean gap
        let gaps = |rs: &[Request]| -> Vec<f64> {
            rs.windows(2).map(|w| w[1].arrival_ms - w[0].arrival_ms)
                .collect()
        };
        let bursty = gen(WorkloadKind::Bursty);
        let mean_steady =
            crate::util::stats::mean(&gaps(&steady));
        let p10_bursty =
            crate::util::stats::quantile(&gaps(&bursty), 0.10);
        assert!(p10_bursty < mean_steady * 0.5,
                "bursts not visible: p10 {p10_bursty} vs steady mean \
                 {mean_steady}");
    }

    #[test]
    fn names_roundtrip() {
        for kind in WorkloadKind::ALL {
            assert_eq!(WorkloadKind::by_name(kind.name()), Some(kind));
        }
        assert_eq!(WorkloadKind::by_name("heavy-tail"),
                   Some(WorkloadKind::HeavyTail));
        assert_eq!(WorkloadKind::by_name("regime-shift"),
                   Some(WorkloadKind::RegimeShift));
        assert!(WorkloadKind::by_name("nope").is_none());
        assert_eq!(WorkloadKind::STATIONARY.len()
                       + WorkloadKind::DRIFTING.len(),
                   WorkloadKind::ALL.len());
        assert!(WorkloadKind::DRIFTING.iter().all(|k| k.is_drifting()));
        assert!(WorkloadKind::STATIONARY.iter().all(|k| !k.is_drifting()));
    }

    /// Per-class share and mean long-context length over a slice.
    fn shape(rs: &[Request]) -> ([f64; 3], f64) {
        let n = rs.len() as f64;
        let mut shares = [0.0; 3];
        let mut long_len = 0.0;
        let mut long_n = 0.0;
        for r in rs {
            let i = SloClass::ALL.iter().position(|&c| c == r.slo).unwrap();
            shares[i] += 1.0 / n;
            if r.slo == SloClass::LongContext {
                long_len += r.tokens.len() as f64;
                long_n += 1.0;
            }
        }
        (shares, long_len / long_n.max(1.0))
    }

    #[test]
    fn drifting_scenarios_move_mix_rate_and_lengths() {
        for kind in WorkloadKind::DRIFTING {
            let reqs = Workload::new(kind, 50.0, 2000, 7).generate();
            let (first, second) = reqs.split_at(1000);
            let (s1, len1) = shape(first);
            let (s2, len2) = shape(second);
            // class mix moves from chat-heavy toward long-heavy
            assert!(s2[2] > s1[2] + 0.15,
                    "{kind:?} long share {:.2} -> {:.2}", s1[2], s2[2]);
            assert!(s1[0] > s2[0] + 0.15,
                    "{kind:?} interactive share {:.2} -> {:.2}",
                    s1[0], s2[0]);
            // documents get longer, not just more frequent
            assert!(len2 > len1 + 100.0,
                    "{kind:?} long length {len1:.0} -> {len2:.0}");
            // the hot regime arrives faster: the second half spans less
            // virtual time per request than the first
            let span = |rs: &[Request]| {
                rs.last().unwrap().arrival_ms - rs[0].arrival_ms
            };
            assert!(span(second) < span(first) * 0.85,
                    "{kind:?} rate did not increase: {:.0} vs {:.0}",
                    span(first), span(second));
        }
        // stationary control: steady's halves look alike
        let reqs = Workload::new(WorkloadKind::Steady, 50.0, 2000, 7)
            .generate();
        let (first, second) = reqs.split_at(1000);
        let (s1, _) = shape(first);
        let (s2, _) = shape(second);
        for i in 0..3 {
            assert!((s1[i] - s2[i]).abs() < 0.08,
                    "steady share {i} moved: {:.2} -> {:.2}", s1[i], s2[i]);
        }
    }

    #[test]
    fn regime_shift_is_abrupt_and_ramp_is_gradual() {
        let quarters = |kind: WorkloadKind| -> Vec<f64> {
            let reqs = Workload::new(kind, 50.0, 2000, 3).generate();
            reqs.chunks(500).map(|c| shape(c).0[2]).collect()
        };
        let shift = quarters(WorkloadKind::RegimeShift);
        // flat before the break, flat after it, one jump between
        assert!((shift[0] - shift[1]).abs() < 0.06, "{shift:?}");
        assert!((shift[2] - shift[3]).abs() < 0.08, "{shift:?}");
        assert!(shift[2] - shift[1] > 0.3, "{shift:?}");
        let ramp = quarters(WorkloadKind::Ramp);
        // monotone-ish climb, no single jump as large as the shift's
        assert!(ramp[3] > ramp[0] + 0.3, "{ramp:?}");
        for w in ramp.windows(2) {
            assert!(w[1] > w[0] - 0.05, "not climbing: {ramp:?}");
            assert!(w[1] - w[0] < 0.3, "ramp jumped: {ramp:?}");
        }
    }
}
