//! Hardware-in-the-loop measurement: maps search configurations onto
//! AOT artifact variants, executes them through PJRT, and turns real
//! wall-clock + numeric-fidelity observations into the `Objectives` the
//! coordinator consumes.
//!
//! This is the evaluator the end-to-end driver plugs into Algorithm 1's
//! line 5 in place of the simulated testbed.  Because the local machine
//! is a CPU (not the paper's GPU fleet), absolute numbers are anchored
//! the same way the oracle is, but the *relative* effects of the
//! inference-stage techniques come from genuinely executed artifacts:
//!
//! * latency ratio  = measured wall-clock(variant) / wall-clock(fp16
//!   sibling of the same architecture family);
//! * fidelity       = mean |logits - baseline logits| / mean |baseline|,
//!   a real numeric-degradation signal that replaces the oracle's
//!   quantization accuracy penalty.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::{Attention, Config, Precision};
use crate::evaluator::{EvalContext, Evaluator};
use crate::models::ModelSpec;
use crate::oracle::{Objectives, Testbed};
use crate::tasks::TaskSpec;
use crate::util::pool::{self, Parallelism};
use crate::util::stats;
use crate::util::Rng;

use super::engine::Engine;

/// Per-variant measurement record.
#[derive(Clone, Debug)]
pub struct VariantMeasurement {
    pub name: String,
    pub baseline: String,
    /// median wall-clock per forward, ms
    pub wall_ms: f64,
    /// wall-clock coefficient of variation across repeats
    pub wall_cv: f64,
    /// relative mean-abs logit error vs the fp16 baseline (0 for fp16)
    pub fidelity_err: f64,
    pub weight_bytes: u64,
}

/// All measurements, keyed by variant name.
#[derive(Clone, Debug)]
pub struct MeasurementTable {
    pub rows: BTreeMap<String, VariantMeasurement>,
}

/// Execute every measurement variant `repeats` times (after `warmup`
/// discarded runs) and record wall-clock + fidelity.
///
/// Sequential wrapper around [`measure_all_with`]: variants run one at
/// a time so the wall-clock numbers are contention-free.  Use the
/// parallel form when you are measuring throughput (or only care about
/// fidelity), not single-stream latency.
pub fn measure_all(engine: &mut Engine, warmup: usize, repeats: usize)
                   -> anyhow::Result<MeasurementTable> {
    measure_all_with(engine, warmup, repeats, Parallelism::Sequential)
}

/// [`measure_all`] with the per-variant measurement loops fanned across
/// `par` workers.
///
/// Compilation stays sequential (`Engine::load` needs `&mut`), then the
/// forward loops — which only need `&Engine` — run concurrently, one
/// variant per worker, and the table is assembled in variant order.
/// Concurrent variants contend for cores, so per-forward wall-clock is
/// an *upper bound* under this mode; the CV column records the spread.
pub fn measure_all_with(engine: &mut Engine, warmup: usize, repeats: usize,
                        par: Parallelism)
                        -> anyhow::Result<MeasurementTable> {
    let names: Vec<String> = engine
        .manifest
        .measurement_variants()
        .iter()
        .map(|v| v.name.clone())
        .collect();
    for name in &names {
        engine.load(name)?;
    }

    // Measurement loops: read-only on the engine, one variant per job.
    let engine_ref: &Engine = engine;
    let measured: Vec<anyhow::Result<(Vec<f64>, Vec<f32>)>> =
        pool::parallel_map(par, &names, |name| {
            let tokens = engine_ref.make_tokens(name, 42)?;
            for _ in 0..warmup {
                engine_ref.forward(name, &tokens)?;
            }
            let mut walls = Vec::with_capacity(repeats);
            let mut last_logits = Vec::new();
            for _ in 0..repeats.max(1) {
                let f = engine_ref.forward(name, &tokens)?;
                walls.push(f.wall_ms);
                last_logits = f.logits;
            }
            Ok((walls, last_logits))
        });

    // Ordered reduce into the table (+ logits cache for fidelity).
    let mut logits_cache: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    let mut rows = BTreeMap::new();
    for (name, result) in names.iter().zip(measured) {
        let (walls, last_logits) = result?;
        logits_cache.insert(name.clone(), last_logits);
        let v = engine.manifest.get(name).unwrap();
        rows.insert(
            name.clone(),
            VariantMeasurement {
                name: name.clone(),
                baseline: v.fidelity_baseline.clone(),
                wall_ms: stats::median(&walls),
                wall_cv: stats::cv(&walls),
                fidelity_err: 0.0, // filled below
                weight_bytes: v.weight_bytes,
            },
        );
    }

    // Fidelity vs baselines (baselines measured above too).  Pure
    // reductions over cached logits — fan out, merge in name order.
    let names_in_table: Vec<String> = rows.keys().cloned().collect();
    let fidelity: Vec<Option<f64>> =
        pool::parallel_map(par, &names_in_table, |name| {
            let baseline = &rows[name].baseline;
            if baseline == name {
                return None;
            }
            let (a, b) =
                (logits_cache.get(name)?, logits_cache.get(baseline)?);
            let mae: f64 = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs() as f64)
                .sum::<f64>()
                / a.len() as f64;
            let scale: f64 =
                b.iter().map(|x| x.abs() as f64).sum::<f64>()
                    / b.len() as f64;
            Some(if scale > 0.0 { mae / scale } else { mae })
        });
    for (name, fid) in names_in_table.iter().zip(fidelity) {
        if let Some(fid) = fid {
            rows.get_mut(name).unwrap().fidelity_err = fid;
        }
    }
    Ok(MeasurementTable { rows })
}

impl MeasurementTable {
    /// Variant family name a search configuration maps onto.
    pub fn variant_for(c: &Config) -> String {
        let attn = match c.arch.attention {
            Attention::Mha => "mha",
            Attention::Gqa => "gqa",
            Attention::Mqa => "mqa",
            Attention::Mla => "mla",
        };
        let quant = match c.inf.precision {
            Precision::Fp16 | Precision::Fp8 => "fp16",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        };
        // MoE / LoRA variants exist only on the gqa backbone at
        // fp16/int8; fall back to the plain family elsewhere.
        if c.arch.moe.is_sparse() && attn == "gqa" && quant != "int4" {
            return format!("gqa_{quant}_moe4");
        }
        if c.ft.method.is_peft() && attn == "gqa" && quant != "int4" {
            return format!("gqa_{quant}_lora16");
        }
        format!("{attn}_{quant}")
    }

    /// Measured latency multiplier of the config's variant vs its fp16
    /// sibling (1.0 when unknown).
    pub fn latency_ratio(&self, c: &Config) -> f64 {
        let name = Self::variant_for(c);
        let Some(row) = self.rows.get(&name) else { return 1.0 };
        let Some(base) = self.rows.get(&row.baseline) else { return 1.0 };
        if base.wall_ms > 0.0 {
            row.wall_ms / base.wall_ms
        } else {
            1.0
        }
    }

    /// Measured numeric-fidelity error of the config's variant.
    pub fn fidelity_err(&self, c: &Config) -> f64 {
        self.rows
            .get(&Self::variant_for(c))
            .map(|r| r.fidelity_err)
            .unwrap_or(0.0)
    }
}

/// The hardware-in-the-loop evaluator: oracle anchoring + measured
/// relative effects.
pub struct MeasuredEvaluator {
    pub table: MeasurementTable,
    pub testbed: Testbed,
    /// Measured evaluations performed (for the §Perf report).  Atomic —
    /// not a `Cell` — so [`Evaluator::measure_batch`] can fan a batch
    /// out across the thread pool while still counting every call.
    calls: AtomicUsize,
}

impl MeasuredEvaluator {
    pub fn new(table: MeasurementTable, testbed: Testbed) -> Self {
        MeasuredEvaluator { table, testbed, calls: AtomicUsize::new(0) }
    }

    /// Measured evaluations performed so far.
    pub fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }

    /// Objectives with the inference-stage effects replaced by real
    /// measurements:
    /// * latency: oracle latency of the config *with the inference stage
    ///   reset to fp16*, multiplied by the measured wall-clock ratio;
    /// * accuracy: oracle accuracy of the fp16-reset config, degraded by
    ///   the measured fidelity error scaled by task sensitivity.
    pub fn objectives(&self, c: &Config, m: &ModelSpec,
                      t: &TaskSpec) -> Objectives {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let mut fp16_cfg = *c;
        fp16_cfg.inf.precision = Precision::Fp16;
        if fp16_cfg.ft.method == crate::config::FtMethod::QLoRA {
            fp16_cfg.ft.method = crate::config::FtMethod::LoRA;
        }
        let base = self.testbed.true_objectives(&fp16_cfg, m, t);
        let o_full = self.testbed.true_objectives(c, m, t);

        let lat_ratio = self.table.latency_ratio(c);
        let fid = self.table.fidelity_err(c);
        // fidelity -> accuracy points: scaled by the task's quantization
        // sensitivity (same mapping slope the oracle uses, but the error
        // signal itself is measured).
        let acc_penalty =
            base.accuracy * fid * (0.5 + 1.5 * t.quant_sensitivity) * 0.6;

        Objectives {
            accuracy: (base.accuracy - acc_penalty).max(0.0),
            latency_ms: base.latency_ms * lat_ratio,
            // memory is a static artifact property; keep the oracle's
            // (manifest bytes validate it in tests)
            memory_gb: o_full.memory_gb,
            energy_j: base.energy_j * lat_ratio
                * (c.inf.precision.bits() as f64 / 16.0).powf(0.35),
        }
    }
}

/// The hardware-in-the-loop backend for Algorithm 1 (DESIGN.md §9):
/// [`objectives`](MeasuredEvaluator::objectives) is a pure function of
/// the configuration (real measurements are taken once, up front, into
/// the [`MeasurementTable`]), so the batch fans out across
/// `ctx.parallelism` workers through the ordered-reduce pool and the
/// result is identical at every parallelism level.  `rng` is untouched:
/// the measured numbers carry their own hardware noise.
impl Evaluator for MeasuredEvaluator {
    fn measure_batch(&mut self, cs: &[Config], ctx: &EvalContext,
                     _rng: &mut Rng) -> Vec<Objectives> {
        let this: &MeasuredEvaluator = self;
        pool::parallel_map(ctx.parallelism, cs, |c| {
            this.objectives(c, ctx.model, ctx.task)
        })
    }

    fn evals(&self) -> usize {
        self.calls()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_mapping_covers_grid() {
        let mut c = Config::default_baseline();
        assert_eq!(MeasurementTable::variant_for(&c), "mha_fp16");
        c.arch.attention = Attention::Gqa;
        c.inf.precision = Precision::Int8;
        assert_eq!(MeasurementTable::variant_for(&c), "gqa_int8");
        c.arch.moe = crate::config::MoE::Sparse { experts: 4, top_k: 2 };
        assert_eq!(MeasurementTable::variant_for(&c), "gqa_int8_moe4");
        c.arch.moe = crate::config::MoE::Dense;
        c.ft = crate::config::FtConfig {
            method: crate::config::FtMethod::LoRA,
            rank: 32,
            alpha_mult: 2,
        };
        assert_eq!(MeasurementTable::variant_for(&c), "gqa_int8_lora16");
        c.inf.precision = Precision::Int4;
        assert_eq!(MeasurementTable::variant_for(&c), "gqa_int4");
    }

    #[test]
    fn variant_mapping_always_resolves_against_manifest() {
        let dir = super::super::manifest::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let manifest = super::super::Manifest::load(&dir).unwrap();
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..500 {
            let c = crate::config::enumerate::sample(&mut rng);
            let name = MeasurementTable::variant_for(&c);
            assert!(manifest.get(&name).is_some(), "unmapped {name}");
        }
    }

    #[test]
    fn empty_table_degrades_gracefully() {
        let table = MeasurementTable { rows: BTreeMap::new() };
        let c = Config::default_baseline();
        assert_eq!(table.latency_ratio(&c), 1.0);
        assert_eq!(table.fidelity_err(&c), 0.0);
    }

    #[test]
    fn evaluator_batch_is_parallelism_invariant_and_counts() {
        // No artifacts needed: an empty table exercises the 1.0-ratio
        // fallbacks while the oracle anchoring does the real work.
        let m = crate::models::by_name("LLaMA-2-7B").unwrap();
        let t = crate::tasks::blended_task();
        let tb = Testbed::noiseless(crate::hardware::a100());
        let mut rng = Rng::new(31);
        let cs: Vec<Config> = (0..24)
            .map(|_| crate::config::enumerate::sample(&mut rng))
            .collect();
        let go = |par: Parallelism| {
            let table = MeasurementTable { rows: BTreeMap::new() };
            let mut ev = MeasuredEvaluator::new(table, tb.clone());
            let ctx = EvalContext::new(&m, &t, par);
            let out = ev.measure_batch(&cs, &ctx, &mut Rng::new(1));
            assert_eq!(ev.calls(), 24);
            assert_eq!(Evaluator::evals(&ev), 24);
            out
        };
        assert_eq!(go(Parallelism::Sequential), go(Parallelism::Threads(4)));
    }
}
