//! Dynamic batch formation (DESIGN.md §11): size- OR deadline-triggered,
//! replacing the old fixed-size `drain` grouping.
//!
//! Requests carry arrival timestamps; the batcher walks them in
//! submission order and closes a batch when
//!
//! 1. it reaches `max_batch` items (size trigger), or
//! 2. the *next* item arrived after the oldest member had already
//!    waited `max_delay_ms` (deadline trigger — the batch would have
//!    been dispatched before that item showed up), or
//! 3. the queue is flushed (end of drain).
//!
//! Each batch records `ready_ms`, the instant it became dispatchable on
//! the serving timeline: the last member's arrival for size-triggered
//! and flushed batches, `first_arrival + max_delay` for deadline-
//! triggered ones.  Batch contents and order are a pure function of the
//! (item, arrival) sequence — nothing here reads a clock — which is
//! what makes dynamically batched serving reproducible.
//!
//! Invariant every consumer relies on: items never reorder.  Batch `k`
//! holds a contiguous run of the submission sequence, and batches are
//! emitted in submission order.

use std::collections::VecDeque;

/// One formed batch: `items` in submission order plus the timestamp at
/// which the batch became dispatchable.
#[derive(Clone, Debug)]
pub struct Batch<T> {
    pub items: Vec<(T, f64)>,
    pub ready_ms: f64,
}

/// Size/deadline-triggered batch former over timestamped items.
#[derive(Clone, Debug)]
pub struct Batcher<T> {
    max_batch: usize,
    max_delay_ms: f64,
    pending: VecDeque<(T, f64)>,
    last_arrival_ms: f64,
}

impl<T> Batcher<T> {
    /// `max_batch` ≥ 1; `max_delay_ms` is the longest a request may sit
    /// waiting for co-riders before a partial batch dispatches.
    pub fn new(max_batch: usize, max_delay_ms: f64) -> Batcher<T> {
        Batcher {
            max_batch: max_batch.max(1),
            max_delay_ms: max_delay_ms.max(0.0),
            pending: VecDeque::new(),
            last_arrival_ms: 0.0,
        }
    }

    pub fn max_delay_ms(&self) -> f64 {
        self.max_delay_ms
    }

    /// Change the batching deadline; pending items are untouched and
    /// the new delay applies at the next formation.
    pub fn set_max_delay_ms(&mut self, delay_ms: f64) {
        self.max_delay_ms = delay_ms.max(0.0);
    }

    /// Enqueue an item.  Arrivals are clamped monotone (a request
    /// cannot arrive before the one submitted ahead of it), keeping the
    /// formation rule well-defined for live wall-clock submitters.
    pub fn push(&mut self, item: T, arrival_ms: f64) {
        let arrival = arrival_ms.max(self.last_arrival_ms);
        self.last_arrival_ms = arrival;
        self.pending.push_back((item, arrival));
    }

    /// Capacity hint: make room for `n` more pending items up front
    /// (workload sizes are known at the fleet call sites).
    pub fn reserve(&mut self, n: usize) {
        self.pending.reserve(n);
    }

    /// Put items back at the *front* of the queue in the given order
    /// (error-path requeue; arrivals are preserved).
    pub fn requeue_front(&mut self, items: Vec<(T, f64)>) {
        for it in items.into_iter().rev() {
            self.pending.push_front(it);
        }
    }

    /// Take the pending queue — items with their (already clamped,
    /// monotone) arrivals, in submission order — leaving the batcher
    /// empty.  The event-driven drain (DESIGN.md §13) re-feeds them
    /// through [`push`](Self::push) one `Arrival` event at a time; the
    /// arrival clamp is reset so the re-feed reproduces each stored
    /// timestamp exactly (the sequence is monotone, so re-pushing it in
    /// order restores the clamp to the same high-water mark).
    pub fn take_pending(&mut self) -> Vec<(T, f64)> {
        self.last_arrival_ms = 0.0;
        self.pending.drain(..).collect()
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Form every batch the pending queue implies and empty it (the
    /// drain path).  The final partial batch is flushed with
    /// `ready_ms` = its last arrival.
    pub fn drain_batches(&mut self) -> Vec<Batch<T>> {
        self.form(None)
    }

    /// Form only the batches whose trigger has fired by `now_ms`
    /// (size-complete, or oldest member past the deadline); later items
    /// stay pending.
    pub fn form_ready(&mut self, now_ms: f64) -> Vec<Batch<T>> {
        self.form(Some(now_ms))
    }

    fn form(&mut self, now_ms: Option<f64>) -> Vec<Batch<T>> {
        let mut out: Vec<Batch<T>> = Vec::new();
        let mut cur: Vec<(T, f64)> = Vec::new();
        let mut first_arrival = 0.0f64;
        while let Some((item, arrival)) = self.pending.pop_front() {
            if cur.is_empty() {
                first_arrival = arrival;
            } else if arrival > first_arrival + self.max_delay_ms {
                // Deadline fired before this item arrived: the open
                // batch dispatched without it.
                let ready = first_arrival + self.max_delay_ms;
                out.push(Batch { items: std::mem::take(&mut cur),
                                 ready_ms: ready });
                first_arrival = arrival;
            }
            cur.push((item, arrival));
            if cur.len() == self.max_batch {
                let ready = cur.last().unwrap().1;
                out.push(Batch { items: std::mem::take(&mut cur),
                                 ready_ms: ready });
            }
        }
        let Some(now) = now_ms else {
            // Drain: flush the tail as soon as its last member arrived.
            if !cur.is_empty() {
                out.push(Batch { ready_ms: cur.last().unwrap().1,
                                 items: cur });
            }
            return out;
        };
        // Close the tail only if its deadline has fired by `now`.
        let mut leftover: Vec<(T, f64)> = Vec::new();
        if !cur.is_empty() {
            let deadline = first_arrival + self.max_delay_ms;
            if deadline <= now {
                out.push(Batch { items: cur, ready_ms: deadline });
            } else {
                leftover = cur;
            }
        }
        // A batch is ripe only once its trigger has fired by `now`
        // (size-complete: last member arrived; deadline: expired).
        // Arrivals are monotone, so ready_ms is non-decreasing and
        // everything from the first unripe batch onward waits.
        let ripe_end = out
            .iter()
            .position(|b| b.ready_ms > now)
            .unwrap_or(out.len());
        for b in out.split_off(ripe_end) {
            for it in b.items {
                self.pending.push_back(it);
            }
        }
        for it in leftover {
            self.pending.push_back(it);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids<T: Copy>(b: &Batch<T>) -> Vec<T> {
        b.items.iter().map(|(x, _)| *x).collect()
    }

    #[test]
    fn size_trigger_groups_in_submission_order() {
        let mut b = Batcher::new(4, 100.0);
        for i in 0..10u64 {
            b.push(i, i as f64); // 1ms apart, well under the deadline
        }
        let batches = b.drain_batches();
        assert_eq!(batches.len(), 3);
        assert_eq!(ids(&batches[0]), vec![0, 1, 2, 3]);
        assert_eq!(ids(&batches[1]), vec![4, 5, 6, 7]);
        assert_eq!(ids(&batches[2]), vec![8, 9]);
        // size-triggered batches dispatch when their last member arrives
        assert_eq!(batches[0].ready_ms, 3.0);
        assert_eq!(batches[1].ready_ms, 7.0);
        assert_eq!(batches[2].ready_ms, 9.0); // flushed tail
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_trigger_closes_partial_batches() {
        let mut b = Batcher::new(8, 30.0);
        b.push(0u64, 0.0);
        b.push(1, 10.0);
        b.push(2, 100.0); // arrives after 0's deadline (0 + 30)
        b.push(3, 105.0);
        let batches = b.drain_batches();
        assert_eq!(batches.len(), 2);
        assert_eq!(ids(&batches[0]), vec![0, 1]);
        assert_eq!(batches[0].ready_ms, 30.0); // first arrival + delay
        assert_eq!(ids(&batches[1]), vec![2, 3]);
        assert_eq!(batches[1].ready_ms, 105.0); // flushed tail
    }

    #[test]
    fn form_ready_leaves_unripe_tail_pending() {
        let mut b = Batcher::new(4, 30.0);
        b.push(0u64, 0.0);
        b.push(1, 5.0);
        // At t=10 neither trigger has fired.
        assert!(b.form_ready(10.0).is_empty());
        assert_eq!(b.len(), 2);
        // At t=31 the deadline has fired.
        let ready = b.form_ready(31.0);
        assert_eq!(ready.len(), 1);
        assert_eq!(ids(&ready[0]), vec![0, 1]);
        assert_eq!(ready[0].ready_ms, 30.0);
        assert!(b.is_empty());
    }

    #[test]
    fn form_ready_never_emits_unripe_batches() {
        // Items time-stamped in the future must not dispatch early —
        // neither as a deadline batch nor as a size-complete one.
        let mut b = Batcher::new(4, 30.0);
        b.push(0u64, 0.0);
        b.push(1, 100.0); // closes [0]'s deadline batch (ready 30)...
        // ...but at now=5 that deadline hasn't fired yet.
        assert!(b.form_ready(5.0).is_empty());
        assert_eq!(b.len(), 2);

        let mut b = Batcher::new(4, 30.0);
        for (i, t) in [(0u64, 100.0), (1, 101.0), (2, 102.0), (3, 103.0)] {
            b.push(i, t);
        }
        // size-complete at t=103, which is after now=0
        assert!(b.form_ready(0.0).is_empty());
        assert_eq!(b.len(), 4);
        let ready = b.form_ready(103.0);
        assert_eq!(ready.len(), 1);
        assert_eq!(ids(&ready[0]), vec![0, 1, 2, 3]);
    }

    #[test]
    fn non_monotone_arrivals_are_clamped() {
        let mut b = Batcher::new(2, 1000.0);
        b.push(0u64, 50.0);
        b.push(1, 10.0); // clamped to 50.0
        let batches = b.drain_batches();
        assert_eq!(batches[0].ready_ms, 50.0);
    }

    #[test]
    fn requeue_front_preserves_order() {
        let mut b = Batcher::new(4, 1000.0);
        b.push(2u64, 2.0);
        b.push(3, 3.0);
        b.requeue_front(vec![(0, 0.0), (1, 1.0)]);
        let batches = b.drain_batches();
        assert_eq!(ids(&batches[0]), vec![0, 1, 2, 3]);
    }

    #[test]
    fn zero_delay_degenerates_to_per_arrival_batches() {
        let mut b = Batcher::new(8, 0.0);
        b.push(0u64, 0.0);
        b.push(1, 1.0);
        b.push(2, 1.0); // same instant: may share a batch
        let batches = b.drain_batches();
        assert_eq!(ids(&batches[0]), vec![0]);
        assert_eq!(ids(&batches[1]), vec![1, 2]);
    }
}
