//! Backend-generic, virtual-time serving (DESIGN.md §11).
//!
//! The serving loop is generic over two seams:
//!
//! * [`ExecBackend`] — what runs a batch ([`PjrtBackend`] for real
//!   artifacts, [`SimulatedBackend`] for the deterministic cost-model
//!   fleet);
//! * [`Clock`] — where time comes from ([`WallClock`] live,
//!   [`VirtualClock`] simulated).
//!
//! Requests carry arrival timestamps and an [`SloClass`]; the dynamic
//! [`Batcher`] forms size- or deadline-triggered batches; completions
//! are accounted on a lane model (one simulated device per lane, batch
//! assigned to the earliest-free lane in submission order), so latency
//! percentiles, SLO violations and energy are pure functions of
//! (workload, config, seed) on the simulated stack — bit-reproducible
//! with no XLA artifacts present.
//!
//! Ordering contract (tests/integration_serve.rs): batch indices and
//! the completion log always follow submission order, at every
//! [`Parallelism`] level and whatever order workers finish in.
//!
//! Since the event-core refactor (DESIGN.md §13), [`drain`](
//! Server::drain) runs on the deterministic [`EventQueue`]: arrivals,
//! batch closes and batch completions are heap events keyed by
//! `(time, seq)`, so wall-clock cost tracks events processed rather
//! than virtual time swept.  The pre-refactor pooled loop survives as
//! [`drain_polled`](Server::drain_polled) — the reference
//! implementation the byte-identity regression tests compare against.

use super::backend::{BatchResult, BatchShape, ExecBackend, PjrtBackend,
                     SimulatedBackend};
use super::batcher::{Batch, Batcher};
use super::clock::{Clock, VirtualClock, WallClock};
use super::events::{Event, EventQueue};
use super::engine::Engine;
use super::fleet::{SloClass, SloPolicy};
use crate::util::json::Json;
use crate::util::pool::{self, Parallelism};
use crate::util::stats;

/// One inference request: a prompt of token ids, an arrival timestamp
/// on the serving clock (0.0 = "now" for live submitters) and an SLO
/// class used for deadline accounting and fleet routing.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub arrival_ms: f64,
    pub slo: SloClass,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<i32>) -> Request {
        Request { id, tokens, arrival_ms: 0.0, slo: SloClass::Interactive }
    }

    /// Set the arrival timestamp (virtual-time workloads).
    pub fn at(mut self, arrival_ms: f64) -> Request {
        self.arrival_ms = arrival_ms;
        self
    }

    /// Tag the request with an SLO class.
    pub fn class(mut self, slo: SloClass) -> Request {
        self.slo = slo;
        self
    }
}

/// Pad/truncate a prompt to the variant's sequence length and clamp
/// token ids into vocabulary range.  An empty prompt becomes a full
/// pad row (id 0) rather than a degenerate unpadded row; returns
/// whether the prompt had to be *truncated* — the quality-SLO breach
/// the fleet router exists to avoid.
pub fn pad_tokens(tokens: &[i32], seq: usize, vocab: usize)
                  -> (Vec<i32>, bool) {
    let truncated = tokens.len() > seq;
    let mut out: Vec<i32> = tokens.iter().take(seq)
        .map(|t| t.rem_euclid(vocab as i32))
        .collect();
    out.resize(seq, 0);
    (out, truncated)
}

/// Per-request completion record.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    /// argmax next-token prediction at the last position
    pub next_token: i32,
    /// time from arrival to batch completion, ms (on the server clock)
    pub latency_ms: f64,
    /// index of the batch this request rode in
    pub batch_index: usize,
    pub slo: SloClass,
    /// deadline missed, or prompt truncated
    pub violated: bool,
    pub truncated: bool,
    /// completion timestamp on the server clock
    pub done_ms: f64,
}

/// Aggregate serving statistics (schema `ae-llm.serve-report/v1`).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeReport {
    pub completed: usize,
    pub batches: usize,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub mean_batch_exec_ms: f64,
    pub throughput_rps: f64,
    pub tokens_per_s: f64,
    /// Requests that missed their SLO deadline or were truncated.
    pub slo_violations: usize,
    pub slo_violation_rate: f64,
    pub truncated: usize,
    /// Total energy the backend accounted, J (0.0 for PJRT).
    pub energy_j: f64,
    /// First arrival to last completion, ms.
    pub makespan_ms: f64,
}

pub const SERVE_REPORT_SCHEMA: &str = "ae-llm.serve-report/v1";

impl ServeReport {
    /// Aggregate a report from raw completion records (shared by the
    /// per-server path and the fleet's merged overall view).
    /// `total_tokens` is Σ completed×seq over the contributing servers.
    pub fn from_completions(completions: &[Completion], batches: usize,
                            batch_exec_ms: &[f64], energy_j: f64,
                            span: Option<(f64, f64)>, total_tokens: usize)
                            -> ServeReport {
        let lats: Vec<f64> =
            completions.iter().map(|c| c.latency_ms).collect();
        let violations =
            completions.iter().filter(|c| c.violated).count();
        let truncated =
            completions.iter().filter(|c| c.truncated).count();
        let makespan_ms = span
            .map(|(first, last)| (last - first).max(0.0))
            .unwrap_or(0.0);
        let wall_s = (makespan_ms / 1e3).max(1e-9);
        ServeReport {
            completed: completions.len(),
            batches,
            p50_latency_ms: stats::quantile(&lats, 0.5),
            p95_latency_ms: stats::quantile(&lats, 0.95),
            mean_batch_exec_ms: stats::mean(batch_exec_ms),
            throughput_rps: completions.len() as f64 / wall_s,
            tokens_per_s: total_tokens as f64 / wall_s,
            slo_violations: violations,
            slo_violation_rate: if completions.is_empty() {
                0.0
            } else {
                violations as f64 / completions.len() as f64
            },
            truncated,
            energy_j,
            makespan_ms,
        }
    }

    /// Serialize (schema `ae-llm.serve-report/v1`; field reference in
    /// docs/SCHEMAS.md).  Every field is a deterministic function of
    /// the serving inputs, so same-seed simulated runs dump
    /// byte-identical JSON.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("schema".into(), Json::Str(SERVE_REPORT_SCHEMA.into()));
        m.insert("completed".into(), Json::Num(self.completed as f64));
        m.insert("batches".into(), Json::Num(self.batches as f64));
        m.insert("p50_latency_ms".into(), Json::Num(self.p50_latency_ms));
        m.insert("p95_latency_ms".into(), Json::Num(self.p95_latency_ms));
        m.insert("mean_batch_exec_ms".into(),
                 Json::Num(self.mean_batch_exec_ms));
        m.insert("throughput_rps".into(), Json::Num(self.throughput_rps));
        m.insert("tokens_per_s".into(), Json::Num(self.tokens_per_s));
        m.insert("slo_violations".into(),
                 Json::Num(self.slo_violations as f64));
        m.insert("slo_violation_rate".into(),
                 Json::Num(self.slo_violation_rate));
        m.insert("truncated".into(), Json::Num(self.truncated as f64));
        m.insert("energy_j".into(), Json::Num(self.energy_j));
        m.insert("makespan_ms".into(), Json::Num(self.makespan_ms));
        Json::Obj(m)
    }

    /// Parse a report back from its JSON form (schema-checked).
    pub fn from_json(j: &Json) -> Result<ServeReport, String> {
        let schema = j.req_str("schema")?;
        if schema != SERVE_REPORT_SCHEMA {
            return Err(format!("unexpected schema {schema:?}"));
        }
        Ok(ServeReport {
            completed: j.req_u64("completed")? as usize,
            batches: j.req_u64("batches")? as usize,
            p50_latency_ms: j.req_f64("p50_latency_ms")?,
            p95_latency_ms: j.req_f64("p95_latency_ms")?,
            mean_batch_exec_ms: j.req_f64("mean_batch_exec_ms")?,
            throughput_rps: j.req_f64("throughput_rps")?,
            tokens_per_s: j.req_f64("tokens_per_s")?,
            slo_violations: j.req_u64("slo_violations")? as usize,
            slo_violation_rate: j.req_f64("slo_violation_rate")?,
            truncated: j.req_u64("truncated")? as usize,
            energy_j: j.req_f64("energy_j")?,
            makespan_ms: j.req_f64("makespan_ms")?,
        })
    }
}

/// Arrival-side observation recorded at submit time — the serving
/// telemetry hook (DESIGN.md §12).  Captures the *raw* prompt length
/// (before padding/truncation), so epoch telemetry sees the workload
/// shape the clients actually sent, not what the serve shape kept.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    pub slo: SloClass,
    /// Raw prompt length in tokens, pre-pad/truncate.
    pub len: usize,
    pub arrival_ms: f64,
}

/// Which serving loop a fleet drives its servers with: the event core
/// ([`Server::drain`], the default) or the pre-refactor pooled loop
/// ([`Server::drain_polled`], kept as the reference implementation the
/// byte-identity tests compare against).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainDriver {
    Event,
    Polled,
}

/// A padded, deadline-stamped queue entry.
#[derive(Clone, Debug)]
struct Item {
    id: u64,
    tokens: Vec<i32>,
    slo: SloClass,
    deadline_ms: f64,
    truncated: bool,
}

/// Dynamic-batch scheduler over one serve variant of an execution
/// backend, on a wall or virtual clock.
pub struct Server<B: ExecBackend, C: Clock> {
    backend: B,
    clock: C,
    variant: String,
    shape: BatchShape,
    batcher: Batcher<Item>,
    policy: SloPolicy,
    /// Arrival log (telemetry hook); one record per submitted request.
    arrivals: Vec<Arrival>,
    completions: Vec<Completion>,
    batch_exec_ms: Vec<f64>,
    energy_j: f64,
    /// Earliest-free time per serving lane (simulated device replicas).
    lane_free: Vec<f64>,
    /// Reused flattened-token buffer for the inline batch path
    /// (cleared per batch, never reallocated — DESIGN.md §15).
    flat_scratch: Vec<i32>,
    /// Reused event heap for [`drain`](Self::drain): cleared and
    /// refilled per drain, so a server drained once per epoch allocates
    /// the heap once at its high-water mark instead of rebuilding it
    /// every epoch (DESIGN.md §15).
    drain_queue: EventQueue<Event>,
    first_arrival_ms: Option<f64>,
    last_done_ms: f64,
    /// Worker count for executing independent batches concurrently in
    /// [`drain_polled`](Self::drain_polled).  Purely an execution-
    /// throughput knob: batch indices, the completion log and (for
    /// deterministic backends) every reported number are identical at
    /// every level — the event-driven [`drain`](Self::drain) executes
    /// inline and ignores it entirely.
    parallelism: Parallelism,
}

impl<'a> Server<PjrtBackend<'a>, WallClock> {
    /// Live PJRT serving on the wall clock.  `variant` must already be
    /// loaded in the engine.
    pub fn new(engine: &'a Engine, variant: &str)
               -> anyhow::Result<Server<PjrtBackend<'a>, WallClock>> {
        anyhow::ensure!(engine.is_loaded(variant),
                        "variant {variant:?} not loaded");
        Server::with_backend(PjrtBackend::new(engine), variant,
                             WallClock::new())
    }
}

impl Server<SimulatedBackend, VirtualClock> {
    /// Artifact-free serving: simulated backend on a virtual clock.
    pub fn simulated(backend: SimulatedBackend, variant: &str)
                     -> anyhow::Result<Server<SimulatedBackend,
                                              VirtualClock>> {
        Server::with_backend(backend, variant, VirtualClock::new())
    }
}

impl<B: ExecBackend, C: Clock> Server<B, C> {
    /// Generic constructor: any backend on any clock.
    pub fn with_backend(backend: B, variant: &str, clock: C)
                        -> anyhow::Result<Server<B, C>> {
        let shape = backend.shape(variant)?;
        Ok(Server {
            backend,
            clock,
            variant: variant.to_string(),
            // No deadline by default: batches close on size or flush,
            // the old fixed-batch behavior.
            batcher: Batcher::new(shape.batch, f64::INFINITY),
            shape,
            policy: SloPolicy::default(),
            arrivals: Vec::new(),
            completions: Vec::new(),
            batch_exec_ms: Vec::new(),
            energy_j: 0.0,
            lane_free: vec![0.0],
            flat_scratch: Vec::new(),
            drain_queue: EventQueue::new(),
            first_arrival_ms: None,
            last_done_ms: 0.0,
            parallelism: Parallelism::Auto,
        })
    }

    /// Override the batch-execution parallelism (e.g. `Sequential` for
    /// clean single-stream latency measurements).
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.parallelism = par;
        self
    }

    /// SLO policy used to stamp per-request deadlines at submit time.
    pub fn with_policy(mut self, policy: SloPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Dynamic-batching deadline: the longest a request waits for
    /// co-riders before a partial batch dispatches.  Already-pending
    /// requests are kept; the new delay applies at the next batch
    /// formation.
    pub fn with_max_delay_ms(mut self, delay_ms: f64) -> Self {
        self.batcher.set_max_delay_ms(delay_ms);
        self
    }

    /// Number of serving lanes (simulated device replicas) completion
    /// times are accounted against.
    pub fn with_lanes(mut self, lanes: usize) -> Self {
        self.lane_free = vec![0.0; lanes.max(1)];
        self
    }

    pub fn batch_size(&self) -> usize {
        self.shape.batch
    }

    pub fn seq_len(&self) -> usize {
        self.shape.seq
    }

    /// Capacity hint for an incoming workload of `n` requests: sizes
    /// the arrival/completion logs, the per-batch accounting and the
    /// batcher queue up front so the serve loop never regrows them
    /// (call-site counts are known before submission — see
    /// `Deployment::serve_with`).
    pub fn reserve_requests(&mut self, n: usize) {
        self.arrivals.reserve(n);
        self.completions.reserve(n);
        self.batch_exec_ms.reserve(n / self.shape.batch.max(1) + 1);
        self.batcher.reserve(n);
    }

    /// Enqueue a request: pads/truncates the prompt, clamps token ids,
    /// stamps the arrival (the later of the request's own timestamp and
    /// the clock) and the SLO deadline.
    pub fn submit(&mut self, r: Request) {
        let arrival = self.clock.now_ms().max(r.arrival_ms);
        self.arrivals.push(Arrival {
            slo: r.slo,
            len: r.tokens.len(),
            arrival_ms: arrival,
        });
        let (tokens, truncated) =
            pad_tokens(&r.tokens, self.shape.seq, self.shape.vocab);
        let deadline_ms = arrival + self.policy.deadline_ms(r.slo);
        self.first_arrival_ms = Some(match self.first_arrival_ms {
            Some(t) => t.min(arrival),
            None => arrival,
        });
        self.batcher.push(
            Item { id: r.id, tokens, slo: r.slo, deadline_ms, truncated },
            arrival,
        );
    }

    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    /// Capacity of the reusable [`drain`](Self::drain) event heap —
    /// exposed so the zero-churn tests can assert the allocation is
    /// retained across epochs rather than rebuilt per drain.
    pub fn drain_queue_capacity(&self) -> usize {
        self.drain_queue.capacity()
    }

    /// Form and execute every batch the queue implies (size- or
    /// deadline-triggered, final partial flushed), on the discrete-
    /// event core (DESIGN.md §13).
    ///
    /// Pending requests replay as `Arrival` events in `(time, seq)`
    /// order; each arrival feeds the batcher, and every batch the
    /// batcher closes is scheduled as a `BatchClose` event at its
    /// `ready_ms`, executed when popped, with a `BatchComplete` event
    /// at its lane completion time advancing the clock.  Because batch
    /// `ready_ms` is non-decreasing in formation order and the heap
    /// tie-break is submission order, batches execute in exactly the
    /// order the one-shot [`drain_polled`](Self::drain_polled) loop
    /// produced — reports stay byte-identical (the regression tests
    /// compare the two paths directly).
    ///
    /// On the first failed batch, every not-yet-recorded request — the
    /// failed batch included — is requeued in order, so no request is
    /// ever silently lost and a retry of `drain()` can pick them up.
    pub fn drain(&mut self) -> anyhow::Result<()> {
        let pending = self.batcher.take_pending();
        if pending.is_empty() {
            return Ok(());
        }
        // Capacity hints sized from the workload: the heap peaks near
        // one Arrival per pending item, and the side tables hold one
        // slot per formed batch.
        let n_batches = pending.len() / self.shape.batch.max(1) + 1;
        let mut queue = std::mem::take(&mut self.drain_queue);
        queue.clear();
        queue.reserve(pending.len() + 2);
        let mut waiting: Vec<Option<(Item, f64)>> =
            Vec::with_capacity(pending.len());
        for (item, arrival) in pending {
            queue.push(arrival, Event::Arrival { index: waiting.len() });
            waiting.push(Some((item, arrival)));
        }
        // Side table of closed-but-not-yet-executed batches, indexed by
        // the `BatchClose` payload.
        let mut closed: Vec<Option<Batch<Item>>> = Vec::with_capacity(n_batches);
        // Completion times, indexed by the `BatchComplete` payload.
        let mut done_at: Vec<f64> = Vec::with_capacity(n_batches);
        while let Some((now, _seq, ev)) = queue.pop() {
            match ev {
                Event::Arrival { index } => {
                    let (item, arrival) =
                        waiting[index].take().expect("arrival fires once");
                    self.batcher.push(item, arrival);
                    for b in self.batcher.form_ready(now) {
                        queue.push(b.ready_ms,
                                   Event::BatchClose { batch: closed.len() });
                        closed.push(Some(b));
                    }
                }
                Event::BatchClose { batch } => {
                    let b = closed[batch].take().expect("close fires once");
                    match self.run_batch(b) {
                        Ok(done) => {
                            queue.push(done, Event::BatchComplete {
                                batch: done_at.len(),
                            });
                            done_at.push(done);
                        }
                        Err((e, failed)) => {
                            self.requeue_after_failure(
                                failed, &mut queue, &mut closed,
                                &mut waiting, &done_at);
                            self.drain_queue = queue;
                            return Err(e);
                        }
                    }
                }
                Event::BatchComplete { batch } => {
                    let done = done_at[batch];
                    self.last_done_ms = self.last_done_ms.max(done);
                    self.clock.advance_to_ms(done);
                }
                Event::EpochBoundary { .. } => {
                    unreachable!("serve drain schedules no epoch events")
                }
            }
        }
        // Hand the (now empty) heap back so the next drain reuses its
        // allocation.
        self.drain_queue = queue;
        // Flush the tail the deadline never closed (ready at its last
        // member's arrival, exactly as the one-shot formation).
        let tail = self.batcher.drain_batches();
        self.run_batches(tail)
    }

    /// Error-path cleanup for the event drain: apply the timeline
    /// effects of batches that already executed, then requeue every
    /// unaccounted request — failed batch, closed-but-unexecuted
    /// batches, unformed pending, unarrived items — in submission
    /// order.
    fn requeue_after_failure(&mut self, failed: Batch<Item>,
                             queue: &mut EventQueue<Event>,
                             closed: &mut [Option<Batch<Item>>],
                             waiting: &mut [Option<(Item, f64)>],
                             done_at: &[f64]) {
        while let Some((_, _, ev)) = queue.pop() {
            if let Event::BatchComplete { batch } = ev {
                let done = done_at[batch];
                self.last_done_ms = self.last_done_ms.max(done);
                self.clock.advance_to_ms(done);
            }
        }
        // Submission order: executed batches precede the failed one,
        // which precedes later closed batches, then the batcher's
        // unformed pending, then items whose arrival never fired.
        let mut front = failed.items;
        for b in closed.iter_mut().filter_map(Option::take) {
            front.extend(b.items);
        }
        self.batcher.requeue_front(front);
        for (item, arrival) in waiting.iter_mut().filter_map(Option::take) {
            self.batcher.push(item, arrival);
        }
    }

    /// The pre-event-core serving loop, kept as the reference
    /// implementation: one-shot batch formation, pooled execution on up
    /// to `self.parallelism` workers, completions merged back in
    /// submission order (the pool's ordered reduce).  Byte-identical to
    /// [`drain`](Self::drain) for deterministic backends — the
    /// regression tests and `benches/perf_cluster.rs` hold the two
    /// paths against each other.
    pub fn drain_polled(&mut self) -> anyhow::Result<()> {
        let batches = self.batcher.drain_batches();
        self.execute(batches)
    }

    /// Drain through the selected [`DrainDriver`].
    pub fn drain_with(&mut self, driver: DrainDriver)
                      -> anyhow::Result<()> {
        match driver {
            DrainDriver::Event => self.drain(),
            DrainDriver::Polled => self.drain_polled(),
        }
    }

    /// Poll-driven serving step (the "before" driver the cluster bench
    /// measures): form every batch that is ripe by `now_ms` and execute
    /// it inline.  Returns the number of batches executed.  Each call
    /// re-walks the pending queue — the per-tick cost the event core
    /// exists to remove.
    pub fn poll_ready(&mut self, now_ms: f64) -> anyhow::Result<usize> {
        let ready = self.batcher.form_ready(now_ms);
        let n = ready.len();
        self.run_batches(ready)?;
        Ok(n)
    }

    /// Execute batches inline, in order, advancing the clock per
    /// completion; on failure requeues the failed batch and everything
    /// after it.
    fn run_batches(&mut self, batches: Vec<Batch<Item>>)
                   -> anyhow::Result<()> {
        let mut iter = batches.into_iter();
        while let Some(b) = iter.next() {
            match self.run_batch(b) {
                Ok(done) => {
                    self.last_done_ms = self.last_done_ms.max(done);
                    self.clock.advance_to_ms(done);
                }
                Err((e, failed)) => {
                    let mut items = failed.items;
                    for rest in iter.by_ref() {
                        items.extend(rest.items);
                    }
                    self.batcher.requeue_front(items);
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Execute one batch: earliest-free-lane assignment, per-item
    /// completion records, energy/exec accounting.  Returns the lane
    /// completion time; on failure hands the batch back untouched.
    fn run_batch(&mut self, b: Batch<Item>)
                 -> Result<f64, (anyhow::Error, Batch<Item>)> {
        let BatchShape { batch, seq, .. } = self.shape;
        // Scratch reuse: the flattened-token buffer persists across
        // batches (clear + refill, no per-batch allocation).
        self.flat_scratch.clear();
        self.flat_scratch.reserve(batch * seq);
        for (item, _) in &b.items {
            self.flat_scratch.extend_from_slice(&item.tokens);
        }
        self.flat_scratch.resize(batch * seq, 0); // padding rows
        let res = match self.backend.execute_batch(&self.variant,
                                                   &self.flat_scratch,
                                                   b.items.len()) {
            Ok(ok) => ok,
            Err(e) => return Err((e, b)),
        };
        Ok(self.account_batch(b, res))
    }

    fn execute(&mut self, batches: Vec<Batch<Item>>) -> anyhow::Result<()> {
        if batches.is_empty() {
            return Ok(());
        }
        let BatchShape { batch, seq, .. } = self.shape;
        let jobs: Vec<(Vec<i32>, usize)> = batches
            .iter()
            .map(|b| {
                let mut flat: Vec<i32> = Vec::with_capacity(batch * seq);
                for (item, _) in &b.items {
                    flat.extend_from_slice(&item.tokens);
                }
                flat.resize(batch * seq, 0); // padding rows
                (flat, b.items.len())
            })
            .collect();
        let backend = &self.backend;
        // Borrow, don't clone: the pool's scoped threads end before the
        // mutable accounting below, so a shared reference suffices.
        let variant = &self.variant;
        let results: Vec<anyhow::Result<BatchResult>> =
            pool::parallel_map(self.parallelism, &jobs, |(flat, rows)| {
                backend.execute_batch(variant, flat, *rows)
            });

        let mut batches_iter = batches.into_iter();
        for result in results {
            let b = batches_iter.next().expect("one batch per result");
            let res = match result {
                Ok(ok) => ok,
                Err(e) => {
                    let mut items = b.items;
                    for rest in batches_iter.by_ref() {
                        items.extend(rest.items);
                    }
                    self.batcher.requeue_front(items);
                    return Err(e);
                }
            };
            let done = self.account_batch(b, res);
            self.last_done_ms = self.last_done_ms.max(done);
            self.clock.advance_to_ms(done);
        }
        Ok(())
    }

    /// Lane-model accounting shared by the event and polled paths:
    /// assign the batch to the earliest-free lane (deterministic
    /// tie-break — completion accounting never depends on worker
    /// scheduling), record exec/energy and one [`Completion`] per item.
    /// Returns the batch's lane completion time.
    fn account_batch(&mut self, b: Batch<Item>, res: BatchResult) -> f64 {
        let lane = (0..self.lane_free.len())
            .min_by(|&x, &y| {
                self.lane_free[x].partial_cmp(&self.lane_free[y]).unwrap()
            })
            .unwrap();
        let start = self.lane_free[lane].max(b.ready_ms);
        let done = start + res.exec_ms;
        self.lane_free[lane] = done;
        self.batch_exec_ms.push(res.exec_ms);
        self.energy_j += res.energy_j;
        let batch_index = self.batch_exec_ms.len() - 1;
        for (row, (item, arrival)) in b.items.into_iter().enumerate() {
            self.completions.push(Completion {
                id: item.id,
                next_token: res.next_tokens.get(row).copied().unwrap_or(0),
                latency_ms: done - arrival,
                batch_index,
                slo: item.slo,
                violated: item.truncated || done > item.deadline_ms,
                truncated: item.truncated,
                done_ms: done,
            });
        }
        done
    }

    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    /// Arrival observations, in submission order (telemetry hook).
    pub fn arrivals(&self) -> &[Arrival] {
        &self.arrivals
    }

    /// Per-batch execution times, in batch-index order.
    pub fn batch_exec_ms(&self) -> &[f64] {
        &self.batch_exec_ms
    }

    /// (first arrival, last completion) on the server clock, if any
    /// request completed.
    pub fn span(&self) -> Option<(f64, f64)> {
        if self.completions.is_empty() {
            return None;
        }
        self.first_arrival_ms.map(|f| (f, self.last_done_ms))
    }

    /// Total energy the backend accounted so far, J.
    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    pub fn report(&self) -> ServeReport {
        ServeReport::from_completions(
            &self.completions,
            self.batch_exec_ms.len(),
            &self.batch_exec_ms,
            self.energy_j,
            self.span(),
            self.completions.len() * self.shape.seq,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::manifest::artifacts_dir;
    use super::*;
    use crate::config::Config;
    use crate::hardware;
    use crate::models::by_name;
    use crate::tasks::blended_task;

    // ---- simulated-backend tests: run everywhere, no artifacts ----

    fn sim_server(noise: f64) -> Server<SimulatedBackend, VirtualClock> {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t = blended_task();
        let backend = SimulatedBackend::for_config(
            "sim", &Config::default_baseline(), &m, &t, &hardware::a100(),
            8, 512, 11)
            .with_noise(noise);
        Server::simulated(backend, "sim").unwrap()
    }

    #[test]
    fn simulated_serving_is_deterministic_and_ordered() {
        let run = |par: Parallelism| {
            let mut s = sim_server(0.05).with_parallelism(par);
            for i in 0..40u64 {
                s.submit(Request::new(i, vec![(i as i32) * 5; 80])
                    .at(i as f64 * 2.0));
            }
            s.drain().unwrap();
            assert_eq!(s.pending(), 0);
            (s.completions()
                .iter()
                .map(|c| (c.id, c.next_token, c.batch_index))
                .collect::<Vec<_>>(),
             s.report())
        };
        let (log_seq, rep_seq) = run(Parallelism::Sequential);
        let (log_par, rep_par) = run(Parallelism::Threads(4));
        assert_eq!(log_seq, log_par);
        assert_eq!(rep_seq, rep_par);
        // completion log follows submission order
        let ids: Vec<u64> = log_seq.iter().map(|(id, _, _)| *id).collect();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
        assert_eq!(rep_seq.completed, 40);
        assert_eq!(rep_seq.batches, 5);
        assert!(rep_seq.p95_latency_ms >= rep_seq.p50_latency_ms);
        assert!(rep_seq.energy_j > 0.0);
    }

    #[test]
    fn drain_reuses_its_event_heap_across_epochs() {
        // Same-sized submit/drain cycles after the first must never
        // regrow the drain heap: the allocation is made once at the
        // high-water mark and recycled (DESIGN.md §15).
        let mut s = sim_server(0.0);
        assert_eq!(s.drain_queue_capacity(), 0);
        let mut serve_epoch = |epoch: u64| {
            for i in 0..60u64 {
                let id = epoch * 60 + i;
                s.submit(Request::new(id, vec![1; 80])
                    .at(epoch as f64 * 1000.0 + i as f64 * 2.0));
            }
            s.drain().unwrap();
        };
        serve_epoch(0);
        let cap = s.drain_queue_capacity();
        assert!(cap >= 60, "first drain sized the heap: {cap}");
        for epoch in 1..4 {
            serve_epoch(epoch);
            assert_eq!(s.drain_queue_capacity(), cap,
                       "drain heap reallocated on epoch {epoch}");
        }
        assert_eq!(s.completions().len(), 240);
    }

    #[test]
    fn event_drain_matches_polled_reference_byte_for_byte() {
        // Same submissions through the event core and through the
        // pre-refactor pooled loop: completion logs (to the bit) and
        // serialized reports must be indistinguishable, at any
        // parallelism.  Timestamps are deliberately tied in triples to
        // stress the (time, seq) tie-break.
        let run = |event: bool, par: Parallelism| {
            let mut s = sim_server(0.05)
                .with_parallelism(par)
                .with_max_delay_ms(40.0)
                .with_lanes(2);
            for i in 0..120u64 {
                let len = 60 + (i as usize % 90);
                s.submit(Request::new(i, vec![(i as i32) % 13; len])
                    .at((i / 3) as f64 * 7.0));
            }
            if event {
                s.drain().unwrap();
            } else {
                s.drain_polled().unwrap();
            }
            assert_eq!(s.pending(), 0);
            (s.completions()
                .iter()
                .map(|c| (c.id, c.next_token, c.batch_index,
                          c.latency_ms.to_bits(), c.done_ms.to_bits(),
                          c.violated))
                .collect::<Vec<_>>(),
             s.report().to_json().dump())
        };
        let (log_event, json_event) = run(true, Parallelism::Sequential);
        let (log_polled, json_polled) = run(false, Parallelism::Threads(4));
        assert_eq!(log_event, log_polled);
        assert_eq!(json_event, json_polled);
    }

    #[test]
    fn same_timestamp_arrivals_complete_in_submission_order() {
        // Twelve requests share each arrival instant: the heap's
        // (time, seq) key must pop them in submission order, at
        // Parallelism 1 and 4 alike.
        for par in [Parallelism::Sequential, Parallelism::Threads(4)] {
            let mut s = sim_server(0.0)
                .with_parallelism(par)
                .with_max_delay_ms(25.0);
            for i in 0..48u64 {
                s.submit(Request::new(i, vec![2; 32])
                    .at((i / 12) as f64 * 100.0));
            }
            s.drain().unwrap();
            let ids: Vec<u64> =
                s.completions().iter().map(|c| c.id).collect();
            assert_eq!(ids, (0..48).collect::<Vec<_>>());
        }
    }

    #[test]
    fn poll_driven_serving_completes_everything() {
        // The tick-polled reference driver: repeatedly form-and-execute
        // whatever is ripe, then flush.  Everything completes exactly
        // once.
        let mut s = sim_server(0.0).with_max_delay_ms(30.0);
        for i in 0..30u64 {
            s.submit(Request::new(i, vec![1; 40]).at(i as f64 * 10.0));
        }
        let mut polled = 0usize;
        let mut t = 0.0;
        while t <= 400.0 {
            polled += s.poll_ready(t).unwrap();
            t += 5.0;
        }
        assert!(polled > 0, "ticks never dispatched a batch");
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 30);
        let mut ids: Vec<u64> =
            s.completions().iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..30).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_ragged_prompts_are_padded_not_degenerate() {
        let (row, trunc) = pad_tokens(&[], 8, 256);
        assert_eq!(row, vec![0; 8]);
        assert!(!trunc);
        let (row, trunc) = pad_tokens(&[5; 40], 8, 256);
        assert_eq!(row.len(), 8);
        assert!(trunc);
        let (row, trunc) = pad_tokens(&[-7, 999, 3], 8, 256);
        assert!(row.iter().all(|&t| (0..256).contains(&t)));
        assert_eq!(row[0], (-7i32).rem_euclid(256));
        assert!(!trunc);

        let mut s = sim_server(0.0);
        s.submit(Request::new(0, vec![]));
        s.submit(Request::new(1, vec![5; 4000]));
        s.submit(Request::new(2, vec![-7, 999, 3]));
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 3);
        assert_eq!(r.truncated, 1);
        // the truncated request is an SLO violation by definition
        assert_eq!(r.slo_violations, 1);
    }

    #[test]
    fn deadline_and_lane_accounting_set_latencies() {
        // Two requests 1000ms apart with a 50ms batching deadline: two
        // deadline-triggered single-row batches; latency = wait + exec.
        let mut s = sim_server(0.0).with_max_delay_ms(50.0);
        s.submit(Request::new(0, vec![1; 16]).at(0.0));
        s.submit(Request::new(1, vec![2; 16]).at(1000.0));
        s.drain().unwrap();
        assert_eq!(s.report().batches, 2);
        let c = s.completions();
        // batch 0 dispatches at t=50 (deadline), not t=1000
        assert!(c[0].latency_ms > 50.0 && c[0].latency_ms < 200.0,
                "latency {}", c[0].latency_ms);
        // second request rides its own batch after its own deadline
        assert!(c[1].done_ms > 1000.0);
    }

    #[test]
    fn slo_deadlines_flag_violations() {
        // A policy with an impossible interactive deadline: everything
        // violates; with a generous one nothing does.
        let tight = SloPolicy { interactive_deadline_ms: 0.01,
                                ..SloPolicy::default() };
        let mut s = sim_server(0.0).with_policy(tight);
        for i in 0..8u64 {
            s.submit(Request::new(i, vec![1; 16]));
        }
        s.drain().unwrap();
        assert_eq!(s.report().slo_violations, 8);

        let mut s = sim_server(0.0);
        for i in 0..8u64 {
            s.submit(Request::new(i, vec![1; 16]));
        }
        s.drain().unwrap();
        assert_eq!(s.report().slo_violations, 0);
    }

    #[test]
    fn more_lanes_reduce_queueing_latency() {
        let run = |lanes: usize| {
            let mut s = sim_server(0.0).with_lanes(lanes);
            for i in 0..64u64 {
                s.submit(Request::new(i, vec![3; 32]).at(0.0));
            }
            s.drain().unwrap();
            s.report().p95_latency_ms
        };
        assert!(run(4) < run(1));
    }

    #[test]
    fn serve_report_json_roundtrips() {
        let mut s = sim_server(0.0);
        for i in 0..20u64 {
            s.submit(Request::new(i, vec![(i as i32) % 7; 64])
                .at(i as f64));
        }
        s.drain().unwrap();
        let r = s.report();
        let j = r.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str),
                   Some(SERVE_REPORT_SCHEMA));
        let back = ServeReport::from_json(&j).unwrap();
        assert_eq!(back, r);
        // schema mismatch is rejected
        let mut wrong = std::collections::BTreeMap::new();
        wrong.insert("schema".to_string(), Json::Str("nope".into()));
        assert!(ServeReport::from_json(&Json::Obj(wrong)).is_err());
    }

    // ---- PJRT tests: skip without artifacts ----

    fn engine_or_skip() -> Option<Engine> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let mut e = Engine::new(&dir).unwrap();
        e.load("serve_gqa_int8").unwrap();
        Some(e)
    }

    #[test]
    fn serves_batched_requests() {
        let Some(e) = engine_or_skip() else { return };
        let mut s = Server::new(&e, "serve_gqa_int8").unwrap();
        assert_eq!(s.batch_size(), 8);
        for i in 0..20 {
            s.submit(Request::new(i, vec![(i as i32) % 256; 100]));
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 20);
        assert_eq!(r.batches, 3); // 8 + 8 + 4(padded)
        assert!(r.p50_latency_ms > 0.0);
        assert!(r.p95_latency_ms >= r.p50_latency_ms);
        assert!(r.throughput_rps > 0.0);
        // every id accounted for exactly once
        let mut ids: Vec<u64> =
            s.completions().iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn rejects_unloaded_variant() {
        let Some(e) = engine_or_skip() else { return };
        assert!(Server::new(&e, "mha_fp16").is_err()); // not loaded
    }

    #[test]
    fn deterministic_next_tokens() {
        let Some(e) = engine_or_skip() else { return };
        let run = || {
            let mut s = Server::new(&e, "serve_gqa_int8").unwrap();
            for i in 0..8 {
                s.submit(Request::new(i, vec![i as i32 * 3; 64]));
            }
            s.drain().unwrap();
            s.completions()
                .iter()
                .map(|c| (c.id, c.next_token))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn parallel_drain_matches_sequential() {
        let Some(e) = engine_or_skip() else { return };
        let run = |par: crate::util::Parallelism| {
            let mut s = Server::new(&e, "serve_gqa_int8")
                .unwrap()
                .with_parallelism(par);
            for i in 0..40 {
                s.submit(Request::new(i, vec![(i as i32) * 5; 80]));
            }
            s.drain().unwrap();
            s.completions()
                .iter()
                .map(|c| (c.id, c.next_token, c.batch_index))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(crate::util::Parallelism::Sequential),
                   run(crate::util::Parallelism::Threads(4)));
    }
}
